"""Hypothesis property tests for table quantization — split from
test_quant.py so the unit suite survives environments without hypothesis."""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given  # noqa: E402

from repro.core import quant  # noqa: E402

hypothesis.settings.register_profile(
    "fast", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("fast")


@given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 1000))
def test_property_quant_idempotent(bits, seed):
    T = jax.random.normal(jax.random.PRNGKey(seed), (2, 4, 4))
    once = quant.fake_quant(T, bits=bits)
    twice = quant.fake_quant(once, bits=bits)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), rtol=1e-4, atol=1e-5)

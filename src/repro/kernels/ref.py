"""Pure-jnp oracle for the fused LUT-AMM kernel.

Semantics contract (kernels/lut_amm.py must match bit-for-bit at fp32):
  1. distances in fp32 via the ||a||^2 - 2 a.P + ||P||^2 expansion
  2. argmin with lowest-index tie-breaking (jnp.argmin)
  3. table dequantized int8 * scale in fp32
  4. one-hot contraction accumulated in fp32, cast to x.dtype at the end
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lut_amm_ref(
    x: jax.Array,          # (N, D)
    centroids: jax.Array,  # (C, K, V)
    table_q: jax.Array,    # (C, K, M) int8
    scale: jax.Array,      # (C, 1, 1) or (C, 1, M) fp32
) -> jax.Array:            # (N, M) in x.dtype
    n, d = x.shape
    c, k, v = centroids.shape
    assert d == c * v, (d, c, v)
    a = x.reshape(n, c, v).astype(jnp.float32)
    p = centroids.astype(jnp.float32)
    cross = jnp.einsum("ncv,ckv->nck", a, p)
    dists = (
        jnp.sum(a * a, -1)[:, :, None]
        - 2.0 * cross
        + jnp.sum(p * p, -1)[None, :, :]
    )
    idx = jnp.argmin(dists, -1)                                   # (N, C)
    onehot = jax.nn.one_hot(idx, k, dtype=jnp.float32)            # (N, C, K)
    table = table_q.astype(jnp.float32) * scale.astype(jnp.float32)
    out = jnp.einsum("nck,ckm->nm", onehot, table)
    return out.astype(x.dtype)


def encode_ref(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """(N, D), (C, K, V) -> int32 (N, C) nearest-centroid indices."""
    n, d = x.shape
    c, k, v = centroids.shape
    a = x.reshape(n, c, v).astype(jnp.float32)
    p = centroids.astype(jnp.float32)
    cross = jnp.einsum("ncv,ckv->nck", a, p)
    dists = (
        jnp.sum(a * a, -1)[:, :, None]
        - 2.0 * cross
        + jnp.sum(p * p, -1)[None, :, :]
    )
    return jnp.argmin(dists, -1).astype(jnp.int32)

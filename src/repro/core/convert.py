"""Dense -> LUT model conversion (the paper's offline pipeline, section 6.1).

  1. graft: copy the trained dense model's weights into a freshly-built
     LUT_TRAIN model (same arch, LUT replacement policy applied); replaced
     layers keep their dense weight as the frozen table source.
  2. k-means init: run the original model on ~1024 training samples with the
     activation tape on, cluster every replaced site's inputs per codebook
     (Eq. 1), write the centroids into the LUT params.
  3. (after soft-PQ fine-tuning) deploy: build + int8-quantize the tables,
     drop the dense weights -> the serving param tree; `deploy_to_artifact`
     additionally packages the result as an on-disk LUTArtifact
     (repro.serving.artifact, DESIGN.md §8) so a fresh server can load it
     with no knowledge of the train-time pytree.

Wired end-to-end for the LM family (incl. BERT); the per-site primitives in
repro.core.lut_layer are model-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ModelBundle, build_model
from repro.core import kmeans, pq, quant
from repro.core.amm import Mode
from repro.models.common import tape_capture
from repro.models import transformer as tf_mod


def _flat_paths(tree: Any) -> dict[str, jax.Array]:
    out = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[path] = leaf
    return out


def graft_dense_to_lut(dense_params: Any, lut_params: Any) -> Any:
    """Copy every shared leaf (w/b/norm/embed) from the dense model into the
    LUT_TRAIN tree. Segments are re-aligned by global layer index: the dense
    model has one segment of L layers, the LUT model splits the same layers
    into (dense-run, lut-run) segments."""
    dflat = _flat_paths(dense_params)
    lflat = _flat_paths(lut_params)

    # global layer offset per lut segment
    def seg_count(params, i):
        return jax.tree.leaves(params["segments"][i])[0].shape[0]

    n_lut_segs = len(lut_params["segments"])
    offsets = []
    off = 0
    for i in range(n_lut_segs):
        offsets.append(off)
        off += seg_count(lut_params, i)

    out = {}
    for path, leaf in lflat.items():
        if path in dflat and dflat[path].shape == leaf.shape:
            out[path] = dflat[path]
            continue
        if path.startswith("segments/"):
            parts = path.split("/")
            seg_i = int(parts[1])
            rest = "/".join(parts[2:])
            src = dflat.get(f"segments/0/{rest}")
            if src is not None and src.shape[1:] == leaf.shape[1:]:
                lo = offsets[seg_i]
                out[path] = src[lo : lo + leaf.shape[0]]
                continue
        out[path] = leaf        # centroids / log_t: keep init
    leaves = [out[p] for p in lflat]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(lut_params), leaves)


def kmeans_init_lut(
    bundle_dense: ModelBundle,
    dense_params: Any,
    bundle_lut: ModelBundle,
    lut_params: Any,
    sample_batches: list[dict[str, jax.Array]],
    key: jax.Array,
    *,
    kmeans_iters: int = 25,
    max_rows: int = 4096,
) -> Any:
    """Capture replaced-site inputs under the ORIGINAL dense model (paper
    section 6.1: the trained network on ~1024 samples) and k-means-init every
    centroid table of the LUT model (Eq. 1)."""
    assert bundle_lut.kind == "lm", "conversion wiring is LM-family (incl. BERT)"
    cfg = dataclasses.replace(bundle_dense.cfg, unroll=True, remat=False)

    tape = tape_capture(max_rows=max_rows)
    with tape:
        for batch in sample_batches:
            b, s = batch["labels"].shape[:2]
            pos = batch.get("pos")
            if pos is None:
                pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
                if bundle_dense.arch.mrope_sections:
                    pos = jnp.broadcast_to(pos[None], (3, b, s))
            tf_mod.lm_apply(
                cfg, dense_params,
                tokens=batch.get("tokens"), embeds=batch.get("embeds"),
                pos=pos, compute_dtype=jnp.float32,
            )

    # lut-model segment layout: map global layer index -> (segment, local)
    seg_counts = [
        jax.tree.leaves(seg)[0].shape[0] for seg in lut_params["segments"]
    ]

    def locate(global_j: int) -> tuple[int, int]:
        off = 0
        for i, c in enumerate(seg_counts):
            if global_j < off + c:
                return i, global_j - off
            off += c
        raise IndexError(global_j)

    lflat = _flat_paths(lut_params)
    updates: dict[str, jax.Array] = {}
    for rec_path, rows_list in tape.records.items():
        # dense capture path = segments/<dense_seg>/<global_j>/<site...>
        parts = rec_path.split("/")
        dense_seg, global_j = int(parts[1]), int(parts[2])
        # dense model may itself have >1 segment: offset by preceding counts
        dense_counts = [
            jax.tree.leaves(seg)[0].shape[0] for seg in dense_params["segments"]
        ]
        global_j += sum(dense_counts[:dense_seg])
        seg_i, local_j = locate(global_j)
        site_path = "/".join(parts[3:])
        leaf_path = f"segments/{seg_i}/{site_path}/centroids"
        if leaf_path not in lflat:
            continue                     # dense-mode segment: nothing to init
        stacked = updates.get(leaf_path, lflat[leaf_path])
        c, k, v = stacked.shape[1:]
        acts = jnp.concatenate(rows_list, axis=0)
        key, sub = jax.random.split(key)
        cents = kmeans.kmeans_per_codebook(sub, acts, k=k, v=v, iters=kmeans_iters)
        updates[leaf_path] = stacked.at[local_j].set(cents)

    out = dict(lflat)
    out.update(updates)
    leaves = [out[p] for p in lflat]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(lut_params), leaves)


def convert_dense_to_lut_train(
    bundle_dense: ModelBundle,
    dense_params: Any,
    sample_batches: list[dict[str, jax.Array]],
    key: jax.Array,
    **kw: Any,
) -> tuple[ModelBundle, Any]:
    """Full offline pipeline: dense model -> soft-PQ-trainable LUT model."""
    bundle_lut = build_model(bundle_dense.arch, Mode.LUT_TRAIN)
    lut_params = bundle_lut.init(jax.random.PRNGKey(0))
    lut_params = graft_dense_to_lut(dense_params, lut_params)
    lut_params = kmeans_init_lut(
        bundle_dense, dense_params, bundle_lut, lut_params, sample_batches, key, **kw
    )
    return bundle_lut, lut_params


def deploy_lut_train_params(bundle_lut: ModelBundle, lut_params: Any) -> tuple[ModelBundle, Any]:
    """LUT_TRAIN params -> LUT_INFER params (int8 tables, weights dropped)."""
    bundle_inf = build_model(bundle_lut.arch, Mode.LUT_INFER)
    inf_params = jax.eval_shape(bundle_inf.init, jax.random.PRNGKey(0))
    iflat = _flat_paths(inf_params)
    tflat = _flat_paths(lut_params)

    out: dict[str, jax.Array] = {}
    for path, spec in iflat.items():
        if path in tflat and tflat[path].shape == spec.shape:
            out[path] = tflat[path]
            continue
        if path.endswith("table_q") or path.endswith("table_scale"):
            base = path.rsplit("/", 1)[0]
            P = tflat[f"{base}/centroids"]
            W = tflat[f"{base}/w"]
            stacked_q, stacked_s = [], []
            for j in range(P.shape[0]):
                t = pq.build_table(P[j], W[j], stop_weight_grad=False)
                qt = quant.quantize_table(t, bits=8)
                stacked_q.append(qt.q)
                stacked_s.append(qt.scale)
            out[f"{base}/table_q"] = jnp.stack(stacked_q)
            out[f"{base}/table_scale"] = jnp.stack(stacked_s)
        elif path not in out:
            raise KeyError(f"no source for deployed param {path}")
    leaves = [out[p] for p in iflat]
    tree = jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(inf_params), leaves)
    return build_model(bundle_lut.arch, Mode.LUT_INFER), tree


def deploy_to_artifact(
    bundle_lut: ModelBundle, lut_params: Any, directory: str | Any
) -> tuple[ModelBundle, Any]:
    """Deploy LUT_TRAIN params and write the serving tree as a LUTArtifact.

    The returned (bundle, params) serve directly; the artifact directory is
    what ships — `launch/serve.py --artifact <dir>` (or
    `repro.serving.artifact.load_artifact`) reconstructs both.
    """
    from repro.serving.artifact import save_artifact

    bundle_inf, inf_params = deploy_lut_train_params(bundle_lut, lut_params)
    save_artifact(directory, bundle_inf, inf_params)
    return bundle_inf, inf_params

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kmeans, pq


def test_inertia_decreases_with_iters(key):
    x = jax.random.normal(key, (256, 8))
    _, i5 = kmeans.kmeans(key, x, k=8, iters=5)
    _, i25 = kmeans.kmeans(key, x, k=8, iters=25)
    assert float(i25) <= float(i5) + 1e-3


def test_recovers_separated_clusters(key):
    k1, k2 = jax.random.split(key)
    centers = jax.random.normal(k1, (4, 6)) * 10.0
    pts = centers[jax.random.randint(k2, (400,), 0, 4)] + 0.05 * jax.random.normal(k2, (400, 6))
    learned, inertia = kmeans.kmeans(key, pts, k=4, iters=30)
    # every true center has a learned centroid within 0.5
    d = jnp.min(jnp.sum((centers[:, None] - learned[None]) ** 2, -1), axis=1)
    assert float(jnp.max(d)) < 0.25
    assert float(inertia) / 400 < 0.1


def test_no_dead_centroids(key):
    """k > #distinct points still yields finite centroids (reseed path)."""
    x = jnp.concatenate([jnp.zeros((50, 4)), jnp.ones((50, 4))])
    c, _ = kmeans.kmeans(key, x, k=8, iters=10)
    assert bool(jnp.all(jnp.isfinite(c)))


def test_per_codebook_shapes(key):
    acts = jax.random.normal(key, (128, 24))
    cents = kmeans.kmeans_per_codebook(key, acts, k=4, v=8)
    assert cents.shape == (3, 4, 8)


def test_kmeans_beats_random_centroids(key):
    """k-means init gives lower PQ reconstruction error than random init —
    the reason the paper seeds soft-PQ with k-means (section 3.1)."""
    k1, k2 = jax.random.split(key)
    centers = jax.random.normal(k1, (16, 16)) * 3
    acts = centers[jax.random.randint(k2, (512,), 0, 16)] + 0.3 * jax.random.normal(k2, (512, 16))
    km = kmeans.kmeans_per_codebook(key, acts, k=8, v=4)
    rnd = jax.random.normal(key, km.shape)
    err_km = float(jnp.mean((pq.pq_reconstruct(acts, km) - acts) ** 2))
    err_rnd = float(jnp.mean((pq.pq_reconstruct(acts, rnd) - acts) ** 2))
    assert err_km < 0.5 * err_rnd


def test_determinism(key):
    x = jax.random.normal(key, (64, 4))
    a, _ = kmeans.kmeans(key, x, k=4, iters=5)
    b, _ = kmeans.kmeans(key, x, k=4, iters=5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""Sharding rules: param/opt/cache/batch PartitionSpecs for the production mesh.

Mesh axes (launch/mesh.py): ("data", "model") single-pod 16x16, or
("pod", "data", "model") = (2, 16, 16) multi-pod. Pods are pure data
parallel: the batch shards over ("pod", "data"); tensor/expert parallelism
stays inside a pod (the "model" axis never crosses the pod boundary).

Parallelism mapping (DESIGN.md section 5):
  DP    batch axis of every input / cache over dp axes
  TP    weight output (or input) dim over "model"; LUT tables column-sharded
        over M — the one-hot contraction is column-parallel exactly like the
        matmul it replaces; codebooks/centroids replicated (KBs)
  EP    MoE expert dim over "model"
  SP    KV caches sequence-sharded over "model" (flash-decoding style: the
        softmax stats all-reduce is tiny, and it works for every head count,
        unlike head sharding — see the uneven-sharding constraint)
  FSDP  giant archs additionally shard weight/table dims over "data"
  ZeRO-1 optimizer moments shard over "data" even when params don't

Only dims divisible by the axis size are sharded (GSPMD-uneven shardings
are rejected by jax for jit arguments); the rules pick the first divisible
candidate dim and fall back to replication.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    fsdp: bool = False           # shard weights over data too (ZeRO-3 style)
    zero1: bool = True           # shard optimizer moments over data
    row_parallel: bool = True    # Megatron row/column site roles (Perf T1)

    @property
    def dp_axes(self) -> tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def dp_size(self) -> int:
        s = 1
        for a in self.dp_axes:
            s *= self.mesh.shape[a]
        return s

    @property
    def tp(self) -> int:
        return self.mesh.shape["model"]

    # ------------------------------------------------------------------
    def _dp_spec_entry(self):
        axes = self.dp_axes
        return axes if len(axes) > 1 else axes[0]

    def batch_dim(self, b: int):
        """Spec entry for a global-batch dim (None when batch=1, long_500k)."""
        return self._dp_spec_entry() if b % self.dp_size == 0 else None

    # ------------------------------------------------------------------
    def param_spec(
        self, path: str, shape: tuple[int, ...],
        site_roles: dict[str, bool] | None = None,
    ) -> P:
        """PartitionSpec for one parameter leaf (possibly layer-stacked).

        `site_roles` maps site path prefixes to their row-parallel role as
        derived from the model's site registry (see `site_roles()`); without
        it the role falls back to the parent-name heuristic.
        """
        tp = self.tp
        name = path.split("/")[-1]
        stacked = any(
            seg in path for seg in ("segments/", "mamba_stack/", "encoder/", "decoder/")
        )
        off = 1 if stacked else 0
        spec = [None] * len(shape)
        eff = shape[off:]

        def put(i_eff: int, axis) -> bool:
            if spec[off + i_eff] is None and eff[i_eff] % _axsize(self.mesh, axis) == 0:
                spec[off + i_eff] = axis
                return True
            return False

        def put_fsdp(prefer: tuple[int, ...]):
            if not self.fsdp:
                return
            dp = self._dp_spec_entry()
            for i in prefer:
                if spec[off + i] is None and eff[i] % self.dp_size == 0:
                    spec[off + i] = dp
                    return

        # Megatron site roles: 'down'/'o'/'out_proj' consume the sharded
        # output of a column-parallel producer -> shard their INPUT dim
        # (weight rows / LUT codebook axis) so the only collective is the
        # bf16 output psum, instead of GSPMD re-sharding the (N, C*K)
        # encoding against an M-sharded table (section Perf, train iter 1).
        parts = path.split("/")
        parent_path = "/".join(parts[:-1])
        if site_roles is not None and parent_path in site_roles:
            row_parallel = self.row_parallel and site_roles[parent_path]
        else:
            parent = parts[-2] if len(parts) >= 2 else ""
            row_parallel = self.row_parallel and parent in ("down", "o", "out_proj")

        if name == "table" and len(eff) == 2:            # embedding (vocab, d)
            put(0, "model") or put(1, "model")
            put_fsdp((1, 0))
        elif name == "w" and len(eff) == 2:              # linear (d_in, d_out)
            if row_parallel:
                put(0, "model") or put(1, "model")
            else:
                put(1, "model") or put(0, "model")
            put_fsdp((0, 1) if not row_parallel else (1, 0))
        elif name == "w" and len(eff) == 3:              # experts (E, d_in, d_out)
            # 2D: expert-parallel over the data axes (tokens reach their
            # expert via all-to-all) x tensor-parallel over model — giants
            # fit WITHOUT the fsdp flag (section Perf, MoE iteration 2)
            put(0, self._dp_spec_entry()) or put(0, "model")
            if spec[off + 0] == self._dp_spec_entry():
                put(2, "model") or put(1, "model")
        elif name == "table_q" and len(eff) == 3:        # LUT (C, K, M)
            if row_parallel:
                put(0, "model") or put(2, "model")
            else:
                put(2, "model")
            put_fsdp((0,) if not row_parallel else (2,))
        elif name == "table_q" and len(eff) == 4:        # MoE LUT (E, C, K, M)
            put(0, self._dp_spec_entry()) or put(0, "model")
            if spec[off + 0] == self._dp_spec_entry():
                put(3, "model")
        elif name == "centroids" and len(eff) == 3 and row_parallel:
            # codebook axis aligns with the C-sharded activations
            put(0, "model")
        elif name == "table_scale":
            pass                                          # tiny: replicate
        # other centroids / log_t / norms / conv / ssm scalars: replicate
        return P(*spec)

    def params_shardings(self, specs: Any, bundle: Any = None) -> Any:
        """Shardings per param leaf; pass the ModelBundle so site roles come
        from the site registry instead of the path-name heuristic."""
        roles = site_roles(bundle) if bundle is not None else None

        def mk(kp, leaf):
            return NamedSharding(
                self.mesh, self.param_spec(_path(kp), leaf.shape, site_roles=roles)
            )

        return jax.tree_util.tree_map_with_path(mk, specs)

    # ------------------------------------------------------------------
    def opt_spec(self, path: str, shape: tuple[int, ...]) -> P:
        """Moments: same layout as the param, plus ZeRO-1 data sharding."""
        if len(shape) == 1 and shape[0] == 0:            # frozen placeholder
            return P()
        if path.endswith("step"):
            return P()
        base = list(self.param_spec(path, shape))
        base += [None] * (len(shape) - len(base))
        dp = self._dp_spec_entry()
        if self.zero1 and not self.fsdp and dp not in base:
            for i, s in enumerate(base):
                if s is None and shape[i] % self.dp_size == 0 and shape[i] > 1:
                    base[i] = dp
                    break
        return P(*base)

    def opt_shardings(self, opt_specs: Any) -> Any:
        def mk(kp, leaf):
            path = _path(kp)
            # strip the AdamWState prefix ('m/...', 'v/...')
            path = path.split("/", 1)[1] if "/" in path else path
            return NamedSharding(self.mesh, self.opt_spec(path, leaf.shape))

        return jax.tree_util.tree_map_with_path(mk, opt_specs)

    # ------------------------------------------------------------------
    def cache_spec(self, path: str, shape: tuple[int, ...], batch: int) -> P:
        """KV/SSM caches. Layer-stacked leading dim, then batch."""
        name = path.split("/")[-1]
        bspec = self.batch_dim(batch)
        if name in ("k_pool", "v_pool") and len(shape) == 5:
            # paged pool (L, n_pages, page_size, KV, Dh): pages replace the
            # slot axis as the data-parallel dim; KV heads over "model" when
            # divisible (SP over the page axis would split single pages)
            pages = (self._dp_spec_entry()
                     if shape[1] % self.dp_size == 0 else None)
            kvh = "model" if shape[3] % self.tp == 0 else None
            return P(None, pages, None, kvh, None)
        if name in ("k", "v") and len(shape) == 5:       # (L, B, S, KV, Dh)
            seq = "model" if shape[2] % self.tp == 0 else None
            return P(None, bspec, seq, None, None)
        if name == "ssm":                                # (L, B, H, P, N)
            hd = "model" if shape[2] % self.tp == 0 else None
            return P(None, bspec, hd, None, None)
        if name == "conv":                               # (L, B, W-1, ch)
            ch = "model" if shape[3] % self.tp == 0 else None
            return P(None, bspec, None, ch)
        return P(*([None] * len(shape)))

    def cache_shardings(self, cache_specs: Any, batch: int) -> Any:
        def mk(kp, leaf):
            return NamedSharding(
                self.mesh, self.cache_spec(_path(kp), leaf.shape, batch)
            )

        return jax.tree_util.tree_map_with_path(mk, cache_specs)

    # ------------------------------------------------------------------
    def batch_shardings(self, batch_specs: dict[str, Any]) -> dict[str, Any]:
        out = {}
        for k, v in batch_specs.items():
            shape = v.shape
            if k == "pos" and len(shape) == 3:           # (3, B, S)
                spec = P(None, self.batch_dim(shape[1]), None)
            elif len(shape) >= 1:
                spec = P(self.batch_dim(shape[0]), *([None] * (len(shape) - 1)))
            else:
                spec = P()
            out[k] = NamedSharding(self.mesh, spec)
        return out


# site kinds that consume a column-parallel producer's sharded output —
# these shard their INPUT dim (Megatron row-parallel role)
_ROW_PARALLEL_LEAF_KINDS = ("down", "o", "out_proj")


def site_roles(bundle: Any) -> dict[str, bool]:
    """{site param-tree path: is_row_parallel} from the site registry."""
    return {
        s.path: s.kind.rsplit("/", 1)[-1] in _ROW_PARALLEL_LEAF_KINDS
        for s in bundle.sites()
    }


def _axsize(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        s = 1
        for a in axis:
            s *= mesh.shape[a]
        return s
    return mesh.shape[axis]


def _path(keypath) -> str:
    parts = []
    for k in keypath:
        parts.append(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))))
    return "/".join(parts)

"""Production mesh construction.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.

`make_mesh` here is the one mesh constructor the repo uses: it requests
explicit `Auto` axis types on jax versions that support them and falls back
cleanly on versions that predate `jax.sharding.AxisType` (where every axis
is implicitly auto-sharded, i.e. the same semantics).
"""

from __future__ import annotations

from typing import Sequence

import jax


def _auto_axis_types_kw(n_axes: int) -> dict:
    """{'axis_types': (Auto,)*n} on jax versions that have AxisType, else {}."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> jax.sharding.Mesh:
    """Version-portable `jax.make_mesh` with Auto axis types when available."""
    axes = tuple(axes)
    kw = _auto_axis_types_kw(len(axes))
    if kw:
        try:
            return jax.make_mesh(tuple(shape), axes, **kw)
        except TypeError:
            pass                     # make_mesh predates the kwarg
    return jax.make_mesh(tuple(shape), axes)


def mesh_from_devices(devices, axes: Sequence[str]) -> jax.sharding.Mesh:
    """Version-portable `jax.sharding.Mesh` over an explicit device array
    (the elastic-rescale path, where the surviving devices are hand-picked)."""
    axes = tuple(axes)
    try:
        return jax.sharding.Mesh(devices, axes, **_auto_axis_types_kw(len(axes)))
    except TypeError:
        return jax.sharding.Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(*, data: int | None = None, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples / serving).

    `model` is the tensor-parallel degree; `data` defaults to using every
    remaining device. Raises if the host doesn't have enough devices.
    """
    n = jax.device_count()
    if data is None:
        data = max(1, n // model)
    if data * model > n:
        raise ValueError(
            f"mesh ({data}, {model}) needs {data * model} devices, "
            f"host has {n} (set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            f"for CPU testing)"
        )
    return make_mesh((data, model), ("data", "model"))

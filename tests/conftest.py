"""Shared fixtures. NOTE: no XLA_FLAGS here — unit tests must see the real
single CPU device; multi-device tests spawn subprocesses with their own
--xla_force_host_platform_device_count (tests/_subproc.py)."""

import jax
import pytest


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)

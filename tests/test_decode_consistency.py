"""Prefill+decode must reproduce the full-sequence forward exactly — the
strongest end-to-end correctness check for KV caches, SSD state passing,
RoPE positions, and the shared-block hybrid cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
import repro.models.transformer as tf
import repro.models.hybrid as hy
import repro.models.encdec as ed

ARCHS = ["llama3_8b", "qwen3_1p7b", "mamba2_370m", "zamba2_1p2b", "qwen2_vl_7b", "whisper_tiny"]


@pytest.mark.parametrize("arch_id", ARCHS)
def test_decode_matches_full(arch_id, key):
    arch = reduce_arch(get_arch(arch_id))
    m = build_model(arch, Mode.DENSE)
    params = m.init(key)
    B, S, S_pre = 2, 12, 7
    tol = dict(rtol=5e-3, atol=5e-3)

    if arch.family == "vlm":
        embeds = jax.random.normal(key, (B, S, arch.d_model))
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
        full, _, _ = tf.lm_apply(m.cfg, params, embeds=embeds, pos=pos, compute_dtype=jnp.float32)
        caches = m.init_caches(B, S, dtype=jnp.float32)
        lg, caches = m.forward_step(
            params, {"embeds": embeds[:, :S_pre], "cache_len": jnp.zeros((B,), jnp.int32)},
            caches, compute_dtype=jnp.float32,
        )
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, :S_pre]), **tol)
        for i in range(S_pre, S):
            lg, caches = m.forward_step(
                params, {"embeds": embeds[:, i : i + 1], "cache_len": jnp.full((B,), i, jnp.int32)},
                caches, compute_dtype=jnp.float32,
            )
            np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, i]), **tol)
        return

    toks = jax.random.randint(key, (B, S), 0, arch.vocab)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    if arch.family == "audio":
        frames = jax.random.normal(key, (B, arch.enc_frames, arch.d_model))
        enc_out = ed.encode(m.cfg, params, frames, compute_dtype=jnp.float32)
        full, _ = ed.decode(
            m.cfg, params, tokens=toks, pos=pos, enc_out=enc_out, compute_dtype=jnp.float32
        )
    elif arch.family == "hybrid":
        full, _, _ = hy.hybrid_apply(m.cfg, params, tokens=toks, pos=pos, compute_dtype=jnp.float32)
    else:
        full, _, _ = tf.lm_apply(m.cfg, params, tokens=toks, pos=pos, compute_dtype=jnp.float32)

    caches = m.init_caches(B, S, dtype=jnp.float32)
    batch = {"tokens": toks[:, :S_pre], "cache_len": jnp.zeros((B,), jnp.int32)}
    if arch.family == "audio":
        batch["frames"] = frames
    lg, caches = m.forward_step(params, batch, caches, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, :S_pre]), **tol)
    for i in range(S_pre, S):
        lg, caches = m.forward_step(
            params, {"tokens": toks[:, i : i + 1], "cache_len": jnp.full((B,), i, jnp.int32)},
            caches, compute_dtype=jnp.float32,
        )
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, i]), **tol)


def test_chunked_prefill_matches_full(key):
    """The engine's chunk loop contract: feeding a prompt as consecutive
    fixed-size prefill chunks (cache_len advancing each pass) must produce
    the same last-position logits as one full-sequence forward."""
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
    m = build_model(arch, Mode.DENSE)
    params = m.init(key)
    B, S, chunk = 2, 12, 4
    toks = jax.random.randint(key, (B, S), 0, arch.vocab)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    full, _, _ = tf.lm_apply(m.cfg, params, tokens=toks, pos=pos, compute_dtype=jnp.float32)

    caches = m.init_caches(B, S, dtype=jnp.float32)
    for start in range(0, S, chunk):
        lg, caches = m.forward_step(
            params,
            {"tokens": toks[:, start : start + chunk],
             "cache_len": jnp.full((B,), start, jnp.int32)},
            caches, compute_dtype=jnp.float32,
        )
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[:, start : start + chunk]),
            rtol=5e-3, atol=5e-3,
        )


def test_ragged_cache_lens(key):
    """Per-slot cursors: decoding with different cache_len per row must match
    per-row single decode (continuous batching correctness)."""
    arch = reduce_arch(get_arch("llama3_8b"), n_layers=2)
    m = build_model(arch, Mode.DENSE)
    params = m.init(key)
    S_max = 16
    toks = jax.random.randint(key, (2, 10), 0, arch.vocab)

    # row 0 prefilled 5 tokens, row 1 prefilled 9
    caches = m.init_caches(2, S_max, dtype=jnp.float32)
    lens = jnp.asarray([5, 9], jnp.int32)
    # prefill rows individually into a batched cache via masking path of engine
    # here: prefill both with same S then step row-wise using cache_len
    lg0, caches = m.forward_step(
        params, {"tokens": toks[:, :5], "cache_len": jnp.zeros((2,), jnp.int32)},
        caches, compute_dtype=jnp.float32,
    )
    lg1, caches = m.forward_step(
        params, {"tokens": toks[:, 5:9], "cache_len": jnp.full((2,), 5, jnp.int32)},
        caches, compute_dtype=jnp.float32,
    )
    # decode one token with ragged lens: row0 continues from 5, row1 from 9
    step_tok = jnp.stack([toks[0, 5], toks[1, 9]])[:, None]
    lg, _ = m.forward_step(
        params, {"tokens": step_tok, "cache_len": lens}, caches, compute_dtype=jnp.float32
    )
    # reference: full forwards truncated per row
    pos = jnp.arange(10, dtype=jnp.int32)[None, :].repeat(2, 0)
    full, _, _ = tf.lm_apply(m.cfg, params, tokens=toks, pos=pos, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg[0, 0]), np.asarray(full[0, 5]), rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(lg[1, 0]), np.asarray(full[1, 9]), rtol=5e-3, atol=5e-3)

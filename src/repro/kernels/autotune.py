"""Shape-keyed block-size autotuner for the LUT Pallas kernels (DESIGN.md §3).

The fused kernels tile over a (N/bn, M/bm, C/bc) grid; the block sizes trade
VMEM residency against HBM re-streaming:

  * bigger bn  -> the int8 table tile is re-read fewer times (N/bn sweeps)
  * bigger bm  -> the activation tile is re-read fewer times (M/bm sweeps)
  * bigger bc  -> fewer grid steps (less per-step overhead), bigger VMEM tiles

All three are capped by the per-step VMEM working set (`vmem_bytes`), which
must fit in 16 MB with double buffering — the budget model is documented in
DESIGN.md §3.1 and enforced by `enumerate_candidates`.

Tuning modes:

  * measured  — a `measure(cfg) -> seconds` callable (real wall-clock on an
    accelerator; benchmarks pass one built from `lut_amm_pallas`).
  * analytic  — no accelerator present: candidates are scored with the
    roofline model in `predict_us` (HBM traffic / compute / per-step
    overhead), using the v5e constants from repro.roofline.analysis.

Winners persist to an on-disk JSON cache (DESIGN.md §3.2) keyed by
(kind, N, M, C, K, V, dtype, backend) and are consumed by `lut_amm_pallas`,
`encode_pallas`, the serving engine warmup, and the benchmarks. Cache path:
$REPRO_AUTOTUNE_CACHE, else ~/.cache/repro/autotune.json.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
import pathlib
import tempfile
from typing import Any, Callable, Iterator

from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

# ---------------------------------------------------------------------------
# hardware model constants (DESIGN.md §3.1)
# ---------------------------------------------------------------------------

VMEM_BYTES = 16 * 2**20          # per-core VMEM (v4/v5 generations)
VMEM_BUDGET = 12 * 2**20         # usable budget: leave headroom for spills
MXU_F32 = PEAK_FLOPS             # dense fp32/bf16 MXU rate (paper constants)
MXU_I8 = 2 * PEAK_FLOPS          # int8 MXU rate: 2x the bf16 rate on v5e
VMEM_BW = 8 * HBM_BW             # rough on-chip bandwidth for VPU passes
STEP_OVERHEAD_S = 1e-6           # fixed per-grid-step cost (DMA setup, sync)

_CACHE_VERSION = 1
_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """One tiling choice for a fused LUT kernel."""

    block_n: int
    block_m: int
    block_c: int

    def as_dict(self) -> dict[str, int]:
        return dataclasses.asdict(self)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _divisors(c: int) -> list[int]:
    return [d for d in range(1, c + 1) if c % d == 0]


# ---------------------------------------------------------------------------
# VMEM budget model (DESIGN.md §3.1)
# ---------------------------------------------------------------------------

def vmem_bytes(
    bn: int, bm: int, bc: int, k: int, v: int, *, kind: str = "lut_amm"
) -> int:
    """Per-step VMEM working set of the fused kernel at one tiling.

    Input tiles are charged twice (the pipeline emitter double-buffers HBM
    streams); the scratch accumulator and the output tile are single-buffered
    because their BlockSpec index maps ignore the innermost grid axis.
    """
    x_tile = bn * bc * v * 4                 # fp32 activations
    p_tile = bc * k * v * 4                  # fp32 codebook
    if kind == "encode":
        out = bn * bc * 4                    # int32 indices
        return 2 * (x_tile + p_tile) + out
    t_tile = bc * k * bm                     # int8 table — stays int8 (v2)
    s_tile = bc * bm * 4                     # scale tile upper bound
    b_tile = bm * 4                          # fused bias row
    acc = bn * bm * 4                        # int32/f32 scratch accumulator
    out = bn * bm * 4                        # fp32 output tile
    return 2 * (x_tile + p_tile + t_tile + s_tile + b_tile) + acc + out


# ---------------------------------------------------------------------------
# analytic cost model
# ---------------------------------------------------------------------------

def predict_us(
    kind: str,
    n: int, m: int, c: int, k: int, v: int,
    bn: int, bm: int, bc: int,
    *,
    version: int = 2,
) -> float:
    """Roofline latency estimate (microseconds) for one tiling.

    HBM traffic counts tile re-streaming exactly as the BlockSpec index maps
    imply: the activation tile ignores the M grid axis (re-fetched per
    M-block revisit), the table tile ignores the N grid axis (re-fetched per
    N-block sweep), and the codebook tile is re-fetched whenever the C
    coordinate cycles. Compute charges the encode matmul per M-block (the
    fused kernel recomputes the argmin for every output tile) and the table
    contraction once; v1 additionally pays a per-step fp32 dequantization of
    the table tile on the VPU and contracts at the fp32 MXU rate, v2
    contracts int8 at the doubled int8 MXU rate (DESIGN.md §2.3). The v1
    dequant is charged additively (not under the roofline max): it is a
    serial VPU pass between the DMA and the MXU contraction that consumes
    its output, so it overlaps with neither.
    """
    gn, gm = _ceil_div(n, bn), (1 if kind == "encode" else _ceil_div(m, bm))
    gc = _ceil_div(c, bc)

    x_bytes = n * c * v * 4 * gm
    p_bytes = c * k * v * 4 * gn * gm
    enc_flops = 2.0 * n * c * v * k * gm

    t_serial = 0.0
    if kind == "encode":
        hbm = x_bytes + p_bytes + n * c * 4
        t_comp = enc_flops / MXU_F32
    else:
        t_bytes = c * k * m * gn             # int8 table, re-read per N sweep
        o_bytes = n * m * 4                  # written exactly once (v2)
        hbm = x_bytes + p_bytes + t_bytes + o_bytes
        lut_flops = 2.0 * n * c * k * m
        if version >= 2:
            t_comp = enc_flops / MXU_F32 + lut_flops / MXU_I8
        else:
            # v1: int8 -> fp32 dequant materialization per codebook step
            # (read int8 + write fp32 in VMEM), then an fp32 contraction.
            t_comp = enc_flops / MXU_F32 + lut_flops / MXU_F32
            t_serial = 5.0 * c * k * m * gn / VMEM_BW

    t_mem = hbm / HBM_BW
    t_steps = gn * gm * gc * STEP_OVERHEAD_S
    return (max(t_mem, t_comp) + t_serial + t_steps) * 1e6


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------

_BN_CHOICES = (8, 16, 32, 64, 128, 256, 512)
_BM_CHOICES = (128, 256, 512, 1024)


def enumerate_candidates(
    kind: str, n: int, m: int, c: int, k: int, v: int,
    *, budget: int = VMEM_BUDGET,
) -> Iterator[BlockConfig]:
    """All tilings under the VMEM budget. Always yields at least one."""
    bns = sorted({min(b, n) for b in _BN_CHOICES})
    if kind == "encode":
        bms = [0]
    else:
        bms = sorted({min(b, m) for b in _BM_CHOICES})
    bcs = _divisors(c)
    emitted = False
    for bn in bns:
        for bm in bms:
            for bc in bcs:
                if vmem_bytes(bn, max(bm, 1), bc, k, v, kind=kind) > budget:
                    continue
                emitted = True
                yield BlockConfig(bn, bm, bc)
    if not emitted:                           # degenerate: smallest tiling
        yield BlockConfig(min(8, n), 0 if kind == "encode" else min(128, m), 1)


def heuristic(kind: str, n: int, m: int, c: int, k: int, v: int) -> BlockConfig:
    """Cache-miss default — the pre-autotuner hardcoded tiling."""
    bn = min(512 if kind == "encode" else 256, n)
    bm = 0 if kind == "encode" else min(512, m)
    bc = max(1, min(c, 2048 // max(v, 1)))
    while c % bc:
        bc -= 1
    return BlockConfig(bn, bm, bc)


# ---------------------------------------------------------------------------
# on-disk cache (DESIGN.md §3.2)
# ---------------------------------------------------------------------------

def shape_key(
    kind: str, n: int, m: int, c: int, k: int, v: int,
    dtype: str, backend: str,
) -> str:
    return f"{kind}|n={n}|m={m}|c={c}|k={k}|v={v}|dtype={dtype}|backend={backend}"


def default_cache_path() -> pathlib.Path:
    env = os.environ.get(_ENV_CACHE)
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "autotune.json"


class AutotuneCache:
    """JSON-backed winner store; safe against concurrent/partial writes."""

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = pathlib.Path(path) if path is not None else default_cache_path()
        self._entries: dict[str, dict[str, Any]] | None = None

    def load(self) -> dict[str, dict[str, Any]]:
        if self._entries is None:
            try:
                raw = json.loads(self.path.read_text())
                ok = isinstance(raw, dict) and raw.get("version") == _CACHE_VERSION
                self._entries = dict(raw["entries"]) if ok else {}
            except (OSError, ValueError, KeyError):
                self._entries = {}
        return self._entries

    def get(self, key: str) -> dict[str, Any] | None:
        return self.load().get(key)

    def put(self, key: str, record: dict[str, Any]) -> None:
        self.load()[key] = record
        _memo_clear()

    def save(self) -> None:
        payload = {"version": _CACHE_VERSION, "entries": self.load()}
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
            raise


_DEFAULT_CACHE: AutotuneCache | None = None
_MEMO: dict[str, BlockConfig] = {}


def get_cache() -> AutotuneCache:
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None or _DEFAULT_CACHE.path != default_cache_path():
        _DEFAULT_CACHE = AutotuneCache()
    return _DEFAULT_CACHE


def _memo_clear() -> None:
    _MEMO.clear()


def _backend() -> str:
    import jax

    return jax.default_backend()


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def lookup(
    kind: str, n: int, m: int, c: int, k: int, v: int,
    *, dtype: str = "float32", backend: str | None = None,
    cache: AutotuneCache | None = None,
) -> BlockConfig:
    """Cheap hot-path lookup: cached winner, else the heuristic tiling.

    Never runs tuning inline — `tune` (benchmarks / engine warmup) populates
    the cache out-of-band.
    """
    backend = backend or _backend()
    key = shape_key(kind, n, m, c, k, v, dtype, backend)
    memo_key = None
    if cache is None:
        cache = get_cache()
        # memo keyed by cache path too: switching $REPRO_AUTOTUNE_CACHE
        # (e.g. per-test isolation) must not serve another cache's winners
        memo_key = f"{cache.path}|{key}"
        if memo_key in _MEMO:
            return _MEMO[memo_key]
    rec = cache.get(key)
    if rec is not None:
        cfg = BlockConfig(rec["block_n"], rec["block_m"], rec["block_c"])
    else:
        cfg = heuristic(kind, n, m, c, k, v)
    if memo_key is not None:
        _MEMO[memo_key] = cfg
    return cfg


def resolve_blocks(
    kind: str, n: int, m: int, c: int, k: int, v: int, dtype: str,
    block_n: int | None, block_m: int | None, block_c: int | None,
) -> tuple[int, int, int]:
    """Fill unspecified block sizes from the cache (or heuristic), then
    clamp to legal values for this shape — the one block-resolution path
    shared by `lut_amm_pallas` and `encode_pallas`."""
    if block_n is None or block_m is None or block_c is None:
        tuned = lookup(kind, n, m, c, k, v, dtype=dtype)
        block_n = block_n if block_n is not None else tuned.block_n
        block_m = block_m if block_m is not None else tuned.block_m
        block_c = block_c if block_c is not None else tuned.block_c
    bn = max(1, min(block_n, n))
    bm = max(1, min(block_m, m)) if m else 0
    bc = max(1, min(block_c, c))
    while c % bc:
        bc -= 1
    return bn, bm, bc


def tune(
    kind: str, n: int, m: int, c: int, k: int, v: int,
    *, dtype: str = "float32", backend: str | None = None,
    cache: AutotuneCache | None = None,
    measure: Callable[[BlockConfig], float] | None = None,
    version: int = 2,
    save: bool = True,
) -> tuple[BlockConfig, dict[str, Any]]:
    """Pick the best tiling for one shape and persist it.

    measure: optional `cfg -> seconds` wall-clock callable; when absent the
    analytic `predict_us` model scores candidates (the only option without
    an accelerator).
    """
    backend = backend or _backend()
    cache = cache or get_cache()
    key = shape_key(kind, n, m, c, k, v, dtype, backend)

    best_cfg, best_t, measured = None, math.inf, measure is not None
    for cand in enumerate_candidates(kind, n, m, c, k, v):
        if measure is not None:
            t_us = measure(cand) * 1e6
        else:
            t_us = predict_us(kind, n, m, c, k, v,
                              cand.block_n, cand.block_m, cand.block_c,
                              version=version)
        if t_us < best_t:
            best_cfg, best_t = cand, t_us

    assert best_cfg is not None
    record = {
        **best_cfg.as_dict(),
        "predicted_us": best_t,
        "measured": measured,
        "source": "wallclock" if measured else "roofline_model",
    }
    cache.put(key, record)
    if save:
        cache.save()
    return best_cfg, record

"""LUTPlan + site registry (DESIGN.md §9): back-compat shim identity,
serialization round trips, registry/param-tree agreement across families,
heterogeneous-plan lifecycle, and the strict graft / vmapped deploy."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    LUTPlan,
    SitePolicy,
    arch_from_dict,
    arch_to_dict,
    build_model,
    effective_plan,
    get_arch,
    reduce_arch,
    rule,
)
from repro.core import convert, pq, quant
from repro.core.amm import Mode
from repro.core.plan import PAPER_DEFAULT
from repro.serving.artifact import load_artifact, save_artifact
from repro.serving.engine import ServingEngine


def _tree_items(tree):
    out = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)] = leaf
    return out


def _assert_trees_equal(a, b):
    fa, fb = _tree_items(a), _tree_items(b)
    assert fa.keys() == fb.keys()
    for p in fa:
        assert fa[p].dtype == fb[p].dtype, p
        np.testing.assert_array_equal(np.asarray(fa[p]), np.asarray(fb[p]), err_msg=p)


# ---------------------------------------------------------------------------
# back-compat shim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,plan_ctor", [
    ("all", LUTPlan.all),
    ("all_but_first", LUTPlan.all_but_first),
    ("last_n:2", lambda **kw: LUTPlan.last_n(2, **kw)),
])
def test_string_shim_builds_identical_trees(policy, plan_ctor, key):
    """An arch configured via the legacy string builds a byte-identical
    param tree to the same arch with the equivalent explicit LUTPlan."""
    base = reduce_arch(get_arch("llama3_8b"), n_layers=3, vocab=64,
                       d_model=64, d_ff=128)
    via_string = dataclasses.replace(base, lut_policy=policy)
    via_plan = dataclasses.replace(base, lut_plan=plan_ctor(v=base.lut_v))
    for mode in (Mode.LUT_TRAIN, Mode.LUT_INFER):
        ms, mp = build_model(via_string, mode), build_model(via_plan, mode)
        assert ms.cfg == mp.cfg
        _assert_trees_equal(ms.init(key), mp.init(key))


def test_shim_segment_structure_preserved():
    """The pre-plan segment layout survives the shim: all_but_first gives
    (1 dense, L-1 lut); bert's last_n:6 gives (6 dense, 6 lut)."""
    m = build_model(get_arch("llama3_8b"), Mode.LUT_TRAIN)
    segs = m.cfg.segments
    assert [n for n, _ in segs] == [1, get_arch("llama3_8b").n_layers - 1]
    assert segs[0][1].attn.q.mode == Mode.DENSE
    assert segs[1][1].attn.q.mode == Mode.LUT_TRAIN

    mb = build_model(get_arch("bert_base"), Mode.LUT_TRAIN)
    assert [n for n, _ in mb.cfg.segments] == [6, 6]


def test_flat_flags_feed_shim_default():
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, lut_int8_dot=True)
    plan = effective_plan(arch)
    assert plan.default.k == arch.lut_k and plan.default.int8_dot is True
    cfg = plan.lut_config(1, "mlp/gate", d_in=128, n_layers=2)
    assert cfg is not None and cfg.int8_dot and cfg.k == arch.lut_k
    assert plan.lut_config(0, "mlp/gate", 128, 2) is None   # all_but_first


# ---------------------------------------------------------------------------
# validation (satellite: last_n > n_layers)
# ---------------------------------------------------------------------------

def test_last_n_beyond_depth_raises():
    arch = reduce_arch(get_arch("bert_base"), n_layers=4, vocab=64,
                       d_model=64, d_ff=128)
    arch = dataclasses.replace(arch, lut_policy="last_n:9")
    with pytest.raises(ValueError, match="last_n"):
        build_model(arch, Mode.LUT_TRAIN)
    with pytest.raises(ValueError, match="last_n"):
        LUTPlan.last_n(9).validate(4)
    LUTPlan.last_n(4).validate(4)        # n == n_layers is legal


def test_layer_set_out_of_range_raises():
    with pytest.raises(ValueError, match="outside"):
        LUTPlan(rules=(rule(layers="set", layer_set=(0, 7)),)).validate(4)


def test_unknown_policy_string_raises():
    with pytest.raises(ValueError, match="unknown lut_policy"):
        LUTPlan.from_policy_string("every_other")


def test_reduce_arch_clamps_stranded_last_n():
    """Depth cuts used to strand bert's last_n:6 past the new layer count
    (negative-count dense segment); reduce_arch now clamps it."""
    arch = reduce_arch(get_arch("bert_base"))          # 4 layers, policy last_n:6
    assert arch.lut_policy == f"last_n:{arch.n_layers}"
    build_model(arch, Mode.LUT_TRAIN)                  # builds cleanly


def test_reduce_arch_pins_set_selector_to_new_depth():
    """Out-of-range explicit layer indices pin to the new last layer rather
    than being dropped — a 'first and last dense' plan keeps its intent."""
    plan = LUTPlan(rules=(
        rule(),
        rule(layers="set", layer_set=(0, 5), replace=False),
    ))
    big = dataclasses.replace(
        reduce_arch(get_arch("qwen3_1p7b"), n_layers=6, vocab=64,
                    d_model=64, d_ff=128), lut_plan=plan
    )
    small = reduce_arch(big, n_layers=4)
    assert small.lut_plan.rules[1].select.layer_set == (0, 3)
    m = build_model(small, Mode.LUT_TRAIN)
    modes = {s.layer: s.mode for s in m.sites() if s.kind == "mlp/gate"}
    assert modes[0] == modes[3] == Mode.DENSE
    assert modes[1] == modes[2] == Mode.LUT_TRAIN


# ---------------------------------------------------------------------------
# serialization round trips
# ---------------------------------------------------------------------------

def test_plan_dict_roundtrip():
    plan = LUTPlan(
        rules=(
            rule(kinds=("mlp/*",), k=16, int8_dot=True),
            rule(kinds=("attn/*",), k=8, bits=4),
            rule(layers="set", layer_set=(0, 3), replace=False),
            rule(layers="last_n", n=2, v=16),
        ),
        default=SitePolicy(k=32).merged_over(PAPER_DEFAULT),
    )
    back = LUTPlan.from_dict(json.loads(json.dumps(plan.to_dict())))
    assert back == plan

    with pytest.raises(ValueError, match="version"):
        LUTPlan.from_dict({"version": 9})


def test_arch_dict_carries_plan():
    plan = LUTPlan(rules=(rule(kinds=("mlp/*",), k=8),))
    arch = dataclasses.replace(
        reduce_arch(get_arch("qwen3_1p7b"), n_layers=2), lut_plan=plan
    )
    d = json.loads(json.dumps(arch_to_dict(arch)))
    assert d["lut_plan"]["rules"][0]["policy"] == {"k": 8}
    back = arch_from_dict(d)
    assert back == arch and back.lut_plan == plan
    # archs without a plan keep lut_plan=None through the round trip
    plain = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
    assert arch_from_dict(arch_to_dict(plain)) == plain


# ---------------------------------------------------------------------------
# site registry vs the real param trees (all three families)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id,kind", [
    ("llama3_8b", "lm"), ("zamba2_1p2b", "hybrid"), ("whisper_tiny", "encdec"),
])
@pytest.mark.parametrize("mode", [Mode.DENSE, Mode.LUT_TRAIN, Mode.LUT_INFER])
def test_sites_match_param_tree(arch_id, kind, mode):
    bundle = build_model(reduce_arch(get_arch(arch_id)), mode)
    assert bundle.kind == kind
    flat = _tree_items(bundle.param_specs())
    dirs = {p.rsplit("/", 1)[0] for p in flat}
    sites = bundle.sites()
    assert sites
    for s in sites:
        assert s.path in dirs, s
        if s.mode == Mode.DENSE:
            w = flat[f"{s.path}/w"]
            assert w.shape[-2:] == (s.d_in, s.d_out), s
            if s.stack_index is not None:
                assert s.stack_index < w.shape[0]
        elif s.mode == Mode.LUT_TRAIN:
            assert f"{s.path}/centroids" in flat and f"{s.path}/w" in flat, s
        else:
            assert f"{s.path}/table_q" in flat, s
            assert flat[f"{s.path}/table_q"].shape[-1] == s.d_out, s
    # converse: every weight/centroid-bearing subtree is a registered site
    site_paths = {s.path for s in sites}
    for p in flat:
        if p.endswith("/w") or p.endswith("/centroids"):
            assert p.rsplit("/", 1)[0] in site_paths, p


def test_sites_tape_keys_cover_capture(key):
    """Unrolled-forward tape record keys == the registry's tape keys, for
    every family (this is the join kmeans_init_lut relies on)."""
    from repro.models.common import tape_capture

    for arch_id in ("llama3_8b", "zamba2_1p2b", "whisper_tiny"):
        arch = reduce_arch(get_arch(arch_id), n_layers=2, vocab=64,
                           d_model=64, d_ff=128)
        bundle = build_model(arch, Mode.DENSE)
        src = dataclasses.replace(
            bundle, cfg=dataclasses.replace(bundle.cfg, unroll=True, remat=False)
        )
        batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
                 "labels": jnp.zeros((2, 8), jnp.int32)}
        if arch.family == "audio":
            batch["frames"] = jnp.zeros((2, arch.enc_frames, arch.d_model))
        with tape_capture() as tape:
            src.loss(bundle.init(key), batch, compute_dtype=jnp.float32)
        expected = {s.tape_key for s in bundle.sites() if s.tape_key is not None}
        assert set(tape.records) == expected, arch_id


# ---------------------------------------------------------------------------
# strict graft (satellite)
# ---------------------------------------------------------------------------

def test_graft_raises_on_unmatched_dense_leaf(key):
    arch = reduce_arch(get_arch("llama3_8b"), n_layers=2, vocab=64,
                       d_model=64, d_ff=128)
    lut = build_model(arch, Mode.LUT_TRAIN).init(key)
    other = build_model(
        dataclasses.replace(arch, d_ff=64), Mode.DENSE
    ).init(key)
    with pytest.raises(ValueError, match="no dense source"):
        convert.graft_dense_to_lut(other, lut)


# ---------------------------------------------------------------------------
# vmapped deploy (satellite)
# ---------------------------------------------------------------------------

def test_deploy_matches_per_layer_reference(key):
    """The vmapped table build equals the per-layer python-loop reference
    (up to XLA contraction-order float noise; codes may shift by at most
    one quantization step), including the site's own quantization layout."""
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=3, vocab=64,
                       d_model=64, d_ff=128, lut_int8_dot=True)
    blut = build_model(arch, Mode.LUT_TRAIN)
    lparams = blut.init(key)
    binf, iparams = convert.deploy_lut_train_params(blut, lparams)

    site = next(s for s in binf.sites() if s.kind == "mlp/gate" and s.mode == Mode.LUT_INFER)
    seg = int(site.path.split("/")[1])
    P = lparams["segments"][seg]["mlp"]["gate"]["centroids"]
    W = lparams["segments"][seg]["mlp"]["gate"]["w"]
    got_q = iparams["segments"][seg]["mlp"]["gate"]["table_q"]
    got_s = iparams["segments"][seg]["mlp"]["gate"]["table_scale"]
    for j in range(P.shape[0]):
        t = pq.build_table(P[j], W[j], stop_weight_grad=False)
        qt = quant.quantize_table(t, bits=site.lut.bits, m_shared=True)
        dq = np.abs(np.asarray(got_q[j], np.int32) - np.asarray(qt.q, np.int32))
        assert dq.max() <= 1 and (dq > 0).mean() < 0.01
        np.testing.assert_allclose(np.asarray(got_s[j]), np.asarray(qt.scale),
                                   rtol=1e-6)
    # int8_dot sites deploy the m-shared (1, 1, M) layout the serving path needs
    assert got_s.shape[1:] == (1, 1, site.d_out)


# ---------------------------------------------------------------------------
# heterogeneous plan lifecycle (acceptance)
# ---------------------------------------------------------------------------

def _hetero_arch(n_layers=4):
    plan = LUTPlan(rules=(
        rule(kinds=("mlp/*",), k=16),
        rule(kinds=("attn/*",), k=8),
        rule(layers="set", layer_set=(0, n_layers - 1), replace=False),
    ))
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=n_layers, vocab=64,
                       d_model=64, d_ff=128)
    return dataclasses.replace(arch, lut_plan=plan)


def _greedy(bundle, params, prompts, n_tokens):
    eng = ServingEngine(bundle, params, n_slots=2, max_seq=32, prefill_chunk=4,
                        autotune_lut=False)
    for p in prompts:
        eng.submit(p, max_tokens=n_tokens)
    return [r.out_tokens for r in sorted(eng.run_until_done(), key=lambda r: r.rid)]


def test_heterogeneous_plan_full_lifecycle(key, tmp_path):
    """K=16 MLP + K=8 attention, first and last layers dense: builds,
    trains one step, deploys to an artifact, and reloads with
    token-identical serving output (manifest v2+ carries the plan)."""
    from repro.optim import SOFT_PQ_RULES, AdamW, lut_frozen_mask
    from repro.train.train_step import make_train_step

    arch = _hetero_arch()
    blut = build_model(arch, Mode.LUT_TRAIN)

    # structure: ends dense, middle mixed-K per kind
    mids = [s for s in blut.sites() if s.layer in (1, 2) and s.stack_index is not None]
    assert all(s.mode == Mode.LUT_TRAIN for s in mids if s.kind != "lm_head")
    assert {s.lut.k for s in mids if s.kind.startswith("attn/")} == {8}
    assert {s.lut.k for s in mids if s.kind.startswith("mlp/")} == {16}
    ends = [s for s in blut.sites() if s.layer in (0, arch.n_layers - 1)
            and s.stack_index is not None]
    assert all(s.mode == Mode.DENSE for s in ends)

    lparams = blut.init(key)
    frozen = lut_frozen_mask(lparams)
    opt = AdamW(lr=1e-3, rules=SOFT_PQ_RULES)
    step = jax.jit(make_train_step(blut, opt, frozen_mask=frozen,
                                   compute_dtype=jnp.float32))
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, 64),
             "labels": jax.random.randint(key, (2, 8), 0, 64)}
    lparams, _, metrics = step(lparams, opt.init(lparams, frozen), batch)
    assert np.isfinite(float(metrics["loss"]))

    binf, iparams = convert.deploy_to_artifact(blut, lparams, tmp_path / "art")
    art = load_artifact(tmp_path / "art")
    assert art.manifest["version"] == 3
    assert art.bundle.arch.lut_plan == arch.lut_plan
    prompts = [[1, 2, 3, 4, 5], [7, 8, 9]]
    assert _greedy(binf, iparams, prompts, 5) == \
        _greedy(art.bundle, art.params, prompts, 5)


def test_v1_artifact_migrates_on_load(key, tmp_path):
    """A version-1 manifest (no plan, legacy lut_policy in the arch dict)
    still loads: the shim resolves the same plan the v1 writer built with."""
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, vocab=64,
                       d_model=64, d_ff=128)
    bundle = build_model(arch, Mode.LUT_INFER)
    params = bundle.init(key)
    d = save_artifact(tmp_path / "art", bundle, params)
    manifest = json.loads((d / "manifest.json").read_text())
    manifest.pop("plan")
    manifest["version"] = 1
    manifest["arch"].pop("lut_plan")
    (d / "manifest.json").write_text(json.dumps(manifest))
    art = load_artifact(d)
    assert art.bundle.arch == arch
    _assert_trees_equal(art.params, params)


def test_v2_manifest_plan_mismatch_rejected(key, tmp_path):
    arch = _hetero_arch(n_layers=2)
    bundle = build_model(arch, Mode.LUT_INFER)
    d = save_artifact(tmp_path / "art", bundle, bundle.init(key))
    manifest = json.loads((d / "manifest.json").read_text())
    manifest["plan"] = LUTPlan.all().to_dict()
    (d / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="plan"):
        load_artifact(d)


# ---------------------------------------------------------------------------
# family-agnostic conversion (the old `kind == "lm"` assert is gone)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", ["zamba2_1p2b", "whisper_tiny"])
def test_convert_works_beyond_lm(arch_id, key):
    from repro.data import MarkovLM

    arch = reduce_arch(get_arch(arch_id), n_layers=2, vocab=64,
                       d_model=64, d_ff=128)
    data = MarkovLM(vocab=arch.vocab, seq_len=8, batch=2)
    dense = build_model(arch, Mode.DENSE)
    dparams = dense.init(key)

    def batch(i):
        b = data.batch_at(i)
        if arch.family == "audio":
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(i), (2, arch.enc_frames, arch.d_model)
            )
        return b

    blut, lparams = convert.convert_dense_to_lut_train(
        dense, dparams, [batch(0)], key, kmeans_iters=3
    )
    rnd = blut.init(jax.random.PRNGKey(0))
    moved = [
        p for p, leaf in _tree_items(lparams).items()
        if p.endswith("centroids")
        and not np.array_equal(np.asarray(leaf), np.asarray(_tree_items(rnd)[p]))
    ]
    assert moved, "k-means init touched no centroids"
    binf, iparams = convert.deploy_lut_train_params(blut, lparams)
    loss = float(binf.loss(iparams, batch(3), compute_dtype=jnp.float32))
    assert np.isfinite(loss)

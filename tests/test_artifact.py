"""Deployment artifacts (DESIGN.md §8): dtype-exact round trip,
self-describing load (no `like` tree), autotune snapshot restore, and the
full train -> deploy -> serve lifecycle through the launchers."""

import json

import jax
import numpy as np
import pytest

from repro.configs import arch_from_dict, arch_to_dict, build_model, get_arch, reduce_arch
from repro.core import convert
from repro.core.amm import Mode
from repro.kernels import autotune
from repro.serving.artifact import load_artifact, restore_autotune_snapshot, save_artifact
from repro.serving.engine import ServingEngine


def _deployed_bundle(key, **reduce_kw):
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, **reduce_kw)
    bundle = build_model(arch, Mode.LUT_INFER)
    return bundle, bundle.init(key)


def _greedy(bundle, params, prompts, n_tokens, **eng_kw):
    eng = ServingEngine(bundle, params, n_slots=2, max_seq=32, prefill_chunk=4,
                        autotune_lut=False, **eng_kw)
    for p in prompts:
        eng.submit(p, max_tokens=n_tokens)
    return [r.out_tokens for r in sorted(eng.run_until_done(), key=lambda r: r.rid)]


def test_arch_spec_dict_roundtrip():
    arch = reduce_arch(get_arch("qwen2_vl_7b"))          # has mrope tuple field
    d = arch_to_dict(arch)
    assert isinstance(d["mrope_sections"], list)          # JSON-safe
    back = arch_from_dict(json.loads(json.dumps(d)))
    assert back == arch
    # unknown keys from a newer writer are ignored
    assert arch_from_dict({**d, "future_field": 1}) == arch
    with pytest.raises(ValueError):
        arch_from_dict({"name": "x"})                     # required fields missing


def test_artifact_roundtrip_exact_dtypes(key, tmp_path):
    """int8 tables and fp32 scales/centroids survive save->load bit-exactly."""
    bundle, params = _deployed_bundle(key)
    save_artifact(tmp_path / "art", bundle, params)
    art = load_artifact(tmp_path / "art")

    leaves_in = jax.tree_util.tree_leaves(params)
    leaves_out = jax.tree_util.tree_leaves(art.params)
    assert len(leaves_in) == len(leaves_out)
    for a, b in zip(leaves_in, leaves_out):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the deployed tree really exercises both dtypes
    dtypes = {str(l.dtype) for l in leaves_in}
    assert "int8" in dtypes and "float32" in dtypes

    m = art.manifest
    assert m["format"] == "lut-artifact" and m["version"] == 3
    assert m["mode"] == "lut_infer" and m["kind"] == "lm"
    assert m["plan"]["version"] == 1 and m["plan"]["rules"]    # manifest v2+ carries the plan
    assert any(v["dtype"] == "int8" for v in m["leaves"].values())


def test_artifact_bfloat16_params_roundtrip(key, tmp_path):
    """bfloat16 param trees (the giants' param_dtype) survive the npz detour
    bit-exactly — npz itself cannot store bf16, so leaves travel as uint16."""
    import jax.numpy as jnp

    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=1, d_model=32,
                       vocab=64, d_ff=64, param_dtype="bfloat16")
    bundle = build_model(arch, Mode.DENSE)
    params = bundle.init(key)
    assert any(l.dtype == jnp.bfloat16 for l in jax.tree_util.tree_leaves(params))
    save_artifact(tmp_path / "art", bundle, params)
    art = load_artifact(tmp_path / "art")
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(art.params)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint16), np.asarray(b).view(np.uint16)
        )


def test_artifact_load_needs_no_like_tree(key, tmp_path):
    """load_artifact rebuilds arch+bundle+tree purely from the manifest."""
    bundle, params = _deployed_bundle(key)
    save_artifact(tmp_path / "art", bundle, params)
    art = load_artifact(tmp_path / "art")
    assert art.bundle.arch == bundle.arch
    assert art.bundle.mode == Mode.LUT_INFER
    assert art.arch_name == "qwen3_1p7b"


def test_artifact_rejects_corruption(key, tmp_path):
    bundle, params = _deployed_bundle(key)
    d = save_artifact(tmp_path / "art", bundle, params)

    with pytest.raises(FileNotFoundError):
        load_artifact(tmp_path / "nope")

    manifest = json.loads((d / "manifest.json").read_text())
    bad = dict(manifest, version=99)
    (d / "manifest.json").write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="version"):
        load_artifact(d)

    # a manifest whose arch no longer matches the stored arrays must fail
    # loudly at load (leaf shape validation), not serve garbage
    bad = dict(manifest)
    bad["arch"] = dict(bad["arch"], d_model=bad["arch"]["d_model"] * 2)
    (d / "manifest.json").write_text(json.dumps(bad))
    with pytest.raises(ValueError):
        load_artifact(d)


def test_artifact_overwrite_in_place(key, tmp_path):
    """Re-deploying to the same directory replaces the artifact atomically:
    the new params load, and no .old/.tmp residue is left behind."""
    bundle, params = _deployed_bundle(key)
    save_artifact(tmp_path / "art", bundle, params)
    params2 = bundle.init(jax.random.PRNGKey(1))
    save_artifact(tmp_path / "art", bundle, params2)
    art = load_artifact(tmp_path / "art")
    for a, b in zip(jax.tree_util.tree_leaves(params2),
                    jax.tree_util.tree_leaves(art.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not (tmp_path / "art.old").exists()
    assert not (tmp_path / "art.tmp").exists()


def test_artifact_serve_parity_in_memory_vs_loaded(key, tmp_path):
    """save -> load -> serve is token-identical to serving the in-memory
    deployed params (greedy)."""
    bundle, params = _deployed_bundle(key)
    save_artifact(tmp_path / "art", bundle, params)
    art = load_artifact(tmp_path / "art")
    prompts = [[1, 2, 3, 4, 5], [7, 8, 9]]
    assert _greedy(bundle, params, prompts, 5) == \
        _greedy(art.bundle, art.params, prompts, 5)


def test_artifact_autotune_snapshot_restores(key, tmp_path, monkeypatch):
    """Winners warmed before save ship with the artifact (scoped to THIS
    bundle's LUT sites) and are merged into a fresh process cache on load
    (existing entries win)."""
    bundle, params = _deployed_bundle(key, lut_use_kernel=True)
    # (m=128, c=8, k=16, v=16) is the reduced qwen3 attention-site signature;
    # tune it plus a shape belonging to no site — only the former may ship
    shape = ("lut_amm", 8, 128, 8, 16, 16)
    autotune.tune(*shape, dtype="float32", backend="cpu")
    autotune.tune("lut_amm", 8, 999, 3, 16, 8, dtype="float32", backend="cpu")
    key_str = autotune.shape_key(*shape, "float32", "cpu")
    foreign = autotune.shape_key("lut_amm", 8, 999, 3, 16, 8, "float32", "cpu")
    d = save_artifact(tmp_path / "art", bundle, params)
    snap = json.loads((d / "autotune.json").read_text())
    assert key_str in snap["entries"]
    assert foreign not in snap["entries"]

    # fresh cache (new path): loading the artifact merges the winner in
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "fresh.json"))
    assert autotune.get_cache().get(key_str) is None
    load_artifact(d)
    assert autotune.get_cache().get(key_str) is not None

    # existing entries are NOT clobbered by a second restore
    autotune.get_cache().put(key_str, {"block_n": 1, "block_m": 1, "block_c": 1})
    assert restore_autotune_snapshot(d) == 0 or \
        autotune.get_cache().get(key_str)["block_n"] == 1


def test_artifact_snapshot_measured_precedence(key, tmp_path, monkeypatch):
    """Measured > snapshot > analytic (DESIGN.md §13.3): records round-trip
    their `measured`/`version` fields through the artifact snapshot, a
    MEASURED snapshot entry replaces a live analytic one, and no snapshot
    entry ever replaces a live measured winner."""
    bundle, params = _deployed_bundle(key, lut_use_kernel=True)
    shape = ("lut_amm", 8, 128, 8, 16, 16)       # reduced-qwen3 site signature
    key_str = autotune.shape_key(*shape, "float32", "cpu")

    # ship a MEASURED winner (as a real accelerator deploy would)
    autotune.tune(*shape, dtype="float32", backend="cpu",
                  measure=lambda cfg, ver: 1e-6 if ver == 1 else 1e-3)
    d = save_artifact(tmp_path / "art", bundle, params)
    snap = json.loads((d / "autotune.json").read_text())
    assert snap["entries"][key_str]["measured"] is True
    assert snap["entries"][key_str]["version"] == 1

    # live cache holds an ANALYTIC record -> the measured snapshot wins
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "live.json"))
    autotune.tune(*shape, dtype="float32", backend="cpu")
    live = autotune.get_cache().get(key_str)
    assert live is not None and not live["measured"]
    assert restore_autotune_snapshot(d) >= 1
    got = autotune.get_cache().get(key_str)
    assert got["measured"] and got["version"] == 1

    # live cache holds a MEASURED record -> the snapshot never clobbers it
    marker = {"block_n": 8, "block_m": 128, "block_c": 8,
              "version": 2, "measured": True, "source": "wallclock"}
    autotune.get_cache().put(key_str, dict(marker))
    restore_autotune_snapshot(d)
    assert autotune.get_cache().get(key_str) == marker


def test_deploy_to_artifact_emits_loadable_artifact(key, tmp_path):
    """convert.deploy_to_artifact: LUT_TRAIN params -> artifact on disk whose
    loaded params equal the returned in-memory deployed tree."""
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, d_model=64,
                       vocab=64, d_ff=128)
    blut = build_model(arch, Mode.LUT_TRAIN)
    lparams = blut.init(key)
    binf, iparams = convert.deploy_to_artifact(blut, lparams, tmp_path / "art")
    art = load_artifact(tmp_path / "art")
    for a, b in zip(jax.tree_util.tree_leaves(iparams),
                    jax.tree_util.tree_leaves(art.params)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert art.bundle.arch == binf.arch


def test_e2e_train_writes_artifact_serve_loads_it(tmp_path, capsys, monkeypatch):
    """The acceptance lifecycle: launch/train.py --lut (reduced) writes a
    LUTArtifact; launch/serve.py --artifact loads it with no hand-built
    `like` tree and serves it, token-identical to the in-memory deployed
    params the pipeline produced."""
    from repro.launch.serve import main as serve_main
    from repro.launch.train import main as train_main

    # capture the pipeline's in-memory deployed (bundle, params) as they
    # flow through the deploy step, for the parity check below
    captured = {}
    orig_deploy = convert.deploy_to_artifact

    def spy(blut, lparams, directory, **kw):
        binf, iparams = orig_deploy(blut, lparams, directory, **kw)
        captured["bundle"], captured["params"] = binf, iparams
        return binf, iparams

    monkeypatch.setattr(convert, "deploy_to_artifact", spy)

    art_dir = tmp_path / "deployed"
    train_main([
        "--arch", "qwen3_1p7b", "--d-model", "32", "--layers", "2",
        "--vocab", "64", "--seq", "16", "--batch", "4", "--steps", "2",
        "--lut", "--ckpt-dir", str(tmp_path / "ckpt"),
        "--artifact-dir", str(art_dir),
    ])
    assert (art_dir / "manifest.json").exists()
    assert (art_dir / "arrays.npz").exists()

    serve_main([
        "--artifact", str(art_dir), "--requests", "2", "--slots", "2",
        "--max-seq", "32", "--max-tokens", "4", "--prefill-chunk", "4",
    ])
    out = capsys.readouterr().out
    assert "artifact" in out and "2 requests" in out

    # greedy outputs from the loaded artifact == serving the in-memory tree
    art = load_artifact(art_dir)
    prompts = [[1, 2, 3], [5, 6, 7, 8]]
    assert _greedy(art.bundle, art.params, prompts, 4) == \
        _greedy(captured["bundle"], captured["params"], prompts, 4)


# ---------------------------------------------------------------------------
# multi-plan artifacts (manifest v3, DESIGN.md §14.1)

def _two_plan_setup(key):
    """One random LUT_TRAIN state deployed under two plans: the full
    trained plan ('draft') and its attn-kept-dense sub-plan ('target')."""
    from repro.configs import effective_plan

    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, d_model=64,
                       vocab=128, d_ff=128)
    blut = build_model(arch, Mode.LUT_TRAIN)
    lparams = blut.init(key)
    trained = effective_plan(arch)
    tb, tp = convert.deploy_lut_train_params(
        blut, lparams, plan=trained.keeping_dense("attn/*"))
    db, dp = convert.deploy_lut_train_params(blut, lparams, plan=trained)
    return (tb, tp), (db, dp)


def test_artifact_multi_plan_roundtrip(key, tmp_path):
    """Both plans round-trip bit-exactly through one shared array payload,
    and the overlapping table leaves are deduplicated on disk."""
    (tb, tp), (db, dp) = _two_plan_setup(key)
    save_artifact(tmp_path / "art", tb, tp, extra_plans={"draft": (db, dp)})

    art = load_artifact(tmp_path / "art")
    assert art.plan_name == "target" and art.plan_names == ["target", "draft"]
    for a, b in zip(jax.tree_util.tree_leaves(tp),
                    jax.tree_util.tree_leaves(art.params)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    draft = load_artifact(tmp_path / "art", plan="draft")
    assert draft.plan_name == "draft"
    for a, b in zip(jax.tree_util.tree_leaves(dp),
                    jax.tree_util.tree_leaves(draft.params)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the two bundles differ only in replacement plan
    assert draft.bundle.arch != art.bundle.arch

    m = art.manifest
    leaves = m["plans"]["draft"]["leaves"]
    shared = [p for p, rec in leaves.items() if rec["key"] == p]
    private = [p for p, rec in leaves.items()
               if rec["key"].startswith("plan.draft/")]
    # the plans overlap on every non-attn LUT site -> real sharing, and the
    # draft's attn tables exist only on the draft -> real private leaves
    assert shared and private
    assert all(rec["key"] == p or rec["key"] == f"plan.draft/{p}"
               for p, rec in leaves.items())


def test_artifact_unknown_plan_lists_available(key, tmp_path):
    (tb, tp), (db, dp) = _two_plan_setup(key)
    save_artifact(tmp_path / "art", tb, tp, extra_plans={"draft": (db, dp)})
    with pytest.raises(ValueError, match=r"no plan 'tiny'.*draft"):
        load_artifact(tmp_path / "art", plan="tiny")


def test_artifact_reserved_plan_name_rejected(key, tmp_path):
    (tb, tp), (db, dp) = _two_plan_setup(key)
    with pytest.raises(ValueError, match="reserved"):
        save_artifact(tmp_path / "art", tb, tp,
                      extra_plans={"target": (db, dp)})


def test_artifact_v2_manifest_still_loads(key, tmp_path):
    """A pre-multi-plan (v2) manifest loads as a single-plan artifact; a
    named-plan request fails with the single-plan explanation."""
    bundle, params = _deployed_bundle(key)
    d = save_artifact(tmp_path / "art", bundle, params)
    manifest = json.loads((d / "manifest.json").read_text())
    assert "plans" not in manifest
    manifest["version"] = 2
    (d / "manifest.json").write_text(json.dumps(manifest))

    art = load_artifact(d)
    assert art.plan_names == ["target"]
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(art.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="single-plan"):
        load_artifact(d, plan="draft")


def test_describe_artifact_lists_plans(key, tmp_path):
    from repro.serving.artifact import describe_artifact

    (tb, tp), (db, dp) = _two_plan_setup(key)
    save_artifact(tmp_path / "art", tb, tp, extra_plans={"draft": (db, dp)})
    out = describe_artifact(tmp_path / "art")
    assert "target" in out and "draft" in out
    assert "FLOPs vs target" in out and "shared" in out

"""EXPERIMENTS.md section Roofline source: aggregate results/dryrun JSONs
into the per-(arch x shape x mesh) three-term roofline table with
MODEL_FLOPS ratios, plus the per-op kernel axis (dense vs one-hot-XLA vs
pallas-v1 vs pallas-v2) from BENCH_kernels.json when present."""

from __future__ import annotations

import json
import pathlib
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1] / "results"
RESULTS = _ROOT / "final" if (_ROOT / "final").exists() else _ROOT / "dryrun"
BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_kernels.json"


def kernel_rows() -> list[dict]:
    """Per-op kernel comparison from the microbench artifact (may be absent)."""
    if not BENCH_JSON.exists():
        return []
    try:
        return json.loads(BENCH_JSON.read_text()).get("rows", [])
    except (OSError, ValueError):
        return []


def print_kernel_axis() -> None:
    rs = kernel_rows()
    if not rs:
        return
    print("# Kernel axis (from BENCH_kernels.json; model_us = v5e projection)")
    print("op,dense_roofline_us,lut_xla_roofline_us,v1_model_us,v2_model_us,"
          "fused_model_us,tuned,blocks")
    for r in rs:
        fused = r.get("fused_model_us")
        fused_s = f"{fused:.1f}" if isinstance(fused, (int, float)) else "nan"
        tuned = f"v{r.get('tuned_version', 2)}/" \
                + ("meas" if r.get("tuned_measured") else "model")
        print(
            f"{r['op']},{r['tpu_roofline_dense_us']:.1f},"
            f"{r['tpu_roofline_lut_us']:.1f},{r['v1_model_us']:.1f},"
            f"{r['v2_model_us']:.1f},{fused_s},{tuned},"
            f"{r['tuned_block_n']}x{r['tuned_block_m']}x{r['tuned_block_c']}"
        )


def rows(suffix: str = "sp", tag: str | None = None):
    out = []
    pat = f"*__{suffix}__{tag}.json" if tag else f"*__{suffix}.json"
    for f in sorted(RESULTS.glob(pat)):
        r = json.loads(f.read_text())
        if r.get("skipped"):
            out.append({"arch": r["arch"], "shape": r["shape"], "skipped": r["skipped"]})
            continue
        ro = r["roofline"]
        n_chips = 1
        for d in r["mesh"]:
            n_chips *= d
        from benchmarks._useful import cell_useful

        u = cell_useful(r["arch"], r["shape"], r["mode"], n_chips)
        bound = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"], 1e-12)
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mode": r["mode"],
            "mesh": "x".join(map(str, r["mesh"])),
            "mem_gib": r["memory"]["total_hbm_bytes"] / 2**30,
            "t_comp": ro["t_compute_s"], "t_mem": ro["t_memory_s"],
            "t_coll": ro["t_collective_s"], "bottleneck": ro["bottleneck"],
            # useful-algorithm flops / compiled flops: >1 would mean the
            # compiled program beats the analytic LUT algorithm (impossible);
            # <<1 flags remat/redundancy waste
            "model_flops_ratio": u["useful_flops_per_dev"] / max(ro["flops_per_device"], 1.0),
            "roofline_fraction": u["t_useful_s"] / bound,
        })
    return out


def main() -> None:
    t0 = time.time()
    print_kernel_axis()
    for suffix, label in (("sp", "single-pod 16x16"), ("mp", "multi-pod 2x16x16")):
        rs = rows(suffix)
        if not rs:
            continue
        print(f"# Roofline table ({label})")
        print("arch,shape,mode,mem_GiB,t_compute_s,t_memory_s,t_collective_s,"
              "bottleneck,model_flops_ratio,roofline_fraction")
        for r in rs:
            if "skipped" in r:
                print(f"{r['arch']},{r['shape']},SKIPPED({r['skipped'][:40]})")
                continue
            print(
                f"{r['arch']},{r['shape']},{r['mode']},{r['mem_gib']:.2f},"
                f"{r['t_comp']:.4f},{r['t_mem']:.4f},{r['t_coll']:.4f},"
                f"{r['bottleneck']},{r['model_flops_ratio']:.3f},"
                f"{r['roofline_fraction']:.4f}"
            )
    print(f"roofline_table,{(time.time()-t0)*1e6:.0f},from_dryrun_json")


if __name__ == "__main__":
    main()

"""Shared model building blocks (pure JAX, no flax).

Parameters are plain nested dicts of arrays. Every weight-bearing projection
in every architecture goes through `linear(...)` below, which dispatches to
the dense path or the LUT-NN path (repro.core.amm) based on a statically
resolved per-site mode — this is how the paper's technique is a first-class
feature of the whole model zoo rather than a bolted-on op.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.amm import LUTConfig, Mode, lut_linear
from repro.core.lut_layer import deploy_param_specs, init_dense
from repro.core.temperature import init_log_temperature

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# linear sites (dense / LUT dual personality)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SiteCfg:
    """Static config of one linear site, resolved at model build time."""

    d_in: int
    d_out: int
    mode: Mode
    lut: LUTConfig
    bias: bool = False
    name: str = ""          # tree-path-relative label for activation capture


def linear_init(key: jax.Array, site: SiteCfg, *, dtype=jnp.float32) -> Params:
    """Init params for a site in its current mode.

    DENSE      -> {"w" [, "b"]}
    LUT_TRAIN  -> {"w" (frozen via stop-grad in build_table), "centroids",
                   "log_t" [, "b"]}  — centroids random here; k-means init is
                   applied by repro.core.convert from activation samples.
    LUT_INFER  -> {"centroids", "table_q", "table_scale" [, "b"]}
    """
    if site.mode == Mode.DENSE:
        return init_dense(key, site.d_in, site.d_out, bias=site.bias, dtype=dtype)
    if site.mode == Mode.LUT_TRAIN:
        kd, kc = jax.random.split(key)
        p = init_dense(kd, site.d_in, site.d_out, bias=site.bias, dtype=dtype)
        c = site.lut.codebooks(site.d_in)
        p["centroids"] = jax.random.normal(kc, (c, site.lut.k, site.lut.v), jnp.float32) * 0.02
        p["log_t"] = init_log_temperature()
        return p
    if site.mode == Mode.LUT_INFER:
        c = site.lut.codebooks(site.d_in)
        kc = key
        specs = deploy_param_specs(site.d_in, site.d_out, site.lut, bias=site.bias)
        p = {
            "centroids": jax.random.normal(kc, (c, site.lut.k, site.lut.v), jnp.float32) * 0.02,
            "table_q": jax.random.randint(kc, specs["table_q"].shape, -127, 127, jnp.int8),
            "table_scale": jnp.full(specs["table_scale"].shape, 0.02, jnp.float32),
        }
        if site.bias:
            p["b"] = jnp.zeros((site.d_out,), dtype)
        return p
    raise ValueError(site.mode)


_TAPE: list | None = None          # activation-capture tape (core.convert)


class tape_capture:
    """Context manager: record LUT-site inputs at every named linear call,
    keyed by '<prefix>/<site.name>'. Only meaningful for eager, unrolled
    forwards (conversion runs the sample batch un-jitted so the tape sees
    concrete arrays; see LMCfg.unroll)."""

    def __init__(self, max_rows: int = 4096):
        self.records: dict[str, list] = {}
        self.prefix: str = ""
        self.max_rows = max_rows

    def record(self, site, x):
        if not site.name:
            return
        key = f"{self.prefix}/{site.name}" if self.prefix else site.name
        rows = x.reshape(-1, x.shape[-1])[: self.max_rows]
        self.records.setdefault(key, []).append(rows)

    def __enter__(self):
        global _TAPE
        self._prev = _TAPE
        _TAPE = self
        return self

    def __exit__(self, *exc):
        global _TAPE
        _TAPE = self._prev
        return False


def set_tape_prefix(prefix: str) -> None:
    """Point subsequent `linear()` records at this tape-key prefix (no-op
    without an active tape). Unrolled layer loops call this so record keys
    match the site registry's `tape_key`s (`ModelBundle.sites()`)."""
    if _TAPE is not None:
        _TAPE.prefix = prefix


def linear(site: SiteCfg, p: Params, x: jax.Array) -> jax.Array:
    """Apply one linear site in its statically-configured mode."""
    if _TAPE is not None:
        _TAPE.record(site, x)
    if site.mode == Mode.LUT_TRAIN:
        # single-tree form: the dense weight lives next to the centroids and
        # is frozen by the stop_gradient inside build_table.
        return lut_linear(site.lut, Mode.LUT_TRAIN, p, x, frozen=p)
    return lut_linear(site.lut, site.mode, p, x)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "relu2":                      # squared ReLU (Nemotron/Minitron)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jax.Array:
    """(d_head/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh), pos: (B, S) int32 -> rotated x (same shape)."""
    inv = rope_freqs(x.shape[-1], theta)                       # (Dh/2,)
    ang = pos[:, :, None].astype(jnp.float32) * inv[None, None, :]  # (B, S, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, pos3: jax.Array, theta: float, sections: tuple[int, ...]
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): pos3 (3, B, S) = (t, h, w) position ids.

    The Dh/2 frequency slots are partitioned into `sections` (summing to
    Dh/2); each section rotates by its own positional stream.
    """
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                                # (Dh/2,)
    ang_k = pos3[:, :, :, None].astype(jnp.float32) * inv[None, None, None, :]  # (3, B, S, Dh/2)
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=dh // 2
    )                                                          # (Dh/2,)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_k, 0, -1),                            # (B, S, Dh/2, 3)
        sec_id[None, None, :, None],
        axis=-1,
    )[..., 0]                                                  # (B, S, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / loss
# ---------------------------------------------------------------------------

def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p: Params, ids: jax.Array) -> jax.Array:
    return jnp.take(p["table"], ids, axis=0)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token-level CE, fp32. logits (..., vocab), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)

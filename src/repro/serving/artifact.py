"""Versioned LUT deployment artifact: the train → serve hand-off (DESIGN.md §8).

`launch/train.py --lut` ends with deployed LUT_INFER params (int8 tables +
fp32 scales/centroids). This module packages them as a self-describing
on-disk directory a fresh server can load with **no** hand-built `like`
tree — the manifest carries everything needed to rebuild the model:

  <dir>/
      manifest.json     format+version, arch-spec fields, mode, bundle kind,
                        tree structure + per-leaf shape/dtype
      arrays.npz        every param leaf keyed by tree path (dtype-exact:
                        int8 tables stay int8)
      autotune.json     snapshot of the warmed kernel block-size cache, so a
                        fresh server starts with tuned tilings instead of
                        re-deriving (or re-measuring) them

Writes follow the Checkpointer's atomic discipline: everything lands in
`<dir>.tmp`, then one `os.replace` commits — a crash mid-write can never
produce a half-readable artifact.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import flatten_tree, tree_paths
from repro.configs import ModelBundle, arch_from_dict, arch_to_dict, build_model, effective_plan
from repro.core.amm import Mode
from repro.core.plan import LUTPlan
from repro.kernels import autotune

FORMAT = "lut-artifact"
# v2 (DESIGN.md §9.3): the manifest additionally records the RESOLVED
# replacement plan under "plan" (LUTPlan.to_dict schema). v1 artifacts,
# written before plans existed, migrate on load: their arch dict carries
# the legacy lut_policy string, which the back-compat shim resolves to the
# same plan the writer used.
VERSION = 2
_READABLE_VERSIONS = (1, 2)

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
_AUTOTUNE = "autotune.json"

# npz cannot represent bfloat16 (it stores raw void bytes that never load
# back); bf16 leaves travel as uint16 bit patterns, with the manifest's
# dtype string as the restore key
_BF16 = np.dtype(jnp.bfloat16)


@dataclasses.dataclass(frozen=True)
class LUTArtifact:
    """A loaded deployment artifact: the rebuilt bundle + host params."""

    bundle: ModelBundle
    params: Any
    manifest: dict[str, Any]
    path: pathlib.Path

    @property
    def arch_name(self) -> str:
        return self.manifest["arch"]["name"]

    @property
    def recipe(self) -> dict[str, Any] | None:
        """The executed training recipe (`Recipe.to_dict` payload), when
        the artifact was deployed through `Recipe.run` (DESIGN.md §10.2)."""
        return self.manifest.get("recipe")


def save_artifact(
    directory: str | os.PathLike,
    bundle: ModelBundle,
    params: Any,
    *,
    autotune_snapshot: bool = True,
    recipe: dict[str, Any] | None = None,
) -> pathlib.Path:
    """Write `(bundle, params)` as a LUTArtifact directory (atomic).

    `params` is typically the LUT_INFER tree from
    `convert.deploy_lut_train_params`; any bundle/tree pair round-trips,
    so dense baselines can ship through the same path. `recipe` (a
    `repro.train.recipe.Recipe.to_dict` payload) records the executed
    training pipeline in the manifest — provenance only, never consulted
    at load; `Recipe.from_dict(manifest["recipe"])` round-trips it.
    """
    final = pathlib.Path(directory)
    tmp = final.parent / (final.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), params)
    flat = flatten_tree(host)
    np.savez(tmp / _ARRAYS, **{
        k: (v.view(np.uint16) if v.dtype == _BF16 else v)
        for k, v in flat.items()
    })

    manifest = {
        "format": FORMAT,
        "version": VERSION,
        "arch": arch_to_dict(bundle.arch),
        "plan": effective_plan(bundle.arch).to_dict(),
        "mode": bundle.mode.value,
        "kind": bundle.kind,
        "treedef": str(jax.tree_util.tree_structure(host)),
        "leaves": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()
        },
    }
    if recipe is not None:
        manifest["recipe"] = recipe
    (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=2))

    if autotune_snapshot:
        entries = _snapshot_entries(bundle)
        (tmp / _AUTOTUNE).write_text(
            json.dumps({"version": 1, "entries": entries}, indent=1, sort_keys=True)
        )

    # commit: move any previous artifact aside BEFORE the replace
    # (os.replace cannot target a non-empty directory). A crash between the
    # two replaces leaves the previous artifact intact at <dir>.old, which
    # load_artifact falls back to — at every instant one of the two is
    # loadable. A stale .old (from such a crash) is only cleared while
    # <dir> itself exists, preserving that invariant across re-deploys.
    old = final.parent / (final.name + ".old")
    if final.exists():
        if old.exists():
            shutil.rmtree(old)
        os.replace(final, old)
    os.replace(tmp, final)
    if old.exists():
        shutil.rmtree(old)
    return final


def _snapshot_entries(bundle: ModelBundle) -> dict[str, Any]:
    """Autotune cache entries belonging to THIS bundle's LUT kernel sites.

    The process cache may hold winners for other archs/backends; shipping
    those would make every server that loads the artifact inherit them
    forever (restored entries suppress re-tuning). Keys are matched on the
    (m, c, k, v) site signature — any n/dtype/backend, since serve-time
    slot counts and hardware are unknown at deploy time.
    """
    sites = set()
    for site in bundle.sites():                          # registry walk (§9.2)
        if site.mode != Mode.LUT_INFER or site.lut is None or not site.lut.use_kernel:
            continue
        lut = site.lut
        c = site.d_in // lut.v
        sites.add(("lut_amm", site.d_out, c, lut.k, lut.v))
        sites.add(("encode", 0, c, lut.k, lut.v))        # shared-encode path
    if not sites:
        return {}

    def key_sig(key: str) -> tuple | None:
        parts = key.split("|")
        try:
            kind = parts[0]
            f = dict(p.split("=", 1) for p in parts[1:])
            return kind, int(f["m"]), int(f["c"]), int(f["k"]), int(f["v"])
        except (IndexError, KeyError, ValueError):
            return None

    return {
        k: dict(rec)
        for k, rec in autotune.get_cache().load().items()
        if key_sig(k) in sites
    }


def _read_manifest(directory: pathlib.Path) -> dict[str, Any]:
    try:
        manifest = json.loads((directory / _MANIFEST).read_text())
    except FileNotFoundError:
        raise FileNotFoundError(f"no {_MANIFEST} in {directory} — not an artifact")
    if manifest.get("format") != FORMAT:
        raise ValueError(f"{directory}: format={manifest.get('format')!r}, "
                         f"expected {FORMAT!r}")
    if manifest.get("version") not in _READABLE_VERSIONS:
        raise ValueError(f"{directory}: artifact version "
                         f"{manifest.get('version')} unsupported (reader: {VERSION})")
    return manifest


def _resolve_artifact_dir(directory: str | os.PathLike) -> pathlib.Path:
    """`<dir>`, falling back to `<dir>.old` when a crash mid-re-deploy
    (between save_artifact's two os.replace calls) stranded the previous
    good artifact there — shared by load_artifact and the inspector."""
    directory = pathlib.Path(directory)
    if not (directory / _MANIFEST).exists():
        old = directory.parent / (directory.name + ".old")
        if (old / _MANIFEST).exists():
            return old
    return directory


def load_artifact(
    directory: str | os.PathLike, *, restore_autotune: bool = True
) -> LUTArtifact:
    """Rebuild the model and params from a saved artifact.

    No `like` tree needed: the arch spec is reconstructed from the manifest,
    the param tree structure from `jax.eval_shape` of the rebuilt bundle's
    init, and every leaf is validated (path, shape, dtype) against both the
    manifest and the live model before device_put. A repo drift that changes
    the param tree therefore fails loudly at load, not as NaNs at serve.
    """
    primary = pathlib.Path(directory)
    resolved = _resolve_artifact_dir(primary)
    try:
        return _load_resolved(resolved, restore_autotune=restore_autotune)
    except FileNotFoundError:
        if resolved == primary:
            raise
        # live-deployer race: .old vanished because the re-deploy committed
        # while we were reading it — the new artifact is at <dir> now
        return _load_resolved(primary, restore_autotune=restore_autotune)


def _load_resolved(directory: pathlib.Path, *, restore_autotune: bool) -> LUTArtifact:
    manifest = _read_manifest(directory)

    arch = arch_from_dict(manifest["arch"])
    if manifest["version"] >= 2:
        # the recorded plan must equal what the arch dict resolves to — a
        # hand-edited manifest whose plan and arch disagree would otherwise
        # rebuild a model that silently mismatches the stored tables
        recorded = LUTPlan.from_dict(manifest["plan"])
        if recorded != effective_plan(arch):
            raise ValueError(
                f"{directory}: manifest plan does not match the arch's "
                f"resolved plan — {recorded.describe()} vs "
                f"{effective_plan(arch).describe()}"
            )
    bundle = build_model(arch, Mode(manifest["mode"]))
    if bundle.kind != manifest["kind"]:
        raise ValueError(
            f"rebuilt bundle kind {bundle.kind!r} != manifest {manifest['kind']!r}"
        )

    specs = bundle.param_specs()
    paths = tree_paths(specs)
    spec_leaves = jax.tree_util.tree_leaves(specs)

    recorded = manifest["leaves"]
    leaves = []
    with np.load(directory / _ARRAYS) as data:
        missing = [p for p in paths if p not in recorded or p not in data.files]
        extra = sorted(set(data.files) - set(paths))
        if missing or extra:
            raise ValueError(
                f"artifact/model tree mismatch: missing={missing[:4]} extra={extra[:4]}"
            )
        for p, spec in zip(paths, spec_leaves):
            a = data[p]
            rec = recorded[p]
            if rec["dtype"] == "bfloat16" and a.dtype == np.uint16:
                a = a.view(_BF16)                    # undo the npz bf16 detour
            if list(a.shape) != rec["shape"] or str(a.dtype) != rec["dtype"]:
                raise ValueError(f"{p}: stored {a.shape}/{a.dtype} != manifest {rec}")
            if a.shape != spec.shape or a.dtype != spec.dtype:
                raise ValueError(
                    f"{p}: artifact {a.shape}/{a.dtype} != model {spec.shape}/{spec.dtype}"
                )
            leaves.append(a)
    params = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(specs), leaves
    )
    # commit leaves to device now — host numpy leaves would be re-uploaded
    # on every engine forward (a mesh-constructed engine re-places them
    # under its sharding specs; that device->device move is cheap)
    params = jax.tree.map(jax.device_put, params)

    if restore_autotune:
        restore_autotune_snapshot(directory)
    return LUTArtifact(bundle=bundle, params=params, manifest=manifest,
                       path=directory)


def restore_autotune_snapshot(directory: str | os.PathLike) -> int:
    """Merge the artifact's autotune winners into the process cache.

    Precedence is measured > snapshot > analytic (DESIGN.md §13.3): a
    snapshot entry fills a hole, and a *measured* snapshot entry (wall-clock
    timed on real hardware at deploy time, `measured: true`) additionally
    replaces a live analytic projection — but never a live measured winner.
    Returns the number of entries merged. Persistence failures are
    swallowed — the snapshot is an optimization, never a load dependency.
    """
    path = pathlib.Path(directory) / _AUTOTUNE
    cache = autotune.get_cache()
    merged = 0
    try:
        raw = json.loads(path.read_text())
        entries = raw["entries"] if raw.get("version") == 1 else {}
        for key, rec in entries.items():
            have = cache.get(key)
            if have is None or (
                isinstance(rec, dict) and rec.get("measured")
                and not have.get("measured")
            ):
                cache.put(key, dict(rec))
                merged += 1
    except (OSError, ValueError, KeyError, TypeError, AttributeError):
        return merged                    # malformed snapshot: never fatal
    if merged:
        try:
            cache.save()
        except OSError:
            pass
    return merged


def describe_artifact(directory: str | os.PathLike) -> str:
    """Human-readable artifact summary (the `python -m repro.serving.artifact
    <dir>` inspector): arch, plan, recipe provenance, leaf accounting."""
    directory = _resolve_artifact_dir(directory)
    manifest = _read_manifest(directory)
    arch = arch_from_dict(manifest["arch"])
    leaves = manifest["leaves"]
    n_bytes = sum(
        int(np.prod(rec["shape"] or [1])) * np.dtype(
            np.uint16 if rec["dtype"] == "bfloat16" else rec["dtype"]
        ).itemsize
        for rec in leaves.values()
    )
    lines = [
        f"LUTArtifact at {directory}",
        f"  format    : {manifest['format']} v{manifest['version']}",
        f"  arch      : {arch.name} ({arch.family}, {arch.n_layers}L, "
        f"d={arch.d_model}, vocab={arch.vocab})",
        f"  mode/kind : {manifest['mode']} / {manifest['kind']}",
        f"  plan      : {effective_plan(arch).describe()}"
        if manifest["version"] >= 2 else "  plan      : (v1: legacy policy)",
        f"  leaves    : {len(leaves)} arrays, {n_bytes/1e6:.2f} MB",
    ]
    int8 = sum(1 for r in leaves.values() if r["dtype"] == "int8")
    if int8:
        lines.append(f"  int8 LUTs : {int8} table leaves")
    recipe = manifest.get("recipe")
    if recipe is not None:
        stages = " -> ".join(s.get("name", s.get("stage", "?"))
                             for s in recipe.get("stages", []))
        lines.append(f"  recipe    : {stages}")
    else:
        lines.append("  recipe    : (none recorded)")
    return "\n".join(lines)


def _main(argv: list[str] | None = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.serving.artifact",
        description="Inspect a LUTArtifact directory.",
    )
    ap.add_argument("directory", help="artifact directory to describe")
    ap.add_argument("--json", action="store_true",
                    help="dump the raw manifest JSON instead")
    args = ap.parse_args(argv)
    if args.json:
        print(json.dumps(_read_manifest(_resolve_artifact_dir(args.directory)),
                         indent=2))
    else:
        print(describe_artifact(args.directory))


if __name__ == "__main__":
    _main()

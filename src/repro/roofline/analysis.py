"""Three-term roofline analysis from compiled XLA artifacts (no hardware).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = wire_bytes_per_device / ICI_bw

`cost_analysis()` on a compiled executable is already per-device (post-SPMD
partitioning — verified empirically: a 16-device sharded matmul reports
1/16th of the global FLOPs). Collective wire bytes are parsed from the
optimized per-device HLO with the standard ring cost model:

  all-reduce        2 x input bytes   (reduce-scatter + all-gather phases)
  all-gather        (n-1)/n x output  ~ output bytes
  reduce-scatter    (n-1)/n x input   ~ input bytes
  all-to-all        (n-1)/n x input   ~ input bytes
  collective-permute  input bytes

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (we charge one link; multi-link overlap is an upside not claimed here).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.M,
)


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Wire bytes per device by collective kind, from optimized HLO text."""
    out: dict[str, float] = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start() : hlo_text.find("\n", m.start())]
        if "-done(" in line:
            continue  # async pair: count the -start only
        b = _shape_bytes(shape_str)
        if kind == "all-reduce":
            b *= 2.0
        out[kind] = out.get(kind, 0.0) + b
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                  # per-device HLO flops
    hbm_bytes: float              # per-device bytes accessed
    coll_bytes: float             # per-device wire bytes
    coll_by_kind: dict[str, float]
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "collective_by_kind": self.coll_by_kind,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def analyze_compiled(compiled) -> Roofline:
    """Trip-count-aware roofline terms from the optimized per-device HLO.

    NOTE: `compiled.cost_analysis()` counts while bodies once (verified:
    a scan of 8 matmuls reports the flops of 1), so all terms here come
    from repro.roofline.hlo_cost, which multiplies loop bodies by XLA's
    known_trip_count annotation. cost_analysis values are kept only as a
    cross-check lower bound.
    """
    from repro.roofline.hlo_cost import analyze_hlo_text

    cost = analyze_hlo_text(compiled.as_text())
    return Roofline(
        flops=cost.flops,
        hbm_bytes=cost.bytes,
        coll_bytes=cost.coll_bytes,
        coll_by_kind=dict(cost.coll),
    )


def model_flops_train(n_params: int, tokens: int) -> float:
    """Dense-equivalent useful FLOPs: 6 * N * D (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params * tokens


def model_flops_decode(n_params: int, tokens: int) -> float:
    return 2.0 * n_params * tokens


def memory_stats(compiled) -> dict[str, float]:
    ma = compiled.memory_analysis()
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    out = {k: float(getattr(ma, k, 0)) for k in keys}
    out["total_hbm_bytes"] = (
        out["argument_size_in_bytes"]
        + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"]
        - out["alias_size_in_bytes"]
    )
    return out

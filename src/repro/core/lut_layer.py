"""Initializers / converters for LUT linear layers.

A "linear site" anywhere in a model is a dict pytree; these helpers create it
in each of the three lifecycle stages:

  dense weights --(collect activations, k-means, Eq.1)--> soft-PQ trainable
  soft-PQ trainable --(build + int8-quantize table, Eq.3)--> deployed LUT
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import kmeans, pq, quant
from repro.core.amm import LUTConfig
from repro.core.temperature import init_log_temperature


def init_dense(key: jax.Array, d: int, m: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None) -> dict[str, Any]:
    """He/LeCun-style init for the dense baseline."""
    s = scale if scale is not None else 1.0 / (d ** 0.5)
    p = {"w": (jax.random.normal(key, (d, m), jnp.float32) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((m,), dtype)
    return p


def lut_train_params_from_dense(
    key: jax.Array,
    dense_params: dict[str, Any],
    acts: jax.Array,
    cfg: LUTConfig,
    *,
    kmeans_iters: int = 25,
) -> tuple[dict[str, Any], dict[str, Any]]:
    """k-means-initialize soft-PQ params from a dense layer + activation samples.

    acts: (N, D) sampled inputs of this layer under the original model
    (paper section 6.1: 1024 samples through the trained network).
    Returns (trainable, frozen) param subtrees.
    """
    d = dense_params["w"].shape[0]
    centroids = kmeans.kmeans_per_codebook(
        key, acts.reshape(-1, d), k=cfg.k, v=cfg.v, iters=kmeans_iters
    )
    trainable = {"centroids": centroids, "log_t": init_log_temperature()}
    frozen = dict(dense_params)
    return trainable, frozen


def deploy_params(
    trainable: dict[str, Any], frozen: dict[str, Any], cfg: LUTConfig
) -> dict[str, Any]:
    """Materialize the inference LUT: int8 table + scales (drops the weight)."""
    table = pq.build_table(trainable["centroids"], frozen["w"], stop_weight_grad=False)
    # int8_dot and the Pallas kernels (v2 and the fused decode kernel) all
    # want the m-shared (1,1,M) scale layout: it factors out of the codebook
    # sum, so the kernel accumulates raw int32 — exact integer arithmetic,
    # which is what makes v2 and fused byte-identical — and dequantizes once
    # per output tile (DESIGN.md §2.3, §13.1).
    qt = quant.quantize_table(
        table, bits=cfg.bits, per_column=cfg.per_column,
        m_shared=cfg.int8_dot or cfg.use_kernel,
    )
    out = {
        "centroids": trainable["centroids"].astype(jnp.float32),
        "table_q": qt.q,
        "table_scale": qt.scale,
    }
    if "b" in frozen:
        out["b"] = frozen["b"]
    return out


def deploy_param_specs(d: int, m: int, cfg: LUTConfig, *, bias: bool = False) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the deployed LUT params (dry-run use)."""
    c = cfg.codebooks(d)
    if cfg.int8_dot or cfg.use_kernel:
        s_shape = (1, 1, m)
    elif cfg.per_column:
        s_shape = (c, 1, m)
    else:
        s_shape = (c, 1, 1)
    specs = {
        "centroids": jax.ShapeDtypeStruct((c, cfg.k, cfg.v), jnp.float32),
        "table_q": jax.ShapeDtypeStruct((c, cfg.k, m), jnp.int8),
        "table_scale": jax.ShapeDtypeStruct(s_shape, jnp.float32),
    }
    if bias:
        specs["b"] = jax.ShapeDtypeStruct((m,), jnp.float32)
    return specs

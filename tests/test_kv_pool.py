"""Paged KV cache (DESIGN.md §12): pool bookkeeping invariants, byte-exact
engine parity paged vs dense (with and without prefix sharing), copy-on-write,
shed-on-exhaustion (never an exception), submit-time capacity checks in
page-pool terms, hybrid/encdec paged paths, and fp8 KV storage."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.models.attention import GARBAGE_PAGE, PagedSpec
from repro.serving.engine import ServingEngine
from repro.serving.kv_pool import KVPagePool
import repro.models.encdec as ed


def _small_bundle(key, n_layers=2):
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=n_layers)
    bundle = build_model(arch, Mode.DENSE)
    return bundle, bundle.init(key)


def _run(eng, prompts, max_tokens=5):
    for p in prompts:
        eng.submit(list(p), max_tokens=max_tokens)
    done = sorted(eng.run_until_done(), key=lambda r: r.rid)
    return [(r.rid, r.status, r.out_tokens) for r in done]


# ---------------------------------------------------------------- pool unit
def test_pool_alloc_free_refcount():
    pool = KVPagePool(5, 8)
    assert pool.n_allocatable == 4 and pool.n_free == 4
    pages = [pool.alloc() for _ in range(4)]
    assert GARBAGE_PAGE not in pages          # page 0 reserved for the kernel
    assert sorted(pages) == [1, 2, 3, 4]
    assert pool.alloc() is None               # exhausted: None, never raises
    assert pool.counters["alloc_failures"] == 1

    pool.ref(pages[0])                        # second mapper
    assert pool.n_shared == 1
    pool.unref(pages[0])
    assert pool.n_shared == 0
    for p in pages:
        pool.unref(p)
    assert pool.n_free == 4 and pool.n_resident == 0
    with pytest.raises(ValueError):
        pool.unref(pages[0])                  # double free
    with pytest.raises(ValueError):
        pool.ref(GARBAGE_PAGE)


def test_pool_prefix_register_lookup_evict():
    pool = KVPagePool(4, 2)                   # 3 allocatable pages
    a, b = pool.alloc(), pool.alloc()
    assert pool.register_prefix((1, 2), a)
    assert pool.register_prefix((1, 2, 3, 4), b)
    assert not pool.register_prefix((1, 2), 99)     # first writer wins
    assert not pool.register_prefix((9, 9), a)      # page keeps its one key

    hit = pool.lookup_prefix([1, 2, 3, 4, 5])       # longest chain, ref'd
    assert hit == [a, b]
    assert pool.refcount[a] == 2 and pool.refcount[b] == 2
    assert pool.lookup_prefix([7, 7, 7]) == []
    assert pool.counters["prefix_hits"] == 2

    # retire both holders: registered pages become evictable, not free
    for p in (a, b, a, b):
        pool.unref(p)
    assert pool.n_cached == 2 and pool.n_free == 1
    # allocation prefers the free list, then evicts oldest-registered first
    c = pool.alloc()
    assert c not in (a, b)
    assert pool.alloc() == a                  # LRU eviction unregisters it
    assert pool.counters["prefix_evictions"] == 1
    assert pool.lookup_prefix([1, 2, 9]) == []      # key is gone
    assert not pool.needs_cow(a)              # exclusively owned again
    assert pool.needs_cow(b)                  # still registered


def test_pool_prefix_sharing_disabled():
    pool = KVPagePool(4, 2, prefix_sharing=False)
    p = pool.alloc()
    assert not pool.register_prefix((1, 2), p)
    assert pool.lookup_prefix([1, 2]) == []
    pool.unref(p)
    assert pool.n_cached == 0 and pool.n_free == 3  # straight back to free


# ------------------------------------------------------- engine byte parity
@pytest.mark.parametrize("sharing", [True, False])
def test_paged_engine_matches_dense(key, sharing):
    """Paged tokens are byte-identical to the dense engine — the paged
    gather reproduces the dense (B, S) cache layout exactly, so logits
    match bit for bit. Mixed prompt lengths cross page boundaries, repeat
    a prompt (prefix hit when sharing), and chunk the long one."""
    bundle, params = _small_bundle(key)
    prompts = [[3, 5, 7], [11, 13, 17, 19, 23, 29, 31, 37, 41],
               [2, 4, 6, 8, 10, 12], [3, 5, 7], [1, 2, 3, 4, 5, 6, 7, 8],
               [11, 13, 17, 19, 23, 29, 31, 37, 41]]   # full-page prefix repeat
    dense = ServingEngine(bundle, params, n_slots=3, max_seq=64,
                          prefill_chunk=8, autotune_lut=False)
    paged = ServingEngine(bundle, params, n_slots=3, max_seq=64,
                          prefill_chunk=8, autotune_lut=False,
                          paged=True, page_size=8, prefix_sharing=sharing)
    assert _run(dense, prompts) == _run(paged, prompts)
    st = paged.stats()
    if sharing:
        assert st["prefill_tokens_skipped"] > 0, st
    else:
        assert st["prefill_tokens_skipped"] == 0
        assert st["prefix_hits"] == 0


def test_prefix_sharing_skips_prefill_forwards(key):
    """Requests sharing a long page-aligned prefix must skip its prefill
    chunks entirely: fewer prefill forwards AND fewer prefill tokens than
    the no-sharing engine, with identical tokens out."""
    bundle, params = _small_bundle(key)
    system = list(range(1, 25))               # 24 tokens = 3 pages of 8
    prompts = [system + [100 + i] for i in range(4)]
    kw = dict(n_slots=1, max_seq=64, prefill_chunk=8, autotune_lut=False,
              paged=True, page_size=8)
    cold = ServingEngine(bundle, params, prefix_sharing=False, **kw)
    warm = ServingEngine(bundle, params, prefix_sharing=True, **kw)
    assert _run(cold, prompts) == _run(warm, prompts)
    sc, sw = cold.stats(), warm.stats()
    assert sw["prefill_tokens_skipped"] == 3 * 24       # all but the first
    assert sw["prefill_forwards"] < sc["prefill_forwards"]
    assert sw["prefill_tokens"] < sc["prefill_tokens"]
    # the shared pages stay resident at refcount 0 between requests
    assert sw["kv_pages_cached"] >= 3


def test_fully_cached_prompt_triggers_cow(key):
    """A page-aligned prompt resubmitted verbatim is fully covered by the
    prefix cache; the clamped final token must copy-on-write the shared
    last page before its KV write — and the tokens still match dense."""
    bundle, params = _small_bundle(key)
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8], [1, 2, 3, 4, 5, 6, 7, 8]]
    dense = ServingEngine(bundle, params, n_slots=1, max_seq=32,
                          prefill_chunk=8, autotune_lut=False)
    paged = ServingEngine(bundle, params, n_slots=1, max_seq=32,
                          prefill_chunk=8, autotune_lut=False,
                          paged=True, page_size=8)
    assert _run(dense, prompts) == _run(paged, prompts)
    st = paged.stats()
    assert st["cow_copies"] >= 1, st
    assert st["prefill_tokens_skipped"] == 7  # clamped to len(prompt)-1


def test_pool_exhaustion_sheds_never_raises(key):
    """Overcommitted pool (5 requests x 41 positions into 4 pages x 8):
    step() must never raise — victims retire with a clean "shed" status and
    exactly one survivor completes "ok"."""
    bundle, params = _small_bundle(key)
    eng = ServingEngine(bundle, params, n_slots=4, max_seq=64,
                        prefill_chunk=8, autotune_lut=False,
                        paged=True, page_size=8, n_pages=5)
    for i in range(5):
        eng.submit([10 + i] * 11, max_tokens=30)
    done = eng.run_until_done()
    statuses = sorted(r.status for r in done)
    assert statuses == ["ok", "shed", "shed", "shed", "shed"], statuses
    ok = next(r for r in done if r.status == "ok")
    assert len(ok.out_tokens) > 0
    st = eng.stats()
    assert st["shed"] == 4 and st["completed"] == 1
    assert st["kv_pages_peak"] <= st["kv_pages_total"]


def test_submit_capacity_checks_paged(key):
    """Capacity checks speak PAGE-POOL terms (the bug fix): a prompt that
    could never hold enough pages is rejected at submit, and max_tokens is
    capped so a lone request completes without shedding itself."""
    bundle, params = _small_bundle(key, n_layers=1)
    eng = ServingEngine(bundle, params, n_slots=1, max_seq=64,
                        prefill_chunk=8, autotune_lut=False,
                        paged=True, page_size=8, n_pages=4)  # 3 allocatable
    with pytest.raises(ValueError, match="pages"):
        eng.submit(list(range(25)), max_tokens=1)   # needs 4 pages > 3
    # boundary: exactly 3 pages of prompt is admissible
    rid = eng.submit(list(range(24)), max_tokens=50)
    done = eng.run_until_done()
    req = next(r for r in done if r.rid == rid)
    assert req.status == "ok"
    # positions capped at 3*8=24: prompt 24 + (max_tokens-1) <= 24
    assert len(req.out_tokens) == 1
    assert eng.stats()["shed"] == 0


def test_paged_engine_hybrid(key):
    """Hybrid (shared-attn + mamba) engine: attention pools page, SSM/conv
    state stays per-slot — tokens must match the dense engine. Mamba needs
    chunk-aligned prompts (engine limitation). Prefix sharing must be
    auto-disabled: skipping a prefill chunk would also skip the per-slot
    SSM/conv state updates for those tokens, which pages cannot carry."""
    arch = reduce_arch(get_arch("zamba2_1p2b"), n_layers=2)
    bundle = build_model(arch, Mode.DENSE)
    params = bundle.init(key)
    prompts = [list(range(1, 9)), list(range(3, 7)), list(range(1, 9))]
    dense = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                          prefill_chunk=4, autotune_lut=False)
    paged = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                          prefill_chunk=4, autotune_lut=False,
                          paged=True, page_size=4)
    assert not paged.pool.prefix_sharing          # auto-disabled for hybrid
    assert _run(dense, prompts, max_tokens=4) == _run(paged, prompts, max_tokens=4)
    assert paged.stats()["prefill_tokens_skipped"] == 0


def test_paged_decode_encdec(key):
    """Whisper decoder: self-attn cache pages, cross-attn cache stays dense
    (it is written once at cache_len==0 and never grows). Model-level
    decode parity against the full-sequence forward."""
    arch = reduce_arch(get_arch("whisper_tiny"))
    m = build_model(arch, Mode.DENSE)
    params = m.init(key)
    B, S, S_pre = 2, 8, 5
    page_size = 4
    toks = jax.random.randint(key, (B, S), 0, arch.vocab)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    frames = jax.random.normal(key, (B, arch.enc_frames, arch.d_model))
    enc_out = ed.encode(m.cfg, params, frames, compute_dtype=jnp.float32)
    full, _ = ed.decode(
        m.cfg, params, tokens=toks, pos=pos, enc_out=enc_out,
        compute_dtype=jnp.float32,
    )

    n_tables = S // page_size
    spec = PagedSpec(n_pages=B * n_tables + 1, page_size=page_size)
    caches = m.init_caches(B, S, dtype=jnp.float32, paged=spec)
    # dense-equivalent block tables: row b owns pages 1+b*P .. (b+1)*P
    bt = jnp.asarray(
        [[1 + b * n_tables + p for p in range(n_tables)] for b in range(B)],
        jnp.int32,
    )
    tol = dict(rtol=5e-3, atol=5e-3)
    batch = {"tokens": toks[:, :S_pre], "cache_len": jnp.zeros((B,), jnp.int32),
             "frames": frames, "block_tables": bt}
    lg, caches = m.forward_step(params, batch, caches, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, :S_pre]), **tol)
    for i in range(S_pre, S):
        lg, caches = m.forward_step(
            params, {"tokens": toks[:, i : i + 1],
                     "cache_len": jnp.full((B,), i, jnp.int32),
                     "block_tables": bt},
            caches, compute_dtype=jnp.float32,
        )
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, i]), **tol)


# ------------------------------------------------------------------ fp8 KV
def test_fp8_kv_engine_parity_dense_vs_paged(key):
    """fp8 KV storage (attention upcasts at the dot): the dense and paged
    engines quantize identically, so their tokens stay byte-identical."""
    bundle, params = _small_bundle(key)
    prompts = [[3, 5, 7, 9, 11], [2, 4, 6], [3, 5, 7, 9, 11]]
    dense = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                          prefill_chunk=4, autotune_lut=False,
                          kv_dtype="float8_e4m3fn")
    paged = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                          prefill_chunk=4, autotune_lut=False,
                          paged=True, page_size=4, kv_dtype="float8_e4m3fn")
    for eng, leaf_name in ((dense, "k"), (paged, "k_pool")):
        leaves = jax.tree_util.tree_flatten_with_path(eng.caches)[0]
        kv = [l for p, l in leaves
              if getattr(p[-1], "key", None) in (leaf_name, "v", "v_pool")]
        assert kv and all(l.dtype == jnp.float8_e4m3fn for l in kv)
    assert _run(dense, prompts, max_tokens=4) == _run(paged, prompts, max_tokens=4)


def test_fp8_kv_decode_close_to_f32(key):
    """fp8 KV decode must stay CLOSE to the f32-cache decode (quantization
    noise only) — backs the attention.py claim that K/V are upcast at use,
    not accumulated in 8 bits."""
    bundle, params = _small_bundle(key, n_layers=1)
    prompt = [3, 5, 7, 9, 11, 13]

    def greedy_logits(kv_dtype):
        caches = bundle.init_caches(1, 32, dtype=kv_dtype)
        toks = jnp.asarray([prompt], jnp.int32)
        lg, caches = bundle.forward_step(
            params, {"tokens": toks, "cache_len": jnp.zeros((1,), jnp.int32)},
            caches, compute_dtype=jnp.float32,
        )
        out = [lg[0, len(prompt) - 1]]
        for i in range(3):
            lg, caches = bundle.forward_step(
                params,
                {"tokens": jnp.asarray([[1 + i]], jnp.int32),
                 "cache_len": jnp.full((1,), len(prompt) + i, jnp.int32)},
                caches, compute_dtype=jnp.float32,
            )
            out.append(lg[0, 0])
        return jnp.stack(out)

    ref = greedy_logits(jnp.float32)
    fp8 = greedy_logits(jnp.float8_e4m3fn)
    assert jnp.isfinite(fp8).all()
    # fp8 mantissa is 3 bits → expect percent-level drift, not garbage
    err = jnp.abs(fp8 - ref).max() / (jnp.abs(ref).max() + 1e-6)
    assert float(err) < 0.15, float(err)

"""Deterministic synthetic data with real statistical structure.

Three generators sized so the paper's relative accuracy claims can be
reproduced on one CPU core (DESIGN.md section 7.2):

  * markov_lm     — token streams from a random sparse Markov chain: a real
                    next-token-prediction task an LM can learn (loss drops
                    well below uniform entropy).
  * clustered_classification — mixture-of-Gaussians features pushed through
                    a frozen random teacher MLP. Features cluster exactly the
                    way PQ assumes (paper section 1: "features of different
                    inputs have semantic similarity"), so LUT-vs-dense
                    accuracy deltas are meaningful.
  * clustered_regression — same features, scalar target (UTKFace-MAE
                    analogue, paper Table 4).

Everything is keyed by (seed, step) so any shard of any batch is
reproducible from metadata alone — the restart path in the trainer relies
on this instead of checkpointing the iterator.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MarkovLM:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    branching: int = 8          # successors per token: lower = more learnable

    def _transitions(self) -> jax.Array:
        key = jax.random.PRNGKey(self.seed)
        succ = jax.random.randint(key, (self.vocab, self.branching), 0, self.vocab)
        return succ

    def batch_at(self, step: int) -> dict[str, jax.Array]:
        succ = self._transitions()
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)
        k0, k1 = jax.random.split(key)
        start = jax.random.randint(k0, (self.batch,), 0, self.vocab)
        choice = jax.random.randint(k1, (self.batch, self.seq_len), 0, self.branching)

        def walk(tok, ch):
            nxt = succ[tok, ch]
            return nxt, nxt

        _, seq = jax.lax.scan(
            lambda t, c: walk(t, c), start, choice.T
        )
        seq = seq.T                                              # (B, S)
        tokens = jnp.concatenate([start[:, None], seq[:, :-1]], axis=1)
        return {"tokens": tokens.astype(jnp.int32), "labels": seq.astype(jnp.int32)}

    def __iter__(self) -> Iterator[dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class ClusteredTask:
    """Mixture-of-Gaussians features -> frozen teacher MLP -> labels."""

    d_in: int = 64
    n_classes: int = 10
    n_clusters: int = 40
    cluster_std: float = 0.35
    teacher_width: int = 128
    seed: int = 0
    regression: bool = False

    def _teacher(self):
        key = jax.random.PRNGKey(self.seed)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        centers = jax.random.normal(k1, (self.n_clusters, self.d_in))
        w1 = jax.random.normal(k2, (self.d_in, self.teacher_width)) / self.d_in**0.5
        out_dim = 1 if self.regression else self.n_classes
        w2 = jax.random.normal(k3, (self.teacher_width, out_dim)) / self.teacher_width**0.5
        return centers, w1, w2

    def sample(self, step: int, batch: int) -> dict[str, jax.Array]:
        centers, w1, w2 = self._teacher()
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 7), step)
        kc, kn = jax.random.split(key)
        cid = jax.random.randint(kc, (batch,), 0, self.n_clusters)
        x = centers[cid] + self.cluster_std * jax.random.normal(kn, (batch, self.d_in))
        h = jnp.tanh(x @ w1) @ w2
        if self.regression:
            return {"x": x, "y": h[:, 0]}
        return {"x": x, "y": jnp.argmax(h, axis=-1).astype(jnp.int32)}


def host_shard(batch: dict, process_index: int, process_count: int) -> dict:
    """Slice the global batch to this host's rows (multi-host data loading)."""
    def cut(a):
        if a.ndim == 0:
            return a
        per = a.shape[0] // process_count
        return a[process_index * per : (process_index + 1) * per]

    return jax.tree.map(cut, batch)

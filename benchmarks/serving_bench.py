"""Serving-engine throughput: decode tok/s, prefill tok/s, and batch
occupancy at two request loads (under-subscribed and over-subscribed slot
pool), through the LUT_INFER int8-table model.

A warm-up request compiles the engine's two token shapes off the clock, so
the rows measure steady-state scheduler throughput, not jit. With
`json_path` set (benchmarks/run.py --json) the rows are written to
BENCH_serving.json so serving perf joins the BENCH_kernels.json trajectory.
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.engine import ServingEngine

N_SLOTS = 4
MAX_SEQ = 64
PREFILL_CHUNK = 8
MAX_TOKENS = 8
# loads: half the slot pool (occupancy-starved) vs 3x the pool (saturated,
# requests queue behind busy slots)
LOADS = [("light_2req", 2), ("heavy_12req", 12)]


def _run_load(bundle, params, n_requests: int) -> dict:
    eng = ServingEngine(
        bundle, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
        prefill_chunk=PREFILL_CHUNK, compute_dtype=jnp.float32,
        autotune_lut=False,
    )
    # warm-up: compile the chunked-prefill and decode shapes off the clock
    eng.submit(list(range(1, PREFILL_CHUNK + 2)), max_tokens=2)
    eng.run_until_done()
    eng.finished.clear()
    eng.reset_stats()

    key = jax.random.PRNGKey(2)
    t0 = time.perf_counter()
    for i in range(n_requests):
        key, k = jax.random.split(key)
        plen = int(jax.random.randint(k, (), 3, 2 * PREFILL_CHUNK))
        eng.submit([(i * 7 + j) % 256 + 1 for j in range(plen)],
                   max_tokens=MAX_TOKENS)
    done = eng.run_until_done(max_steps=10_000)
    wall_s = max(time.perf_counter() - t0, 1e-9)
    assert len(done) == n_requests, (len(done), n_requests)

    st = eng.stats()
    return {
        "requests": n_requests,
        "n_slots": N_SLOTS,
        "prefill_chunk": PREFILL_CHUNK,
        "steps": st["steps"],
        "prefill_tokens": st["prefill_tokens"],
        "prefill_forwards": st["prefill_forwards"],
        "prefill_tok_s": round(st["prefill_tok_s"], 1),
        "decode_tokens": st["decode_tokens"],
        "decode_forwards": st["decode_forwards"],
        "decode_tok_s": round(st["decode_tok_s"], 1),
        "decode_occupancy": round(st["decode_occupancy"], 3),
        "shape_cache_hits": st["shape_cache_hits"],
        "wall_s": round(wall_s, 3),
    }


def main(json_path: str | pathlib.Path | None = None) -> list[dict]:
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
    bundle = build_model(arch, Mode.LUT_INFER)
    params = bundle.init(jax.random.PRNGKey(0))

    rows = []
    cols = ["load", "requests", "decode_tok_s", "prefill_tok_s",
            "decode_occupancy", "steps", "shape_cache_hits"]
    print(",".join(cols))
    for load, n in LOADS:
        row = {"load": load, **_run_load(bundle, params, n)}
        rows.append(row)
        print(",".join(str(row[c]) for c in cols))

    if json_path is not None:
        payload = {
            "schema": "serving_bench.v1",
            "arch": "qwen3_1p7b(reduced,L=2)",
            "mode": "lut_infer",
            "backend": jax.default_backend(),
            "rows": rows,
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=1))
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    _JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"
    main(json_path=_JSON if "--json" in sys.argv else None)

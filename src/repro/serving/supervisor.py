"""Supervised serving: the engine in a worker process, restarted from the
LUTArtifact on crash (DESIGN.md §11.4).

A library-loop engine dies with its process; the artifact + Recipe work
only pays off if a deployed LUT model stays up. `EngineSupervisor` puts the
`ServingEngine` in a child process (spawn — a fresh interpreter, so a
corrupted JAX runtime never survives a restart) built entirely from a
`LUTArtifact` path, and supervises it:

  * **Restart on crash** — any worker death (step exception that exhausts
    the in-worker `StepGuard` retries, an `InjectedKill`, a real segfault)
    is followed by a respawn from the artifact, delayed by capped
    exponential backoff (`distributed.fault_tolerance.Backoff`). A worker
    that stayed healthy for `healthy_after_s` resets the consecutive-crash
    counter; `max_restarts` consecutive crashes mark the supervisor failed
    and resolve every live request as "error" — nothing hangs forever.
  * **Requeue with a retry budget** — requests that were inside the dead
    worker are re-submitted to the fresh one (generation restarts from
    scratch; subscribers get a `("restart", None)` event so streams can
    discard partial output — deterministic per-request sampling makes the
    replay token-identical). Each requeue spends one unit of the request's
    `retry_budget`; past it the request resolves as "error" ("lost").
    Deadlines are absolute: a requeued request carries only its *remaining*
    deadline, and one that expired while the worker was down resolves as
    "timeout" without ever being resent.
  * **Fault injection** — a `faults.FaultSpec` is shipped (as a dict) to
    the worker, which wires a `FaultInjector` into its engine. By default
    (`faults_once=True`) only the FIRST worker incarnation gets the spec, so
    "kill at step 7" tests recovery instead of a crash loop.

The parent-side object implements the same backend interface as
`server.EnginePump` (submit/cancel/stats/pending/healthy/close/
abort_pending), so `server.FrontEnd` serves a supervised engine unchanged.
All parent bookkeeping lives behind one re-entrant lock; a single monitor
thread owns the worker pipe.
"""

from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import threading
import time
from typing import Any, Callable

from repro.distributed.fault_tolerance import Backoff, StepGuard
from repro.serving.engine import validate_spec

KILL_EXIT = 43               # worker exit code for an InjectedKill hard crash
_STATS_PERIOD_S = 0.25


# ---------------------------------------------------------------------------
# worker side
# ---------------------------------------------------------------------------

def _worker_main(
    conn,
    artifact_path: str,
    engine_kwargs: dict[str, Any],
    fault_dict: dict[str, Any] | None,
    step_retries: int,
) -> None:
    """Worker entry point: load the artifact, build the engine, serve the
    pipe. Crashes are the supervisor's problem — this function either runs
    forever or exits the process."""
    from repro.serving.artifact import load_artifact
    from repro.serving.engine import ServingEngine, TokenTap, submit_from_spec
    from repro.serving.faults import FaultInjector, FaultSpec, InjectedKill

    try:
        art = load_artifact(artifact_path)
        injector = (
            FaultInjector(FaultSpec.from_dict(fault_dict)) if fault_dict else None
        )
        # spec-decode handshake: engine_kwargs crossed the pipe JSON-safe,
        # so the draft arrives as a plan NAME resolved here against the
        # same artifact (DESIGN.md §14.3) — restarts reload both plans
        engine_kwargs = dict(engine_kwargs)
        draft_plan = engine_kwargs.pop("draft_plan", None)
        if engine_kwargs.get("spec_decode") and draft_plan is not None:
            draft = load_artifact(artifact_path, plan=draft_plan,
                                  restore_autotune=False)
            engine_kwargs.update(
                draft_bundle=draft.bundle, draft_params=draft.params)
        eng = ServingEngine(
            art.bundle, art.params, autotune_lut=False, faults=injector,
            **engine_kwargs,
        )
        tap = TokenTap(eng, consume=True)
        guard = StepGuard(max_retries=step_retries)
        e2g: dict[int, int] = {}          # engine rid -> supervisor grid
        g2e: dict[int, int] = {}
        conn.send(("ready", eng.stats()))
        last_stats = time.monotonic()
        while True:
            timeout = 0.0 if eng.has_work() else 0.02
            while conn.poll(timeout):
                cmd, payload = conn.recv()
                if cmd == "submit":
                    grid, spec = payload
                    rid = submit_from_spec(eng, spec)
                    e2g[rid] = grid
                    g2e[grid] = rid
                elif cmd == "cancel":
                    rid = g2e.get(payload)
                    if rid is not None:
                        eng.cancel(rid)   # retirement flows back via tap
                elif cmd == "stop":
                    conn.send(("stopped", None))
                    return
                timeout = 0.0
            if eng.has_work():
                # transient step faults retry in-place; exhaustion crashes
                # the worker and the supervisor takes over
                guard.run(eng.step)
            tokens, done = tap.poll()
            for rid, toks in tokens:
                if rid in e2g:
                    conn.send(("tokens", (e2g[rid], toks)))
            for req in done:
                grid = e2g.pop(req.rid, None)
                if grid is not None:
                    g2e.pop(grid, None)
                    conn.send(("done", (grid, req.status, req.out_tokens)))
            now = time.monotonic()
            if tokens or done or now - last_stats > _STATS_PERIOD_S:
                conn.send(("stats", eng.stats()))
                last_stats = now
    except InjectedKill:
        os._exit(KILL_EXIT)              # simulated hard crash: no goodbye
    except BaseException as e:           # noqa: BLE001 — report, then die
        try:
            conn.send(("crash", repr(e)))
        except Exception:                # noqa: BLE001 — pipe may be gone
            pass
        os._exit(1)


# ---------------------------------------------------------------------------
# parent side
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _ReqState:
    grid: int
    spec: dict[str, Any]
    deadline: float | None               # absolute time.monotonic()
    on_event: Callable[[tuple[str, Any]], None] | None
    tokens: list[int] = dataclasses.field(default_factory=list)
    status: str | None = None            # terminal status once done
    retries: int = 0
    in_worker: bool = False              # sent to the CURRENT worker
    done_ev: threading.Event = dataclasses.field(default_factory=threading.Event)

    @property
    def done(self) -> bool:
        return self.status is not None


class EngineSupervisor:
    """Crash-supervised serving backend over a LUTArtifact directory."""

    def __init__(
        self,
        artifact_path: str | os.PathLike,
        *,
        engine_kwargs: dict[str, Any] | None = None,
        faults: Any | None = None,        # faults.FaultSpec
        faults_once: bool = True,
        retry_budget: int = 1,
        max_restarts: int = 3,
        backoff: Backoff = Backoff(base_s=0.05, factor=2.0, cap_s=2.0),
        step_retries: int = 1,
        healthy_after_s: float = 5.0,
        mp_context: str = "spawn",
    ):
        self.artifact_path = str(artifact_path)
        self.engine_kwargs = dict(engine_kwargs or {})
        self.faults = faults
        self.faults_once = faults_once
        self.retry_budget = retry_budget
        self.max_restarts = max_restarts
        self.backoff = backoff
        self.step_retries = step_retries
        self.healthy_after_s = healthy_after_s
        self._ctx = mp.get_context(mp_context)

        self._lock = threading.RLock()
        self._requests: dict[int, _ReqState] = {}
        self._outbox: list[int] = []      # grids not yet sent to any worker
        self._cancelbox: list[int] = []   # grids to cancel in the worker
        self._next_grid = 0
        self._stats: dict[str, Any] = {}
        self._stats_t = time.monotonic()  # when _stats last heard from a worker
        self._last_crash: str | None = None
        self.counters = {"spawns": 0, "restarts": 0, "requeued": 0, "lost": 0}
        self._stop = False
        self._failed = False
        self._ready = threading.Event()   # first worker came up
        self._monitor = threading.Thread(
            target=self._run, name="engine-supervisor", daemon=True
        )
        self._monitor.start()

    # -- backend interface (mirrors server.EnginePump) ---------------------
    @property
    def healthy(self) -> bool:
        return not self._failed and not self._stop

    def wait_ready(self, timeout: float | None = None) -> bool:
        """Block until the first worker is serving (or `timeout`)."""
        return self._ready.wait(timeout)

    def submit(self, spec: dict[str, Any],
               on_event: Callable[[tuple[str, Any]], None] | None = None) -> int:
        # validate BEFORE the pipe hop: a malformed field (non-numeric
        # priority/deadline_s, bad prompt) must surface as a ValueError here
        # — HTTP 400 — not as a worker crash loop on the far side
        validate_spec(spec)
        with self._lock:
            if not self.healthy:
                raise RuntimeError(
                    f"supervisor failed (last crash: {self._last_crash})"
                )
            grid = self._next_grid
            self._next_grid += 1
            deadline_s = spec.get("deadline_s")
            st = _ReqState(
                grid=grid, spec=dict(spec), on_event=on_event,
                deadline=(None if deadline_s is None
                          else time.monotonic() + float(deadline_s)),
            )
            self._requests[grid] = st
            self._outbox.append(grid)
        return grid

    def cancel(self, grid: int) -> bool:
        with self._lock:
            st = self._requests.get(grid)
            if st is None or st.done:
                return False
            if not st.in_worker and grid in self._outbox:
                self._outbox.remove(grid)
                self._finish(st, "cancelled")
            else:
                self._cancelbox.append(grid)
            return True

    def stats(self) -> dict[str, Any]:
        with self._lock:
            s = dict(self._stats)
            s.update(self.counters)
            s["backend"] = "supervised"
            s["pending"] = sum(not r.done for r in self._requests.values())
            s["failed"] = int(self._failed)
            # how stale the worker-reported gauges (queue_depth,
            # active_slots, ...) are — the router's load scorer caps on this
            s["stats_age_s"] = time.monotonic() - self._stats_t
        return s

    def pending(self) -> int:
        with self._lock:
            return sum(not r.done for r in self._requests.values())

    def abort_pending(self) -> int:
        with self._lock:
            live = [r for r in self._requests.values() if not r.done]
            for st in live:
                self._finish(st, "error")
            self._outbox.clear()
            return len(live)

    def wait(self, grid: int, timeout: float | None = None) -> _ReqState:
        """Block until `grid` is terminal; returns its state record."""
        st = self._requests[grid]
        if not st.done_ev.wait(timeout):
            raise TimeoutError(f"request {grid} not terminal after {timeout}s")
        return st

    def results(self) -> dict[int, _ReqState]:
        with self._lock:
            return dict(self._requests)

    def close(self) -> None:
        self._stop = True
        self._monitor.join(timeout=30)

    # -- internals ---------------------------------------------------------
    def _finish(self, st: _ReqState, status: str,
                tokens: list[int] | None = None) -> None:
        if st.done:
            return
        st.status = status
        if tokens is not None:
            st.tokens = list(tokens)
        st.done_ev.set()
        if st.on_event is not None:
            try:
                st.on_event(("done", (status, st.tokens)))
            except Exception:            # noqa: BLE001
                pass

    def _dispatch(self, st: _ReqState, ev: tuple[str, Any]) -> None:
        if st.on_event is not None:
            try:
                st.on_event(ev)
            except Exception:            # noqa: BLE001
                pass

    def _send_request(self, conn, st: _ReqState) -> None:
        """Ship one live request to the current worker, shrinking its
        deadline to the remaining budget (terminal "timeout" if spent)."""
        spec = dict(st.spec)
        if st.deadline is not None:
            remaining = st.deadline - time.monotonic()
            if remaining <= 0:
                self._finish(st, "timeout")
                return
            spec["deadline_s"] = remaining
        conn.send(("submit", (st.grid, spec)))
        st.in_worker = True

    def _on_worker_ready(self, conn, stats: dict[str, Any]) -> None:
        """A (re)started worker is serving: requeue every live request.

        Requests that were inside the dead worker spend one retry; past
        `retry_budget` they resolve as "error" rather than looping forever.
        """
        with self._lock:
            self._stats = stats
            self._stats_t = time.monotonic()
            for grid in sorted(g for g, r in self._requests.items() if not r.done):
                st = self._requests[grid]
                if st.in_worker:          # was lost with the previous worker
                    st.retries += 1
                    if st.retries > self.retry_budget:
                        self.counters["lost"] += 1
                        self._finish(st, "error")
                        continue
                    self.counters["requeued"] += 1
                    if st.tokens:
                        st.tokens = []
                        self._dispatch(st, ("restart", None))
                st.in_worker = False
                self._send_request(conn, st)
            self._outbox.clear()          # everything live was just sent
        self._ready.set()

    def _pump(self, conn) -> None:
        """Send queued submits/cancels to the live worker."""
        with self._lock:
            grids, self._outbox = self._outbox, []
            cancels, self._cancelbox = self._cancelbox, []
            for grid in grids:
                st = self._requests[grid]
                if not st.done:
                    self._send_request(conn, st)
            for grid in cancels:
                st = self._requests[grid]
                if not st.done and st.in_worker:
                    conn.send(("cancel", grid))

    def _handle(self, msg: tuple[str, Any], conn) -> None:
        kind, payload = msg
        if kind == "ready":
            self._on_worker_ready(conn, payload)
        elif kind == "tokens":
            grid, toks = payload
            with self._lock:
                st = self._requests.get(grid)
                if st is not None and not st.done:
                    st.tokens.extend(toks)
                    self._dispatch(st, ("tokens", toks))
        elif kind == "done":
            grid, status, out_tokens = payload
            with self._lock:
                st = self._requests.get(grid)
                if st is not None:
                    self._finish(st, status, out_tokens)
        elif kind == "stats":
            with self._lock:
                self._stats = payload
                self._stats_t = time.monotonic()
        elif kind == "crash":
            self._last_crash = payload

    def _fail_closed(self, reason: str) -> None:
        """Terminal supervisor failure: resolve every live rid as "error",
        refuse new submits, unblock wait_ready — nothing hangs forever."""
        self._last_crash = reason
        with self._lock:
            self._failed = True
            for st in [r for r in self._requests.values() if not r.done]:
                self.counters["lost"] += 1
                self._finish(st, "error")
        self._ready.set()

    def _check_artifact(self) -> str | None:
        """Parent-side serveability probe before every worker (re)spawn.

        A worker built from a vanished or corrupted artifact dies on load,
        restarts, dies again — a crash loop that burns `max_restarts` on a
        condition no respawn can fix (and the multi-replica router multiplies
        how often this path runs). Catch it here and fail closed with an
        actionable error instead. Returns the error string, or None when the
        artifact still looks serveable."""
        from repro.serving.artifact import check_artifact_dir

        try:
            check_artifact_dir(self.artifact_path)
        except (FileNotFoundError, ValueError, OSError) as e:
            return (f"artifact at {self.artifact_path} is not serveable: {e} "
                    f"— refusing to (re)spawn a worker that cannot load it")
        return None

    def _run(self) -> None:
        consecutive = 0
        incarnation = 0
        proc = None
        while not self._stop:
            err = self._check_artifact()
            if err is not None:
                self._fail_closed(err)
                return
            fault_dict = None
            if self.faults is not None and (incarnation == 0 or not self.faults_once):
                fault_dict = self.faults.to_dict()
            parent_conn, child_conn = self._ctx.Pipe()
            proc = self._ctx.Process(
                target=_worker_main,
                args=(child_conn, self.artifact_path, self.engine_kwargs,
                      fault_dict, self.step_retries),
                daemon=True,
            )
            proc.start()
            child_conn.close()
            self.counters["spawns"] += 1
            incarnation += 1
            born = time.monotonic()
            saw_ready = False
            stopped_clean = False
            while not self._stop:
                try:
                    if saw_ready:
                        self._pump(parent_conn)
                    if parent_conn.poll(0.02):
                        msg = parent_conn.recv()
                        if msg[0] == "ready":
                            saw_ready = True
                        elif msg[0] == "stopped":
                            stopped_clean = True
                            break
                        self._handle(msg, parent_conn)
                        continue
                except (EOFError, OSError, BrokenPipeError):
                    break
                if not proc.is_alive():
                    # drain any buffered messages the dying worker flushed
                    try:
                        while parent_conn.poll(0):
                            self._handle(parent_conn.recv(), parent_conn)
                    except (EOFError, OSError, BrokenPipeError):
                        pass
                    break
            if self._stop or stopped_clean:
                self._shutdown_worker(proc, parent_conn)
                break
            # worker died: decide whether (and when) to restart
            alive_for = time.monotonic() - born
            if saw_ready and alive_for >= self.healthy_after_s:
                consecutive = 0
            consecutive += 1
            self.counters["restarts"] += 1
            parent_conn.close()
            if consecutive > self.max_restarts:
                self._fail_closed(
                    self._last_crash
                    or f"{consecutive} consecutive worker crashes "
                       f"(max_restarts={self.max_restarts})"
                )
                return
            time.sleep(self.backoff.delay(consecutive - 1))
        if proc is not None and self._stop:
            self._shutdown_worker(proc, None)

    def _shutdown_worker(self, proc, conn) -> None:
        if conn is not None:
            try:
                conn.send(("stop", None))
            except (OSError, BrokenPipeError):
                pass
        proc.join(timeout=5)
        if proc.is_alive():
            proc.terminate()
            proc.join(timeout=5)
        if conn is not None:
            conn.close()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import maddness, pq


def test_encode_in_range(key):
    acts = np.asarray(jax.random.normal(key, (256, 16)))
    tree = maddness.fit_hash_trees(acts, k=8, v=4)
    idx = maddness.maddness_encode(jnp.asarray(acts), tree, 4)
    assert idx.shape == (256, 4)
    assert int(idx.min()) >= 0 and int(idx.max()) < 8


def test_balanced_split(key):
    """Median thresholds keep buckets roughly balanced on training data."""
    acts = np.asarray(jax.random.normal(key, (512, 8)))
    tree = maddness.fit_hash_trees(acts, k=4, v=8)
    idx = np.asarray(maddness.maddness_encode(jnp.asarray(acts), tree, 8))[:, 0]
    counts = np.bincount(idx, minlength=4)
    assert counts.min() > 512 // 4 // 4  # no bucket starved


def test_hashing_worse_than_kmeans(key):
    """Paper section 2.1/Fig. 3: hashing encodes with HIGHER quantization
    error than k-means distance encoding."""
    from repro.core import kmeans

    k1, k2 = jax.random.split(key)
    centers = jax.random.normal(k1, (8, 16)) * 2
    acts = centers[jax.random.randint(k2, (512,), 0, 8)] + 0.3 * jax.random.normal(k2, (512, 16))
    acts_np = np.asarray(acts)

    tree = maddness.fit_hash_trees(acts_np, k=8, v=4)
    protos = maddness.bucket_prototypes(acts_np, tree, k=8, v=4)
    idx = maddness.maddness_encode(acts, tree, 4)
    rec_h = protos[jnp.arange(4)[None, :], idx]             # (N, C, V)
    err_h = float(jnp.mean((rec_h.reshape(512, 16) - acts) ** 2))

    km = kmeans.kmeans_per_codebook(key, acts, k=8, v=4)
    err_k = float(jnp.mean((pq.pq_reconstruct(acts, km) - acts) ** 2))
    assert err_k < err_h

"""Unit tests for table quantization. Property-based (hypothesis) cases live
in test_quant_properties.py, guarded for environments without hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant


def test_roundtrip_error_bound(key):
    T = jax.random.normal(key, (3, 4, 8)) * 5
    qt = quant.quantize_table(T, bits=8)
    err = jnp.abs(qt.dequant(jnp.float32) - T)
    # symmetric linear quant: |err| <= scale/2 per codebook
    assert bool(jnp.all(err <= qt.scale / 2 + 1e-6))
    assert qt.q.dtype == jnp.int8


def test_int4_range(key):
    T = jax.random.normal(key, (2, 4, 4))
    qt = quant.quantize_table(T, bits=4)
    assert int(jnp.max(jnp.abs(qt.q))) <= 7


def test_per_column_scales_tighter(key):
    """Per-column scales (our beyond-paper variant) never increase error."""
    T = jax.random.normal(key, (2, 8, 16)) * jnp.logspace(-2, 1, 16)[None, None, :]
    e_tab = jnp.mean((quant.quantize_table(T, bits=8).dequant(jnp.float32) - T) ** 2)
    e_col = jnp.mean(
        (quant.quantize_table(T, bits=8, per_column=True).dequant(jnp.float32) - T) ** 2
    )
    assert float(e_col) < float(e_tab)


def test_fake_quant_ste(key):
    T = jax.random.normal(key, (2, 4, 8))
    fq = quant.fake_quant(T, bits=8)
    qt = quant.quantize_table(T, bits=8)
    np.testing.assert_allclose(
        np.asarray(fq), np.asarray(qt.dequant(jnp.float32)), rtol=1e-6, atol=1e-6
    )
    # backward: exact identity (straight-through)
    g = jax.grad(lambda t: jnp.sum(quant.fake_quant(t, bits=8) * 3.0))(T)
    np.testing.assert_allclose(np.asarray(g), 3.0 * np.ones_like(g), rtol=1e-6)

"""Production training loop: checkpoint/restart, straggler + failure policy.

The loop is deliberately plain Python around one jitted step so every
control-plane feature is visible and testable:

  * periodic async checkpoints (params + opt state + step), atomic commit
  * crash/preemption recovery: `resume()` restores the newest committed
    checkpoint and replays the data stream from the step counter
    (deterministic batch_at(step) data makes the restart exact)
  * StepGuard retries transient failures, then falls back to a restore
  * StragglerMonitor flags slow steps (scheduler hook on a real pod)
  * failure injection hook for tests (fail_at / fail_exc)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.distributed.fault_tolerance import HeartbeatFile, StepGuard, StragglerMonitor


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    log_every: int = 10
    max_retries: int = 2
    # per-step restore-and-continue budget: a step that fails this many
    # times without ever completing is a deterministic fault and re-raises
    # (instead of restore -> replay -> fail forever); completing a step
    # resets its budget, so scattered transient faults never accumulate
    max_restores: int = 3
    heartbeat: str | None = None


@dataclasses.dataclass
class Trainer:
    step_fn: Callable            # (params, opt_state, batch) -> (params, opt, metrics)
    batch_at: Callable[[int], Any]
    cfg: TrainerConfig
    fail_at: int | None = None               # test hook: raise at this step
    fail_exc: Exception | None = None
    fail_times: int = 1                      # > max_retries exhausts the StepGuard
    on_checkpoint: Callable[[int], None] | None = None   # after each committed save

    def __post_init__(self):
        self.ckpt = Checkpointer(self.cfg.ckpt_dir, keep_last=self.cfg.keep_last)
        self.monitor = StragglerMonitor()
        self.guard = StepGuard(max_retries=self.cfg.max_retries)
        self.hb = HeartbeatFile(self.cfg.heartbeat) if self.cfg.heartbeat else None
        self.history: list[dict] = []
        self._fail_count = 0
        self._restores_at_step: dict[int, int] = {}

    # ------------------------------------------------------------------
    def resume(self, params: Any, opt_state: Any) -> tuple[int, Any, Any]:
        """Restore the newest committed checkpoint if one exists."""
        self.ckpt.wait()            # an in-flight async save must commit first
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, params, opt_state
        _, tree = self.ckpt.restore({"params": params, "opt": opt_state})
        return latest, tree["params"], tree["opt"]

    # ------------------------------------------------------------------
    def fit(self, params: Any, opt_state: Any, *, start_step: int | None = None):
        step, params, opt_state = (
            (start_step, params, opt_state)
            if start_step is not None
            else self.resume(params, opt_state)
        )
        while step < self.cfg.total_steps:
            batch = self.batch_at(step)
            t0 = time.time()

            def run(step=step, batch=batch, params=params, opt_state=opt_state):
                if self.fail_at == step and self._fail_count < self.fail_times:
                    self._fail_count += 1
                    raise (self.fail_exc or RuntimeError("injected failure"))
                return self.step_fn(params, opt_state, batch)

            try:
                params, opt_state, metrics = self.guard.run(run)
            except RuntimeError:
                # exhausted retries -> restore-and-continue (fault tolerance).
                # With nothing committed there is nothing to restore: falling
                # back to the CURRENT (already-advanced) params at step 0
                # would double-apply updates and loop forever on a
                # persistent failure — re-raise instead. Likewise, a fault
                # that keeps recurring across max_restores restore cycles is
                # deterministic, not transient: re-raise rather than replay
                # the same failing step forever.
                self.ckpt.wait()
                if self.ckpt.latest_step() is None:
                    raise
                n = self._restores_at_step.get(step, 0) + 1
                self._restores_at_step[step] = n
                if n > self.cfg.max_restores:
                    raise
                step, params, opt_state = self.resume(params, opt_state)
                continue

            # this step completed: its restore budget resets (only a step
            # that NEVER completes accumulates toward max_restores)
            self._restores_at_step.pop(step, None)
            dt = time.time() - t0
            slow = self.monitor.record(step, dt)
            rec = {
                "step": step,
                **{k: float(v) for k, v in metrics.items()},
                "seconds": dt,
                "straggler": slow,
            }
            self.history.append(rec)
            if self.hb:
                self.hb.beat(step, loss=rec["loss"])
            if self.cfg.log_every and step % self.cfg.log_every == 0:
                # learned softmax temperature: converges toward 0 (argmax
                # limit) as centroid learning sharpens (paper §3.2)
                temp = (f" t {rec['t_mean']:.3f}/{rec['t_min']:.3f}"
                        if "t_mean" in rec else "")
                kl = f" kl {rec['distill_kl']:.4f}" if "distill_kl" in rec else ""
                print(
                    f"step {step:6d} loss {rec['loss']:.4f} "
                    f"gnorm {rec['grad_norm']:.3f}{temp}{kl} {dt*1e3:.0f}ms"
                    + (" [straggler]" if slow else "")
                )
            step += 1
            if step % self.cfg.ckpt_every == 0 or step == self.cfg.total_steps:
                # on_checkpoint fires on the writer thread post-commit so the
                # loop keeps its async-save property
                self.ckpt.save(step, {"params": params, "opt": opt_state},
                               on_commit=self.on_checkpoint)
        self.ckpt.wait()
        return params, opt_state

"""Sharded checkpointing with async save, atomic commit, and resharding.

Layout (one directory per step):

  <dir>/step_000123/
      manifest.json      tree structure, shapes, dtypes, step metadata
      arrays.npz         flattened leaves keyed by tree path

Production notes:
  * save() snapshots to host (device_get) then writes on a background
    thread — the training loop never blocks on disk.
  * commit is atomic (write to step_xxx.tmp, os.replace) so a crash
    mid-write can never produce a half-readable checkpoint; restore() picks
    the newest *committed* step.
  * restore(..., shardings=...) device_puts each leaf with the *target*
    sharding — this is the elastic-rescale path: a checkpoint written on a
    16x16 mesh restores cleanly onto any other mesh (tests/test_elastic.py).
  * keep_last bounds disk usage.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


def atomic_write_json(path: str | os.PathLike, obj: Any) -> None:
    """Write JSON with the same tmp-then-os.replace discipline as checkpoint
    commits: a crash mid-write can never produce a half-readable file. Used
    by the recipe run manifest (repro.train.recipe) and any other small
    control-plane state that must survive kills."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.parent / (path.name + ".tmp")
    tmp.write_text(json.dumps(obj, indent=2, sort_keys=True))
    os.replace(tmp, path)


def tree_paths(tree: Any) -> list[str]:
    """Slash-joined key path of every leaf, in tree-flatten order — the one
    path convention shared by checkpoints and deployment artifacts."""
    out = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out.append("/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp))
    return out


def flatten_tree(tree: Any) -> dict[str, np.ndarray]:
    """{path: host ndarray} for every leaf (dtype-preserving)."""
    return dict(zip(
        tree_paths(tree),
        (np.asarray(leaf) for leaf in jax.tree_util.tree_leaves(tree)),
    ))


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, *, keep_last: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = False,
             on_commit=None) -> None:
        """Snapshot now, write in the background (unless blocking).

        `on_commit(step)` runs on the writer thread right after the atomic
        commit — manifest-sync hooks piggyback on it without turning the
        training loop's async save synchronous; exceptions are swallowed
        (the hook must never fail a committed checkpoint)."""
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, on_commit), daemon=True
        )
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, on_commit=None) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = flatten_tree(host_tree)
        np.savez(tmp / "arrays.npz", **flat)
        treedef = jax.tree_util.tree_structure(host_tree)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()
            },
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        if on_commit is not None:
            try:
                on_commit(step)
            except Exception:       # noqa: BLE001 — never fail a committed save
                pass
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, like: Any, *, step: int | None = None, shardings: Any | None = None
    ) -> tuple[int, Any]:
        """Restore into the structure of `like`; device_put with `shardings`
        (same tree structure) for elastic remapping onto a new mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with np.load(self.dir / f"step_{step:08d}" / "arrays.npz") as data:
            leaves = [data[p] for p in tree_paths(like)]
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.device_put, tree)
        return step, tree

"""Per-arch reduced-config smoke tests (deliverable f): one forward/train
step on CPU asserting output shapes + no NaNs, plus a serve prefill+decode
in the deployed LUT mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, build_model, get_arch, input_specs, reduce_arch
from repro.core.amm import Mode


def _batch(arch, key, B=2, S=16):
    b = {"labels": jax.random.randint(key, (B, S), 0, arch.vocab)}
    if arch.family == "vlm":
        b["embeds"] = jax.random.normal(key, (B, S, arch.d_model))
        b["pos"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S)
        )
    elif arch.family == "audio":
        b["frames"] = jax.random.normal(key, (B, arch.enc_frames, arch.d_model))
        b["tokens"] = jax.random.randint(key, (B, S), 0, arch.vocab)
    else:
        b["tokens"] = jax.random.randint(key, (B, S), 0, arch.vocab)
    return b


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_smoke(arch_id, key):
    arch = reduce_arch(get_arch(arch_id))
    for mode in (Mode.DENSE, Mode.LUT_TRAIN):
        m = build_model(arch, mode)
        params = m.init(key)
        batch = _batch(arch, key)
        loss, grads = jax.value_and_grad(
            lambda p: m.loss(p, batch, compute_dtype=jnp.float32)
        )(params)
        assert np.isfinite(float(loss)), (arch_id, mode)
        gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert np.isfinite(gsum) and gsum > 0, (arch_id, mode)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_serve_smoke(arch_id, key):
    arch = reduce_arch(get_arch(arch_id))
    m = build_model(arch, Mode.LUT_INFER)
    params = m.init(key)
    B, S_max, S_pre = 2, 24, 8
    caches = m.init_caches(B, S_max, dtype=jnp.float32)
    batch = {"cache_len": jnp.zeros((B,), jnp.int32)}
    if arch.family == "vlm":
        batch["embeds"] = jax.random.normal(key, (B, S_pre, arch.d_model))
    elif arch.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, arch.enc_frames, arch.d_model))
        batch["tokens"] = jax.random.randint(key, (B, S_pre), 0, arch.vocab)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S_pre), 0, arch.vocab)
    logits, caches = m.forward_step(params, batch, caches, compute_dtype=jnp.float32)
    assert logits.shape == (B, S_pre, arch.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    step = {"cache_len": jnp.full((B,), S_pre, jnp.int32)}
    if arch.family == "vlm":
        step["embeds"] = jax.random.normal(key, (B, 1, arch.d_model))
    else:
        step["tokens"] = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    logits2, _ = m.forward_step(params, step, caches, compute_dtype=jnp.float32)
    assert logits2.shape == (B, 1, arch.vocab)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch_id):
    arch = get_arch(arch_id)
    for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        specs = input_specs(arch, shape)
        assert all(hasattr(v, "shape") for v in specs.values())
        if shape == "train_4k":
            assert "labels" in specs


def test_paper_replacement_policy():
    """First layer stays dense (paper section 6.1); BERT: last-6 only."""
    arch = get_arch("llama3_8b")
    m = build_model(arch, Mode.LUT_TRAIN)
    segs = m.cfg.segments
    assert segs[0][0] == 1 and segs[0][1].attn.q.mode == Mode.DENSE
    assert segs[1][0] == arch.n_layers - 1
    assert segs[1][1].attn.q.mode == Mode.LUT_TRAIN

    bert = get_arch("bert_base")
    mb = build_model(bert, Mode.LUT_TRAIN)
    assert mb.cfg.segments[0][0] == 6 and mb.cfg.segments[1][0] == 6
    assert mb.cfg.segments[0][1].attn.q.mode == Mode.DENSE
    assert mb.cfg.segments[1][1].attn.q.mode == Mode.LUT_TRAIN

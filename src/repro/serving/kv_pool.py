"""Host-side page-pool bookkeeping for the paged KV cache (DESIGN.md §12).

The device side is a fixed pool of (n_pages, page_size) K/V pages per
attention layer (models/attention.py paged_init_cache); this module owns
everything the jitted step must NOT see: the free list, per-page
refcounts, the prefix cache that maps page-aligned prompt prefixes onto
already-written pages, and the copy-on-write decision. All methods are
O(pages touched) python — the engine calls them between forwards.

Sharing model:
  - a page is *live* while any request maps it (refcount >= 1);
  - a page whose content is a registered full-page prompt prefix stays
    resident after its last request retires (refcount 0, on the evictable
    LRU) so later requests with the same prefix skip prefill for it;
  - eviction (reclaiming a cached page for a fresh allocation) comes
    before shedding: `alloc` pops the free list first, then the oldest
    evictable page, and only returns None when both are empty — at which
    point the engine sheds a request (never OOMs).

Prefix keys are the literal token-id tuples `prompt[:k*page_size]` — exact
match by construction, no hash-collision risk. Registered pages are
immutable: any write that would land on one (or on a page another request
can see) triggers copy-on-write in the engine, guided by `needs_cow`.

Page 0 is the reserved garbage page (attention.GARBAGE_PAGE): masked
writes in the kernel are routed there, so it is never allocated here.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.models.attention import GARBAGE_PAGE


class KVPagePool:
    def __init__(self, n_pages: int, page_size: int, *, prefix_sharing: bool = True):
        if n_pages < 2:
            raise ValueError(f"n_pages={n_pages}: need >= 2 (page 0 is reserved)")
        if page_size < 1:
            raise ValueError(f"page_size={page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.prefix_sharing = bool(prefix_sharing)
        self.refcount = np.zeros((n_pages,), np.int64)
        # pop() allocates ascending from 1; GARBAGE_PAGE never enters the list
        self._free: list[int] = list(range(n_pages - 1, GARBAGE_PAGE, -1))
        self._prefix_pages: dict[tuple, int] = {}   # token-id tuple -> page
        self._page_key: dict[int, tuple] = {}       # page -> its registered key
        self._evictable: OrderedDict[int, None] = OrderedDict()  # rc==0, registered
        self.counters: dict[str, int] = {}
        self.peak_resident = 0
        self.reset_counters()

    # ---------------- capacity views ----------------
    @property
    def n_allocatable(self) -> int:
        """Pages a request could ever hold (pool minus the garbage page)."""
        return self.n_pages - 1

    @property
    def n_free(self) -> int:
        """Pages on the free list (content-less)."""
        return len(self._free)

    @property
    def n_cached(self) -> int:
        """Retired prefix pages kept resident for reuse (evictable)."""
        return len(self._evictable)

    @property
    def n_resident(self) -> int:
        """Pages holding live or cached content."""
        return self.n_pages - 1 - len(self._free)

    @property
    def n_shared(self) -> int:
        """Pages mapped by more than one request right now."""
        return int((self.refcount > 1).sum())

    def reset_counters(self) -> None:
        self.counters = {
            "prefix_lookups": 0,
            "prefix_hits": 0,
            "cow_copies": 0,
            "prefix_evictions": 0,
            "alloc_failures": 0,
        }
        self.peak_resident = self.n_resident

    # ---------------- allocation ----------------
    def alloc(self) -> int | None:
        """One exclusively-owned page (refcount 1), or None when the pool is
        exhausted — free list empty AND nothing evictable. Never raises and
        never returns GARBAGE_PAGE; exhaustion is the caller's scheduling
        problem (the engine sheds a request, DESIGN.md §12.3)."""
        if self._free:
            page = self._free.pop()
        elif self._evictable:
            page, _ = self._evictable.popitem(last=False)        # oldest first
            del self._prefix_pages[self._page_key.pop(page)]
            self.counters["prefix_evictions"] += 1
        else:
            self.counters["alloc_failures"] += 1
            return None
        self.refcount[page] = 1
        self.peak_resident = max(self.peak_resident, self.n_resident)
        return page

    def ref(self, page: int) -> None:
        if page == GARBAGE_PAGE:
            raise ValueError("refusing to map the garbage page")
        if self.refcount[page] == 0:
            # cached -> live again: it must leave the evictable list
            self._evictable.pop(page, None)
        self.refcount[page] += 1

    def unref(self, page: int) -> None:
        if self.refcount[page] <= 0:
            raise ValueError(f"unref of unmapped page {page}")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            if page in self._page_key:
                self._evictable[page] = None     # keep resident for prefix reuse
            else:
                self._free.append(page)

    # ---------------- prefix cache ----------------
    def lookup_prefix(self, prompt) -> list[int]:
        """Longest chain of cached full-page prefixes of `prompt`; the
        returned pages are ref'd for the caller (one request)."""
        if not self.prefix_sharing:
            return []
        self.counters["prefix_lookups"] += 1
        pages: list[int] = []
        for pi in range(len(prompt) // self.page_size):
            page = self._prefix_pages.get(tuple(prompt[: (pi + 1) * self.page_size]))
            if page is None:
                break
            pages.append(page)
        for p in pages:
            self.ref(p)
        self.counters["prefix_hits"] += len(pages)
        return pages

    def register_prefix(self, prefix: tuple, page: int) -> bool:
        """Publish `page` as holding the K/V of token prefix `prefix`
        (a full-page-aligned token-id tuple). First writer wins; a page
        already carrying a key keeps it."""
        if not self.prefix_sharing:
            return False
        if prefix in self._prefix_pages or page in self._page_key:
            return False
        self._prefix_pages[prefix] = page
        self._page_key[page] = prefix
        return True

    def is_registered(self, page: int) -> bool:
        return page in self._page_key

    def needs_cow(self, page: int) -> bool:
        """A write may not land on a page other requests can see (shared)
        or that the prefix cache has published (immutable content)."""
        return self.refcount[page] > 1 or page in self._page_key

"""Speculative decoding over shared-table LUT plans (DESIGN.md §14).

LUT-NN's per-site K/V/bits dial means one set of learned centroids resolves
under *two* plans: an aggressive all-LUT **draft** and a higher-fidelity
**target** that keeps selected sites dense (`LUTPlan.keeping_dense`). Both
deploy from the same LUT_TRAIN checkpoint into one multi-plan artifact, and
every table they share is byte-identical — so the draft model costs ~zero
extra weight memory, unlike a conventional separate draft network.

`SpecDecoder` replaces the engine's `(n_slots, 1)` decode step with a
draft/verify round:

1. **draft**: up to γ greedy `(n_slots, 1)` forwards through the draft
   model propose d_1..d_γ per slot (d_0 is the slot's last emitted token).
   The draft keeps its OWN dense `(n_slots, max_seq)` KV caches even when
   the engine is paged — rollback on the draft side is then pure
   `cache_len` bookkeeping.
2. **verify**: ONE target forward over `(n_slots, γ+1)` tokens
   [d_0..d_γ] — the chunked-prefill row-masked shape, so the target's jit
   cache stays at O(1) entries (prefill chunk, width-1 decode, and this
   one fixed verify width).
3. **accept/emit**: at verify position j the engine samples t_j from the
   target logits with the slot's own sampling params and PRNG counter
   `len(out_tokens) + j` — the exact stream key non-speculative decode
   would use for that token. The round emits t_0..t_{m-1} where m is the
   longest run with d_j == t_{j-1}: every emitted token is conditioned on
   an accepted prefix and drawn from the target's distribution with the
   token's own stream key, so output is byte-identical to the
   non-speculative engine in BOTH greedy and sampled modes. (Trade-off vs
   classic min(1, p/q) rejection sampling: slightly lower sampled-mode
   acceptance, in exchange for the seeded-stream determinism the test
   suite and replay tooling rely on.)
4. **rollback**: target-side, positions beyond the accepted prefix are
   already invalid by `cache_len` masking (dense) and additionally have
   their pages rewound to the free list (paged, PR 7 pool unref); draft-
   side, `cache_len` rewinds, with one masked catch-up forward only for
   slots that accepted all γ drafts plus the bonus token.

Per-slot γ_eff adapts to each request's remaining token budget and cache
headroom (and to a per-request `spec_decode=False` opt-out: γ_eff=0 rides
the verify forward as a plain width-1 decode). Acceptance counters surface
in `engine.stats()` → `/metrics`; `target_forwards_per_token < 1` is the
whole point.

Spec decoding requires position-indexed caches on both sides: bundles with
per-slot recurrent state (mamba conv/ssm, encdec cross-KV) cannot roll
back by bookkeeping, so the engine auto-disables with a warning — the same
seam as PR 7's prefix-sharing probe.
"""

from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampling import GREEDY, batch_arrays, sample_tokens

# counters contributed to engine.stats() (zeroed by reset_counters)
_COUNTER_KEYS = (
    "spec_rounds",
    "spec_slot_rounds",
    "spec_draft_forwards",
    "spec_prefill_forwards",
    "spec_verify_forwards",
    "spec_catchup_forwards",
    "spec_tokens_proposed",
    "spec_tokens_accepted",
    "spec_bonus_tokens",
    "spec_tokens_emitted",
    "spec_pages_rewound",
)


class SpecDecoder:
    """Draft/verify decode scheduler bolted onto a ServingEngine.

    Owns the draft model (bundle + params + dense KV caches + its own
    jitted row-masked step) and the accept/rollback bookkeeping; the
    target forward, sampling streams, slot lifecycle, and paged pool stay
    with the engine. Self-draft (draft == target) is valid and useful for
    smoke tests: acceptance is ~1.0 and output parity is trivially exact.
    """

    def __init__(self, engine: Any, draft_bundle: Any, draft_params: Any,
                 *, gamma: int, compute_dtype, kv_dtype):
        if gamma < 1:
            raise ValueError(f"spec_gamma={gamma} must be >= 1")
        t_arch, d_arch = engine.bundle.arch, draft_bundle.arch
        if (draft_bundle.kind, d_arch.vocab) != (engine.bundle.kind, t_arch.vocab):
            raise ValueError(
                f"draft bundle ({draft_bundle.kind}, vocab={d_arch.vocab}) is "
                f"not interchangeable with the target "
                f"({engine.bundle.kind}, vocab={t_arch.vocab})"
            )
        self.eng = engine
        self.gamma = gamma
        self.draft_bundle = draft_bundle
        self.draft_params = draft_params
        # dense draft caches regardless of engine paging: rollback is then
        # cache_len bookkeeping only, and the draft never touches the pool
        self.draft_caches = draft_bundle.init_caches(
            engine.n_slots, engine.max_seq, dtype=kv_dtype
        )
        self.cache_len = np.zeros((engine.n_slots,), np.int32)
        n_slots = engine.n_slots

        def draft_step(params, tokens, cache_len, caches, slot_mask):
            logits, new_caches = draft_bundle.forward_step(
                params, {"tokens": tokens, "cache_len": cache_len}, caches,
                compute_dtype=compute_dtype,
            )

            def merge(old, new):
                shape = [1] * old.ndim
                shape[1] = n_slots            # every leaf is (L, B, ...)
                return jnp.where(slot_mask.reshape(shape), new, old)

            return logits, jax.tree_util.tree_map(merge, caches, new_caches)

        self._draft_fn = jax.jit(draft_step)
        self.reset_counters()

    # ------------------------------------------------------------------
    def reset_counters(self) -> None:
        self._c: dict[str, int] = {k: 0 for k in _COUNTER_KEYS}

    def counters(self) -> dict[str, Any]:
        """Spec counters + derived rates, merged into engine.stats()."""
        c: dict[str, Any] = dict(self._c)
        c["spec_gamma"] = self.gamma
        prop = c["spec_tokens_proposed"]
        c["spec_acceptance_rate"] = (
            c["spec_tokens_accepted"] / prop if prop else 0.0
        )
        em = c["spec_tokens_emitted"]
        # per-SLOT verify participations over emitted tokens: plain decode
        # is exactly 1.0 by this measure, so < 1.0 isolates the speculation
        # win from batching occupancy (each slot-round emits 1+accepted)
        c["target_forwards_per_token"] = (
            c["spec_slot_rounds"] / em if em else 0.0
        )
        return c

    def reset_slot(self, slot: int) -> None:
        """Called by the engine on slot admit/retire."""
        self.cache_len[slot] = 0

    # ------------------------------------------------------------------
    def _draft_forward(self, toks: np.ndarray, mask: np.ndarray) -> jax.Array:
        logits, self.draft_caches = self._draft_fn(
            self.draft_params, jnp.asarray(toks), jnp.asarray(self.cache_len),
            self.draft_caches, jnp.asarray(mask),
        )
        self.eng._record(toks, tag="draft")
        return logits

    def mirror_prefill(self, toks, cache_len, mask, write_len) -> None:
        """Feed the same prompt chunk the target just consumed through the
        draft model, so the draft's dense cache tracks every prompt token.
        Called by the engine's _prefill_step with the SAME pre-update
        arrays its own forward used."""
        logits, self.draft_caches = self._draft_fn(
            self.draft_params, jnp.asarray(toks), jnp.asarray(cache_len),
            self.draft_caches, jnp.asarray(mask),
        )
        jax.block_until_ready(logits)      # draft prefill rides the timed path
        self.eng._record(toks, tag="draft")
        self._c["spec_prefill_forwards"] += 1
        adv = np.asarray(mask)
        self.cache_len[adv] = cache_len[adv] + write_len[adv]

    def _sample_grid(self, logits: jax.Array) -> np.ndarray:
        """Sample every (slot, verify position) with the slot's sampling
        params and PRNG counter len(out_tokens)+j — the exact stream keys
        non-speculative decode would use for those tokens."""
        eng = self.eng
        params = [
            (eng.slots[i].sampling if eng.slots[i] is not None else GREEDY)
            for i in range(eng.n_slots)
        ]
        if all(p.greedy for p in params):
            return np.asarray(jnp.argmax(logits, axis=-1))
        width = logits.shape[1]
        row_params = [p for p in params for _ in range(width)]
        counters: list[int] = []
        for i in range(eng.n_slots):
            base = len(eng.slots[i].out_tokens) if eng.slots[i] is not None else 0
            counters.extend(base + j for j in range(width))
        flat = sample_tokens(
            logits.reshape(eng.n_slots * width, -1),
            *batch_arrays(row_params, counters),
        )
        return np.asarray(flat).reshape(eng.n_slots, width)

    def _rewind_pages(self, slot: int) -> None:
        """Drop pages wholly beyond the accepted prefix back to the pool.
        Decode-extended pages are never prefix-registered, so unref sends
        them straight to the free list; the kept partial page was COW'd
        private before the verify wrote it."""
        eng = self.eng
        ps = eng.pool.page_size
        keep = -(-int(eng.cache_len[slot]) // ps)
        pages = eng.slot_pages[slot]
        while len(pages) > keep:
            eng.pool.unref(pages.pop())
            eng.block_tables[slot, len(pages)] = 0
            self._c["spec_pages_rewound"] += 1

    # ------------------------------------------------------------------
    def decode_round(self) -> None:
        """One spec round for every DECODE-phase slot: γ draft forwards,
        one (n_slots, γ+1) target verify, accept/emit, rollback."""
        eng = self.eng
        dec = [
            (i, r) for i, r in enumerate(eng.slots)
            if r is not None and r.prefill_done
        ]
        if not dec:
            return
        t0 = time.perf_counter()
        # per-slot speculation depth: remaining token budget (a round may
        # emit γ_eff+1 tokens), cache headroom (verify writes positions
        # s..s+γ_eff), and the per-request opt-out (γ_eff=0 rides the
        # verify forward as plain width-1 decode)
        gam: dict[int, int] = {}
        for i, r in dec:
            g = self.gamma if r.spec_decode is not False else 0
            g = min(g, r.max_tokens - len(r.out_tokens) - 1,
                    eng.max_seq - 1 - int(eng.cache_len[i]))
            gam[i] = max(g, 0)
        if eng.paged:
            for i, r in dec:
                if eng.slots[i] is not r:
                    continue              # shed while preparing another slot
                eng._prepare_slot_writes(i, gam[i] + 1)
            dec = [(i, r) for i, r in dec if eng.slots[i] is r]
            eng._flush_copies()
            if not dec:
                return
        self._c["spec_rounds"] += 1
        self._c["spec_slot_rounds"] += len(dec)
        s0 = {i: int(eng.cache_len[i]) for i, _ in dec}

        # ---- draft: greedy chain d_1..d_γeff per slot, batched row-masked
        drafts = {
            i: [r.out_tokens[-1] if r.out_tokens else r.prompt[-1]]
            for i, r in dec
        }
        for j in range(max(gam.values())):
            toks = np.zeros((eng.n_slots, 1), np.int32)
            mask = np.zeros((eng.n_slots,), bool)
            for i, _ in dec:
                if gam[i] > j:
                    toks[i, 0] = drafts[i][j]
                    mask[i] = True
            logits = self._draft_forward(toks, mask)
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
            self._c["spec_draft_forwards"] += 1
            for i, _ in dec:
                if gam[i] > j:
                    drafts[i].append(int(nxt[i]))
                    self.cache_len[i] += 1

        # ---- verify: ONE target forward at the FIXED (n_slots, γ+1)
        # shape; per-slot γ_eff rides write_len (paged) / causal masking
        # (dense padding writes land above every valid query position)
        width = self.gamma + 1
        toks = np.zeros((eng.n_slots, width), np.int32)
        cache_len = np.zeros((eng.n_slots,), np.int32)
        mask = np.zeros((eng.n_slots,), bool)
        write_len = np.zeros((eng.n_slots,), np.int32)
        for i, _ in dec:
            row = drafts[i]
            toks[i, : len(row)] = row
            cache_len[i] = s0[i]
            mask[i] = True
            write_len[i] = gam[i] + 1
        step_args = (
            eng.params, jnp.asarray(toks), jnp.asarray(cache_len),
            eng.caches, jnp.asarray(mask),
        )
        if eng.paged:
            step_args += (jnp.asarray(eng.block_tables), jnp.asarray(write_len))
        logits, eng.caches = eng._step_fn(*step_args)
        logits = jax.block_until_ready(logits)
        eng._record(toks)
        self._c["spec_verify_forwards"] += 1
        eng._counters["decode_forwards"] += 1

        # ---- accept / emit / rollback
        t = self._sample_grid(logits)
        catchup: list[tuple[int, int, int]] = []       # (slot, token, pos)
        for i, r in dec:
            g, d = gam[i], drafts[i]
            m = 1
            while m <= g and d[m] == int(t[i, m - 1]):
                m += 1
            self._c["spec_tokens_proposed"] += g
            self._c["spec_tokens_accepted"] += m - 1
            if g and m == g + 1:
                self._c["spec_bonus_tokens"] += 1
            emitted = 0
            for j in range(m):
                eng.cache_len[i] = s0[i] + j + 1
                tok = int(t[i, j])
                r.out_tokens.append(tok)
                emitted += 1
                self._c["spec_tokens_emitted"] += 1
                eng._counters["decode_tokens"] += 1
                eng._check_done_after_token(i, r, tok)
                if eng.slots[i] is not r:
                    break                 # EOS/budget: drop later accepts
            if eng.slots[i] is not r:
                continue                  # retired: _retire reset the slot
            # draft prefix through s0+emitted-1 holds the emitted tokens
            # (d_j == t_{j-1} for every accepted j); full-accept slots need
            # one catch-up write of d_γ at position s0+γ
            if emitted == g + 1 and g:
                catchup.append((i, d[g], s0[i] + g))
            else:
                self.cache_len[i] = s0[i] + emitted
            if eng.paged:
                self._rewind_pages(i)
        if catchup:
            toks = np.zeros((eng.n_slots, 1), np.int32)
            mask = np.zeros((eng.n_slots,), bool)
            for i, tok, pos in catchup:
                toks[i, 0] = tok
                mask[i] = True
                self.cache_len[i] = pos
            self._draft_forward(toks, mask)           # logits discarded
            self._c["spec_catchup_forwards"] += 1
            for i, _, pos in catchup:
                self.cache_len[i] = pos + 1
        eng._counters["decode_s"] += time.perf_counter() - t0

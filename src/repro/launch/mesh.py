"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(*, data: int | None = None, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )

"""Snowflake Arctic 480B — 128 experts top-2 + dense residual branch
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs import ArchSpec

ARCH = ArchSpec(
    name="arctic_480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=4864,
    vocab=32000,
    n_experts=128, top_k=2,
    moe_dense_residual=True,
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    grad_accum=2,
)

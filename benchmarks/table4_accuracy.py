"""Paper Table 4 analog: LUT-NN ~ original model >> MADDNESS.

Classification + regression (UTKFace-MAE analog) on clustered features,
replacing ALL hidden layers (harsher than the paper's all-but-first):
  original  : dense model
  MADDNESS  : hash encode, bucket prototypes, no end-to-end learning
  LUT-NN    : k-means init + soft-PQ QAT fine-tune (learned temperature)
"""

from __future__ import annotations

import time

import jax

from benchmarks._mlp import (
    MLPSpec,
    attach_pq,
    evaluate,
    finetune_softpq,
    train_dense,
)
from repro.data import ClusteredTask


def run_one(regression: bool, steps: int = 300):
    key = jax.random.PRNGKey(1 if regression else 0)
    spec = MLPSpec(d_in=64, width=128, depth=4, n_out=1 if regression else 10)
    task = ClusteredTask(d_in=spec.d_in, n_classes=spec.n_out, regression=regression)
    dense = train_dense(key, spec, task, steps=steps)
    base = evaluate(dense, spec, task)

    n_layers = spec.depth + 1
    # paper policy: keep input- and output-adjacent layers exact
    layer_ids = list(range(1, n_layers - 1))

    md = attach_pq(key, dense, spec, task, layer_ids, kind="maddness")
    md_metric = evaluate(md, spec, task,
                         modes=[("maddness" if i in layer_ids else None) for i in range(n_layers)])

    lut = attach_pq(key, dense, spec, task, layer_ids, kind="pq")
    lut, _ = finetune_softpq(key, lut, spec, task, layer_ids, steps=2 * steps)
    lut_metric = evaluate(lut, spec, task,
                          modes=[("pq" if i in layer_ids else None) for i in range(n_layers)])
    return base, md_metric, lut_metric


def main() -> None:
    t0 = time.time()
    print("# Table 4 analog (classification acc higher-better; regression MAE lower-better)")
    print("task,original,maddness,lutnn")
    b, m, l = run_one(False)
    print(f"classification,{b:.4f},{m:.4f},{l:.4f}")
    assert l > m, "LUT-NN must beat MADDNESS (paper: +66..92%)"
    b2, m2, l2 = run_one(True)
    print(f"regression_mae,{b2:.4f},{m2:.4f},{l2:.4f}")
    print(f"claim_lutnn_near_original,{abs(l - b) < 0.05}")
    print(f"claim_lutnn_beats_maddness,{l - m:.4f}")
    print(f"table4_accuracy,{(time.time()-t0)*1e6:.0f},accuracy")


if __name__ == "__main__":
    main()

"""BERT-base — the paper's own NLP model (Tables 2/5, Fig. 13).

Used by benchmarks/tests (not part of the assigned 40-cell matrix).
lut_policy last_n:6 reproduces the paper's default of replacing the FC
operators of the last 6 layers; (K, V) = (16, 32) per paper Table 2.
"""
from repro.configs import ArchSpec

ARCH = ArchSpec(
    name="bert_base",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=3072,
    vocab=30522,
    act="gelu",
    mlp_gated=False,
    causal=False,
    tie_embeddings=True,
    lut_policy="last_n:6",
    rope_theta=10_000.0,
)

"""Pallas kernels vs the pure-jnp oracle: shape/dtype sweep, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels.dist_argmin import encode_pallas
from repro.kernels.lut_amm import lut_amm_pallas
from repro.kernels.ref import encode_ref, lut_amm_ref

SHAPES = [
    # (N, D, M, K, V, block_n, block_m, block_c)
    (32, 32, 64, 16, 4, 16, 64, 4),
    (64, 64, 128, 16, 8, 32, 128, 8),
    (100, 64, 130, 16, 32, 32, 128, None),      # padding on N and M
    (17, 96, 48, 8, 32, 8, 128, 1),             # tiny blocks, K=8
    (128, 256, 512, 16, 32, 128, 256, None),    # production-ish tile
    (8, 128, 384, 16, 16, 8, 128, 2),
]


@pytest.mark.parametrize("shape", SHAPES, ids=[str(s[:5]) for s in SHAPES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_amm_matches_ref(shape, dtype):
    n, d, m, k, v, bn, bm, bc = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n * d), 3)
    x = jax.random.normal(k1, (n, d), dtype)
    P = jax.random.normal(k2, (d // v, k, v), jnp.float32)
    T = jax.random.normal(k3, (d // v, k, m), jnp.float32)
    qt = quant.quantize_table(T, bits=8)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = lut_amm_pallas(
        x, P, qt.q, qt.scale, block_n=bn, block_m=bm, block_c=bc, interpret=True
    )
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("shape", SHAPES[:4], ids=[str(s[:5]) for s in SHAPES[:4]])
def test_per_column_scale_variant(shape):
    n, d, m, k, v, bn, bm, bc = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1 + n), 3)
    x = jax.random.normal(k1, (n, d))
    P = jax.random.normal(k2, (d // v, k, v))
    T = jax.random.normal(k3, (d // v, k, m))
    qt = quant.quantize_table(T, bits=8, per_column=True)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = lut_amm_pallas(
        x, P, qt.q, qt.scale, block_n=bn, block_m=bm, block_c=bc, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "n,d,k,v", [(32, 32, 16, 4), (100, 256, 16, 32), (7, 64, 8, 8)]
)
def test_encode_kernel_matches_ref(n, d, k, v):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n))
    x = jax.random.normal(k1, (n, d))
    P = jax.random.normal(k2, (d // v, k, v))
    out = encode_pallas(x, P, block_n=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(encode_ref(x, P)))


def test_kernel_argmin_tie_break(key):
    """Duplicate centroids: kernel must pick the lowest index like jnp."""
    P = jnp.zeros((1, 4, 4)).at[0, 1].set(1.0)      # rows 0,2,3 identical
    x = jnp.zeros((8, 4))
    out = encode_pallas(x, P, interpret=True)
    assert int(jnp.max(out)) == 0

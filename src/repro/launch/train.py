"""Training launcher (end-to-end driver).

Runs the full LUT-NN lifecycle on any registered arch at a CPU-feasible
reduction, or lowers the production config when --dryrun is given:

  dense pretrain -> convert (k-means init) -> soft-PQ QAT fine-tune ->
  int8 deploy -> eval -> LUTArtifact written to --artifact-dir
  (the train half of the train -> deploy -> serve lifecycle; the serve
  half is `launch/serve.py --artifact <dir>`).

Example (the (b) end-to-end driver; ~100M-param model for a few hundred
steps):

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_1p7b \
      --d-model 512 --layers 8 --steps 300 --lut
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.core import convert
from repro.data import MarkovLM
from repro.optim import SOFT_PQ_RULES, AdamW, lut_frozen_mask
from repro.optim.schedule import cosine_with_warmup
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ("bert_base",), default="qwen3_1p7b")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lut", action="store_true", help="run the full LUT pipeline")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--artifact-dir", default=None,
                    help="where the deployed LUTArtifact is written at the "
                         "end of the --lut pipeline (default: "
                         "<ckpt-dir>_artifact); serve it with "
                         "launch/serve.py --artifact <dir>")
    args = ap.parse_args(argv)

    arch = reduce_arch(
        get_arch(args.arch),
        d_model=args.d_model,
        n_layers=args.layers,
        vocab=args.vocab,
        d_ff=0 if get_arch(args.arch).d_ff == 0 else 2 * args.d_model,
    )
    data = MarkovLM(vocab=arch.vocab, seq_len=args.seq, batch=args.batch)
    key = jax.random.PRNGKey(0)

    bundle = build_model(arch, Mode.DENSE)
    params = bundle.init(key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{arch.name}: {n_params/1e6:.1f}M params, dense pretrain {args.steps} steps")

    opt = AdamW(lr=cosine_with_warmup(3e-3, total_steps=args.steps, warmup_steps=20))
    trainer = Trainer(
        step_fn=jax.jit(make_train_step(bundle, opt, compute_dtype=jnp.float32)),
        batch_at=data.batch_at,
        cfg=TrainerConfig(
            total_steps=args.steps, ckpt_every=max(50, args.steps // 4),
            ckpt_dir=args.ckpt_dir, log_every=25,
        ),
    )
    t0 = time.time()
    params, _ = trainer.fit(params, opt.init(params), start_step=0)
    print(f"dense done in {time.time()-t0:.1f}s, final loss {trainer.history[-1]['loss']:.4f}")

    if not args.lut:
        return

    from repro.configs import effective_plan

    print(f"replacement plan: {effective_plan(arch).describe()}")
    print("converting: k-means centroid init from activation samples ...")
    samples = [data.batch_at(10_000 + i) for i in range(2)]
    blut, lparams = convert.convert_dense_to_lut_train(bundle, params, samples, key)
    frozen = lut_frozen_mask(lparams)
    opt2 = AdamW(
        lr=cosine_with_warmup(1e-3, total_steps=args.steps, warmup_steps=10),
        rules=SOFT_PQ_RULES,
    )
    trainer2 = Trainer(
        step_fn=jax.jit(
            make_train_step(blut, opt2, frozen_mask=frozen, compute_dtype=jnp.float32)
        ),
        batch_at=data.batch_at,
        cfg=TrainerConfig(
            total_steps=args.steps, ckpt_every=max(50, args.steps // 4),
            ckpt_dir=args.ckpt_dir + "_lut", log_every=25,
        ),
    )
    lparams, _ = trainer2.fit(lparams, opt2.init(lparams, frozen), start_step=0)
    print(f"soft-PQ fine-tune final loss {trainer2.history[-1]['loss']:.4f}")

    artifact_dir = args.artifact_dir or args.ckpt_dir + "_artifact"
    binf, iparams = convert.deploy_to_artifact(blut, lparams, artifact_dir)
    eval_loss = binf.loss(iparams, data.batch_at(99_999), compute_dtype=jnp.float32)
    print(f"deployed INT8 LUT eval loss: {float(eval_loss):.4f}")
    print(f"wrote LUTArtifact to {artifact_dir} "
          f"(serve: python -m repro.launch.serve --artifact {artifact_dir})")


if __name__ == "__main__":
    main()

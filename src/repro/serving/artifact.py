"""Versioned LUT deployment artifact: the train → serve hand-off (DESIGN.md §8).

`launch/train.py --lut` ends with deployed LUT_INFER params (int8 tables +
fp32 scales/centroids). This module packages them as a self-describing
on-disk directory a fresh server can load with **no** hand-built `like`
tree — the manifest carries everything needed to rebuild the model:

  <dir>/
      manifest.json     format+version, arch-spec fields, mode, bundle kind,
                        tree structure + per-leaf shape/dtype
      arrays.npz        every param leaf keyed by tree path (dtype-exact:
                        int8 tables stay int8)
      autotune.json     snapshot of the warmed kernel block-size cache, so a
                        fresh server starts with tuned tilings instead of
                        re-deriving (or re-measuring) them

Writes follow the Checkpointer's atomic discipline: everything lands in
`<dir>.tmp`, then one `os.replace` commits — a crash mid-write can never
produce a half-readable artifact.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import flatten_tree, tree_paths
from repro.configs import ModelBundle, arch_from_dict, arch_to_dict, build_model, effective_plan
from repro.core.amm import Mode
from repro.core.plan import LUTPlan
from repro.kernels import autotune

FORMAT = "lut-artifact"
# v2 (DESIGN.md §9.3): the manifest additionally records the RESOLVED
# replacement plan under "plan" (LUTPlan.to_dict schema). v1 artifacts,
# written before plans existed, migrate on load: their arch dict carries
# the legacy lut_policy string, which the back-compat shim resolves to the
# same plan the writer used.
# v3 (DESIGN.md §14): one artifact can carry MULTIPLE resolved plans over a
# shared array payload — manifest["plans"] maps extra plan names (e.g.
# "draft") to {plan, leaves}, where each leaf record's "key" points either
# at a target leaf (byte-identical, deduplicated) or at a private
# "plan.<name>/<path>" entry in arrays.npz. A v2 artifact migrates on load
# as carrying exactly the implicit plan {"target"}.
VERSION = 3
_READABLE_VERSIONS = (1, 2, 3)

#: the reserved name of the main plan every artifact carries
TARGET_PLAN = "target"

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
_AUTOTUNE = "autotune.json"

# npz cannot represent bfloat16 (it stores raw void bytes that never load
# back); bf16 leaves travel as uint16 bit patterns, with the manifest's
# dtype string as the restore key
_BF16 = np.dtype(jnp.bfloat16)


@dataclasses.dataclass(frozen=True)
class LUTArtifact:
    """A loaded deployment artifact: the rebuilt bundle + host params."""

    bundle: ModelBundle
    params: Any
    manifest: dict[str, Any]
    path: pathlib.Path
    plan_name: str = TARGET_PLAN

    @property
    def arch_name(self) -> str:
        return self.manifest["arch"]["name"]

    @property
    def plan_names(self) -> list[str]:
        """Every plan this artifact can resolve, target first."""
        return [TARGET_PLAN] + sorted(self.manifest.get("plans", {}))

    @property
    def recipe(self) -> dict[str, Any] | None:
        """The executed training recipe (`Recipe.to_dict` payload), when
        the artifact was deployed through `Recipe.run` (DESIGN.md §10.2)."""
        return self.manifest.get("recipe")


def _arch_sans_plan(arch) -> dict[str, Any]:
    d = arch_to_dict(arch)
    d.pop("lut_plan", None)
    return d


def save_artifact(
    directory: str | os.PathLike,
    bundle: ModelBundle,
    params: Any,
    *,
    autotune_snapshot: bool = True,
    recipe: dict[str, Any] | None = None,
    extra_plans: dict[str, tuple[ModelBundle, Any]] | None = None,
) -> pathlib.Path:
    """Write `(bundle, params)` as a LUTArtifact directory (atomic).

    `params` is typically the LUT_INFER tree from
    `convert.deploy_lut_train_params`; any bundle/tree pair round-trips,
    so dense baselines can ship through the same path. `recipe` (a
    `repro.train.recipe.Recipe.to_dict` payload) records the executed
    training pipeline in the manifest — provenance only, never consulted
    at load; `Recipe.from_dict(manifest["recipe"])` round-trips it.

    `extra_plans` maps additional plan names (e.g. "draft") to
    `(bundle, params)` pairs deployed from the SAME training state under a
    different LUTPlan (convert.deploy_lut_train_params(plan=...)). Each
    extra bundle must share the target's arch modulo `lut_plan`. Leaves
    byte-identical to a target leaf at the same path are deduplicated —
    the manifest records a `key` pointing at the shared array — so a
    draft plan whose tables the target also carries costs ~zero extra
    bytes on disk (DESIGN.md §14.1).
    """
    final = pathlib.Path(directory)
    tmp = final.parent / (final.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), params)
    flat = flatten_tree(host)
    arrays = {
        k: (v.view(np.uint16) if v.dtype == _BF16 else v)
        for k, v in flat.items()
    }

    plans: dict[str, Any] = {}
    for name, (pbundle, pparams) in (extra_plans or {}).items():
        if name == TARGET_PLAN:
            raise ValueError(f"plan name {TARGET_PLAN!r} is reserved for the "
                             f"artifact's main (bundle, params)")
        if (pbundle.mode != bundle.mode or pbundle.kind != bundle.kind
                or _arch_sans_plan(pbundle.arch) != _arch_sans_plan(bundle.arch)):
            raise ValueError(
                f"extra plan {name!r}: its bundle must share the target's "
                f"arch/mode/kind modulo lut_plan"
            )
        phost = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), pparams)
        pflat = flatten_tree(phost)
        leaves = {}
        for path, v in pflat.items():
            shared = flat.get(path)
            if (shared is not None and shared.shape == v.shape
                    and shared.dtype == v.dtype
                    and shared.tobytes() == v.tobytes()):
                key = path                       # dedupe: reuse the target leaf
            else:
                key = f"plan.{name}/{path}"
                arrays[key] = v.view(np.uint16) if v.dtype == _BF16 else v
            leaves[path] = {"shape": list(v.shape), "dtype": str(v.dtype),
                            "key": key}
        plans[name] = {
            "plan": effective_plan(pbundle.arch).to_dict(),
            "leaves": leaves,
        }

    np.savez(tmp / _ARRAYS, **arrays)

    manifest = {
        "format": FORMAT,
        "version": VERSION,
        "arch": arch_to_dict(bundle.arch),
        "plan": effective_plan(bundle.arch).to_dict(),
        "mode": bundle.mode.value,
        "kind": bundle.kind,
        "treedef": str(jax.tree_util.tree_structure(host)),
        "leaves": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()
        },
    }
    if plans:
        manifest["plans"] = plans
    if recipe is not None:
        manifest["recipe"] = recipe
    (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=2))

    if autotune_snapshot:
        entries = _snapshot_entries(
            [bundle] + [b for b, _ in (extra_plans or {}).values()]
        )
        (tmp / _AUTOTUNE).write_text(
            json.dumps({"version": 1, "entries": entries}, indent=1, sort_keys=True)
        )

    # commit: move any previous artifact aside BEFORE the replace
    # (os.replace cannot target a non-empty directory). A crash between the
    # two replaces leaves the previous artifact intact at <dir>.old, which
    # load_artifact falls back to — at every instant one of the two is
    # loadable. A stale .old (from such a crash) is only cleared while
    # <dir> itself exists, preserving that invariant across re-deploys.
    old = final.parent / (final.name + ".old")
    if final.exists():
        if old.exists():
            shutil.rmtree(old)
        os.replace(final, old)
    os.replace(tmp, final)
    if old.exists():
        shutil.rmtree(old)
    return final


def _snapshot_entries(bundles: list[ModelBundle]) -> dict[str, Any]:
    """Autotune cache entries belonging to these bundles' LUT kernel sites
    (the target plus any extra plans' bundles — a draft plan can replace
    sites the target keeps dense, so its signatures must ship too).

    The process cache may hold winners for other archs/backends; shipping
    those would make every server that loads the artifact inherit them
    forever (restored entries suppress re-tuning). Keys are matched on the
    (m, c, k, v) site signature — any n/dtype/backend, since serve-time
    slot counts and hardware are unknown at deploy time.
    """
    sites = set()
    for bundle in bundles:
        for site in bundle.sites():                      # registry walk (§9.2)
            if site.mode != Mode.LUT_INFER or site.lut is None or not site.lut.use_kernel:
                continue
            lut = site.lut
            c = site.d_in // lut.v
            sites.add(("lut_amm", site.d_out, c, lut.k, lut.v))
            sites.add(("encode", 0, c, lut.k, lut.v))    # shared-encode path
    if not sites:
        return {}

    def key_sig(key: str) -> tuple | None:
        parts = key.split("|")
        try:
            kind = parts[0]
            f = dict(p.split("=", 1) for p in parts[1:])
            return kind, int(f["m"]), int(f["c"]), int(f["k"]), int(f["v"])
        except (IndexError, KeyError, ValueError):
            return None

    return {
        k: dict(rec)
        for k, rec in autotune.get_cache().load().items()
        if key_sig(k) in sites
    }


def _read_manifest(directory: pathlib.Path) -> dict[str, Any]:
    try:
        manifest = json.loads((directory / _MANIFEST).read_text())
    except FileNotFoundError:
        raise FileNotFoundError(f"no {_MANIFEST} in {directory} — not an artifact")
    if manifest.get("format") != FORMAT:
        raise ValueError(f"{directory}: format={manifest.get('format')!r}, "
                         f"expected {FORMAT!r}")
    if manifest.get("version") not in _READABLE_VERSIONS:
        raise ValueError(f"{directory}: artifact version "
                         f"{manifest.get('version')} unsupported (reader: {VERSION})")
    return manifest


def _resolve_artifact_dir(directory: str | os.PathLike) -> pathlib.Path:
    """`<dir>`, falling back to `<dir>.old` when a crash mid-re-deploy
    (between save_artifact's two os.replace calls) stranded the previous
    good artifact there — shared by load_artifact and the inspector."""
    directory = pathlib.Path(directory)
    if not (directory / _MANIFEST).exists():
        old = directory.parent / (directory.name + ".old")
        if (old / _MANIFEST).exists():
            return old
    return directory


def check_artifact_dir(directory: str | os.PathLike) -> dict[str, Any]:
    """Cheap serveability probe: resolve the directory (honoring the .old
    crash fallback), read the manifest, and validate format/version —
    WITHOUT touching the array payload. Raises FileNotFoundError when the
    directory or manifest is gone and ValueError when the manifest fails
    validation; returns the manifest dict otherwise.

    Used by the serving supervisor before every worker (re)spawn so an
    artifact that disappeared or was corrupted between restarts fails
    closed with an actionable error instead of burning `max_restarts` on a
    crash loop (DESIGN.md §15.3)."""
    resolved = _resolve_artifact_dir(pathlib.Path(directory))
    try:
        return _read_manifest(resolved)
    except json.JSONDecodeError as e:
        raise ValueError(f"{resolved}: unreadable {_MANIFEST}: {e}") from e


def load_artifact(
    directory: str | os.PathLike, *, plan: str = TARGET_PLAN,
    restore_autotune: bool = True
) -> LUTArtifact:
    """Rebuild the model and params from a saved artifact.

    No `like` tree needed: the arch spec is reconstructed from the manifest,
    the param tree structure from `jax.eval_shape` of the rebuilt bundle's
    init, and every leaf is validated (path, shape, dtype) against both the
    manifest and the live model before device_put. A repo drift that changes
    the param tree therefore fails loudly at load, not as NaNs at serve.

    `plan` selects which resolved plan of a multi-plan (v3) artifact to
    load: "target" (the default, and the only plan v1/v2 artifacts carry)
    or a name from `manifest["plans"]` (e.g. "draft"). A named plan shares
    the target's arch modulo `lut_plan` and reads its leaves from the
    shared array payload via the manifest's key indirection.
    """
    primary = pathlib.Path(directory)
    resolved = _resolve_artifact_dir(primary)
    try:
        return _load_resolved(resolved, plan=plan,
                              restore_autotune=restore_autotune)
    except FileNotFoundError:
        if resolved == primary:
            raise
        # live-deployer race: .old vanished because the re-deploy committed
        # while we were reading it — the new artifact is at <dir> now
        return _load_resolved(primary, plan=plan,
                              restore_autotune=restore_autotune)


def _plan_arch(manifest: dict[str, Any], directory, plan: str):
    """(arch, leaf records, npz-key map) for the requested plan."""
    import dataclasses as _dc

    arch = arch_from_dict(manifest["arch"])
    if plan == TARGET_PLAN:
        recorded = manifest["leaves"]
        return arch, recorded, {p: p for p in recorded}
    plans = manifest.get("plans", {})
    if plan not in plans:
        have = [TARGET_PLAN] + sorted(plans)
        raise ValueError(
            f"{directory}: no plan {plan!r} in this artifact — available: "
            f"{have}" + ("" if plans else
                         " (v%d artifact: single-plan)" % manifest["version"])
        )
    entry = plans[plan]
    arch = _dc.replace(arch, lut_plan=LUTPlan.from_dict(entry["plan"]))
    recorded = entry["leaves"]
    return arch, recorded, {p: rec["key"] for p, rec in recorded.items()}


def _load_resolved(directory: pathlib.Path, *, plan: str,
                   restore_autotune: bool) -> LUTArtifact:
    manifest = _read_manifest(directory)

    arch, recorded, keymap = _plan_arch(manifest, directory, plan)
    if manifest["version"] >= 2 and plan == TARGET_PLAN:
        # the recorded plan must equal what the arch dict resolves to — a
        # hand-edited manifest whose plan and arch disagree would otherwise
        # rebuild a model that silently mismatches the stored tables
        rec_plan = LUTPlan.from_dict(manifest["plan"])
        if rec_plan != effective_plan(arch):
            raise ValueError(
                f"{directory}: manifest plan does not match the arch's "
                f"resolved plan — {rec_plan.describe()} vs "
                f"{effective_plan(arch).describe()}"
            )
    bundle = build_model(arch, Mode(manifest["mode"]))
    if bundle.kind != manifest["kind"]:
        raise ValueError(
            f"rebuilt bundle kind {bundle.kind!r} != manifest {manifest['kind']!r}"
        )

    specs = bundle.param_specs()
    paths = tree_paths(specs)
    spec_leaves = jax.tree_util.tree_leaves(specs)

    leaves = []
    with np.load(directory / _ARRAYS) as data:
        missing = [p for p in paths
                   if p not in recorded or keymap[p] not in data.files]
        if plan == TARGET_PLAN:
            # extra-plan private leaves legitimately live under "plan.<name>/"
            extra = sorted(k for k in set(data.files) - set(paths)
                           if not k.startswith("plan."))
        else:
            extra = []
        if missing or extra:
            raise ValueError(
                f"artifact/model tree mismatch: missing={missing[:4]} extra={extra[:4]}"
            )
        for p, spec in zip(paths, spec_leaves):
            a = data[keymap[p]]
            rec = recorded[p]
            if rec["dtype"] == "bfloat16" and a.dtype == np.uint16:
                a = a.view(_BF16)                    # undo the npz bf16 detour
            if list(a.shape) != rec["shape"] or str(a.dtype) != rec["dtype"]:
                raise ValueError(f"{p}: stored {a.shape}/{a.dtype} != manifest {rec}")
            if a.shape != spec.shape or a.dtype != spec.dtype:
                raise ValueError(
                    f"{p}: artifact {a.shape}/{a.dtype} != model {spec.shape}/{spec.dtype}"
                )
            leaves.append(a)
    params = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(specs), leaves
    )
    # commit leaves to device now — host numpy leaves would be re-uploaded
    # on every engine forward (a mesh-constructed engine re-places them
    # under its sharding specs; that device->device move is cheap)
    params = jax.tree.map(jax.device_put, params)

    if restore_autotune:
        restore_autotune_snapshot(directory)
    return LUTArtifact(bundle=bundle, params=params, manifest=manifest,
                       path=directory, plan_name=plan)


def restore_autotune_snapshot(directory: str | os.PathLike) -> int:
    """Merge the artifact's autotune winners into the process cache.

    Precedence is measured > snapshot > analytic (DESIGN.md §13.3): a
    snapshot entry fills a hole, and a *measured* snapshot entry (wall-clock
    timed on real hardware at deploy time, `measured: true`) additionally
    replaces a live analytic projection — but never a live measured winner.
    Returns the number of entries merged. Persistence failures are
    swallowed — the snapshot is an optimization, never a load dependency.
    """
    path = pathlib.Path(directory) / _AUTOTUNE
    cache = autotune.get_cache()
    merged = 0
    try:
        raw = json.loads(path.read_text())
        entries = raw["entries"] if raw.get("version") == 1 else {}
        for key, rec in entries.items():
            have = cache.get(key)
            if have is None or (
                isinstance(rec, dict) and rec.get("measured")
                and not have.get("measured")
            ):
                cache.put(key, dict(rec))
                merged += 1
    except (OSError, ValueError, KeyError, TypeError, AttributeError):
        return merged                    # malformed snapshot: never fatal
    if merged:
        try:
            cache.save()
        except OSError:
            pass
    return merged


def _plan_cost(arch, mode: str) -> tuple[int, int, float]:
    """(n_lut_sites, n_sites, est. per-token linear-site FLOPs) for one
    resolved plan. LUT sites cost the encode matmul (2·d_in·K per codebook
    group = 2·d_in·K) plus the table accumulate (2·C·d_out); dense sites the
    full GEMM (2·d_in·d_out). Config walk only — no params are built."""
    bundle = build_model(arch, Mode(mode))
    n_lut = n_sites = 0
    flops = 0.0
    for s in bundle.sites():
        n_sites += 1
        if s.mode != Mode.DENSE and s.lut is not None:
            n_lut += 1
            c = s.d_in // s.lut.v
            flops += 2.0 * s.d_in * s.lut.k + 2.0 * c * s.d_out
        else:
            flops += 2.0 * s.d_in * s.d_out
    return n_lut, n_sites, flops


def describe_artifact(directory: str | os.PathLike) -> str:
    """Human-readable artifact summary (the `python -m repro.serving.artifact
    <dir>` inspector): arch, every resolved plan with its site counts and
    estimated FLOP ratio vs the target, recipe provenance, leaf accounting."""
    import dataclasses as _dc

    directory = _resolve_artifact_dir(directory)
    manifest = _read_manifest(directory)
    arch = arch_from_dict(manifest["arch"])
    leaves = manifest["leaves"]

    def rec_bytes(rec) -> int:
        return int(np.prod(rec["shape"] or [1])) * np.dtype(
            np.uint16 if rec["dtype"] == "bfloat16" else rec["dtype"]
        ).itemsize

    n_bytes = sum(rec_bytes(rec) for rec in leaves.values())
    lines = [
        f"LUTArtifact at {directory}",
        f"  format    : {manifest['format']} v{manifest['version']}",
        f"  arch      : {arch.name} ({arch.family}, {arch.n_layers}L, "
        f"d={arch.d_model}, vocab={arch.vocab})",
        f"  mode/kind : {manifest['mode']} / {manifest['kind']}",
        f"  plan      : {effective_plan(arch).describe()}"
        if manifest["version"] >= 2 else "  plan      : (v1: legacy policy)",
        f"  leaves    : {len(leaves)} arrays, {n_bytes/1e6:.2f} MB",
    ]
    int8 = sum(1 for r in leaves.values() if r["dtype"] == "int8")
    if int8:
        lines.append(f"  int8 LUTs : {int8} table leaves")

    # per-plan accounting (v3): site counts + estimated FLOP ratio vs the
    # target, so an operator can sanity-check a spec-decode deployment
    # (draft well under 1.0x) before serving it
    plans = manifest.get("plans", {})
    if manifest["version"] >= 2:
        t_lut, t_sites, t_flops = _plan_cost(arch, manifest["mode"])
        lines.append(f"  plans     : {len(plans) + 1} "
                     f"({', '.join([TARGET_PLAN] + sorted(plans))})")
        lines.append(f"    {TARGET_PLAN:<8}: {t_lut}/{t_sites} sites LUT, "
                     f"1.00x FLOPs (reference)")
        for name in sorted(plans):
            entry = plans[name]
            parch = _dc.replace(arch, lut_plan=LUTPlan.from_dict(entry["plan"]))
            p_lut, p_sites, p_flops = _plan_cost(parch, manifest["mode"])
            shared = sum(1 for rec in entry["leaves"].values()
                         if not rec["key"].startswith("plan."))
            priv_bytes = sum(rec_bytes(rec) for rec in entry["leaves"].values()
                             if rec["key"].startswith("plan."))
            lines.append(
                f"    {name:<8}: {p_lut}/{p_sites} sites LUT, "
                f"{p_flops / t_flops:.2f}x FLOPs vs {TARGET_PLAN}, "
                f"{shared}/{len(entry['leaves'])} leaves shared "
                f"(+{priv_bytes/1e6:.2f} MB private)"
            )
            lines.append(f"      plan    : "
                         f"{LUTPlan.from_dict(entry['plan']).describe()}")

    recipe = manifest.get("recipe")
    if recipe is not None:
        stages = " -> ".join(s.get("name", s.get("stage", "?"))
                             for s in recipe.get("stages", []))
        lines.append(f"  recipe    : {stages}")
    else:
        lines.append("  recipe    : (none recorded)")
    return "\n".join(lines)


def _main(argv: list[str] | None = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.serving.artifact",
        description="Inspect a LUTArtifact directory.",
    )
    ap.add_argument("directory", help="artifact directory to describe")
    ap.add_argument("--json", action="store_true",
                    help="dump the raw manifest JSON instead")
    args = ap.parse_args(argv)
    if args.json:
        print(json.dumps(_read_manifest(_resolve_artifact_dir(args.directory)),
                         indent=2))
    else:
        print(describe_artifact(args.directory))


if __name__ == "__main__":
    _main()

"""Roofline analyzer unit tests: HLO collective parser + term math."""

import jax
import jax.numpy as jnp

from repro.roofline.analysis import Roofline, collective_bytes, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("f32[8,16]") == 8 * 16 * 4
    assert _shape_bytes("bf16[2,3,4]{2,1,0}") == 24 * 2
    assert _shape_bytes("(s8[10], f32[2])") == 10 + 8
    assert _shape_bytes("pred[]") == 1


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[2048]{0} all-gather(%y), dimensions={0}
  ROOT %rs = f32[512]{0} reduce-scatter(%z), dimensions={0}
  %cp = u8[100]{0} collective-permute(%w)
  %a2a = (f32[64]{0}, f32[64]{0}) all-to-all(%p, %q)
  %done = f32[1024]{0} all-reduce-done(%ar2)
"""
    c = collective_bytes(hlo)
    assert c["all-reduce"] == 1024 * 4 * 2          # 2x ring model
    assert c["all-gather"] == 2048 * 2
    assert c["reduce-scatter"] == 512 * 4
    assert c["collective-permute"] == 100
    assert c["all-to-all"] == 2 * 64 * 4


def test_terms_and_bottleneck():
    r = Roofline(flops=197e12, hbm_bytes=0, coll_bytes=0, coll_by_kind={})
    assert abs(r.t_compute - 1.0) < 1e-9
    assert r.bottleneck == "compute"
    r2 = Roofline(flops=0, hbm_bytes=819e9, coll_bytes=100e9, coll_by_kind={})
    assert r2.bottleneck == "collective"            # 2.0s vs 1.0s


def test_real_compiled_module_collectives():
    """An actually-sharded matmul must show a nonzero collective term."""
    from tests._subproc import run_with_devices
    import textwrap

    out = run_with_devices(
        textwrap.dedent(
            """
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P, NamedSharding
            from repro.roofline.analysis import analyze_compiled
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((4,), ("model",))
            def f(x, w):
                return x @ w          # contraction dim sharded -> psum
            xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
            ws = jax.ShapeDtypeStruct((128, 64), jnp.float32)
            with mesh:
                c = jax.jit(
                    f,
                    in_shardings=(NamedSharding(mesh, P(None, "model")),
                                  NamedSharding(mesh, P("model", None))),
                    out_shardings=NamedSharding(mesh, P(None, None)),
                ).lower(xs, ws).compile()
            r = analyze_compiled(c)
            assert r.coll_bytes > 0, c.as_text()[:2000]
            print("COLL_BYTES", r.coll_bytes)
            """
        ),
        n_devices=4,
    )
    assert "COLL_BYTES" in out

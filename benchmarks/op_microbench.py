"""Paper Fig. 7 analog: per-operator cost, dense vs LUT-NN.

Real TPU wall-clock is unavailable here, so this reports BOTH:
  * measured CPU wall-clock of the XLA one-hot LUT path vs dense matmul
    (honest but CPU-flavored), and
  * the derived v5e roofline time per op (bytes/819GBps vs flops/197TFLOPs)
    for dense-bf16 vs LUT-int8-table — the decode-regime byte advantage is
    the paper's memory/latency claim transposed to TPU (DESIGN.md §2).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import pq, quant
from repro.core.amm import LUTConfig
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

OPS = [
    # (name, N, D, M, K, V)
    ("bert_ffn_up", 512, 768, 3072, 16, 32),
    ("llama3_qproj", 256, 4096, 4096, 16, 32),
    ("llama3_ffn_gate", 256, 4096, 14336, 16, 32),
]


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main() -> None:
    t0 = time.time()
    print("# Fig. 7 analog: per-op dense vs LUT")
    print("op,cpu_dense_ms,cpu_lut_ms,tpu_roofline_dense_us,tpu_roofline_lut_us,decode_byte_ratio")
    for name, n, d, m, k, v in OPS:
        cfg = LUTConfig(k=k, v=v)
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (n, d), jnp.float32)
        w = jax.random.normal(key, (d, m), jnp.float32)
        P = jax.random.normal(key, (d // v, k, v))
        qt = quant.quantize_table(pq.build_table(P, w, stop_weight_grad=False))

        dense_fn = jax.jit(lambda x, w: x @ w)
        def lut_fn(x, P, tq, ts):
            tbl = (tq.astype(jnp.float32) * ts)
            enc = pq.hard_encode(pq.pairwise_sq_dists(pq.split_subvectors(x, v), P))
            return pq.lut_contract(enc, tbl)
        lut_jit = jax.jit(lut_fn)

        t_dense = _time(dense_fn, x, w) * 1e3
        t_lut = _time(lut_jit, x, P, qt.q, qt.scale) * 1e3

        # v5e roofline (decode regime: weight/table bytes dominate)
        dense_bytes_ = d * m * 2 + (n * d + n * m) * 2
        lut_bytes_ = (d // v) * k * m + (d // v) * k * v * 4 + (n * d + n * m) * 2
        dense_flops_ = 2 * n * d * m
        lut_flops_ = 2 * n * d * k + 2 * n * (d // v) * k * m   # one-hot MXU path
        t_roof_dense = max(dense_bytes_ / HBM_BW, dense_flops_ / PEAK_FLOPS) * 1e6
        t_roof_lut = max(lut_bytes_ / HBM_BW, lut_flops_ / PEAK_FLOPS) * 1e6
        print(
            f"{name},{t_dense:.2f},{t_lut:.2f},{t_roof_dense:.1f},{t_roof_lut:.1f},"
            f"{(d * m * 2) / ((d // v) * k * m):.2f}"
        )
    print(f"op_microbench,{(time.time()-t0)*1e6:.0f},cpu+roofline")


if __name__ == "__main__":
    main()

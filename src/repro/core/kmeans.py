"""jit-compiled k-means (Lloyd) with k-means++ seeding, for centroid init.

The paper initializes soft-PQ centroids with k-means over activations sampled
from the original model on ~1024 training samples (section 6.1). We vmap Lloyd
over the C codebooks so a whole layer initializes in one XLA call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _sq_dists(x: jax.Array, c: jax.Array) -> jax.Array:
    """(N, V), (K, V) -> (N, K) squared distances, fp32."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    return (
        jnp.sum(x * x, -1)[:, None]
        - 2.0 * x @ c.T
        + jnp.sum(c * c, -1)[None, :]
    )


def kmeans_plusplus(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """k-means++ seeding: (N, V) -> (K, V)."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = x[jax.random.randint(k0, (), 0, n)]

    def body(carry, key_i):
        centers, i, min_d = carry
        # min_d holds distance to the closest already-chosen center.
        p = min_d / jnp.maximum(jnp.sum(min_d), 1e-12)
        idx = jax.random.choice(key_i, n, p=p)
        c_new = x[idx]
        centers = centers.at[i].set(c_new)
        d_new = _sq_dists(x, c_new[None, :])[:, 0]
        return (centers, i + 1, jnp.minimum(min_d, d_new)), None

    centers0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(first)
    min_d0 = _sq_dists(x, first[None, :])[:, 0]
    (centers, _, _), _ = jax.lax.scan(
        body, (centers0, 1, min_d0), jax.random.split(key, k - 1)
    )
    return centers


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key: jax.Array, x: jax.Array, *, k: int, iters: int = 25) -> tuple[jax.Array, jax.Array]:
    """Lloyd's algorithm. x: (N, V) -> (centroids (K, V), inertia scalar).

    Dead centroids (empty clusters) are reseeded to the point currently
    farthest from its assigned centroid, which keeps all K codes live — the
    LUT kernel assumes a dense codebook.
    """
    x = x.astype(jnp.float32)
    init = kmeans_plusplus(key, x, k)

    def step(centers, _):
        d = _sq_dists(x, centers)                       # (N, K)
        assign = jnp.argmin(d, -1)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # (N, K)
        counts = jnp.sum(onehot, 0)                     # (K,)
        sums = onehot.T @ x                             # (K, V)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # reseed empties at the globally worst-represented point
        worst = x[jnp.argmax(jnp.min(d, -1))]
        new = jnp.where((counts > 0)[:, None], new, worst[None, :])
        return new, None

    centers, _ = jax.lax.scan(step, init, None, length=iters)
    inertia = jnp.sum(jnp.min(_sq_dists(x, centers), -1))
    return centers, inertia


@functools.partial(jax.jit, static_argnames=("k", "v", "iters"))
def kmeans_per_codebook(
    key: jax.Array, acts: jax.Array, *, k: int, v: int, iters: int = 25
) -> jax.Array:
    """Per-codebook k-means over layer activations.

    acts: (N, D) activation samples -> centroids (C, K, V), C = D // v.
    This is the paper's Eq. 1 objective, solved independently per codebook.
    """
    n, d = acts.shape
    c = d // v
    sub = acts.reshape(n, c, v).swapaxes(0, 1)          # (C, N, V)
    keys = jax.random.split(key, c)
    centroids, _ = jax.vmap(lambda kk, xx: kmeans(kk, xx, k=k, iters=iters))(keys, sub)
    return centroids

"""Serving launcher: batch mode (timed request burst) or an HTTP front end
over the continuous-batching engine with a LUT_INFER (int8 table) model.

  # serve a deployed artifact (the output of launch/train.py --lut):
  PYTHONPATH=src python -m repro.launch.serve --artifact /tmp/ckpt_artifact

  # HTTP front end (per-token streaming, /healthz /readyz /metrics,
  # graceful drain on SIGTERM — DESIGN.md §11.2):
  PYTHONPATH=src python -m repro.launch.serve --artifact <dir> --port 8000

  # crash-supervised: the engine runs in a worker process restarted from
  # the artifact on failure (DESIGN.md §11.4):
  PYTHONPATH=src python -m repro.launch.serve --artifact <dir> --port 8000 --supervise

  # tensor-parallel over 2 devices, bfloat16 compute:
  XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
  PYTHONPATH=src python -m repro.launch.serve --artifact <dir> --tp 2 --dtype bfloat16

  # no artifact: randomly-initialized tables (smoke/perf mode only)
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1p7b --requests 8

In batch mode a warm-up request runs (and is discarded) before the timed
region so the reported tok/s measures steady state, not the one-off jit
compile of the two engine shapes. In HTTP mode the process exits 0 on a
clean drain and `server.EXIT_STRANDED` if the drain deadline expired with
requests unresolved.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.engine import KV_DTYPES, ServingEngine
from repro.serving.sampling import SamplingParams


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default=None,
                    help="path to a LUTArtifact directory (launch/train.py "
                         "--lut output): serve the DEPLOYED tables instead "
                         "of randomly-initialized ones")
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3_1p7b",
                    help="arch for random-init mode (ignored with --artifact: "
                         "the manifest carries the arch)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard tables/weights over "
                         "a (1, tp) ('data','model') mesh (needs >= tp "
                         "devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--dtype", choices=("float32", "bfloat16"), default="float32",
                    help="engine compute dtype; also keys the LUT autotune "
                         "warmup so tuned blocks match runtime")
    # paged KV cache (DESIGN.md §12)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: pooled pages + block tables with "
                         "prefix sharing and copy-on-write; tokens are "
                         "byte-identical to the dense engine")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (must divide --max-seq)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="pool size in pages; default slots*max_seq/page_size"
                         "+1 (dense-equivalent) — pass less to overcommit "
                         "memory (exhaustion sheds, never OOMs)")
    ap.add_argument("--kv-dtype", choices=sorted(KV_DTYPES), default=None,
                    help="KV-cache storage dtype (default: compute dtype); "
                         "fp8 halves cache HBM, K/V are upcast at use")
    # speculative decoding (DESIGN.md §14)
    ap.add_argument("--spec-decode", action="store_true",
                    help="draft/verify speculative decoding: γ cheap draft "
                         "forwards per round, one batched target verify; "
                         "tokens are byte-identical to plain decode")
    ap.add_argument("--draft-plan", default="draft",
                    help="plan name to load the draft model from a multi-"
                         "plan artifact (see describe_artifact); 'target' "
                         "= explicit self-draft; random-init mode always "
                         "self-drafts")
    ap.add_argument("--spec-gamma", type=int, default=4,
                    help="speculation depth: draft tokens proposed per "
                         "verify forward (the verify shape is (slots, γ+1))")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 = greedy")
    ap.add_argument("--top-k", type=int, default=0, help="top-k filter; 0 disables")
    ap.add_argument("--top-p", type=float, default=1.0, help="nucleus mass; 1 disables")
    ap.add_argument("--seed", type=int, default=0, help="base sampling seed")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the untimed warm-up request (tok/s then "
                         "includes jit compile)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="run LUT sites through the fused Pallas v2 kernel "
                         "(random-init mode; artifacts carry their own "
                         "lut_use_kernel setting)")
    # random-init reductions (CI / laptop smoke: serve a tiny model)
    ap.add_argument("--layers", type=int, default=None,
                    help="random-init mode: reduce the arch to N layers")
    ap.add_argument("--d-model", type=int, default=None,
                    help="random-init mode: reduce the arch width")
    ap.add_argument("--vocab", type=int, default=None,
                    help="random-init mode: reduce the vocab")
    # HTTP front end (DESIGN.md §11.2)
    ap.add_argument("--port", type=int, default=None,
                    help="start the HTTP front end on this port instead of "
                         "the batch run (/generate streaming, /healthz, "
                         "/readyz, /metrics; SIGTERM drains gracefully)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="admission high-water mark: past it the lowest-"
                         "priority queued request is shed (HTTP mode)")
    ap.add_argument("--drain-timeout", type=float, default=30.0,
                    help="seconds SIGTERM waits for in-flight requests "
                         "before aborting them and exiting non-zero")
    ap.add_argument("--supervise", action="store_true",
                    help="run the engine in a crash-supervised worker "
                         "process restarted from the artifact (requires "
                         "--artifact; DESIGN.md §11.4)")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="consecutive worker crashes before the supervisor "
                         "gives up (with --supervise or --replicas)")
    # multi-replica router (DESIGN.md §15)
    ap.add_argument("--replicas", type=int, default=1,
                    help="run N crash-supervised engine replicas off the one "
                         "artifact behind an EngineRouter (requires "
                         "--artifact and --port; implies supervision)")
    ap.add_argument("--routing", choices=("least_loaded", "prefix_affinity"),
                    default="least_loaded",
                    help="router placement policy: least-loaded live replica, "
                         "or rendezvous-hash on the first KV page of the "
                         "prompt (same-prefix sessions share a replica and "
                         "its prefix cache) with load-based spill")
    ap.add_argument("--fault-json", default=None,
                    help="JSON FaultSpec (e.g. '{\"kill_at_step\": 4}') "
                         "injected into ONE replica's worker, for failover "
                         "testing (with --replicas)")
    ap.add_argument("--fault-replica", type=int, default=0,
                    help="replica index --fault-json applies to")
    args = ap.parse_args(argv)

    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.replicas > 1:
        if not args.artifact:
            ap.error("--replicas > 1 requires --artifact (each replica's "
                     "worker restarts from the artifact directory)")
        if args.port is None:
            ap.error("--replicas > 1 requires --port")
        if args.tp > 1:
            ap.error("--replicas does not compose with --tp > 1")
    if args.fault_json is not None and args.replicas < 2:
        ap.error("--fault-json needs --replicas >= 2 (a survivor must "
                 "exist to fail over to)")
    if args.supervise and not args.artifact:
        ap.error("--supervise requires --artifact (the worker restarts "
                 "from the artifact directory)")
    if args.supervise and args.port is None:
        ap.error("--supervise requires --port (supervised batch mode is "
                 "not wired)")
    if args.supervise and args.tp > 1:
        ap.error("--supervise does not support --tp > 1 yet")
    if args.spec_decode and args.tp > 1:
        ap.error("--spec-decode does not compose with --tp > 1 (the draft "
                 "caches are host-managed)")

    if args.port is not None:
        return _serve_http(args)

    if args.artifact:
        from repro.serving.artifact import load_artifact

        art = load_artifact(args.artifact)
        bundle, params = art.bundle, art.params
        # per-site plans can mix kernel/XLA sites: report kernel use from
        # the registry, not a global flag
        use_kernel = any(
            s.lut is not None and s.lut.use_kernel for s in bundle.lut_sites()
        )
        source = f"artifact {args.artifact} ({art.arch_name})"
    else:
        arch = _reduced_arch(args)
        bundle = build_model(arch, Mode.LUT_INFER)
        params = bundle.init(jax.random.PRNGKey(0))
        use_kernel = args.use_kernel
        source = f"random init ({arch.name})"

    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(data=1, model=args.tp)

    compute_dtype = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32
    eng = ServingEngine(
        bundle, params, n_slots=args.slots, max_seq=args.max_seq,
        prefill_chunk=args.prefill_chunk, compute_dtype=compute_dtype,
        mesh=mesh, **_paged_kwargs(args),
        **_resolve_draft(_spec_kwargs(args), args.artifact),
    )

    if not args.no_warmup:
        eng.warmup()          # compile both engine shapes off the clock

    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for i in range(args.requests):
        key, k = jax.random.split(key)
        plen = int(jax.random.randint(k, (), 4, 24))
        prompt = list(range(i + 1, i + 1 + plen))
        eng.submit(
            prompt, max_tokens=args.max_tokens,
            sampling=SamplingParams(
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, seed=args.seed + i,
            ),
        )
    done = eng.run_until_done()
    dt = max(time.time() - t0, 1e-9)
    total_tok = sum(len(r.out_tokens) for r in done)
    mode = "pallas kernel (autotuned v1/v2/fused)" if use_kernel else "XLA one-hot"
    st = eng.stats()
    tp = f", tp={args.tp}" if mesh is not None else ""
    print(f"{len(done)} requests, {total_tok} tokens in {dt:.1f}s "
          f"({total_tok/dt:.1f} tok/s, {args.slots} slots, LUT INT8 tables, "
          f"{mode}, {args.dtype}{tp}, {source}, "
          f"{eng.n_lut_shapes_tuned} LUT shapes autotuned)")
    print(f"  steps={st['steps']} prefill: {st['prefill_tokens']} tok / "
          f"{st['prefill_forwards']} fwd ({st['prefill_tok_s']:.1f} tok/s)  "
          f"decode: {st['decode_tokens']} tok / {st['decode_forwards']} fwd "
          f"({st['decode_tok_s']:.1f} tok/s)  "
          f"occupancy={st['decode_occupancy']:.2f}  "
          f"shape_cache_hits={st['shape_cache_hits']}")
    if args.paged:
        hits = (st["prefix_hits"] / st["prefix_lookups"]
                if st["prefix_lookups"] else 0.0)
        print(f"  pool: {st['kv_pages_resident']}/{st['kv_pages_total']} pages "
              f"resident (peak {st['kv_pages_peak']}, "
              f"util {st['pool_utilization']:.2f}, "
              f"{st['kv_bytes_resident']} B vs dense "
              f"{st['kv_bytes_dense_equiv']} B)  "
              f"prefix: {st['prefix_hits']} hits / {st['prefix_lookups']} "
              f"lookups ({hits:.2f}/req), {st['prefill_tokens_skipped']} "
              f"prefill tok skipped  cow={st['cow_copies']}  "
              f"shed={st['shed']}")
    if eng.spec is not None:
        print(f"  spec: γ={st['spec_gamma']} acceptance="
              f"{st['spec_acceptance_rate']:.2f} "
              f"target_forwards_per_token={st['target_forwards_per_token']:.2f} "
              f"({st['spec_rounds']} rounds, {st['spec_draft_forwards']} draft "
              f"fwd, {st['spec_bonus_tokens']} bonus)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")


def _paged_kwargs(args) -> dict:
    """Engine kwargs for the paged pool + KV dtype. JSON-safe on purpose:
    the supervisor ships engine_kwargs to the worker process as JSON, and
    KV_DTYPES resolves the dtype string on the far side."""
    kw: dict = {}
    if args.paged:
        kw.update(paged=True, page_size=args.page_size, n_pages=args.n_pages)
    if args.kv_dtype is not None:
        kw["kv_dtype"] = args.kv_dtype
    return kw


def _spec_kwargs(args) -> dict:
    """Speculative-decoding kwargs, JSON-safe like _paged_kwargs: the
    supervisor ships these to the worker, which resolves `draft_plan`
    against the artifact on its side of the pipe."""
    kw: dict = {}
    if args.spec_decode:
        kw.update(spec_decode=True, spec_gamma=args.spec_gamma,
                  draft_plan=args.draft_plan)
    return kw


def _resolve_draft(engine_kwargs: dict, artifact: str | None) -> dict:
    """In-process half of the draft_plan handshake: swap the JSON-safe
    plan NAME for loaded draft_bundle/draft_params. Without an artifact
    (random-init smoke) the engine self-drafts."""
    plan = engine_kwargs.pop("draft_plan", None)
    if engine_kwargs.get("spec_decode") and plan is not None and artifact:
        from repro.serving.artifact import load_artifact

        art = load_artifact(artifact, plan=plan, restore_autotune=False)
        engine_kwargs.update(draft_bundle=art.bundle, draft_params=art.params)
    return engine_kwargs


def _reduced_arch(args):
    overrides = {"lut_use_kernel": args.use_kernel}
    if args.layers is not None:
        overrides["n_layers"] = args.layers
    if args.d_model is not None:
        overrides["d_model"] = args.d_model
    if args.vocab is not None:
        overrides["vocab"] = args.vocab
    return reduce_arch(get_arch(args.arch), **overrides)


def _serve_http(args) -> None:
    """HTTP front-end mode: build a backend (local pump or supervised
    worker), serve until SIGTERM drains it, exit with the drain code."""
    import asyncio

    from repro.serving.server import EnginePump, run_server

    engine_kwargs = dict(
        n_slots=args.slots, max_seq=args.max_seq,
        prefill_chunk=args.prefill_chunk, max_queue=args.max_queue,
        **_paged_kwargs(args), **_spec_kwargs(args),
    )
    if args.replicas > 1:
        import json

        from repro.serving.faults import FaultSpec
        from repro.serving.router import EngineRouter

        faults = None
        if args.fault_json is not None:
            faults = [None] * args.replicas
            faults[args.fault_replica] = FaultSpec.from_dict(
                json.loads(args.fault_json))
        backend = EngineRouter(
            args.artifact, replicas=args.replicas, routing=args.routing,
            engine_kwargs=engine_kwargs, faults=faults,
            supervisor_kwargs={"max_restarts": args.max_restarts},
        )
        if not backend.wait_ready(timeout=600) or not backend.healthy:
            print("no router replica came up", file=sys.stderr)
            sys.exit(1)
        source = (f"artifact {args.artifact} x{args.replicas} replicas "
                  f"({args.routing})")
    elif args.supervise:
        from repro.serving.supervisor import EngineSupervisor

        backend = EngineSupervisor(
            args.artifact, engine_kwargs=engine_kwargs,
            max_restarts=args.max_restarts,
        )
        if not backend.wait_ready(timeout=600) or not backend.healthy:
            print("supervised worker failed to come up", file=sys.stderr)
            sys.exit(1)
        source = f"supervised artifact {args.artifact}"
    else:
        compute_dtype = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32
        if args.artifact:
            from repro.serving.artifact import load_artifact

            art = load_artifact(args.artifact)
            bundle, params = art.bundle, art.params
            source = f"artifact {args.artifact} ({art.arch_name})"
        else:
            arch = _reduced_arch(args)
            bundle = build_model(arch, Mode.LUT_INFER)
            params = bundle.init(jax.random.PRNGKey(0))
            source = f"random init ({arch.name})"
        mesh = None
        if args.tp > 1:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh(data=1, model=args.tp)
        eng = ServingEngine(
            bundle, params, compute_dtype=compute_dtype, mesh=mesh,
            **_resolve_draft(engine_kwargs, args.artifact),
        )
        if not args.no_warmup:
            eng.warmup()          # compile both engine shapes before /readyz
        backend = EnginePump(eng)

    def on_started(fe):
        print(f"serving {source} on http://{fe.host}:{fe.port} "
              f"({args.slots} slots, max_queue={args.max_queue}; "
              f"SIGTERM drains, timeout {args.drain_timeout:.0f}s)",
              flush=True)

    code = asyncio.run(run_server(
        backend, args.host, args.port,
        drain_timeout_s=args.drain_timeout, on_started=on_started,
    ))
    sys.exit(code)


if __name__ == "__main__":
    main()

"""Product-quantization math for LUT-NN (paper Eqs. 1-6).

All functions are pure and jit-friendly. Shape conventions:

  a      : (N, D)        input activations (rows of A)
  P      : (C, K, V)     centroids / codebooks, C = D // V
  W      : (D, M)        dense weight being replaced
  T      : (C, K, M)     lookup table, T[c] = P[c] @ W[c*V:(c+1)*V, :]  (Eq. 3)
  dists  : (N, C, K)     squared Euclidean distances per codebook
  enc    : (N, C, K)     encoding (one-hot for hard, probabilities for soft)

Distances are always computed in fp32 for numerical robustness; the AMM
contraction runs in the activation dtype (bf16 on TPU) with fp32 accumulation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def split_subvectors(a: jax.Array, V: int) -> jax.Array:
    """(..., D) -> (..., C, V) with C = D // V. D must be divisible by V."""
    *lead, D = a.shape
    if D % V:
        raise ValueError(f"feature dim {D} not divisible by sub-vector length {V}")
    return a.reshape(*lead, D // V, V)


def pairwise_sq_dists(a_sub: jax.Array, P: jax.Array) -> jax.Array:
    """Squared Euclidean distances between sub-vectors and centroids.

    a_sub: (N, C, V), P: (C, K, V) -> (N, C, K), computed in fp32 via the
    ||a||^2 - 2 a.P + ||P||^2 expansion so the inner term maps onto the MXU.
    """
    a32 = a_sub.astype(jnp.float32)
    p32 = P.astype(jnp.float32)
    # (N, C, K) <- contract V;  batched over codebook axis C.
    cross = jnp.einsum("ncv,ckv->nck", a32, p32)
    a_nrm = jnp.sum(a32 * a32, axis=-1)[:, :, None]          # (N, C, 1)
    p_nrm = jnp.sum(p32 * p32, axis=-1)[None, :, :]          # (1, C, K)
    return a_nrm - 2.0 * cross + p_nrm


def hard_encode(dists: jax.Array) -> jax.Array:
    """onehot(argmin) encoding, Eq. 2/4.  (N, C, K) -> (N, C, K) in dists dtype."""
    K = dists.shape[-1]
    idx = jnp.argmin(dists, axis=-1)
    return jax.nn.one_hot(idx, K, dtype=dists.dtype)


def soft_encode(dists: jax.Array, t: jax.Array) -> jax.Array:
    """softmax(-dists / t), Eq. 5.  t > 0 is the (learned) temperature."""
    return jax.nn.softmax(-dists / t, axis=-1)


def ste_encode(dists: jax.Array, t: jax.Array) -> jax.Array:
    """Soft-PQ straight-through encoding, Eq. 6.

    Forward value  = hard one-hot (what inference uses).
    Backward value = softmax gradient (differentiable w.r.t. dists and t).
    """
    soft = soft_encode(dists, t)
    hard = hard_encode(dists)
    return soft + jax.lax.stop_gradient(hard - soft)


def build_table(P: jax.Array, W: jax.Array, *, stop_weight_grad: bool = True) -> jax.Array:
    """Lookup-table construction h^c(b^c) (Eq. 3):  T[c] = P[c] @ W_c.

    P: (C, K, V), W: (D, M) with D = C*V -> T: (C, K, M).
    The replaced weight is frozen during soft-PQ training (paper trains
    centroids + temperature only), so gradients through W are stopped.
    """
    C, K, V = P.shape
    D, M = W.shape
    if D != C * V:
        raise ValueError(f"weight rows {D} != C*V = {C}*{V}")
    w = jax.lax.stop_gradient(W) if stop_weight_grad else W
    w_sub = w.reshape(C, V, M)
    return jnp.einsum("ckv,cvm->ckm", P.astype(w.dtype), w_sub)


def lut_contract(enc: jax.Array, T: jax.Array) -> jax.Array:
    """AMM read+accumulate (Eq. 4): sum_c enc[n,c,:] . T[c,:,m] -> (N, M).

    enc (N, C, K) is one-hot (inference) or a probability vector (soft path).
    On TPU this is a single (N, C*K) x (C*K, M) matmul: the MXU *is* the
    parallel table-lookup unit (see DESIGN.md section 2). Accumulate fp32.
    """
    N = enc.shape[0]
    C, K, M = T.shape
    out = jax.lax.dot_general(
        enc.reshape(N, C * K),
        T.reshape(C * K, M),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return out


def lut_contract_int8(
    enc_hard: jax.Array,   # (N, C, K) one-hot (any float dtype; cast to int8)
    table_q: jax.Array,    # (C, K, M) int8
    scale_m: jax.Array,    # (1, 1, M) fp32 — m_shared quantization layout
) -> jax.Array:
    """Integer table read: int8 one-hot x int8 table -> int32, one fp32
    rescale per output column.

    This is the paper's section-5.2 mixed-precision accumulation adapted to
    the MXU: the table streams from HBM ONCE as int8 (no bf16
    dequant-materialization pass, which costs 5x the table bytes on the
    naive path: read int8 + write bf16 + read bf16). Requires the
    m_shared=(1,1,M) scale layout so the rescale factors out of the
    (C*K)-contraction; the one-hot "values" are exactly +-1 so int8 carries
    them losslessly and the int32 accumulator bounds |sum| <= C*127.
    """
    n = enc_hard.shape[0]
    c, k, m = table_q.shape
    acc = jax.lax.dot_general(
        enc_hard.reshape(n, c * k).astype(jnp.int8),
        table_q.reshape(c * k, m),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * scale_m.reshape(1, m)


def encode_indices(a: jax.Array, P: jax.Array) -> jax.Array:
    """Inference-side encoder g^c: (N, D) -> int32 (N, C) centroid indices."""
    a_sub = split_subvectors(a, P.shape[-1])
    return jnp.argmin(pairwise_sq_dists(a_sub, P), axis=-1).astype(jnp.int32)


def gather_lut(idx: jax.Array, T: jax.Array) -> jax.Array:
    """Reference gather-based table read: (N, C) idx, (C, K, M) -> (N, M).

    The dynamic-gather formulation of Eq. 4 (what the CPU shuffle instruction
    does). Kept as an oracle / alternative path; the deployed TPU path is the
    one-hot matmul in :func:`lut_contract`.
    """
    # T[c, idx[n, c], :] summed over c.
    idx_cn = idx.T[:, :, None].astype(jnp.int32)            # (C, N, 1)
    gathered = jnp.take_along_axis(T, idx_cn, axis=1)       # (C, N, M)
    return jnp.sum(gathered, axis=0)


def pq_reconstruct(a: jax.Array, P: jax.Array) -> jax.Array:
    """Quantize-dequantize a through its nearest centroids (analysis util)."""
    a_sub = split_subvectors(a, P.shape[-1])
    enc = hard_encode(pairwise_sq_dists(a_sub, P))          # (N, C, K)
    rec = jnp.einsum("nck,ckv->ncv", enc.astype(P.dtype), P)
    return rec.reshape(a.shape)

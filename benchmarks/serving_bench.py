"""Serving-engine throughput: decode tok/s, prefill tok/s, and batch
occupancy through the LUT_INFER int8-table model, across four configs:

  * light_2req / heavy_12req — under- vs over-subscribed slot pool,
    in-memory params (the PR-2 baseline rows)
  * artifact_12req — the same heavy load served from a LUTArtifact
    round-tripped through disk (DESIGN.md §8): any artifact-load overhead
    or drift shows up against heavy_12req
  * tp2_12req — heavy load on a (1, 2) ("data", "model") mesh in a
    subprocess with 2 forced host devices (the tests/_subproc.py pattern),
    measuring the tensor-parallel engine path end to end
  * prefix_chat_{shared,nosharing}_8req — the paged-KV chat pattern
    (DESIGN.md §12): a primer request warms the prefix cache, then 8
    requests share a 32-token system prompt. The shared row must beat the
    no-sharing row on prefill forwards (pages skip prefill), and its KV
    bytes resident must sit strictly below the dense per-slot footprint —
    both asserted here so the bench doubles as a perf regression gate.

A warm-up request compiles the engine's two token shapes off the clock, so
the rows measure steady-state scheduler throughput, not jit. With
`json_path` set (benchmarks/run.py --json) the rows are written to
BENCH_serving.json so serving perf joins the BENCH_kernels.json trajectory.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.engine import ServingEngine

N_SLOTS = 4
MAX_SEQ = 64
PREFILL_CHUNK = 8
MAX_TOKENS = 8
# loads: half the slot pool (occupancy-starved) vs 3x the pool (saturated,
# requests queue behind busy slots)
LOADS = [("light_2req", 2), ("heavy_12req", 12)]
_TP2_MARKER = "TP2_ROW "
# prefix-heavy chat scenario: 8 requests share a 4-page system prompt
PAGE_SIZE = 8
SYS_PROMPT = [(j * 5) % 256 + 1 for j in range(32)]       # 4 full pages
N_CHAT = 8


def _run_load(bundle, params, n_requests: int, *, mesh=None) -> dict:
    eng = ServingEngine(
        bundle, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
        prefill_chunk=PREFILL_CHUNK, compute_dtype=jnp.float32,
        autotune_lut=False, mesh=mesh,
    )
    # warm-up: compile the chunked-prefill and decode shapes off the clock
    eng.warmup()

    key = jax.random.PRNGKey(2)
    t0 = time.perf_counter()
    for i in range(n_requests):
        key, k = jax.random.split(key)
        plen = int(jax.random.randint(k, (), 3, 2 * PREFILL_CHUNK))
        eng.submit([(i * 7 + j) % 256 + 1 for j in range(plen)],
                   max_tokens=MAX_TOKENS)
    done = eng.run_until_done(max_steps=10_000)
    wall_s = max(time.perf_counter() - t0, 1e-9)
    assert len(done) == n_requests, (len(done), n_requests)

    st = eng.stats()
    return {
        "requests": n_requests,
        "n_slots": N_SLOTS,
        "prefill_chunk": PREFILL_CHUNK,
        "tp": 1 if mesh is None else int(mesh.shape["model"]),
        "steps": st["steps"],
        "prefill_tokens": st["prefill_tokens"],
        "prefill_forwards": st["prefill_forwards"],
        "prefill_tok_s": round(st["prefill_tok_s"], 1),
        "decode_tokens": st["decode_tokens"],
        "decode_forwards": st["decode_forwards"],
        "decode_tok_s": round(st["decode_tok_s"], 1),
        "decode_occupancy": round(st["decode_occupancy"], 3),
        "shape_cache_hits": st["shape_cache_hits"],
        "wall_s": round(wall_s, 3),
    }


def _prefix_chat_row(bundle, params, *, sharing: bool) -> dict:
    """Paged engine under the chat pattern. A primer request registers the
    system prompt's pages, then the timed burst: N requests with distinct
    tails plus one verbatim resubmit of the system prompt (fully cached —
    exercises the final-token clamp and copy-on-write)."""
    eng = ServingEngine(
        bundle, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
        prefill_chunk=PREFILL_CHUNK, compute_dtype=jnp.float32,
        autotune_lut=False, paged=True, page_size=PAGE_SIZE,
        prefix_sharing=sharing,
    )
    eng.warmup()
    # primer: one completed request leaves the system prompt's 4 pages
    # registered and resident (refcount 0, evictable) for the burst
    eng.submit(SYS_PROMPT + [200], max_tokens=2)
    eng.run_until_done(max_steps=10_000)
    eng.finished.clear()
    eng.reset_stats()

    t0 = time.perf_counter()
    for i in range(N_CHAT):
        tail = [] if i == 0 else [210 + i, 220 + i, 230 + i]
        eng.submit(SYS_PROMPT + tail, max_tokens=MAX_TOKENS)
    done = eng.run_until_done(max_steps=10_000)
    wall_s = max(time.perf_counter() - t0, 1e-9)
    assert len(done) == N_CHAT and all(r.status == "ok" for r in done)

    st = eng.stats()
    return {
        "requests": N_CHAT,
        "n_slots": N_SLOTS,
        "prefill_chunk": PREFILL_CHUNK,
        "tp": 1,
        "steps": st["steps"],
        "prefill_tokens": st["prefill_tokens"],
        "prefill_forwards": st["prefill_forwards"],
        "prefill_tok_s": round(st["prefill_tok_s"], 1),
        "decode_tokens": st["decode_tokens"],
        "decode_forwards": st["decode_forwards"],
        "decode_tok_s": round(st["decode_tok_s"], 1),
        "decode_occupancy": round(st["decode_occupancy"], 3),
        "shape_cache_hits": st["shape_cache_hits"],
        "wall_s": round(wall_s, 3),
        # pool gauges (deterministic scheduler counters — regression-gated)
        "page_size": PAGE_SIZE,
        "kv_pages_total": st["kv_pages_total"],
        "kv_pages_peak": st["kv_pages_peak"],
        "kv_bytes_resident": st["kv_bytes_resident"],
        "kv_bytes_peak": st["kv_bytes_peak"],
        "kv_bytes_dense_equiv": st["kv_bytes_dense_equiv"],
        "pool_utilization": round(st["pool_utilization"], 3),
        "prefix_hits": st["prefix_hits"],
        "prefix_lookups": st["prefix_lookups"],
        "prefix_hit_rate": round(
            st["prefix_hits"] / st["prefix_lookups"], 3
        ) if st["prefix_lookups"] else 0.0,
        "prefill_tokens_skipped": st["prefill_tokens_skipped"],
        "cow_copies": st["cow_copies"],
    }


def _bundle_and_params():
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
    bundle = build_model(arch, Mode.LUT_INFER)
    return bundle, bundle.init(jax.random.PRNGKey(0))


def _tp2_row(timeout: int = 900) -> dict:
    """Heavy load on a tp=2 mesh, in a subprocess with 2 forced host
    devices (the tests/_subproc.py pattern — works on any host)."""
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    env = dict(os.environ)
    # append (not replace) so user-set XLA flags apply to the tp2 row too —
    # otherwise the tp=1 vs tp=2 rows would measure different XLA configs
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2").strip()
    env["PYTHONPATH"] = str(src) + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, str(pathlib.Path(__file__).resolve()), "--tp2-child"],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    if out.returncode != 0:
        raise RuntimeError(f"tp2 child failed:\n{out.stdout}\n{out.stderr}")
    for line in out.stdout.splitlines():
        if line.startswith(_TP2_MARKER):
            return json.loads(line[len(_TP2_MARKER):])
    raise RuntimeError(f"tp2 child printed no row:\n{out.stdout}")


def _tp2_child() -> None:
    from repro.launch.mesh import make_host_mesh

    bundle, params = _bundle_and_params()
    mesh = make_host_mesh(data=1, model=2)
    row = {"load": "tp2_12req", **_run_load(bundle, params, 12, mesh=mesh)}
    print(_TP2_MARKER + json.dumps(row))


def main(json_path: str | pathlib.Path | None = None) -> list[dict]:
    bundle, params = _bundle_and_params()

    rows = []
    cols = ["load", "requests", "tp", "decode_tok_s", "prefill_tok_s",
            "decode_occupancy", "steps", "shape_cache_hits"]
    print(",".join(cols))

    def emit(row):
        rows.append(row)
        print(",".join(str(row[c]) for c in cols))

    for load, n in LOADS:
        emit({"load": load, **_run_load(bundle, params, n)})

    # artifact-loaded engine: disk round trip, then the heavy load again
    with tempfile.TemporaryDirectory() as td:
        from repro.serving.artifact import load_artifact, save_artifact

        save_artifact(pathlib.Path(td) / "art", bundle, params)
        art = load_artifact(pathlib.Path(td) / "art")
        emit({"load": "artifact_12req", **_run_load(art.bundle, art.params, 12)})

    # paged-KV chat pattern: prefix sharing must pay for itself, in both
    # compute (prefill forwards skipped) and memory (resident below dense)
    shared = _prefix_chat_row(bundle, params, sharing=True)
    cold = _prefix_chat_row(bundle, params, sharing=False)
    emit({"load": "prefix_chat_shared_8req", **shared})
    emit({"load": "prefix_chat_nosharing_8req", **cold})
    assert shared["prefill_forwards"] < cold["prefill_forwards"], (shared, cold)
    assert shared["prefill_tokens_skipped"] > 0, shared
    assert shared["kv_bytes_peak"] < shared["kv_bytes_dense_equiv"], shared

    try:
        emit(_tp2_row())
    except Exception as e:  # noqa: BLE001 — the tp row is best-effort
        print(f"# tp2 row skipped: {e!r:.200}")

    if json_path is not None:
        payload = {
            "schema": "serving_bench.v3",
            "arch": "qwen3_1p7b(reduced,L=2)",
            "mode": "lut_infer",
            "backend": jax.default_backend(),
            "rows": rows,
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=1))
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    if "--tp2-child" in sys.argv:
        _tp2_child()
    else:
        _JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"
        main(json_path=_JSON if "--json" in sys.argv else None)

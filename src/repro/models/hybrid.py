"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

The shared transformer block (attention + MLP, one set of weights) is
invoked every `attn_every` mamba layers; its input is a learned fusion of
the current hidden state with the original embeddings (concat -> linear),
and its output is projected back into the residual stream — following
Zamba2 (arXiv:2411.15242). Each invocation has its own KV cache but reuses
the same weights, so in LUT mode the block's tables are amortized across
all invocations (DESIGN.md section 4).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models.common import (
    Params,
    SiteCfg,
    embed,
    embed_init,
    linear,
    linear_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.models.transformer import BlockCfg, block_init, block_apply


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    vocab: int
    d_model: int
    n_layers: int                     # mamba layers
    attn_every: int                   # shared block before layers k, 2k, ...
    mamba_block: BlockCfg             # kind == "mamba"
    shared_attn: attn_mod.AttnCfg
    shared_mlp: mlp_mod.MLPCfg
    fuse: SiteCfg                     # 2*d_model -> d_model (dense)
    out: SiteCfg                      # d_model -> d_model
    remat: bool = True
    unroll: bool = False              # python-loop layers (activation capture)

    @property
    def invocation_points(self) -> tuple[int, ...]:
        return tuple(range(self.attn_every, self.n_layers + 1, self.attn_every))

    @property
    def segment_bounds(self) -> tuple[tuple[int, int], ...]:
        pts = (0, *self.invocation_points)
        segs = [(pts[i], pts[i + 1]) for i in range(len(pts) - 1)]
        if pts[-1] < self.n_layers:
            segs.append((pts[-1], self.n_layers))
        return tuple(segs)


def hybrid_init(key: jax.Array, cfg: HybridCfg, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    mamba_stack = jax.vmap(lambda k: block_init(k, cfg.mamba_block, dtype=dtype))(layer_keys)
    return {
        "embed": embed_init(ks[1], cfg.vocab, cfg.d_model, dtype),
        "mamba_stack": mamba_stack,
        "shared": {
            "fuse": linear_init(ks[2], cfg.fuse, dtype=dtype),
            "norm1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn_mod.attn_init(ks[3], cfg.shared_attn, dtype=dtype),
            "norm2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_mod.mlp_init(ks[4], cfg.shared_mlp, dtype=dtype),
            "out": linear_init(ks[5], cfg.out, dtype=dtype),
        },
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }


def hybrid_caches(cfg: HybridCfg, b: int, s_max: int, dtype=jnp.bfloat16, abstract: bool = False,
                  paged: attn_mod.PagedSpec | None = None):
    n_inv = len(cfg.invocation_points)
    if abstract:
        one_m = mamba_mod.mamba2_cache_specs(b, cfg.mamba_block.mamba, dtype)
        mstack = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers, *s.shape), s.dtype), one_m
        )
        one_a = (attn_mod.paged_cache_specs(paged, cfg.shared_attn, dtype) if paged is not None
                 else attn_mod.cache_specs(b, s_max, cfg.shared_attn, dtype))
        astack = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_inv, *s.shape), s.dtype), one_a
        )
    else:
        one_m = mamba_mod.mamba2_init_cache(b, cfg.mamba_block.mamba, dtype)
        mstack = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers, *a.shape)).copy(), one_m
        )
        one_a = (attn_mod.paged_init_cache(paged, cfg.shared_attn, dtype) if paged is not None
                 else attn_mod.init_cache(b, s_max, cfg.shared_attn, dtype))
        astack = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_inv, *a.shape)).copy(), one_a
        )
    return {"mamba": mstack, "attn": astack}


def _shared_block(
    cfg: HybridCfg, p: Params, x: jax.Array, x0: jax.Array, *,
    pos, cache, cache_len, block_tables=None, write_len=None,
) -> tuple[jax.Array, Params | None]:
    h = linear(cfg.fuse, p["fuse"], jnp.concatenate([x, x0], axis=-1))
    a, new_cache = attn_mod.attention(
        cfg.shared_attn, p["attn"], rmsnorm(p["norm1"], h),
        pos=pos, cache=cache, cache_len=cache_len,
        block_tables=block_tables, write_len=write_len,
    )
    h = h + a
    h = h + mlp_mod.mlp(cfg.shared_mlp, p["mlp"], rmsnorm(p["norm2"], h))
    return x + linear(cfg.out, p["out"], h), new_cache


def hybrid_apply(
    cfg: HybridCfg,
    params: Params,
    *,
    tokens: jax.Array,
    pos: jax.Array,
    caches: Params | None = None,
    cache_len: jax.Array | None = None,
    compute_dtype=jnp.float32,
    block_tables: jax.Array | None = None,
    write_len: jax.Array | None = None,
) -> tuple[jax.Array, Params | None, jax.Array]:
    x = embed(params["embed"], tokens).astype(compute_dtype)
    x0 = x

    def mamba_seg(x, lo, hi, cstack):
        if cfg.unroll:
            # eager layer loop so the conversion tape sees concrete arrays,
            # keyed by the registry's mamba_stack/<layer> prefixes
            from repro.models.common import set_tape_prefix

            new_c = [] if cstack is not None else None
            for j in range(hi - lo):
                set_tape_prefix(f"mamba_stack/{lo + j}")
                pl_ = jax.tree.map(lambda a: a[lo + j], params["mamba_stack"])
                cl_ = None if cstack is None else jax.tree.map(lambda a: a[lo + j], cstack)
                x, nc, _ = block_apply(cfg.mamba_block, pl_, x, pos=pos, cache=cl_)
                if cstack is not None:
                    new_c.append(nc)
            if cstack is not None:
                new_c = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_c)
            return x, new_c

        seg_p = jax.tree.map(lambda a: a[lo:hi], params["mamba_stack"])

        def body(xc, layer_in):
            if cstack is None:
                y, _, _ = block_apply(cfg.mamba_block, layer_in, xc, pos=pos)
                return y, None
            pl_, cl_ = layer_in
            y, nc, _ = block_apply(cfg.mamba_block, pl_, xc, pos=pos, cache=cl_)
            return y, nc

        fn = jax.checkpoint(body) if (cfg.remat and cstack is None) else body
        xs = seg_p if cstack is None else (seg_p, jax.tree.map(lambda a: a[lo:hi], cstack))
        return jax.lax.scan(fn, x, xs)

    new_m, new_a = [], []
    inv = 0
    for lo, hi in cfg.segment_bounds:
        x, nc = mamba_seg(x, lo, hi, None if caches is None else caches["mamba"])
        if caches is not None:
            new_m.append(nc)
        if hi in cfg.invocation_points:
            from repro.models.common import set_tape_prefix

            # the shared block is weight-shared across invocation points:
            # one registry path, activations pooled across invocations
            set_tape_prefix("shared")
            a_cache = (
                None if caches is None
                else jax.tree.map(lambda a: a[inv], caches["attn"])
            )
            x, nac = _shared_block(
                cfg, params["shared"], x, x0,
                pos=pos, cache=a_cache, cache_len=cache_len,
                block_tables=block_tables, write_len=write_len,
            )
            if caches is not None:
                new_a.append(nac)
            inv += 1

    x = rmsnorm(params["final_norm"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype))
    new_caches = None
    if caches is not None:
        mstack = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_m)
        astack = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_a)
        new_caches = {"mamba": mstack, "attn": astack}
    return logits, new_caches, jnp.zeros((), jnp.float32)

"""Elastic scaling: re-map a training job onto a different device pool.

On a real cluster this runs when nodes join/leave: the job checkpoints,
the coordinator rebuilds the mesh from the surviving hosts, and training
resumes with re-sharded state and a re-lowered step. All of that is
mesh-shape arithmetic + the checkpointer's reshard-on-restore path, so it
is fully exercisable on CPU host devices (tests/test_elastic.py scales a
run 8 -> 4 devices mid-training and the loss curve continues seamlessly).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.distributed.sharding import ShardingRules


def best_mesh_shape(n_devices: int, *, prefer_model: int = 1) -> tuple[int, int]:
    """(data, model) factorization for a surviving device count."""
    model = prefer_model
    while model > 1 and (n_devices % model or model > n_devices):
        model //= 2
    return n_devices // model, model


@dataclasses.dataclass
class ElasticContext:
    """Everything that must be rebuilt when the device pool changes."""

    mesh: jax.sharding.Mesh
    rules: ShardingRules
    step_fn: Callable          # freshly jitted for the new mesh

    @classmethod
    def build(
        cls,
        devices: list,
        make_step: Callable[[jax.sharding.Mesh, ShardingRules], Callable],
        *,
        prefer_model: int = 1,
        fsdp: bool = False,
    ) -> "ElasticContext":
        import numpy as np

        from repro.launch.mesh import mesh_from_devices

        data, model = best_mesh_shape(len(devices), prefer_model=prefer_model)
        mesh = mesh_from_devices(
            np.asarray(devices[: data * model]).reshape(data, model),
            ("data", "model"),
        )
        rules = ShardingRules(mesh, fsdp=fsdp)
        return cls(mesh=mesh, rules=rules, step_fn=make_step(mesh, rules))


def rescale(
    ckpt: Checkpointer,
    like: Any,
    new_ctx: ElasticContext,
    shardings: Any,
) -> tuple[int, Any]:
    """Restore the latest checkpoint re-sharded for the new mesh."""
    return ckpt.restore(like, shardings=shardings)

"""Token sampling for the serving engine (DESIGN.md §6.2).

Batched temperature / top-k / top-p / greedy sampling over one logits row
per slot. The whole filter+sample runs as a single jitted `(B, V)` kernel so
a mixed batch (greedy request next to a temperature-0.9 request) costs one
forward regardless of composition — per-slot parameters arrive as arrays,
never as python branches.

Determinism: each sampled token uses `fold_in(PRNGKey(seed), n_sampled)`,
keyed only on the request's seed and its own token index — never on slot
placement, batch composition, or prefill chunking — so the same request
replays identically under any scheduler interleaving (tested in
tests/test_serving.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling policy.

    temperature <= 0 selects greedy argmax (top_k/top_p are then ignored);
    top_k == 0 and top_p == 1.0 disable the respective filters.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self) -> None:
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        # seeds ride an int32 array through batch_arrays; fold oversized
        # values (e.g. time_ns()) here instead of overflowing mid-step
        if not -(2**31) <= self.seed < 2**31:
            object.__setattr__(self, "seed", self.seed & 0x7FFFFFFF)

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


@jax.jit
def sample_tokens(
    logits: jax.Array,     # (B, V) float
    temps: jax.Array,      # (B,) float32; <= 0 means greedy
    top_ks: jax.Array,     # (B,) int32; 0 disables
    top_ps: jax.Array,     # (B,) float32; 1.0 disables
    seeds: jax.Array,      # (B,) int32 per-request seed
    counters: jax.Array,   # (B,) int32 index of the token being sampled
) -> jax.Array:
    """One token per row. Greedy rows take argmax of the raw logits, so a
    greedy request through the sampler is bit-identical to `jnp.argmax`."""
    b, v = logits.shape
    logits = logits.astype(jnp.float32)
    greedy = temps <= 0.0

    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]              # (B, V)

    # top-k: mask everything strictly below the k-th largest value (ties at
    # the threshold survive — harmless, standard behavior)
    k_eff = jnp.where(top_ks > 0, jnp.clip(top_ks, 1, v), v)
    kth = jnp.take_along_axis(sorted_desc, k_eff[:, None] - 1, axis=-1)
    filtered = jnp.where(scaled < kth, -jnp.inf, scaled)

    # top-p (nucleus): keep the smallest sorted prefix whose mass reaches
    # top_p; "mass before this token < p" always keeps the top-1 token
    probs_desc = jax.nn.softmax(sorted_desc, axis=-1)
    mass_before = jnp.cumsum(probs_desc, axis=-1) - probs_desc
    keep_sorted = mass_before < top_ps[:, None]                   # prefix mask
    n_keep = jnp.sum(keep_sorted, axis=-1, dtype=jnp.int32)
    cutoff = jnp.take_along_axis(sorted_desc, n_keep[:, None] - 1, axis=-1)
    filtered = jnp.where(scaled < cutoff, -jnp.inf, filtered)

    def draw(seed, counter, row):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), counter)
        return jax.random.categorical(key, row)

    sampled = jax.vmap(draw)(seeds, counters, filtered)
    return jnp.where(greedy, jnp.argmax(logits, axis=-1), sampled).astype(jnp.int32)


def batch_arrays(params: list[SamplingParams], counters: list[int]):
    """Pack per-slot SamplingParams into the arrays `sample_tokens` takes."""
    return (
        jnp.asarray(np.array([p.temperature for p in params], np.float32)),
        jnp.asarray(np.array([p.top_k for p in params], np.int32)),
        jnp.asarray(np.array([p.top_p for p in params], np.float32)),
        jnp.asarray(np.array([p.seed for p in params], np.int32)),
        jnp.asarray(np.array(counters, np.int32)),
    )

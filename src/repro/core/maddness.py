"""MADDNESS baseline (Blalock & Guttag 2021) — hashing-based PQ encoding.

The paper's Fig. 3b / Table 4 baseline: instead of argmin over Euclidean
distances, each sub-vector is encoded by traversing a balanced binary
regression tree (depth log2(K), one split dimension per level, per-node
thresholds). Training is the greedy SSE-reduction heuristic; prototypes are
bucket means with an optional global ridge refit. Encoding is NOT
differentiable — which is exactly the failure mode LUT-NN's soft-PQ fixes.

Tree fitting runs offline in numpy (it is data-dependent control flow);
encoding is pure jnp and jit-friendly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class HashTree(NamedTuple):
    """Per-codebook balanced binary split trees.

    split_dims : (C, L) int32      — split dimension per level
    thresholds : (C, L, 2**(L-1))  — per-(level, bucket) thresholds (padded)
    """

    split_dims: jax.Array
    thresholds: jax.Array

    @property
    def depth(self) -> int:
        return self.split_dims.shape[-1]


def _fit_tree_1cb(x: np.ndarray, depth: int) -> tuple[np.ndarray, np.ndarray]:
    """Fit one codebook's tree on (N, V) sub-vectors. Greedy: at each level,
    pick the dim whose bucket-median split removes the most SSE."""
    n, v = x.shape
    buckets = np.zeros(n, np.int64)
    split_dims = np.zeros(depth, np.int32)
    thresholds = np.zeros((depth, 2 ** (depth - 1)), np.float32)
    for level in range(depth):
        nb = 2**level
        best_dim, best_gain = 0, -np.inf
        best_th = np.zeros(nb, np.float32)
        for dim in range(v):
            gain, ths = 0.0, np.zeros(nb, np.float32)
            col = x[:, dim]
            for b in range(nb):
                m = buckets == b
                if m.sum() < 2:
                    continue
                cb = col[m]
                th = np.median(cb)
                ths[b] = th
                lo, hi = cb[cb <= th], cb[cb > th]
                sse_parent = ((cb - cb.mean()) ** 2).sum()
                sse_kids = sum(((s - s.mean()) ** 2).sum() for s in (lo, hi) if len(s))
                gain += sse_parent - sse_kids
            if gain > best_gain:
                best_dim, best_gain, best_th = dim, gain, ths
        split_dims[level] = best_dim
        thresholds[level, :nb] = best_th[:nb]
        col = x[:, best_dim]
        buckets = buckets * 2 + (col > thresholds[level, buckets]).astype(np.int64)
    return split_dims, thresholds


def fit_hash_trees(acts: np.ndarray, *, k: int, v: int) -> HashTree:
    """acts: (N, D) activation samples -> trees for C = D // v codebooks."""
    depth = int(np.log2(k))
    if 2**depth != k:
        raise ValueError(f"MADDNESS needs power-of-two K, got {k}")
    n, d = acts.shape
    c = d // v
    sub = acts.reshape(n, c, v)
    dims, ths = zip(*(_fit_tree_1cb(np.asarray(sub[:, i, :], np.float32), depth) for i in range(c)))
    return HashTree(
        split_dims=jnp.asarray(np.stack(dims)),
        thresholds=jnp.asarray(np.stack(ths)),
    )


def maddness_encode(a: jax.Array, tree: HashTree, V: int) -> jax.Array:
    """Hash-encode (N, D) -> int32 (N, C) bucket indices via tree traversal."""
    n, d = a.shape
    c = d // V
    sub = a.reshape(n, c, V).astype(jnp.float32)
    bucket = jnp.zeros((n, c), jnp.int32)
    for level in range(tree.depth):                      # static L=log2(K) steps
        dim = tree.split_dims[:, level]                  # (C,)
        vals = jnp.take_along_axis(sub, dim[None, :, None], axis=2)[:, :, 0]  # (N, C)
        th_lvl = tree.thresholds[:, level, :]            # (C, 2**(L-1))
        th = jnp.take_along_axis(th_lvl[None, :, :], bucket[:, :, None], axis=2)[:, :, 0]
        bucket = bucket * 2 + (vals > th).astype(jnp.int32)
    return bucket


def bucket_prototypes(acts: np.ndarray, tree: HashTree, *, k: int, v: int) -> jax.Array:
    """Prototypes = per-bucket means (MADDNESS 'centroids'): (C, K, V)."""
    idx = np.asarray(maddness_encode(jnp.asarray(acts), tree, v))   # (N, C)
    n, d = acts.shape
    c = d // v
    sub = acts.reshape(n, c, v)
    protos = np.zeros((c, k, v), np.float32)
    for ci in range(c):
        for b in range(k):
            m = idx[:, ci] == b
            protos[ci, b] = sub[m, ci].mean(0) if m.any() else sub[:, ci].mean(0)
    return jnp.asarray(protos)

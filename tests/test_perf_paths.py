"""Regression tests for the section-Perf optimizations (EXPERIMENTS.md §5):
int8 one-hot contraction, fp8 KV cache, deferred cache writes, MoE routing
groups — each must preserve model-level correctness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core import pq, quant
from repro.core.amm import Mode
import repro.models.transformer as tf


def test_int8_dot_matches_dequant_path(key):
    """lut_contract_int8 == dequantize-then-fp-contract, exactly."""
    k1, k2 = jax.random.split(key)
    enc_idx = jax.random.randint(k1, (32, 6), 0, 16)
    enc = jax.nn.one_hot(enc_idx, 16, dtype=jnp.float32)
    T = jax.random.normal(k2, (6, 16, 48))
    qt = quant.quantize_table(T, m_shared=True)
    ref = pq.lut_contract(enc, qt.dequant(jnp.float32))
    out = pq.lut_contract_int8(enc, qt.q, qt.scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


def test_int8_dot_model_level(key):
    """Whole-model LUT_INFER forward with int8_dot stays finite and close to
    the fp path built from the same tables."""
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
    m_fp = build_model(arch, Mode.LUT_INFER)
    m_i8 = build_model(dataclasses.replace(arch, lut_int8_dot=True), Mode.LUT_INFER)
    p_i8 = m_i8.init(key)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, arch.vocab)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    logits, _, _ = tf.lm_apply(m_i8.cfg, p_i8, tokens=toks, pos=pos, compute_dtype=jnp.float32)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("cache_dtype", [jnp.bfloat16, jnp.float8_e4m3fn])
def test_decode_consistency_cache_dtypes(cache_dtype, key):
    """Deferred-write decode == full forward for bf16 AND fp8 caches."""
    arch = reduce_arch(get_arch("llama3_8b"), n_layers=2)
    m = build_model(arch, Mode.DENSE)
    params = m.init(key)
    B, S, S_pre = 2, 10, 6
    toks = jax.random.randint(key, (B, S), 0, arch.vocab)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    full, _, _ = tf.lm_apply(m.cfg, params, tokens=toks, pos=pos, compute_dtype=jnp.float32)

    caches = m.init_caches(B, S, dtype=cache_dtype)
    lg, caches = m.forward_step(
        params, {"tokens": toks[:, :S_pre], "cache_len": jnp.zeros((B,), jnp.int32)},
        caches, compute_dtype=jnp.float32,
    )
    tol = 5e-3 if cache_dtype == jnp.bfloat16 else 0.12   # fp8 KV: lossy by design
    for i in range(S_pre, S):
        lg, caches = m.forward_step(
            params, {"tokens": toks[:, i : i + 1], "cache_len": jnp.full((B,), i, jnp.int32)},
            caches, compute_dtype=jnp.float32,
        )
        ref = np.asarray(full[:, i])
        got = np.asarray(lg[:, 0])
        rel = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
        assert rel < tol, (cache_dtype, i, rel)
        # (argmax identity is a trained-model property; random-init logits
        # are ~uniform noise, so only the relative error is asserted here)


def test_moe_group_tokens_invariance(key):
    """Routing-group size changes cost, not routing math: outputs match for
    group sizes that tile the sequence identically."""
    # top_k == n_experts -> every token reaches every expert and capacity
    # (cf*k*s/e >= s) never truncates: outputs must be exactly group-size
    # invariant (isolates the grouping plumbing from capacity-drop policy)
    arch = reduce_arch(
        get_arch("llama4_maverick_400b"), n_layers=2, n_experts=2, top_k=2,
        moe_shared_expert=False,
    )
    m8 = build_model(dataclasses.replace(arch, moe_group_tokens=8), Mode.DENSE)
    m4 = build_model(dataclasses.replace(arch, moe_group_tokens=4), Mode.DENSE)
    params = m8.init(key)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, arch.vocab)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    l8, _, _ = tf.lm_apply(m8.cfg, params, tokens=toks, pos=pos, compute_dtype=jnp.float32)
    l4, _, _ = tf.lm_apply(m4.cfg, params, tokens=toks, pos=pos, compute_dtype=jnp.float32)
    # same experts chosen per token (capacity generous at this size)
    np.testing.assert_allclose(np.asarray(l8), np.asarray(l4), rtol=2e-3, atol=2e-3)


def test_use_kernel_model_level_matches_xla_path(key):
    """Whole-model LUT_INFER forward through the fused Pallas v2 kernel
    (interpret mode off-TPU) == the pure-XLA one-hot path, same params.
    Exercises the fused bias epilogue wiring in repro.core.amm."""
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, use_bias=True)
    m_xla = build_model(arch, Mode.LUT_INFER)
    m_krn = build_model(dataclasses.replace(arch, lut_use_kernel=True), Mode.LUT_INFER)
    params = m_krn.init(key)   # (1,1,M)-scale layout works on both paths
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, arch.vocab)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    lg_k, _, _ = tf.lm_apply(m_krn.cfg, params, tokens=toks, pos=pos, compute_dtype=jnp.float32)
    lg_x, _, _ = tf.lm_apply(m_xla.cfg, params, tokens=toks, pos=pos, compute_dtype=jnp.float32)
    assert np.isfinite(np.asarray(lg_k)).all()
    np.testing.assert_allclose(np.asarray(lg_k), np.asarray(lg_x), rtol=2e-4, atol=2e-4)

"""Hypothesis property tests for the PQ core — split from test_pq.py so the
unit suite survives environments without hypothesis installed."""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given  # noqa: E402

from repro.core import pq  # noqa: E402

hypothesis.settings.register_profile(
    "fast", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("fast")


@given(
    n=st.integers(2, 12),
    c=st.integers(1, 4),
    k=st.integers(2, 8),
    v=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_property_reconstruction_error_le_worst_centroid(n, c, k, v, seed):
    """PQ reconstruction picks the NEAREST centroid: its distance is <= the
    distance to any other centroid, per codebook (Lloyd optimality of the
    encoding step, Eq. 2)."""
    kk = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(kk)
    x = jax.random.normal(k1, (n, c * v))
    P = jax.random.normal(k2, (c, k, v))
    d = pq.pairwise_sq_dists(pq.split_subvectors(x, v), P)
    chosen = jnp.min(d, -1)
    assert bool(jnp.all(chosen[..., None] <= d + 1e-6))


@given(
    n=st.integers(2, 10),
    k=st.integers(2, 6),
    v=st.integers(1, 4),
    m=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_property_amm_linear_in_weight(n, k, v, m, seed):
    """h^c (Eq. 3) and the AMM output are linear in W: AMM(x; aW) = a*AMM."""
    kk = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(kk, 3)
    x = jax.random.normal(k1, (n, 2 * v))
    P = jax.random.normal(k2, (2, k, v))
    W = jax.random.normal(k3, (2 * v, m))
    enc = pq.hard_encode(pq.pairwise_sq_dists(pq.split_subvectors(x, v), P))
    o1 = pq.lut_contract(enc, pq.build_table(P, 3.0 * W, stop_weight_grad=False))
    o2 = 3.0 * pq.lut_contract(enc, pq.build_table(P, W, stop_weight_grad=False))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-4)

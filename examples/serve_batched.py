"""Serving example: batched requests through the continuous-batching engine
with int8 LUT tables (the paper's deployment mode), chunked prefill, and
nucleus sampling.

  PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main([
        "--arch", "qwen3_1p7b", "--requests", "8", "--slots", "4",
        "--prefill-chunk", "8", "--temperature", "0.8", "--top-p", "0.95",
        "--seed", "0",
    ])

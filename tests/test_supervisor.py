"""Supervised crash recovery (DESIGN.md §11.4): worker kill -> restart from
artifact -> requeue with token parity; retry-budget / max-restart exhaustion
resolves every rid instead of hanging. Spawns real worker processes, so
these are the slowest serving tests (~tens of seconds on CPU)."""

import jax
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.artifact import save_artifact
from repro.serving.faults import FaultSpec
from repro.serving.supervisor import EngineSupervisor

ENGINE_KW = dict(n_slots=2, max_seq=64, prefill_chunk=4)


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=1)
    bundle = build_model(arch, Mode.DENSE)
    params = bundle.init(jax.random.PRNGKey(0))
    path = tmp_path_factory.mktemp("sup") / "artifact"
    save_artifact(path, bundle, params)
    return path


def _specs(n=3):
    return [{"prompt": [i * 3 + 1, i * 3 + 2, i * 3 + 3], "max_tokens": 4}
            for i in range(n)]


def test_kill_restart_requeue_token_parity(artifact):
    # fault-free reference — also exercises supervisor-side cancel/timeout
    events: dict[int, list] = {}
    ref = EngineSupervisor(artifact, engine_kwargs=ENGINE_KW)
    try:
        grids = [ref.submit(s) for s in _specs()]
        g_cancel = ref.submit({"prompt": [9, 9], "max_tokens": 4})
        assert ref.cancel(g_cancel) is True       # cancelled from the outbox
        assert ref.cancel(g_cancel) is False      # already terminal
        g_late = ref.submit({"prompt": [8, 8], "max_tokens": 4,
                             "deadline_s": 1e-4})
        baseline = {g: ref.wait(g, timeout=300) for g in grids}
        assert all(st.status == "ok" for st in baseline.values())
        assert ref.wait(g_cancel, timeout=60).status == "cancelled"
        # deadline spent before the worker ever saw it: local timeout
        assert ref.wait(g_late, timeout=60).status == "timeout"
        assert ref.stats()["restarts"] == 0
    finally:
        ref.close()

    # kill the worker mid-run: restart from the artifact, requeue, replay
    sup = EngineSupervisor(
        artifact, engine_kwargs=ENGINE_KW,
        faults=FaultSpec(kill_at_step=1), retry_budget=2,
    )
    try:
        grids = []
        for s in _specs():
            g = sup.submit(s, on_event=lambda ev, _l=events.setdefault(
                len(events), []): _l.append(ev))
            grids.append(g)
        states = {g: sup.wait(g, timeout=300) for g in grids}
        stats = sup.stats()
        assert stats["restarts"] >= 1
        assert stats["requeued"] >= 1
        assert stats["lost"] == 0
        for g in grids:
            st = states[g]
            assert st.status == "ok"              # no rid silently lost
            # deterministic per-request sampling: the replayed generation is
            # byte-identical to the fault-free run
            assert st.tokens == list(baseline[g].tokens), g
        # a request that had streamed tokens before the crash told its
        # subscriber to discard them
        restart_evs = [ev for evs in events.values() for ev in evs
                       if ev[0] == "restart"]
        requeued_with_tokens = [g for g in grids if states[g].retries > 0]
        if requeued_with_tokens:
            assert restart_evs or all(
                not any(e[0] == "tokens" for e in evs) for evs in events.values()
            )
    finally:
        sup.close()


def test_crash_loop_exhausts_restarts_and_fails(artifact):
    # the fault respawns with EVERY worker incarnation: a crash loop. After
    # max_restarts consecutive deaths the supervisor fails closed — every
    # live rid resolves as "error", new submits are refused, nothing hangs.
    sup = EngineSupervisor(
        artifact, engine_kwargs=ENGINE_KW,
        faults=FaultSpec(kill_at_step=0), faults_once=False,
        retry_budget=5, max_restarts=1, healthy_after_s=3600.0,
    )
    try:
        g = sup.submit({"prompt": [1, 2, 3], "max_tokens": 4})
        st = sup.wait(g, timeout=300)
        assert st.status == "error"
        stats = sup.stats()
        assert stats["failed"] == 1
        assert not sup.healthy
        assert sup.pending() == 0
        with pytest.raises(RuntimeError, match="supervisor failed"):
            sup.submit({"prompt": [1], "max_tokens": 1})
    finally:
        sup.close()


def test_missing_artifact_fails_closed_immediately(tmp_path):
    # no crash-loop burning max_restarts against a directory that cannot be
    # served: the pre-spawn probe fails closed with an actionable error
    sup = EngineSupervisor(tmp_path / "nope", max_restarts=50)
    try:
        assert sup.wait_ready(timeout=60)         # unblocked, not hung
        assert not sup.healthy
        assert sup.stats()["spawns"] == 0         # never even spawned
        with pytest.raises(RuntimeError, match="not serveable"):
            sup.submit({"prompt": [1], "max_tokens": 1})
    finally:
        sup.close()


def test_artifact_vanishing_between_restarts_fails_closed(artifact, tmp_path):
    # the router multiplies how often the restart path runs: a worker crash
    # with the artifact gone must resolve every rid as "error" after ONE
    # failed probe, not spin through max_restarts respawn attempts
    import shutil

    copy = tmp_path / "artifact"
    shutil.copytree(artifact, copy)
    sup = EngineSupervisor(
        copy, engine_kwargs=ENGINE_KW,
        faults=FaultSpec(kill_at_step=1), max_restarts=50,
    )
    try:
        assert sup.wait_ready(timeout=300)
        g = sup.submit({"prompt": [1, 2, 3], "max_tokens": 8})
        shutil.rmtree(copy)          # gone before the injected crash restarts
        st = sup.wait(g, timeout=300)
        assert st.status == "error"
        assert not sup.healthy
        assert sup.pending() == 0
        assert sup.stats()["spawns"] == 1         # no respawn against the void
        with pytest.raises(RuntimeError, match="not serveable"):
            sup.submit({"prompt": [1], "max_tokens": 1})
    finally:
        sup.close()


def test_check_artifact_dir_probe(artifact, tmp_path):
    from repro.serving.artifact import check_artifact_dir

    manifest = check_artifact_dir(artifact)
    assert isinstance(manifest, dict)
    with pytest.raises(FileNotFoundError):
        check_artifact_dir(tmp_path / "absent")
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "manifest.json").write_text("{not json")
    with pytest.raises(ValueError, match="manifest"):
        check_artifact_dir(bad)

"""Fused encode→lookup decode kernel (v3, DESIGN.md §13) vs the oracle,
plus the version-dispatch wiring in repro.kernels.ops.

Acceptance (ISSUE 8): byte-/token-parity with the two-pass path across
ragged shapes and every scale layout; fused bias/activation epilogue; a
structural guarantee that the codes live in VMEM scratch and never touch
HBM; and `ops.lut_amm` routing by the per-shape autotune record.
"""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import autotune, ops
from repro.kernels.fused_decode import (
    _fused_decode_call,
    _fused_decode_kernel,
    fused_decode_pallas,
)
from repro.kernels.lut_amm import lut_amm_pallas
from repro.kernels.ref import encode_ref, lut_amm_ref

# N/M not multiples of the blocks; C ragged against the v1/v2 block_c axis
RAGGED = [
    # (N, D, M, K, V, block_n, block_m)
    (33, 64, 70, 16, 8, 16, 64),
    (100, 64, 130, 16, 32, 32, 128),
    (7, 96, 130, 8, 16, 8, 128),
    (65, 160, 48, 16, 32, 64, 128),
    (17, 96, 384, 16, 16, 16, 256),
]


def _mk(n, d, m, k, v, seed=None):
    k1, k2, k3 = jax.random.split(
        jax.random.PRNGKey(seed if seed is not None else n * d), 3
    )
    x = jax.random.normal(k1, (n, d))
    P = jax.random.normal(k2, (d // v, k, v))
    T = jax.random.normal(k3, (d // v, k, m))
    return x, P, T


@pytest.mark.parametrize("shape", RAGGED, ids=[str(s[:5]) for s in RAGGED])
@pytest.mark.parametrize("layout", ["per_codebook", "per_column", "m_shared"])
def test_fused_ragged_shapes_all_scale_layouts(shape, layout):
    """Acceptance sweep: fused matches the fp32 dequantize reference within
    1e-4 on ragged shapes across every scale layout."""
    n, d, m, k, v, bn, bm = shape
    x, P, T = _mk(n, d, m, k, v)
    kw = {"per_column": layout == "per_column", "m_shared": layout == "m_shared"}
    qt = quant.quantize_table(T, bits=8, **kw)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = fused_decode_pallas(
        x, P, qt.q, qt.scale, block_n=bn, block_m=bm, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("shape", RAGGED[:3], ids=[str(s[:5]) for s in RAGGED[:3]])
def test_fused_byte_parity_with_v2_m_shared(shape):
    """On the deployed m-shared layout both kernels accumulate raw int32 and
    dequantize once — the outputs must be BYTE-identical, not merely close."""
    n, d, m, k, v, bn, bm = shape
    x, P, T = _mk(n, d, m, k, v, seed=11 + n)
    qt = quant.quantize_table(T, m_shared=True)
    v2 = lut_amm_pallas(
        x, P, qt.q, qt.scale, block_n=bn, block_m=bm, interpret=True
    )
    fused = fused_decode_pallas(
        x, P, qt.q, qt.scale, block_n=bn, block_m=bm, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(v2))


def test_fused_encode_agrees_with_encode_ref():
    """Token parity: the argmin the fused kernel bakes into its one-hot codes
    is the reference encode — verified end-to-end by contracting against an
    identity-scale table whose (c, k) slots are distinct powers of 2."""
    n, d, k, v = 24, 64, 8, 8
    c = d // v
    x, P, _ = _mk(n, d, 1, k, v, seed=5)
    # table_q[c, k, 0] = unique id per (c, k) slot so the contraction output
    # uniquely determines the chosen code per codebook
    ids = jnp.arange(c * k, dtype=jnp.int8).reshape(c, k, 1)
    scale = jnp.ones((1, 1, 1), jnp.float32)
    out = fused_decode_pallas(x, P, ids, scale, block_n=8, block_m=1,
                              interpret=True)
    codes = np.asarray(encode_ref(x, P))                    # (n, c)
    want = (codes + np.arange(c)[None, :] * k).sum(axis=1)  # sum of slot ids
    np.testing.assert_array_equal(np.asarray(out[:, 0]), want.astype(np.float32))


@pytest.mark.parametrize("act", ["none", "relu", "silu", "gelu", "relu2"])
def test_fused_bias_activation_epilogue(act):
    import repro.models.common as common

    n, d, m, k, v = 40, 64, 100, 16, 8
    x, P, T = _mk(n, d, m, k, v, seed=7)
    b = jax.random.normal(jax.random.PRNGKey(9), (m,))
    qt = quant.quantize_table(T, m_shared=True)
    ref = lut_amm_ref(x, P, qt.q, qt.scale) + b
    if act != "none":
        ref = common.activation(act, ref)
    out = fused_decode_pallas(
        x, P, qt.q, qt.scale, bias=b, act=act,
        block_n=16, block_m=64, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_fused_autotuned_default_blocks():
    """No explicit blocks -> the wrapper takes the fused heuristic tiling
    and still matches the oracle."""
    n, d, m, k, v = 50, 96, 75, 16, 16
    x, P, T = _mk(n, d, m, k, v, seed=3)
    qt = quant.quantize_table(T)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = fused_decode_pallas(x, P, qt.q, qt.scale, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_fused_chunked_per_codebook_path():
    """Tiles big enough that the (chunk, bn, bm) int32 partial bound kicks
    in: chunk_c = 2^21/(4·32·2048) = 8 < C = 32, so the per-codebook
    contraction runs 4 chunks — each rescaled in fp32 — and must still
    match the oracle."""
    n, d, m, k, v = 32, 256, 2048, 16, 8         # C = 32
    x, P, T = _mk(n, d, m, k, v, seed=13)
    qt = quant.quantize_table(T, per_column=True)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = fused_decode_pallas(
        x, P, qt.q, qt.scale, block_n=32, block_m=2048, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_fused_structure_codes_never_hbm():
    """Structural acceptance: the codes buffer is VMEM scratch — it has no
    output ref, the pallas_call has exactly ONE out_shape (the (N, M)
    result), so codes cannot be materialized to HBM."""
    src = inspect.getsource(_fused_decode_call)
    # single output: out_shape is one ShapeDtypeStruct, not a tuple/list
    assert src.count("out_shape=") == 1
    assert "out_shape=jax.ShapeDtypeStruct" in src
    # the code buffer is declared as VMEM scratch, not an operand/output
    assert "scratch_shapes=[pltpu.VMEM(code_shape, jnp.int8)]" in src

    ksrc = inspect.getsource(_fused_decode_kernel)
    # encode runs once per N tile, guarded on the first M step
    assert "pl.when(m_step == 0)" in ksrc
    # output tile written exactly once — no read-modify-write accumulation
    assert ksrc.count("o_ref[...] =") == 1
    assert "o_ref[...] +=" not in ksrc and "= o_ref" not in ksrc
    # int8 MXU contraction, not an fp32 table materialization
    assert "t_ref[...].astype" not in ksrc
    assert "preferred_element_type=jnp.int32" in ksrc


# ---------------------------------------------------------------------------
# ops.lut_amm version dispatch
# ---------------------------------------------------------------------------

def _spy(monkeypatch, calls):
    for name, attr in [("fused", "fused_decode_pallas"),
                       ("v2", "lut_amm_pallas"),
                       ("v1", "lut_amm_pallas_v1")]:
        real = getattr(ops, attr)

        def wrap(*a, _real=real, _name=name, **kw):
            calls.append(_name)
            return _real(*a, **kw)

        monkeypatch.setattr(ops, attr, wrap)


@pytest.mark.parametrize("version,expect", [(1, "v1"), (2, "v2"), (3, "fused")])
def test_ops_explicit_version_forces_generation(monkeypatch, version, expect):
    calls = []
    _spy(monkeypatch, calls)
    x, P, T = _mk(16, 32, 48, 16, 4, seed=21)
    qt = quant.quantize_table(T, m_shared=True)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = ops.lut_amm(x, P, qt.q, qt.scale, version=version, interpret=True)
    assert calls == [expect]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_ops_explicit_blocks_keep_historical_v2(monkeypatch):
    """Callers that pass block sizes but no version (op_microbench's v2
    column, older call sites) must keep getting the v2 kernel."""
    calls = []
    _spy(monkeypatch, calls)
    x, P, T = _mk(16, 32, 48, 16, 4, seed=22)
    qt = quant.quantize_table(T, m_shared=True)
    ops.lut_amm(x, P, qt.q, qt.scale, block_n=8, block_m=48, interpret=True)
    assert calls == ["v2"]


def test_ops_default_follows_autotune_record(monkeypatch, tmp_path):
    """With no explicit version/blocks, ops.lut_amm consults the per-shape
    autotune record: a version=3 record routes to the fused kernel."""
    calls = []
    _spy(monkeypatch, calls)
    n, d, m, k, v = 16, 32, 48, 16, 4
    c = d // v
    cache = autotune.get_cache()
    key = autotune.shape_key("lut_amm", n, m, c, k, v, "float32",
                             autotune._backend())
    cache.put(key, {"block_n": 8, "block_m": 48, "block_c": c,
                    "version": 3, "measured": True, "source": "wallclock"})
    x, P, T = _mk(n, d, m, k, v, seed=23)
    qt = quant.quantize_table(T, m_shared=True)
    out = ops.lut_amm(x, P, qt.q, qt.scale, interpret=True)
    assert calls == ["fused"]
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(lut_amm_ref(x, P, qt.q, qt.scale)),
        atol=1e-4,
    )


def test_ops_no_record_small_m_interpret_falls_back_to_v1(monkeypatch):
    """ISSUE 8 satellite: the v2-slower-than-v1 regression fix — with no
    record, interpret-mode small-M shapes run v1, not v2."""
    calls = []
    _spy(monkeypatch, calls)
    x, P, T = _mk(16, 32, 48, 16, 4, seed=24)
    qt = quant.quantize_table(T, m_shared=True)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = ops.lut_amm(x, P, qt.q, qt.scale, interpret=True)
    assert calls == ["v1"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_ops_v1_with_m_shared_scale_and_bias():
    """The dispatch shim broadcasts m-shared scales to v1's (C, ...) layout
    and applies bias/activation outside the kernel — same contract as the
    fused generations."""
    import repro.models.common as common

    x, P, T = _mk(16, 32, 48, 16, 4, seed=25)
    b = jax.random.normal(jax.random.PRNGKey(2), (48,))
    qt = quant.quantize_table(T, m_shared=True)
    ref = common.activation("relu", lut_amm_ref(x, P, qt.q, qt.scale) + b)
    out = ops.lut_amm(x, P, qt.q, qt.scale, bias=b, act="relu",
                      version=1, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)

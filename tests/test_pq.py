"""Unit tests for the PQ core (paper Eqs. 1-6).

Property-based (hypothesis) cases live in test_pq_properties.py, guarded so
this module still runs when hypothesis is not installed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pq
from repro.core.temperature import init_log_temperature, temperature


def _mk(key, n, d, m, k, v):
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, d))
    P = jax.random.normal(k2, (d // v, k, v))
    W = jax.random.normal(k3, (d, m))
    return x, P, W


def test_split_subvectors_roundtrip(key):
    x = jax.random.normal(key, (5, 12))
    assert pq.split_subvectors(x, 4).shape == (5, 3, 4)
    np.testing.assert_array_equal(
        np.asarray(pq.split_subvectors(x, 4).reshape(5, 12)), np.asarray(x)
    )
    with pytest.raises(ValueError):
        pq.split_subvectors(x, 5)


def test_distances_match_naive(key):
    x, P, _ = _mk(key, 7, 8, 3, 4, 2)
    sub = pq.split_subvectors(x, 2)
    d = pq.pairwise_sq_dists(sub, P)
    naive = np.zeros((7, 4, 4))
    for n in range(7):
        for c in range(4):
            for kk in range(4):
                naive[n, c, kk] = np.sum(
                    (np.asarray(sub[n, c]) - np.asarray(P[c, kk])) ** 2
                )
    np.testing.assert_allclose(np.asarray(d), naive, rtol=1e-4, atol=1e-4)


def test_hard_encode_is_argmin_onehot(key):
    x, P, _ = _mk(key, 16, 8, 3, 4, 2)
    d = pq.pairwise_sq_dists(pq.split_subvectors(x, 2), P)
    enc = pq.hard_encode(d)
    assert np.allclose(np.asarray(enc.sum(-1)), 1.0)
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(enc, -1)), np.asarray(jnp.argmin(d, -1))
    )


def test_ste_forward_equals_hard_backward_soft(key):
    x, P, W = _mk(key, 8, 8, 6, 4, 2)
    d = pq.pairwise_sq_dists(pq.split_subvectors(x, 2), P)
    t = jnp.asarray(0.7)
    ste = pq.ste_encode(d, t)
    np.testing.assert_allclose(
        np.asarray(ste), np.asarray(pq.hard_encode(d)), atol=1e-6
    )
    # gradient flows through the soft branch
    g_ste = jax.grad(lambda dd: jnp.sum(pq.ste_encode(dd, t) ** 2))(d)
    g_soft_of_hard = jax.grad(lambda dd: jnp.sum(pq.hard_encode(dd) ** 2))(d)
    assert float(jnp.abs(g_ste).sum()) > 0
    assert float(jnp.abs(g_soft_of_hard).sum()) == 0  # argmin alone: no grads


def test_soft_approaches_hard_as_t_to_zero(key):
    # controlled distance gaps (>=0.25) so the limit is well-conditioned;
    # random data can produce near-ties where soft correctly stays at ~0.5
    d = jax.random.uniform(key, (32, 3, 4)) * 0.1
    d = d + 0.25 * jnp.argsort(jax.random.uniform(jax.random.PRNGKey(7), (32, 3, 4)), -1)
    hard = pq.hard_encode(d)
    for t, tol in ((1e-2, 1e-5), (1e-3, 1e-9)):
        soft = pq.soft_encode(d, jnp.asarray(t))
        assert float(jnp.max(jnp.abs(soft - hard))) < tol


def test_centroid_exactness(key):
    """AMM is EXACT when input rows are themselves centroids (paper: the
    approximation error is entirely input-to-centroid distance)."""
    x, P, W = _mk(key, 8, 8, 6, 4, 2)
    # build inputs whose sub-vectors are centroid rows
    idx = jax.random.randint(key, (8, 4), 0, 4)
    a = jnp.take_along_axis(P[None], idx[:, :, None, None], axis=2)[:, :, 0].reshape(8, 8)
    T = pq.build_table(P, W, stop_weight_grad=False)
    enc = pq.hard_encode(pq.pairwise_sq_dists(pq.split_subvectors(a, 2), P))
    out = pq.lut_contract(enc, T)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ W), rtol=1e-4, atol=1e-4)


def test_gather_matches_onehot(key):
    x, P, W = _mk(key, 9, 8, 5, 4, 2)
    T = pq.build_table(P, W, stop_weight_grad=False)
    idx = pq.encode_indices(x, P)
    g = pq.gather_lut(idx, T)
    enc = pq.hard_encode(pq.pairwise_sq_dists(pq.split_subvectors(x, 2), P))
    o = pq.lut_contract(enc, T)
    np.testing.assert_allclose(np.asarray(g), np.asarray(o), rtol=1e-5, atol=1e-5)


def test_build_table_stops_weight_grad(key):
    x, P, W = _mk(key, 4, 8, 5, 4, 2)

    def f(w):
        return jnp.sum(pq.build_table(P, w) ** 2)

    g = jax.grad(f)(W)
    assert float(jnp.abs(g).sum()) == 0.0
    g2 = jax.grad(lambda w: jnp.sum(pq.build_table(P, w, stop_weight_grad=False) ** 2))(W)
    assert float(jnp.abs(g2).sum()) > 0.0


def test_temperature_param():
    lt = init_log_temperature(1.0)
    assert float(temperature(lt)) == pytest.approx(1.0)
    assert float(temperature(jnp.asarray(-50.0))) >= 0.99e-4  # floor (fp32)

"""jit'd public wrappers for the LUT kernels with platform dispatch.

`lut_amm` runs the fused Pallas kernel on TPU and transparently falls back to
interpret mode elsewhere (this container is CPU-only: interpret=True executes
the kernel body in Python for correctness validation; the XLA one-hot path in
repro.core.pq is the production fallback used by the distributed dry-run).

The default entry points are the v2 kernels (int8-native MXU table read,
VMEM scratch accumulation, fused bias/activation epilogue — DESIGN.md §2.3)
with autotuned block sizes (DESIGN.md §3). `lut_amm_v1` keeps the original
kernel callable for side-by-side benchmarking.
"""

from __future__ import annotations

import jax

from repro.kernels.dist_argmin import encode_pallas
from repro.kernels.lut_amm import lut_amm_pallas, lut_amm_pallas_v1
from repro.kernels.ref import encode_ref, lut_amm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def lut_amm(
    x: jax.Array,
    centroids: jax.Array,
    table_q: jax.Array,
    scale: jax.Array,
    *,
    bias: jax.Array | None = None,
    act: str = "none",
    block_n: int | None = None,
    block_m: int | None = None,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused LUT-NN approximate matmul (v2): (N, D) -> (N, M)."""
    if interpret is None:
        interpret = not _on_tpu()
    return lut_amm_pallas(
        x,
        centroids,
        table_q,
        scale,
        bias=bias,
        act=act,
        block_n=block_n,
        block_m=block_m,
        block_c=block_c,
        interpret=interpret,
    )


def lut_amm_v1(
    x: jax.Array,
    centroids: jax.Array,
    table_q: jax.Array,
    scale: jax.Array,
    *,
    block_n: int = 256,
    block_m: int = 512,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Original fused kernel (fp32 dequant per step + o_ref accumulation)."""
    if interpret is None:
        interpret = not _on_tpu()
    return lut_amm_pallas_v1(
        x,
        centroids,
        table_q,
        scale,
        block_n=block_n,
        block_m=block_m,
        block_c=block_c,
        interpret=interpret,
    )


def encode(
    x: jax.Array,
    centroids: jax.Array,
    *,
    block_n: int | None = None,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Closest-centroid encode: (N, D) -> int32 (N, C)."""
    if interpret is None:
        interpret = not _on_tpu()
    return encode_pallas(
        x, centroids, block_n=block_n, block_c=block_c, interpret=interpret
    )


__all__ = ["lut_amm", "lut_amm_v1", "encode", "lut_amm_ref", "encode_ref"]

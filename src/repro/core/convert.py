"""Dense -> LUT model conversion (the paper's offline pipeline, section 6.1).

  1. graft: copy the trained dense model's weights into a freshly-built
     LUT_TRAIN model (same arch, LUT replacement plan applied); replaced
     layers keep their dense weight as the frozen table source.
  2. k-means init: run the original model on ~1024 training samples with the
     activation tape on, cluster every replaced site's inputs per codebook
     (Eq. 1), write the centroids into the LUT params.
  3. (after soft-PQ fine-tuning) deploy: build + int8-quantize the tables,
     drop the dense weights -> the serving param tree; `deploy_to_artifact`
     additionally packages the result as an on-disk LUTArtifact
     (repro.serving.artifact, DESIGN.md §8) so a fresh server can load it
     with no knowledge of the train-time pytree.

All three passes are family-agnostic walks of the site registry
(`ModelBundle.sites()`, DESIGN.md §9.2): activation-tape records join to
centroid leaves on (layer, kind), and deployed tables are built per
registered site with that site's own LUTConfig — no per-family path-string
surgery.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ModelBundle, build_model
from repro.core import kmeans, pq, quant
from repro.core.amm import Mode
from repro.models.common import tape_capture

# LUT_TRAIN leaves with no dense-model source: these legitimately keep
# their fresh init through the graft. Anything else unmatched is a drifted
# tree and must fail loudly instead of silently serving random weights.
_TRAINABLE_LUT_LEAVES = ("centroids", "log_t")


def _flat_paths(tree: Any) -> dict[str, jax.Array]:
    out = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[path] = leaf
    return out


def graft_dense_to_lut(dense_params: Any, lut_params: Any) -> Any:
    """Copy every shared leaf (w/b/norm/embed) from the dense model into the
    LUT_TRAIN tree.

    Direct path+shape matches cover the families whose stacking is
    identical across modes (hybrid, enc-dec, and all unreplaced leaves).
    LM segments are re-aligned by global layer index: the dense model has
    one segment of L layers, the LUT model splits the same layers into
    per-plan runs. Only the trainable LUT leaves (centroids, log_t) may
    keep their fresh init — any other unmatched leaf raises.
    """
    dflat = _flat_paths(dense_params)
    lflat = _flat_paths(lut_params)

    # global layer offset per lut segment (LM family only)
    offsets: list[int] = []
    if isinstance(lut_params, dict) and "segments" in lut_params:
        off = 0
        for seg in lut_params["segments"]:
            offsets.append(off)
            off += jax.tree.leaves(seg)[0].shape[0]

    out = {}
    for path, leaf in lflat.items():
        if path in dflat and dflat[path].shape == leaf.shape:
            out[path] = dflat[path]
            continue
        if offsets and path.startswith("segments/"):
            parts = path.split("/")
            seg_i = int(parts[1])
            rest = "/".join(parts[2:])
            src = dflat.get(f"segments/0/{rest}")
            if src is not None and src.shape[1:] == leaf.shape[1:]:
                lo = offsets[seg_i]
                out[path] = src[lo : lo + leaf.shape[0]]
                continue
        if path.rsplit("/", 1)[-1] in _TRAINABLE_LUT_LEAVES:
            out[path] = leaf        # centroids / log_t: keep init
            continue
        raise ValueError(
            f"graft: no dense source for {path} (shape {leaf.shape}) — the "
            f"dense and LUT models were built from different archs/plans"
        )
    leaves = [out[p] for p in lflat]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(lut_params), leaves)


def _unrolled(bundle: ModelBundle) -> ModelBundle:
    """Same bundle with an eager python-loop layer walk (tape capture)."""
    cfg = dataclasses.replace(bundle.cfg, unroll=True, remat=False)
    return dataclasses.replace(bundle, cfg=cfg)


def kmeans_init_lut(
    bundle_dense: ModelBundle,
    dense_params: Any,
    bundle_lut: ModelBundle,
    lut_params: Any,
    sample_batches: list[dict[str, jax.Array]],
    key: jax.Array,
    *,
    kmeans_iters: int = 25,
    max_rows: int = 4096,
) -> Any:
    """Capture replaced-site inputs under the ORIGINAL dense model (paper
    section 6.1: the trained network on ~1024 samples) and k-means-init every
    centroid table of the LUT model (Eq. 1).

    Tape records (keyed by the dense registry's `tape_key`) are joined to
    the LUT registry on (layer, kind), which absorbs the differing segment
    layouts of the two models — and works for every bundle kind.
    """
    src = _unrolled(bundle_dense)

    tape = tape_capture(max_rows=max_rows)
    with tape:
        for batch in sample_batches:
            if (bundle_dense.kind == "lm" and bundle_dense.arch.mrope_sections
                    and "pos" not in batch):
                b, s = batch["labels"].shape[:2]
                pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
                batch = dict(batch, pos=jnp.broadcast_to(pos[None], (3, b, s)))
            src.loss(dense_params, batch, compute_dtype=jnp.float32)

    dense_by_tape = {
        s.tape_key: s for s in bundle_dense.sites() if s.tape_key is not None
    }
    lut_by_site = {(s.layer, s.kind): s for s in bundle_lut.sites()}

    lflat = _flat_paths(lut_params)
    updates: dict[str, jax.Array] = {}
    for rec_key, rows_list in tape.records.items():
        ds = dense_by_tape.get(rec_key)
        if ds is None:
            continue
        ls = lut_by_site.get((ds.layer, ds.kind))
        if ls is None or ls.mode != Mode.LUT_TRAIN:
            continue                     # site stays dense under the plan
        leaf_path = f"{ls.path}/centroids"
        if leaf_path not in lflat:
            continue
        acts = jnp.concatenate(rows_list, axis=0)
        key, sub = jax.random.split(key)
        if ls.stack_index is None:
            c, k, v = lflat[leaf_path].shape
            updates[leaf_path] = kmeans.kmeans_per_codebook(
                sub, acts, k=k, v=v, iters=kmeans_iters
            )
        else:
            stacked = updates.get(leaf_path, lflat[leaf_path])
            c, k, v = stacked.shape[1:]
            cents = kmeans.kmeans_per_codebook(sub, acts, k=k, v=v, iters=kmeans_iters)
            updates[leaf_path] = stacked.at[ls.stack_index].set(cents)

    out = dict(lflat)
    out.update(updates)
    leaves = [out[p] for p in lflat]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(lut_params), leaves)


def convert_dense_to_lut_train(
    bundle_dense: ModelBundle,
    dense_params: Any,
    sample_batches: list[dict[str, jax.Array]],
    key: jax.Array,
    **kw: Any,
) -> tuple[ModelBundle, Any]:
    """Full offline pipeline: dense model -> soft-PQ-trainable LUT model."""
    bundle_lut = build_model(bundle_dense.arch, Mode.LUT_TRAIN)
    lut_params = bundle_lut.init(jax.random.PRNGKey(0))
    lut_params = graft_dense_to_lut(dense_params, lut_params)
    lut_params = kmeans_init_lut(
        bundle_dense, dense_params, bundle_lut, lut_params, sample_batches, key, **kw
    )
    return bundle_lut, lut_params


def _build_quantize_tables(P: jax.Array, W: jax.Array, lut) -> tuple[jax.Array, jax.Array]:
    """Table build + int8 quantization for one site, vmapped over every
    leading stack axis so a multi-layer (and multi-expert) deploy is ONE
    traced computation instead of a per-layer python loop.

    P: (*lead_p, C, K, V) centroids; W: (*lead_w, D, M) frozen weights with
    lead_w = lead_p (layer-stacked) plus optionally one extra expert axis
    that shares the codebooks (lead_p == () or a prefix of lead_w).
    """
    def one(p, w):
        t = pq.build_table(p, w, stop_weight_grad=False)
        qt = quant.quantize_table(
            t, bits=lut.bits, per_column=lut.per_column,
            m_shared=lut.int8_dot or lut.use_kernel,
        )
        return qt.q, qt.scale

    fn = one
    shared_lead = W.ndim - 2 - (P.ndim - 3)     # expert axes: codebooks shared
    for _ in range(shared_lead):
        fn = jax.vmap(fn, in_axes=(None, 0))
    for _ in range(P.ndim - 3):                 # layer-stacked axes
        fn = jax.vmap(fn, in_axes=(0, 0))
    return jax.jit(fn)(P, W)


def deploy_lut_train_params(
    bundle_lut: ModelBundle, lut_params: Any, *, plan: Any | None = None
) -> tuple[ModelBundle, Any]:
    """LUT_TRAIN params -> LUT_INFER params (int8 tables, weights dropped).

    Walks the LUT_INFER registry: every replaced site's table is built and
    quantized with that site's own LUTConfig (bits / per-column / m-shared
    layout for int8_dot and the fused kernel), so heterogeneous plans
    deploy each site exactly as its serving path expects.

    `plan` (a LUTPlan) deploys the SAME training state under a different
    replacement plan (DESIGN.md §14.1). This works because LUT_TRAIN
    params keep the frozen dense `w` at every replaced site: a plan whose
    LUT sites are a subset of the trained plan's resolves each site either
    from its centroids+w (LUT — tables byte-identical to the trained
    plan's deploy) or from the frozen `w` directly (kept dense — exact).
    A plan that replaces a site the trained plan left dense has no
    centroids to build from and fails loudly. LM segment boundaries move
    with the plan, so leaves are re-grouped through global layer indices.
    """
    arch = bundle_lut.arch
    if plan is not None:
        arch = dataclasses.replace(arch, lut_plan=plan)
    bundle_inf = build_model(arch, Mode.LUT_INFER)
    inf_specs = jax.eval_shape(bundle_inf.init, jax.random.PRNGKey(0))
    iflat = _flat_paths(inf_specs)
    tflat = _flat_paths(lut_params)

    site_by_path = {}
    for s in bundle_inf.sites():
        site_by_path.setdefault(s.path, s)      # dedupe layer-stacked entries

    # LM segment realignment: train and inf group the same global layers
    # into different scan runs when their plans differ, so "segments/i/..."
    # paths and stack counts disagree. Resolve through global layer
    # indices: slice the train leaf's stacked axis per layer, re-stack per
    # the inf bundle's own segments. (graft_dense_to_lut's offset trick,
    # generalized to arbitrary source segmentation.)
    train_offsets: list[int] = []
    if isinstance(lut_params, dict) and "segments" in lut_params:
        off = 0
        for seg in lut_params["segments"]:
            train_offsets.append(off)
            off += jax.tree.leaves(seg)[0].shape[0]
    inf_runs: list[tuple[int, int]] = []        # (global layer offset, count)
    if bundle_inf.kind == "lm":
        off = 0
        for count, _ in bundle_inf.cfg.segments:
            inf_runs.append((off, count))
            off += count

    def train_leaf(path: str):
        """Train-tree source for an inf-tree path; None when absent.
        Segment-qualified LM paths gather per-layer slices so any
        train/inf segmentation pair lines up."""
        parts = path.split("/")
        if parts[0] == "segments" and train_offsets:
            lo, count = inf_runs[int(parts[1])]
            rest = "/".join(parts[2:])
            rows = []
            for g in range(lo, lo + count):
                si = max(i for i, o in enumerate(train_offsets) if o <= g)
                src = tflat.get(f"segments/{si}/{rest}")
                if src is None:
                    return None
                rows.append(src[g - train_offsets[si]])
            return jnp.stack(rows)
        return tflat.get(path)

    out: dict[str, jax.Array] = {}
    for path, spec in iflat.items():
        src = train_leaf(path)
        if src is not None and src.shape == spec.shape:
            out[path] = src
            continue
        if not (path.endswith("/table_q") or path.endswith("/table_scale")):
            if path.endswith("/centroids"):
                raise ValueError(
                    f"{path.rsplit('/', 1)[0]}: the deploy plan replaces this "
                    f"site but the trained checkpoint carries no centroids for "
                    f"it — a deploy plan may only replace sites the TRAINED "
                    f"plan replaced (derive sub-plans with LUTPlan.keeping_dense)"
                )
            raise KeyError(f"no source for deployed param {path}")
        base = path.rsplit("/", 1)[0]
        if f"{base}/table_q" in out:
            continue                             # sibling already built
        site = site_by_path.get(base)
        if site is None or site.mode != Mode.LUT_INFER or site.lut is None:
            raise KeyError(f"deployed table at {base} has no registered LUT site")
        P, W = train_leaf(f"{base}/centroids"), train_leaf(f"{base}/w")
        if P is None or W is None:
            raise ValueError(
                f"{base}: the deploy plan replaces this site but the trained "
                f"checkpoint carries no centroids for it — a deploy plan may "
                f"only replace sites the TRAINED plan replaced (derive "
                f"sub-plans with LUTPlan.keeping_dense)"
            )
        q, scale = _build_quantize_tables(P, W, site.lut)
        for leaf_path, leaf in ((f"{base}/table_q", q), (f"{base}/table_scale", scale)):
            if leaf.shape != iflat[leaf_path].shape:
                raise ValueError(
                    f"{leaf_path}: deployed shape {leaf.shape} != model spec "
                    f"{iflat[leaf_path].shape} — the deploy plan's K/V/bits "
                    f"must match what the site was trained with"
                )
            out[leaf_path] = leaf
    leaves = [out[p] for p in iflat]
    tree = jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(inf_specs), leaves)
    return bundle_inf, tree


def deploy_to_artifact(
    bundle_lut: ModelBundle, lut_params: Any, directory: str | Any,
    *, recipe: dict[str, Any] | None = None,
    target_plan: Any | None = None,
    extra_plans: dict[str, Any] | None = None,
) -> tuple[ModelBundle, Any]:
    """Deploy LUT_TRAIN params and write the serving tree as a LUTArtifact.

    The returned (bundle, params) serve directly; the artifact directory is
    what ships — `launch/serve.py --artifact <dir>` (or
    `repro.serving.artifact.load_artifact`) reconstructs both. `recipe`
    (a `Recipe.to_dict` payload) is recorded in the manifest for training
    provenance (DESIGN.md §10.2).

    `target_plan` deploys the artifact's main plan under an override (a
    sub-plan of the trained plan, e.g. trained.keeping_dense("attn/*"));
    `extra_plans` maps extra plan names to LUTPlans deployed from the same
    training state into the same artifact — the multi-plan manifest that
    spec-decode serving loads a "draft" from (DESIGN.md §14.1). Shared
    leaves are deduplicated on disk by save_artifact.
    """
    from repro.serving.artifact import save_artifact

    bundle_inf, inf_params = deploy_lut_train_params(
        bundle_lut, lut_params, plan=target_plan
    )
    extras = {
        name: deploy_lut_train_params(bundle_lut, lut_params, plan=p)
        for name, p in (extra_plans or {}).items()
    }
    save_artifact(directory, bundle_inf, inf_params, recipe=recipe,
                  extra_plans=extras or None)
    return bundle_inf, inf_params

"""Serving engine: continuous batching correctness + slot isolation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.engine import ServingEngine


def _greedy_reference(bundle, params, prompt, n_tokens):
    """Single-request greedy decode, no engine."""
    caches = bundle.init_caches(1, 64, dtype=jnp.float32)
    toks = jnp.asarray([prompt], jnp.int32)
    logits, caches = bundle.forward_step(
        params, {"tokens": toks, "cache_len": jnp.zeros((1,), jnp.int32)},
        caches, compute_dtype=jnp.float32,
    )
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    pos = len(prompt)
    while len(out) < n_tokens:
        logits, caches = bundle.forward_step(
            params, {"tokens": jnp.asarray([[out[-1]]], jnp.int32),
                     "cache_len": jnp.full((1,), pos, jnp.int32)},
            caches, compute_dtype=jnp.float32,
        )
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


def test_engine_matches_single_request(key):
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
    bundle = build_model(arch, Mode.DENSE)
    params = bundle.init(key)

    prompts = [[3, 5, 7], [11, 13, 17, 19, 23], [2, 4]]
    refs = [_greedy_reference(bundle, params, p, 5) for p in prompts]

    eng = ServingEngine(bundle, params, n_slots=2, max_seq=64, prefill_chunk=4)
    for p in prompts:
        eng.submit(p, max_tokens=5)
    done = sorted(eng.run_until_done(), key=lambda r: r.rid)
    assert len(done) == 3
    for r, ref in zip(done, refs):
        assert r.out_tokens == ref, (r.rid, r.out_tokens, ref)


def test_engine_eos_stops(key):
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=1)
    bundle = build_model(arch, Mode.DENSE)
    params = bundle.init(key)
    eng = ServingEngine(bundle, params, n_slots=1, max_seq=64, prefill_chunk=4)
    ref = _greedy_reference(bundle, params, [1, 2, 3], 8)
    eos = ref[2]                       # will be hit on the 3rd generated token
    eng.submit([1, 2, 3], max_tokens=8, eos_id=eos)
    done = eng.run_until_done()
    assert done[0].out_tokens[-1] == eos
    assert len(done[0].out_tokens) <= 8

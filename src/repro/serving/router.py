"""Multi-replica router: one front end, N supervised engines (DESIGN.md §15).

LUT-NN's premise makes replicas cheap — ≤7x smaller models and ≤6.5x less
memory mean one host can run several engine processes off a single
`LUTArtifact` — so the path to heavy traffic is horizontal: N
`EngineSupervisor` replicas behind one `EngineRouter` that implements the
SAME backend interface as `server.EnginePump` / `EngineSupervisor`
(submit/cancel/stats/pending/healthy/close/abort_pending/wait_ready), so
`server.FrontEnd` serves a multi-replica deployment completely unchanged.

  * **Health-aware scheduling** — each replica carries a live load score:
    the router-tracked in-flight count (exact by construction) maxed with
    the worker-reported `queue_depth + active_slots` gauges when they are
    fresh (the report rides the supervisor's periodic stats push; its
    `stats_age_s` plus the router's own poll age is capped by
    `stats_staleness_s`, past which only the in-flight count is trusted).
    `least_loaded` places each request on the lowest-scored live replica
    (ties to the lowest index); priority and deadline pass through to the
    replica's engine untouched.
  * **Prefix affinity** — `routing="prefix_affinity"` keys each request on
    the first full KV page of its prompt token ids (`kv_pool`'s page-size
    tokenization) and ranks replicas by rendezvous (highest-random-weight)
    hashing, so same-prefix sessions land on the same replica — where PR 7's
    refcounted prefix cache turns their prefill into a lookup — and replica
    death never re-ranks the survivors' keys. When the favorite's load score
    reaches `spill_threshold` and a strictly less-loaded replica exists, the
    request spills there (counter `spills`); otherwise it sticks
    (`affinity_hits`).
  * **Failover** — a replica whose supervisor fails closed (artifact gone,
    `max_restarts` consecutive crashes — PR 6 semantics) resolves its live
    rids as "error" with `healthy=False`; the router intercepts those
    terminal events, marks the replica dead (`failovers`), and requeues each
    request onto a survivor (`requeues`) with a retry budget and the
    remaining-deadline shrink, delayed by `fault_tolerance.Backoff`. The
    existing `("restart", None)` stream-discard event tells subscribers to
    drop partial output — deterministic per-request sampling makes the
    replayed generation byte-identical. Past `retry_budget` (or with no
    survivor left) the request resolves as "error" (`lost`). The router
    serves degraded until the LAST replica dies, at which point it fails
    closed like a single supervisor would.
  * **Lifecycle + observability** — `healthy` (and therefore `/readyz`) is
    true iff ≥1 replica is live; `close()` drains every replica and records
    a per-replica exit summary. `stats()` aggregates the numeric engine
    counters across replicas (so `/metrics` keeps exporting the
    `lutnn_serving_*` gauges unchanged) plus the routing counters
    (`affinity_hits`, `spills`, `failovers`, `requeues`, `routed`, `lost`)
    and a `per_replica` sub-dict that `server.metrics_text` renders as
    `lutnn_replica_*{replica="i"}` gauges.

Lock discipline: `_lock` guards all router bookkeeping. Supervisor
callbacks run under the owning supervisor's lock and call into the router,
so the router must NEVER call a lock-taking supervisor method (`submit`,
`cancel`, `stats`, ...) while holding `_lock` — only the lock-free
`healthy` flag may be read anywhere. Routing therefore picks under `_lock`,
releases, submits, and re-acquires to record the result; the monitor
thread polls replica stats into cached load reports for the same reason.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
import time
from typing import Any, Callable

from repro.distributed.fault_tolerance import Backoff
from repro.serving.engine import validate_spec
from repro.serving.supervisor import EngineSupervisor

ROUTING_POLICIES = ("least_loaded", "prefix_affinity")

_POLL_PERIOD_S = 0.02


def affinity_key(prompt: list[int], page_size: int) -> tuple[int, ...]:
    """The token-id tuple prefix-affinity hashes on: the first full KV page
    of the prompt (mirroring `kv_pool`'s page-size tokenization, so the
    affinity domain is exactly the unit the prefix cache shares), or the
    whole prompt when it is shorter than one page."""
    return tuple(prompt[:page_size])


def _hrw_weight(key: tuple, replica: int) -> int:
    """Rendezvous (highest-random-weight) hash of (key, replica): each key
    ranks every replica; removing a dead replica promotes that key's
    next-ranked survivor without re-ranking any other key."""
    h = hashlib.blake2b(repr((key, replica)).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


@dataclasses.dataclass
class _Replica:
    index: int
    sup: EngineSupervisor
    inflight: set[int] = dataclasses.field(default_factory=set)  # live grids
    routed: int = 0                  # requests ever placed here
    dead: bool = False               # failed closed; excluded from routing
    load_report: int = 0             # worker-reported queue_depth+active_slots
    report_t: float = -1e9           # monotonic time the report was measured


@dataclasses.dataclass
class _RoutedRequest:
    grid: int
    spec: dict[str, Any]
    deadline: float | None           # absolute time.monotonic()
    on_event: Callable[[tuple[str, Any]], None] | None
    replica: int | None = None       # index currently serving this request
    sub_grid: int | None = None      # grid inside that replica's supervisor
    tokens: list[int] = dataclasses.field(default_factory=list)
    status: str | None = None
    retries: int = 0                 # router-level failover requeues spent
    queued_for_retry: bool = False   # sits in a retry/route box right now
    done_ev: threading.Event = dataclasses.field(default_factory=threading.Event)

    @property
    def done(self) -> bool:
        return self.status is not None


class EngineRouter:
    """N supervised engine replicas sharing one artifact, one backend."""

    def __init__(
        self,
        artifact_path: str | os.PathLike,
        *,
        replicas: int = 2,
        routing: str = "least_loaded",
        engine_kwargs: dict[str, Any] | None = None,
        supervisor_kwargs: dict[str, Any] | None = None,
        faults: Any = None,           # FaultSpec | [FaultSpec|None per replica]
        retry_budget: int = 2,
        backoff: Backoff = Backoff(base_s=0.05, factor=2.0, cap_s=1.0),
        affinity_page_size: int | None = None,
        spill_threshold: int | None = None,
        stats_staleness_s: float = 1.0,
    ):
        if replicas < 1:
            raise ValueError(f"replicas={replicas}: need >= 1")
        if routing not in ROUTING_POLICIES:
            raise ValueError(
                f"routing={routing!r}: must be one of {ROUTING_POLICIES}")
        self.routing = routing
        self.retry_budget = retry_budget
        self.backoff = backoff
        engine_kwargs = dict(engine_kwargs or {})
        # the affinity key unit defaults to the engines' actual KV page size
        # so affinity domains and prefix-cache share units coincide
        self.affinity_page_size = (
            affinity_page_size
            if affinity_page_size is not None
            else int(engine_kwargs.get("page_size", 16)))
        # favorite saturation = more live work than decode slots (a queue is
        # forming); below it affinity always sticks
        self.spill_threshold = (
            spill_threshold
            if spill_threshold is not None
            else int(engine_kwargs.get("n_slots", 4)))
        self.stats_staleness_s = stats_staleness_s

        fault_list = (list(faults) if isinstance(faults, (list, tuple))
                      else [faults] + [None] * (replicas - 1))
        if len(fault_list) != replicas:
            raise ValueError(
                f"faults: got {len(fault_list)} specs for {replicas} replicas")

        self._lock = threading.RLock()
        self._requests: dict[int, _RoutedRequest] = {}
        self._next_grid = 0
        self._retrybox: list[int] = []    # failover requeues (charge a retry)
        self._routebox: list[int] = []    # never reached a worker (no charge)
        self._wake = threading.Event()
        self._stop = False
        self.counters = {
            "routed": 0, "affinity_hits": 0, "spills": 0,
            "failovers": 0, "requeues": 0, "lost": 0,
        }
        self.exit_summary: str | None = None   # set by close()

        sup_kwargs = dict(supervisor_kwargs or {})
        self._replicas = [
            _Replica(i, EngineSupervisor(
                artifact_path, engine_kwargs=engine_kwargs,
                faults=fault_list[i], **sup_kwargs,
            ))
            for i in range(replicas)
        ]
        self._monitor = threading.Thread(
            target=self._run, name="engine-router", daemon=True)
        self._monitor.start()

    # -- backend interface (mirrors server.EnginePump) ---------------------
    @property
    def healthy(self) -> bool:
        """True iff >= 1 replica can still take traffic (drives /readyz)."""
        return not self._stop and any(
            not r.dead and r.sup.healthy for r in self._replicas)

    def wait_ready(self, timeout: float | None = None) -> bool:
        """Block until >= 1 replica is serving (or every replica has failed,
        or `timeout`)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            for rep in self._replicas:
                if rep.sup.wait_ready(timeout=0.05) and rep.sup.healthy:
                    return True
            if all(not r.sup.healthy for r in self._replicas):
                return False
            if deadline is not None and time.monotonic() >= deadline:
                return False

    def submit(self, spec: dict[str, Any],
               on_event: Callable[[tuple[str, Any]], None] | None = None) -> int:
        validate_spec(spec)
        with self._lock:
            if not self.healthy:
                raise RuntimeError(
                    "router failed: every replica is dead "
                    f"({self._replica_summary()})")
            grid = self._next_grid
            self._next_grid += 1
            deadline_s = spec.get("deadline_s")
            st = _RoutedRequest(
                grid=grid, spec=dict(spec), on_event=on_event,
                deadline=(None if deadline_s is None
                          else time.monotonic() + float(deadline_s)),
            )
            self._requests[grid] = st
        self._send(st)
        return grid

    def cancel(self, grid: int) -> bool:
        with self._lock:
            st = self._requests.get(grid)
            if st is None or st.done:
                return False
            rep = (self._replicas[st.replica]
                   if st.replica is not None else None)
            sub = st.sub_grid
            if rep is None or sub is None or st.queued_for_retry:
                # not inside any worker: terminal here and now
                st.queued_for_retry = False
                self._finish_locked(st, "cancelled")
                return True
        return rep.sup.cancel(sub)        # retirement flows back via events

    def stats(self) -> dict[str, Any]:
        # snapshot replica objects outside any supervisor call, then poll
        # each supervisor WITHOUT the router lock (lock discipline above)
        with self._lock:
            reps = list(self._replicas)
            counters = dict(self.counters)
            pending = sum(not r.done for r in self._requests.values())
        agg: dict[str, Any] = {}
        per: dict[str, dict[str, Any]] = {}
        ages: list[float] = []
        for rep in reps:
            s = rep.sup.stats()
            s["routed"] = rep.routed
            s["inflight"] = len(rep.inflight)
            s["dead"] = int(rep.dead or not rep.sup.healthy)
            per[str(rep.index)] = s
            ages.append(s.get("stats_age_s", 0.0))
            for k, v in s.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                agg[k] = agg.get(k, 0) + v
        agg.update(counters)
        agg["backend"] = "router"
        agg["replicas"] = len(reps)
        agg["replicas_live"] = sum(1 - p["dead"] for p in per.values())
        agg["replicas_dead"] = sum(p["dead"] for p in per.values())
        agg["pending"] = pending
        agg["failed"] = int(not self.healthy)
        agg["stats_age_s"] = max(ages) if ages else 0.0
        agg["per_replica"] = per
        return agg

    def pending(self) -> int:
        with self._lock:
            return sum(not r.done for r in self._requests.values())

    def abort_pending(self) -> int:
        """Force-resolve every live request as "error" (drain deadline
        expiry), then best-effort abort inside each replica."""
        with self._lock:
            live = [r for r in self._requests.values() if not r.done]
            for st in live:
                st.queued_for_retry = False
                self._finish_locked(st, "error")
            self._retrybox.clear()
            self._routebox.clear()
        for rep in self._replicas:
            try:
                rep.sup.abort_pending()
            except Exception:            # noqa: BLE001 — replica may be dead
                pass
        return len(live)

    def close(self) -> None:
        """Router-level drain: stop routing, close every replica, aggregate
        their exit states into `exit_summary`."""
        self._stop = True
        self._wake.set()
        self._monitor.join(timeout=30)
        # snapshot BEFORE closing: sup.close() flips healthy on replicas
        # that were serving fine, which would read as "dead" here
        self.exit_summary = self._replica_summary()
        for rep in self._replicas:
            rep.sup.close()

    # -- test/bench conveniences (mirror EngineSupervisor) -----------------
    def wait(self, grid: int, timeout: float | None = None) -> _RoutedRequest:
        st = self._requests[grid]
        if not st.done_ev.wait(timeout):
            raise TimeoutError(f"request {grid} not terminal after {timeout}s")
        return st

    def results(self) -> dict[int, _RoutedRequest]:
        with self._lock:
            return dict(self._requests)

    # -- internals ---------------------------------------------------------
    def _replica_summary(self) -> str:
        return ", ".join(
            f"replica {r.index}: "
            + ("dead" if r.dead or not r.sup.healthy else "live")
            + (f" ({r.sup._last_crash})" if r.dead and r.sup._last_crash else "")
            for r in self._replicas)

    def _finish_locked(self, st: _RoutedRequest, status: str,
                       tokens: list[int] | None = None) -> None:
        if st.done:
            return
        st.status = status
        if tokens is not None:
            st.tokens = list(tokens)
        st.done_ev.set()
        self._dispatch(st, ("done", (status, st.tokens)))

    def _dispatch(self, st: _RoutedRequest, ev: tuple[str, Any]) -> None:
        if st.on_event is not None:
            try:
                st.on_event(ev)
            except Exception:            # noqa: BLE001 — a bad subscriber
                pass                     # must not poison the router

    def _mark_dead_locked(self, rep: _Replica) -> None:
        if not rep.dead:
            rep.dead = True
            self.counters["failovers"] += 1

    def _queue_retry_locked(self, st: _RoutedRequest) -> None:
        if not st.done and not st.queued_for_retry:
            st.queued_for_retry = True
            self._retrybox.append(st.grid)
            self._wake.set()

    # -- load scoring + placement ------------------------------------------
    def _score_locked(self, rep: _Replica, now: float) -> int:
        """Live load: router-tracked in-flight count (exact), maxed with the
        worker-reported queue_depth+active_slots when that report is fresh
        (its total age — supervisor stats push + router poll — is capped)."""
        score = len(rep.inflight)
        if now - rep.report_t <= self.stats_staleness_s:
            score = max(score, rep.load_report)
        return score

    def _pick_locked(self, st: _RoutedRequest, now: float) -> _Replica | None:
        alive = [r for r in self._replicas if not r.dead and r.sup.healthy]
        if not alive:
            return None
        if self.routing == "prefix_affinity" and st.spec.get("prompt"):
            key = affinity_key(st.spec["prompt"], self.affinity_page_size)
            fav = max(alive, key=lambda r: _hrw_weight(key, r.index))
            fav_score = self._score_locked(fav, now)
            if fav_score >= self.spill_threshold:
                best = min(alive,
                           key=lambda r: (self._score_locked(r, now), r.index))
                if self._score_locked(best, now) < fav_score:
                    self.counters["spills"] += 1
                    return best
            self.counters["affinity_hits"] += 1
            return fav
        return min(alive, key=lambda r: (self._score_locked(r, now), r.index))

    def _send(self, st: _RoutedRequest) -> None:
        """Place one request on a live replica (outside `_lock` for the
        actual submit — see the lock-discipline note in the module doc)."""
        with self._lock:
            if st.done:
                return
            now = time.monotonic()
            rep = self._pick_locked(st, now)
            if rep is None:
                self.counters["lost"] += 1
                self._finish_locked(st, "error")
                return
            remaining = None
            if st.deadline is not None:
                remaining = st.deadline - now
                if remaining <= 0:       # expired while down/queued
                    self._finish_locked(st, "timeout")
                    return
            st.replica = rep.index
            st.sub_grid = None
            rep.inflight.add(st.grid)
            rep.routed += 1
            self.counters["routed"] += 1
            spec = dict(st.spec)
            if remaining is not None:
                spec["deadline_s"] = remaining
        grid, idx = st.grid, rep.index
        try:
            sub = rep.sup.submit(
                spec, on_event=lambda ev: self._on_replica_event(grid, idx, ev))
        except RuntimeError:
            # replica failed between pick and submit: the request never ran
            # there, so re-route without charging its retry budget
            with self._lock:
                rep.inflight.discard(grid)
                self._mark_dead_locked(rep)
                if not st.done and not st.queued_for_retry:
                    st.replica = None
                    st.queued_for_retry = True
                    self._routebox.append(grid)
            self._wake.set()
            return
        with self._lock:
            st.sub_grid = sub

    # -- replica event bridge ----------------------------------------------
    def _on_replica_event(self, grid: int, rep_index: int,
                          ev: tuple[str, Any]) -> None:
        kind, payload = ev
        with self._lock:
            st = self._requests.get(grid)
            if st is None or st.done or st.replica != rep_index:
                return                   # stale event from a failed-over run
            rep = self._replicas[rep_index]
            if kind == "tokens":
                st.tokens.extend(payload)
                self._dispatch(st, ev)
            elif kind == "restart":
                # the replica's own worker restarted: replay is coming,
                # subscribers (and we) discard partial output
                st.tokens = []
                self._dispatch(st, ev)
            elif kind == "done":
                status, out_tokens = payload
                rep.inflight.discard(grid)
                if (status == "error" and not rep.sup.healthy
                        and not self._stop):
                    # the replica failed closed underneath this request —
                    # that "error" is the replica's verdict, not the
                    # request's: fail over to a survivor
                    self._mark_dead_locked(rep)
                    self._queue_retry_locked(st)
                    return
                self._finish_locked(st, status, out_tokens)

    # -- monitor thread ----------------------------------------------------
    def _run(self) -> None:
        while not self._stop:
            self._poll_loads()
            self._scan_replicas()
            retries, routes = self._drain_boxes()
            for grid in routes:
                st = self._requests.get(grid)
                if st is not None:
                    self._send(st)
            for grid in retries:
                self._requeue(grid)
            if not (retries or routes):
                self._wake.wait(_POLL_PERIOD_S)
                self._wake.clear()

    def _poll_loads(self) -> None:
        """Refresh each live replica's cached load report (outside `_lock`,
        then record under it). The report's effective age folds in the
        supervisor's own stats_age_s so a wedged worker's last gauges do
        not masquerade as fresh."""
        now = time.monotonic()
        for rep in self._replicas:
            if rep.dead or not rep.sup.healthy:
                continue
            s = rep.sup.stats()
            with self._lock:
                rep.load_report = (int(s.get("queue_depth", 0))
                                   + int(s.get("active_slots", 0)))
                rep.report_t = now - float(s.get("stats_age_s", 1e9))

    def _scan_replicas(self) -> None:
        """Safety net: flag replicas that failed closed with no live rids
        (no error events will arrive to trigger the callback path), and
        requeue any stranded in-flight grids exactly once."""
        for rep in self._replicas:
            if rep.dead or rep.sup.healthy:
                continue
            with self._lock:
                self._mark_dead_locked(rep)
                for grid in sorted(rep.inflight):
                    st = self._requests.get(grid)
                    if st is not None and st.replica == rep.index:
                        self._queue_retry_locked(st)
                rep.inflight.clear()

    def _drain_boxes(self) -> tuple[list[int], list[int]]:
        with self._lock:
            retries, self._retrybox = self._retrybox, []
            routes, self._routebox = self._routebox, []
        return retries, routes

    def _requeue(self, grid: int) -> None:
        """Failover path: spend one retry, discard streamed tokens, back
        off, re-route onto a survivor with the remaining deadline."""
        with self._lock:
            st = self._requests.get(grid)
            if st is None or st.done:
                return
            st.queued_for_retry = False
            st.replica = None
            st.retries += 1
            if st.retries > self.retry_budget:
                self.counters["lost"] += 1
                self._finish_locked(st, "error")
                return
            self.counters["requeues"] += 1
            if st.tokens:
                st.tokens = []
                self._dispatch(st, ("restart", None))
            attempt = st.retries - 1
        time.sleep(self.backoff.delay(attempt))
        self._send(st)

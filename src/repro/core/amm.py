"""Functional LUT-NN approximate-matmul layer (the paper's core operator).

One entry point, `lut_linear`, with three statically-selected modes:

  DENSE      — exact x @ W (+bias): the original operator / accuracy baseline.
  LUT_TRAIN  — soft-PQ QAT forward (paper section 3): table rebuilt from the
               frozen weight each step, fake-quantized (section 3.3), encoding
               via the argmin/softmax straight-through estimator (Eq. 6) with
               the learned temperature (section 3.2).
  LUT_INFER  — deployed path: int8 table + hard argmin encode + one-hot MXU
               contraction (or the fused Pallas kernel on TPU).

Param pytrees (see repro.core.lut_layer for initializers):

  dense   : {"w": (D, M) [, "b": (M,)]}
  train   : {"centroids": (C,K,V), "log_t": ()} (+ frozen {"w", "b"})
  deploy  : {"centroids": (C,K,V), "table_q": int8 (C,K,M),
             "table_scale": (C,1,1)|(C,1,M) [, "b": (M,)]}
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.core import pq, quant
from repro.core.temperature import temperature


class Mode(str, enum.Enum):
    DENSE = "dense"
    LUT_TRAIN = "lut_train"
    LUT_INFER = "lut_infer"


@dataclasses.dataclass(frozen=True)
class LUTConfig:
    """Static LUT hyper-parameters for one layer family.

    k: centroids per codebook (paper default 16 — one SIMD register there,
       one-hot lane group on the MXU here).
    v: sub-vector length (paper: 9 for 3x3 conv, 4 for 1x1, 16/32 for BERT FC;
       we default 32 for LM projections).
    bits/per_column: table scalar quantization (section 3.3).
    """

    k: int = 16
    v: int = 32
    bits: int = 8
    per_column: bool = False
    # deployed-path integer contraction: int8 one-hot x int8 table -> int32
    # with (1,1,M) scales (DESIGN.md section 2). Halves+ the decode memory
    # term by never materializing a dequantized bf16 table.
    int8_dot: bool = False
    # Pallas fused v2 kernel for LUT_INFER (int8-native MXU table read +
    # fused bias epilogue, autotuned blocks — DESIGN.md §2.3/§3); False =
    # pure-XLA one-hot path, which is what the multi-pod dry-run lowers
    # (CPU backend can't emit Mosaic).
    use_kernel: bool = False

    def codebooks(self, d: int) -> int:
        if d % self.v:
            raise ValueError(f"D={d} not divisible by V={self.v}")
        return d // self.v


def _flatten_lead(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def lut_linear(
    cfg: LUTConfig,
    mode: Mode,
    params: Mapping[str, Any],
    x: jax.Array,
    *,
    frozen: Mapping[str, Any] | None = None,
) -> jax.Array:
    """Apply one (possibly LUT-replaced) linear layer. x: (..., D) -> (..., M)."""
    if mode == Mode.DENSE:
        w = params["w"]
        y = jnp.einsum("...d,dm->...m", x, w.astype(x.dtype))
        b = params.get("b")
        return y + b.astype(y.dtype) if b is not None else y

    if mode == Mode.LUT_TRAIN:
        assert frozen is not None, "LUT_TRAIN needs the frozen dense weight"
        P = params["centroids"]
        t = temperature(params["log_t"])
        table = pq.build_table(P, frozen["w"], stop_weight_grad=True)
        table = quant.fake_quant(
            table, bits=cfg.bits, per_column=cfg.per_column, m_shared=cfg.int8_dot
        )
        xf, lead = _flatten_lead(x)
        dists = pq.pairwise_sq_dists(pq.split_subvectors(xf, cfg.v), P)
        enc = pq.ste_encode(dists, t)
        y = pq.lut_contract(enc.astype(x.dtype), table.astype(x.dtype))
        b = frozen.get("b")
        y = y + b.astype(y.dtype) if b is not None else y
        return y.reshape(*lead, -1).astype(x.dtype)

    if mode == Mode.LUT_INFER:
        P = params["centroids"]
        qt = quant.QuantizedTable(params["table_q"], params["table_scale"])
        xf, lead = _flatten_lead(x)
        b = params.get("b")
        if cfg.use_kernel:
            from repro.kernels import ops  # local import: kernels are optional

            # bias rides the kernel's fused epilogue (DESIGN.md §2.3) — no
            # separate elementwise pass over the (N, M) output. The kernel
            # generation (v1 / v2 / fused-decode) is NOT pinned here:
            # ops.lut_amm consults the per-shape autotune record — measured
            # wall-clock winners when available (DESIGN.md §13.3) — so every
            # LUT site runs whichever kernel actually wins on its shape.
            y = ops.lut_amm(xf, P, qt.q, qt.scale, bias=b)
        else:
            if cfg.int8_dot:
                dists = pq.pairwise_sq_dists(pq.split_subvectors(xf, cfg.v), P)
                y = pq.lut_contract_int8(pq.hard_encode(dists), qt.q, qt.scale)
            else:
                table = qt.dequant(dtype=x.dtype)
                dists = pq.pairwise_sq_dists(pq.split_subvectors(xf, cfg.v), P)
                enc = pq.hard_encode(dists).astype(x.dtype)
                y = pq.lut_contract(enc, table)
            y = y + b.astype(y.dtype) if b is not None else y
        return y.reshape(*lead, -1).astype(x.dtype)

    raise ValueError(f"unknown mode {mode}")


def lut_flops(n: int, d: int, m: int, cfg: LUTConfig) -> int:
    """Paper Table 1: N*D*K (encode) + N*M*D/V (lookup-accumulate)."""
    return n * d * cfg.k + n * m * d // cfg.v


def dense_flops(n: int, d: int, m: int) -> int:
    return n * d * m


def lut_table_bytes(d: int, m: int, cfg: LUTConfig) -> int:
    """int8 table + fp32 scales + fp32 codebook bytes (paper Table 1 size)."""
    c = d // cfg.v
    table = c * cfg.k * m                       # int8
    scales = c * 4 * (m if cfg.per_column else 1)
    codebook = c * cfg.k * cfg.v * 4
    return table + scales + codebook


def dense_bytes(d: int, m: int, dtype_bytes: int = 4) -> int:
    return d * m * dtype_bytes

"""Learned softmax temperature (paper section 3.2).

The temperature is strictly positive, so it is parameterized in log space:
t = exp(log_t), initialized at t=1 (log_t=0). Each replaced layer owns one
scalar log_t, trained with its own (larger) learning rate — the optimizer's
param-group machinery (repro.optim) matches the paper's centroid-lr vs
temperature-lr split (Table 3: 1e-3/1e-4 vs 1e-1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

TEMP_PARAM = "log_t"


def init_log_temperature(init_t: float = 1.0) -> jax.Array:
    return jnp.asarray(jnp.log(init_t), jnp.float32)


def temperature(log_t: jax.Array, *, min_t: float = 1e-4) -> jax.Array:
    """exp(log_t), floored for numeric safety as t -> 0 (argmax limit)."""
    return jnp.maximum(jnp.exp(log_t.astype(jnp.float32)), min_t)

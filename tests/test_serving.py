"""Serving engine: continuous batching correctness, chunked prefill,
admission batching, done-condition off-by-one, cache bounds, sampling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams, sample_tokens


def _greedy_reference(bundle, params, prompt, n_tokens):
    """Single-request greedy decode, no engine."""
    caches = bundle.init_caches(1, 64, dtype=jnp.float32)
    toks = jnp.asarray([prompt], jnp.int32)
    logits, caches = bundle.forward_step(
        params, {"tokens": toks, "cache_len": jnp.zeros((1,), jnp.int32)},
        caches, compute_dtype=jnp.float32,
    )
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    pos = len(prompt)
    while len(out) < n_tokens:
        logits, caches = bundle.forward_step(
            params, {"tokens": jnp.asarray([[out[-1]]], jnp.int32),
                     "cache_len": jnp.full((1,), pos, jnp.int32)},
            caches, compute_dtype=jnp.float32,
        )
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


def _small_bundle(key, n_layers=2):
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=n_layers)
    bundle = build_model(arch, Mode.DENSE)
    return bundle, bundle.init(key)


def test_engine_matches_single_request(key):
    bundle, params = _small_bundle(key)
    prompts = [[3, 5, 7], [11, 13, 17, 19, 23], [2, 4]]
    refs = [_greedy_reference(bundle, params, p, 5) for p in prompts]

    eng = ServingEngine(bundle, params, n_slots=2, max_seq=64, prefill_chunk=4)
    for p in prompts:
        eng.submit(p, max_tokens=5)
    done = sorted(eng.run_until_done(), key=lambda r: r.rid)
    assert len(done) == 3
    for r, ref in zip(done, refs):
        assert r.out_tokens == ref, (r.rid, r.out_tokens, ref)


def test_chunked_prefill_matches_reference(key):
    """Prompts LONGER than prefill_chunk go through the multi-chunk loop and
    must still be token-identical to the single-shot reference."""
    bundle, params = _small_bundle(key)
    prompts = [[3, 5, 7, 9, 11, 13, 17, 19, 23, 29, 31],    # 11 tokens, chunk 4
               list(range(2, 2 + 9))]                        # 9 tokens
    refs = [_greedy_reference(bundle, params, p, 4) for p in prompts]
    eng = ServingEngine(bundle, params, n_slots=2, max_seq=64, prefill_chunk=4)
    for p in prompts:
        eng.submit(p, max_tokens=4)
    done = sorted(eng.run_until_done(), key=lambda r: r.rid)
    for r, ref in zip(done, refs):
        assert r.out_tokens == ref, (r.rid, r.out_tokens, ref)
    # 11 -> 3 chunks, 9 -> 3 chunks, admitted together: chunks shared
    assert eng.stats()["prefill_forwards"] == 3


def test_batched_admission_single_prefill_forward(key):
    """k>1 requests admitted in one step share exactly ONE prefill forward."""
    bundle, params = _small_bundle(key)
    eng = ServingEngine(bundle, params, n_slots=3, max_seq=64, prefill_chunk=8)
    for p in ([1, 2, 3], [4, 5], [6, 7, 8]):      # all fit one chunk
        eng.submit(p, max_tokens=3)
    done = eng.run_until_done()
    assert len(done) == 3
    st = eng.stats()
    assert st["prefill_forwards"] == 1
    assert st["prefill_tokens"] == 8              # valid tokens, not padding


def test_max_tokens_one_returns_one_token(key):
    """The prefill-produced token counts toward max_tokens (off-by-one fix)."""
    bundle, params = _small_bundle(key, n_layers=1)
    ref = _greedy_reference(bundle, params, [1, 2, 3], 1)
    eng = ServingEngine(bundle, params, n_slots=1, max_seq=64, prefill_chunk=4)
    eng.submit([1, 2, 3], max_tokens=1)
    done = eng.run_until_done()
    assert len(done) == 1
    assert done[0].out_tokens == ref              # exactly 1 token
    assert eng.stats()["decode_forwards"] == 0    # never entered decode


def test_eos_on_prefill_token(key):
    """EOS hit by the very first (prefill-sampled) token retires immediately."""
    bundle, params = _small_bundle(key, n_layers=1)
    ref = _greedy_reference(bundle, params, [1, 2, 3], 1)
    eng = ServingEngine(bundle, params, n_slots=1, max_seq=64, prefill_chunk=4)
    eng.submit([1, 2, 3], max_tokens=8, eos_id=ref[0])
    done = eng.run_until_done()
    assert done[0].out_tokens == ref
    assert eng.stats()["decode_forwards"] == 0


def test_engine_eos_stops(key):
    bundle, params = _small_bundle(key, n_layers=1)
    eng = ServingEngine(bundle, params, n_slots=1, max_seq=64, prefill_chunk=4)
    ref = _greedy_reference(bundle, params, [1, 2, 3], 8)
    eos = ref[2]                       # will be hit on the 3rd generated token
    eng.submit([1, 2, 3], max_tokens=8, eos_id=eos)
    done = eng.run_until_done()
    assert done[0].out_tokens[-1] == eos
    assert len(done[0].out_tokens) <= 8


def test_overlong_prompt_rejected(key):
    bundle, params = _small_bundle(key, n_layers=1)
    eng = ServingEngine(bundle, params, n_slots=1, max_seq=8, prefill_chunk=4,
                        autotune_lut=False)
    with pytest.raises(ValueError):
        eng.submit(list(range(9)))                # 9 > max_seq=8
    with pytest.raises(ValueError):
        eng.submit([1], max_tokens=0)
    # an exactly-fitting prompt (pads to 8 == max_seq) is accepted
    eng.submit(list(range(1, 8)), max_tokens=1)
    done = eng.run_until_done()
    assert len(done) == 1 and len(done[0].out_tokens) == 1


def test_chunk_padded_prompt_rejected_and_max_tokens_capped(key):
    bundle, params = _small_bundle(key, n_layers=1)
    eng = ServingEngine(bundle, params, n_slots=1, max_seq=6, prefill_chunk=4,
                        autotune_lut=False)
    # 5 tokens pad to 8 > max_seq=6: the padded writes would be dropped at
    # the cache boundary, so submit must refuse
    with pytest.raises(ValueError):
        eng.submit(list(range(1, 6)))
    # 4 tokens fit exactly; max_tokens is capped to remaining cache
    # (max_seq - len + 1 = 3) instead of silently overflowing
    eng.submit([1, 2, 3, 4], max_tokens=100)
    done = eng.run_until_done()
    assert len(done) == 1
    assert len(done[0].out_tokens) == 3


def test_seeded_sampling_deterministic(key):
    """Same seed => identical tokens across runs and slot placements."""
    bundle, params = _small_bundle(key, n_layers=1)
    sp = SamplingParams(temperature=0.9, top_k=40, top_p=0.95, seed=123)

    def run(n_slots, extra_first):
        eng = ServingEngine(bundle, params, n_slots=n_slots, max_seq=64,
                            prefill_chunk=4, autotune_lut=False)
        if extra_first:        # perturb slot placement / batch composition
            eng.submit([9, 8, 7], max_tokens=6,
                       sampling=SamplingParams(temperature=0.9, seed=7))
        eng.submit([1, 2, 3, 4, 5], max_tokens=6, sampling=sp)
        done = sorted(eng.run_until_done(), key=lambda r: r.rid)
        return done[-1].out_tokens

    a = run(1, False)
    b = run(2, True)
    assert a == b
    assert len(a) == 6


def test_sampler_filters_reduce_to_greedy(key):
    """top_k=1 and tiny top_p must pick the argmax at any temperature."""
    logits = jax.random.normal(key, (3, 33))
    ref = np.asarray(jnp.argmax(logits, axis=-1))
    B = logits.shape[0]
    f32 = lambda v: jnp.full((B,), v, jnp.float32)
    i32 = lambda v: jnp.full((B,), v, jnp.int32)
    topk1 = sample_tokens(logits, f32(5.0), i32(1), f32(1.0), i32(0), i32(0))
    topp0 = sample_tokens(logits, f32(5.0), i32(0), f32(1e-6), i32(3), i32(1))
    greedy = sample_tokens(logits, f32(0.0), i32(0), f32(1.0), i32(9), i32(2))
    np.testing.assert_array_equal(np.asarray(topk1), ref)
    np.testing.assert_array_equal(np.asarray(topp0), ref)
    np.testing.assert_array_equal(np.asarray(greedy), ref)


def test_invalid_sampling_params():
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)


def test_stats_counters(key):
    bundle, params = _small_bundle(key, n_layers=1)
    eng = ServingEngine(bundle, params, n_slots=2, max_seq=64, prefill_chunk=4,
                        autotune_lut=False)
    eng.submit([1, 2, 3], max_tokens=4)
    eng.submit([4, 5, 6], max_tokens=4)
    eng.run_until_done()
    st = eng.stats()
    assert st["prefill_tokens"] == 6
    assert st["decode_tokens"] == 6               # 3 post-prefill tokens x 2
    assert st["decode_occupancy"] == 1.0          # both slots every decode step
    assert st["prefill_s"] > 0 and st["decode_s"] > 0
    # 1 prefill + 3 decode forwards over 2 shapes: 2 misses, 2 hits
    assert st["shape_cache_hits"] == st["prefill_forwards"] + st["decode_forwards"] - 2

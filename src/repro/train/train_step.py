"""pjit-able train/serve step factories.

`make_train_step` builds the canonical production step:
  loss (bf16 compute, fp32 reductions) -> grads -> global-norm clip ->
  AdamW with param groups -> new params/opt-state + metrics.

Gradient accumulation (giant archs) scans over microbatches so the saved
activations of only one microbatch are live at a time; grads accumulate in
fp32. Under pjit, the gradient all-reduce across the data axes is emitted
by GSPMD from the sharding of params (replicated or FSDP) vs batch (data-
sharded) — no explicit collectives here.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import ModelBundle
from repro.optim import AdamW, AdamWState


def make_loss_fn(bundle: ModelBundle, *, compute_dtype=jnp.bfloat16):
    def loss_fn(params, batch):
        return bundle.loss(params, batch, compute_dtype=compute_dtype)

    return loss_fn


def make_train_step(
    bundle: ModelBundle,
    opt: AdamW,
    *,
    frozen_mask: Any | None = None,
    compute_dtype=jnp.bfloat16,
    grad_accum: int = 1,
) -> Callable:
    loss_fn = make_loss_fn(bundle, compute_dtype=compute_dtype)

    def split_micro(batch):
        def r(a):
            if a.ndim == 0:
                return a
            b = a.shape[0]
            if a.shape[0] % grad_accum:
                raise ValueError(f"batch {b} not divisible by grad_accum {grad_accum}")
            return a.reshape(grad_accum, b // grad_accum, *a.shape[1:])

        # pos (3, B, S) splits on axis 1
        out = {}
        for k, v in batch.items():
            if k == "pos" and v.ndim == 3:
                g = v.shape[1] // grad_accum
                out[k] = v.reshape(3, grad_accum, g, v.shape[2]).swapaxes(0, 1)
            else:
                out[k] = r(v)
        return out

    def train_step(params, opt_state: AdamWState, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = split_micro(batch)

            def acc_fn(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (loss_acc + l, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        new_params, new_opt, gnorm = opt.update(grads, opt_state, params, frozen_mask)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(bundle: ModelBundle, *, compute_dtype=jnp.bfloat16) -> Callable:
    def serve_step(params, batch, caches):
        return bundle.forward_step(params, batch, caches, compute_dtype=compute_dtype)

    return serve_step

"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table4_accuracy   # one artifact
  PYTHONPATH=src python -m benchmarks.run --json op_microbench
      # also write per-op microbench rows to BENCH_kernels.json so future
      # PRs have a kernel-perf trajectory to regress against
  PYTHONPATH=src python -m benchmarks.run --json serving_bench
      # likewise BENCH_serving.json: decode/prefill tok/s + occupancy

Each module prints its table as CSV plus `name,us_per_call,derived` at the
end. The dry-run roofline tables (EXPERIMENTS.md sections Dry-run/Roofline)
are produced by benchmarks/roofline_table from results/dryrun/*.json.
"""

from __future__ import annotations

import pathlib
import sys
import time
import traceback

MODULES = [
    "table1_flops",
    "fig3_layer_replacement",
    "table4_accuracy",
    "fig11_temperature",
    "fig12_kv_sweep",
    "fig13_replaced_layers",
    "quant_ablation",
    "op_microbench",
    "serving_bench",
    "serving_spec",
    "serving_faults",
    "serving_router",
    "roofline_table",
]

_ROOT = pathlib.Path(__file__).resolve().parents[1]
# modules that emit a perf-trajectory JSON artifact under --json
JSON_ARTIFACTS = {
    "op_microbench": _ROOT / "BENCH_kernels.json",
    "serving_bench": _ROOT / "BENCH_serving.json",
    "serving_spec": _ROOT / "BENCH_spec.json",
    "serving_faults": _ROOT / "BENCH_faults.json",
    "serving_router": _ROOT / "BENCH_router.json",
    "fig13_replaced_layers": _ROOT / "BENCH_plans.json",
}


def main() -> None:
    argv = sys.argv[1:]
    json_mode = "--json" in argv
    only = [a for a in argv if a != "--json"] or None
    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        print(f"\n===== benchmarks.{name} =====")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            if json_mode and name in JSON_ARTIFACTS:
                mod.main(json_path=JSON_ARTIFACTS[name])
            else:
                mod.main()
        except Exception as e:  # noqa: BLE001 — keep the suite running
            failures.append((name, repr(e)))
            traceback.print_exc()
        print(f"[{name}: {time.time()-t0:.1f}s]")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()

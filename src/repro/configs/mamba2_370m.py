"""Mamba2-370M — SSD, attention-free [arXiv:2405.21060]."""
from repro.configs import ArchSpec

ARCH = ArchSpec(
    name="mamba2_370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0, n_kv_heads=0, d_head=0,      # attention-free
    d_ff=0,                                  # no MLP: pure mixer stack
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,                         # 32 SSD heads
    ssm_groups=1,
    tie_embeddings=True,
    sub_quadratic=True,
    notes="SSD (state-space duality); d_inner=2048, 32 heads of 64, N=128.",
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import SOFT_PQ_RULES, AdamW, lut_frozen_mask


def _params():
    return {
        "site": {
            "w": jnp.ones((4, 4)),
            "b": jnp.zeros((4,)),
            "centroids": jnp.ones((2, 3, 2)),
            "log_t": jnp.zeros(()),
        },
        "norm": {"scale": jnp.ones((4,))},
        "plain": {"w": jnp.ones((4, 2))},
    }


def test_frozen_mask_structural():
    mask = lut_frozen_mask(_params())
    assert mask["site"]["w"] is True and mask["site"]["b"] is True
    assert mask["site"]["centroids"] is False
    assert mask["plain"]["w"] is False          # dense site: trainable


def test_frozen_leaves_not_updated_and_zero_state():
    p = _params()
    mask = lut_frozen_mask(p)
    opt = AdamW(lr=0.1, rules=SOFT_PQ_RULES, clip_norm=None)
    st = opt.init(p, mask)
    assert st.m["site"]["w"].shape == (0,)      # no moment memory for frozen
    g = jax.tree.map(jnp.ones_like, p)
    p2, st2, _ = opt.update(g, st, p, mask)
    np.testing.assert_array_equal(np.asarray(p2["site"]["w"]), np.asarray(p["site"]["w"]))
    assert not np.allclose(np.asarray(p2["site"]["centroids"]), np.asarray(p["site"]["centroids"]))


def test_temperature_group_lr_scale():
    p = _params()
    mask = lut_frozen_mask(p)
    opt = AdamW(lr=1e-3, rules=SOFT_PQ_RULES, clip_norm=None)
    st = opt.init(p, mask)
    g = jax.tree.map(jnp.ones_like, p)
    p2, _, _ = opt.update(g, st, p, mask)
    d_logt = abs(float(p2["site"]["log_t"] - p["site"]["log_t"]))
    d_cent = abs(float((p2["site"]["centroids"] - p["site"]["centroids"]).reshape(-1)[0]))
    # paper Table 3: temperature lr = 100x centroid lr
    assert d_logt > 50 * d_cent


def test_grad_clip():
    p = {"w": jnp.ones((4,))}
    opt = AdamW(lr=1.0, clip_norm=1.0)
    st = opt.init(p)
    _, _, gnorm = opt.update({"w": jnp.full((4,), 100.0)}, st, p)
    assert float(gnorm) == 200.0                 # reported pre-clip norm


def test_adamw_descends_quadratic():
    opt = AdamW(lr=0.05, clip_norm=None)
    p = {"w": jnp.asarray([3.0, -2.0])}
    st = opt.init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st, _ = opt.update(g, st, p)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.05

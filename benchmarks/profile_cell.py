"""Profile one (arch x shape) dry-run cell: roofline terms + HLO hotspots.

  python benchmarks/profile_cell.py qwen3_1p7b decode_32k
  python benchmarks/profile_cell.py llama3_8b train_4k '{"mode": "lut_train"}'

Must own the first jax import: it forces 512 host devices before any
device state exists, so run it as a standalone script, not via
benchmarks/run.py.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch.dryrun import lower_cell
from repro.roofline.hlo_cost import hotspots


def main() -> None:
    arch, shape = sys.argv[1], sys.argv[2]
    kw = json.loads(sys.argv[3]) if len(sys.argv) > 3 else {}
    rec, compiled = lower_cell(arch, shape, **kw)
    r = rec["roofline"]
    print(f"== {arch} x {shape} {kw} ==")
    print(f"mem/dev {rec['memory']['total_hbm_bytes']/2**30:.2f} GiB | "
          f"t_comp {r['t_compute_s']:.3f}s t_mem {r['t_memory_s']:.3f}s "
          f"t_coll {r['t_collective_s']:.3f}s -> {r['bottleneck']}")
    print("collectives by kind (GB/dev):",
          {k: round(v / 1e9, 2) for k, v in r["collective_by_kind"].items()})
    print(f"{'op_name':70s} {'GFLOP':>9s} {'GB':>9s} {'collGB':>8s}")
    for name, c in hotspots(compiled.as_text(), top=22, depth=5):
        print(f"{name[:70]:70s} {c.flops/1e9:9.1f} {c.bytes/1e9:9.2f} "
              f"{c.coll_bytes/1e9:8.2f}")


if __name__ == "__main__":
    main()

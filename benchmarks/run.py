"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table4_accuracy   # one artifact

Each module prints its table as CSV plus `name,us_per_call,derived` at the
end. The dry-run roofline tables (EXPERIMENTS.md sections Dry-run/Roofline)
are produced by benchmarks/roofline_table from results/dryrun/*.json.
"""

from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "table1_flops",
    "fig3_layer_replacement",
    "table4_accuracy",
    "fig11_temperature",
    "fig12_kv_sweep",
    "fig13_replaced_layers",
    "quant_ablation",
    "op_microbench",
    "roofline_table",
]


def main() -> None:
    only = sys.argv[1:] if len(sys.argv) > 1 else None
    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        print(f"\n===== benchmarks.{name} =====")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception as e:  # noqa: BLE001 — keep the suite running
            failures.append((name, repr(e)))
            traceback.print_exc()
        print(f"[{name}: {time.time()-t0:.1f}s]")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()

"""Pallas kernels vs the pure-jnp oracle: shape/dtype sweep, interpret mode.

`lut_amm_pallas` is the v2 kernel (int8-native MXU table read, VMEM scratch
accumulation, fused epilogue — DESIGN.md §2.3); `lut_amm_pallas_v1` is the
original generation kept for benchmarking. Both must match the oracle.
"""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels.dist_argmin import encode_pallas
from repro.kernels.lut_amm import (
    _lut_amm_kernel_v2,
    lut_amm_pallas,
    lut_amm_pallas_v1,
)
from repro.kernels.ref import encode_ref, lut_amm_ref

SHAPES = [
    # (N, D, M, K, V, block_n, block_m, block_c)
    (32, 32, 64, 16, 4, 16, 64, 4),
    (64, 64, 128, 16, 8, 32, 128, 8),
    (100, 64, 130, 16, 32, 32, 128, None),      # padding on N and M
    (17, 96, 48, 8, 32, 8, 128, 1),             # tiny blocks, K=8
    (128, 256, 512, 16, 32, 128, 256, None),    # production-ish tile
    (8, 128, 384, 16, 16, 8, 128, 2),
]

# ragged cases for the v2 acceptance sweep: N/M not multiples of the blocks,
# block_c not dividing C (the wrapper shrinks it to the next divisor)
RAGGED = [
    # (N, D, M, K, V, block_n, block_m, block_c)
    (33, 64, 70, 16, 8, 16, 64, 3),             # bc=3, C=8 -> shrinks to 2
    (100, 64, 130, 16, 32, 32, 128, None),
    (7, 96, 130, 8, 16, 8, 128, 4),             # bc=4, C=6 -> shrinks to 3
    (65, 160, 48, 16, 32, 64, 128, 5),
]


def _mk(n, d, m, k, v, seed=None, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed if seed is not None else n * d), 3)
    x = jax.random.normal(k1, (n, d), dtype)
    P = jax.random.normal(k2, (d // v, k, v), jnp.float32)
    T = jax.random.normal(k3, (d // v, k, m), jnp.float32)
    return x, P, T


@pytest.mark.parametrize("shape", SHAPES, ids=[str(s[:5]) for s in SHAPES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_amm_matches_ref(shape, dtype):
    n, d, m, k, v, bn, bm, bc = shape
    x, P, T = _mk(n, d, m, k, v, dtype=dtype)
    qt = quant.quantize_table(T, bits=8)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = lut_amm_pallas(
        x, P, qt.q, qt.scale, block_n=bn, block_m=bm, block_c=bc, interpret=True
    )
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("shape", SHAPES[:4], ids=[str(s[:5]) for s in SHAPES[:4]])
def test_per_column_scale_variant(shape):
    n, d, m, k, v, bn, bm, bc = shape
    x, P, T = _mk(n, d, m, k, v, seed=1 + n)
    qt = quant.quantize_table(T, bits=8, per_column=True)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = lut_amm_pallas(
        x, P, qt.q, qt.scale, block_n=bn, block_m=bm, block_c=bc, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", RAGGED, ids=[str(s[:5]) for s in RAGGED])
@pytest.mark.parametrize("layout", ["per_codebook", "per_column", "m_shared"])
def test_v2_ragged_shapes_all_scale_layouts(shape, layout):
    """Acceptance sweep: v2 matches the fp32 dequantize reference within
    1e-4 on ragged shapes across every scale layout (per-codebook (C,1,1),
    per-column (C,1,M), m-shared (1,1,M) — the single-dequantize path)."""
    n, d, m, k, v, bn, bm, bc = shape
    x, P, T = _mk(n, d, m, k, v)
    kw = {"per_column": layout == "per_column", "m_shared": layout == "m_shared"}
    qt = quant.quantize_table(T, bits=8, **kw)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = lut_amm_pallas(
        x, P, qt.q, qt.scale, block_n=bn, block_m=bm, block_c=bc, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("shape", SHAPES[:3], ids=[str(s[:5]) for s in SHAPES[:3]])
def test_v1_matches_ref(shape):
    n, d, m, k, v, bn, bm, bc = shape
    x, P, T = _mk(n, d, m, k, v)
    qt = quant.quantize_table(T, bits=8)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = lut_amm_pallas_v1(
        x, P, qt.q, qt.scale, block_n=bn, block_m=bm, block_c=bc, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ["none", "relu", "silu", "gelu", "relu2"])
def test_fused_bias_activation_epilogue(act):
    """bias + activation fused into the final-step epilogue == applying them
    to the oracle output."""
    import repro.models.common as common

    n, d, m, k, v = 40, 64, 100, 16, 8
    x, P, T = _mk(n, d, m, k, v, seed=7)
    b = jax.random.normal(jax.random.PRNGKey(9), (m,))
    qt = quant.quantize_table(T, m_shared=True)
    ref = lut_amm_ref(x, P, qt.q, qt.scale) + b
    if act != "none":
        ref = common.activation(act, ref)
    out = lut_amm_pallas(
        x, P, qt.q, qt.scale, bias=b, act=act,
        block_n=16, block_m=128, block_c=2, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_autotuned_default_blocks():
    """With no explicit blocks the wrapper consults the autotuner (cache miss
    -> heuristic) and still matches the oracle."""
    n, d, m, k, v = 50, 96, 75, 16, 16
    x, P, T = _mk(n, d, m, k, v, seed=3)
    qt = quant.quantize_table(T)
    ref = lut_amm_ref(x, P, qt.q, qt.scale)
    out = lut_amm_pallas(x, P, qt.q, qt.scale, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_v2_structure_no_output_rmw_single_dequant():
    """Acceptance: the v2 kernel never read-modify-writes o_ref (accumulation
    lives in the VMEM scratch) and dequantizes exactly once per output tile
    on the shared-scale path (one scale multiply, in the final epilogue)."""
    src = inspect.getsource(_lut_amm_kernel_v2)
    assert "o_ref[...] +=" not in src and "o_ref[...]+=" not in src
    # o_ref is stored exactly once (epilogue) and never read
    assert src.count("o_ref[...] =") == 1
    assert "= o_ref" not in src and "o_ref[...])" not in src
    # scratch accumulator carries the running sum instead
    assert "acc_ref[...] +=" in src


def test_v2_no_fp32_table_materialization():
    """The int8 table tile must enter the MXU contraction directly — no
    `t_ref[...].astype` dequant materialization anywhere in v2."""
    src = inspect.getsource(_lut_amm_kernel_v2)
    assert "t_ref[...].astype" not in src
    assert "preferred_element_type=jnp.int32" in src


@pytest.mark.parametrize(
    "n,d,k,v", [(32, 32, 16, 4), (100, 256, 16, 32), (7, 64, 8, 8)]
)
def test_encode_kernel_matches_ref(n, d, k, v):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n))
    x = jax.random.normal(k1, (n, d))
    P = jax.random.normal(k2, (d // v, k, v))
    out = encode_pallas(x, P, block_n=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(encode_ref(x, P)))


def test_encode_autotuned_default_blocks():
    x = jax.random.normal(jax.random.PRNGKey(0), (23, 96))
    P = jax.random.normal(jax.random.PRNGKey(1), (6, 16, 16))
    out = encode_pallas(x, P, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(encode_ref(x, P)))


def test_kernel_argmin_tie_break(key):
    """Duplicate centroids: kernel must pick the lowest index like jnp."""
    P = jnp.zeros((1, 4, 4)).at[0, 1].set(1.0)      # rows 0,2,3 identical
    x = jnp.zeros((8, 4))
    out = encode_pallas(x, P, interpret=True)
    assert int(jnp.max(out)) == 0

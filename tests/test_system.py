"""End-to-end behaviour tests for the LUT-NN system (paper claims in
miniature — the full-size counterparts live in benchmarks/)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pq, quant
from repro.core.amm import LUTConfig, Mode, dense_flops, lut_flops, lut_linear
from repro.core.lut_layer import deploy_params, init_dense, lut_train_params_from_dense


def test_flops_reduction_matches_table1(key):
    """Paper Table 1/section 6.2: reduction = M / (K + M/V)."""
    n, d, m = 1024, 768, 3072                      # BERT FFN up-projection
    cfg = LUTConfig(k=16, v=32)
    red = dense_flops(n, d, m) / lut_flops(n, d, m, cfg)
    expect = m / (cfg.k + m / cfg.v)
    assert abs(red - expect) < 1e-9
    assert red > 26                                # paper: up to 16x e2e, more per-op


def test_lut_approximates_clustered_activations(key):
    """On inputs with cluster structure (the paper's premise), LUT-AMM with
    k-means centroids approximates the dense op well; on the same data with
    random centroids it does not."""
    k1, k2, k3 = jax.random.split(key, 3)
    d, m, n_clusters = 64, 96, 16
    centers = jax.random.normal(k1, (n_clusters, d))
    x = centers[jax.random.randint(k2, (512,), 0, n_clusters)]
    x = x + 0.05 * jax.random.normal(k2, (512, d))
    dense = init_dense(k3, d, m)
    cfg = LUTConfig(k=16, v=8)
    y_ref = lut_linear(cfg, Mode.DENSE, dense, x)

    trainable, frozen = lut_train_params_from_dense(k3, dense, x, cfg)
    dep = deploy_params(trainable, frozen, cfg)
    y_lut = lut_linear(cfg, Mode.LUT_INFER, dep, x)
    rel = float(jnp.linalg.norm(y_lut - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 0.30, rel

    rnd = dict(dep, centroids=jax.random.normal(k1, dep["centroids"].shape))
    tbl = pq.build_table(rnd["centroids"], frozen["w"], stop_weight_grad=False)
    qt = quant.quantize_table(tbl)
    rnd.update(table_q=qt.q, table_scale=qt.scale)
    y_rnd = lut_linear(cfg, Mode.LUT_INFER, rnd, x)
    rel_rnd = float(jnp.linalg.norm(y_rnd - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 0.5 * rel_rnd, (rel, rel_rnd)


def test_int8_table_accuracy_claim(key):
    """Section 6.3: INT8 table ~ FP32 table accuracy (0.04% drop there)."""
    k1, k2, k3 = jax.random.split(key, 3)
    d, m = 64, 128
    centers = jax.random.normal(k1, (16, d))
    x = centers[jax.random.randint(k2, (256,), 0, 16)] + 0.05 * jax.random.normal(k2, (256, d))
    dense = init_dense(k3, d, m)
    cfg8 = LUTConfig(k=16, v=8, bits=8)
    trainable, frozen = lut_train_params_from_dense(k3, dense, x, cfg8)
    y_ref = lut_linear(cfg8, Mode.DENSE, dense, x)

    tbl = pq.build_table(trainable["centroids"], frozen["w"], stop_weight_grad=False)
    enc = pq.hard_encode(
        pq.pairwise_sq_dists(pq.split_subvectors(x, cfg8.v), trainable["centroids"])
    )
    y_fp32 = pq.lut_contract(enc, tbl)
    dep = deploy_params(trainable, frozen, cfg8)
    y_int8 = lut_linear(cfg8, Mode.LUT_INFER, dep, x)

    e_fp = float(jnp.linalg.norm(y_fp32 - y_ref))
    e_i8 = float(jnp.linalg.norm(y_int8 - y_ref))
    assert e_i8 < 1.05 * e_fp + 1e-3               # int8 adds <5% extra error

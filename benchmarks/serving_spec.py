"""Speculative-decoding bench: draft/verify scheduler vs plain decode on
the SAME artifact, greedy parity asserted in-line (DESIGN.md §14).

Two scenarios, both regression-gated through BENCH_spec.json:

  * self_draft — the serving plan drafts for itself (no draft bundle).
    Every proposal is accepted by construction, so this row isolates the
    scheduler overhead ceiling: target_forwards_per_token must sit
    STRICTLY below 1.0 (a plain-decode engine is exactly 1.0 — each
    emitted token costs its slot one verify participation).
  * shared_draft — the paper's deployment shape: one k-means-initialized
    LUT_TRAIN checkpoint deployed as a TWO-plan artifact (draft = all-LUT
    trained plan, target = keeping_dense("attn/*")), table leaves shared
    on disk. The k-means init stands in for soft-PQ training (no training
    loop on the bench clock), so acceptance is low but nonzero — the row
    records the honest acceptance-rate floor and asserts tfpt <= 1.0:
    speculation must never cost more target forwards than plain decode.

Both rows assert greedy parity: the spec engine's emitted tokens are
byte-identical to a plain engine's on the same requests (the §14.3
emitted-token rule makes this exact, not statistical). With `json_path`
set (benchmarks/run.py --json) rows land in BENCH_spec.json and
benchmarks/check_regression.py diffs the deterministic counters.
"""

from __future__ import annotations

import json
import pathlib
import sys
import tempfile
import time
import warnings

import jax
import jax.numpy as jnp

from repro.configs import build_model, effective_plan, get_arch, reduce_arch
from repro.core import convert
from repro.core.amm import Mode
from repro.serving.engine import ServingEngine

N_SLOTS = 4
MAX_SEQ = 64
PREFILL_CHUNK = 8
MAX_TOKENS = 8
N_REQ = 6
GAMMA = 3
KMEANS_BATCH = 4       # sample batches for the draft's k-means init
SEQ = 32


def _prompts() -> list[list[int]]:
    return [[(i * 7 + j) % 200 + 1 for j in range(3 + (i * 5) % 12)]
            for i in range(N_REQ)]


def _run(bundle, params, *, spec: bool, draft=None) -> tuple[list, dict, float]:
    """Serve the fixed request trace; returns (finished, stats, wall_s)."""
    kw: dict = {}
    if spec:
        kw.update(spec_decode=True, spec_gamma=GAMMA)
        if draft is not None:
            kw.update(draft_bundle=draft[0], draft_params=draft[1])
    eng = ServingEngine(
        bundle, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
        prefill_chunk=PREFILL_CHUNK, compute_dtype=jnp.float32,
        autotune_lut=False, **kw,
    )
    eng.warmup()
    t0 = time.perf_counter()
    for p in _prompts():
        eng.submit(p, max_tokens=MAX_TOKENS)
    done = eng.run_until_done(max_steps=10_000)
    wall_s = max(time.perf_counter() - t0, 1e-9)
    assert len(done) == N_REQ and all(r.status == "ok" for r in done), done
    return done, eng.stats(), wall_s


def _scenario(name: str, bundle, params, *, draft=None) -> dict:
    """Run plain + spec engines on one artifact; assert parity; build row."""
    plain_done, plain_st, _ = _run(bundle, params, spec=False)
    spec_done, st, wall_s = _run(bundle, params, spec=True, draft=draft)

    plain = {r.rid: list(r.out_tokens) for r in plain_done}
    for r in spec_done:
        assert list(r.out_tokens) == plain[r.rid], (
            f"{name}: spec output diverged from plain decode "
            f"(rid={r.rid}): {list(r.out_tokens)} != {plain[r.rid]}"
        )

    tfpt = st["target_forwards_per_token"]
    if draft is None:
        # self-draft: the draft IS the target, but its proposals come from
        # a separate width-1 jit while verification reruns the same math at
        # width γ+1 — on random-init near-flat logits a rounding-level
        # argmax tie can occasionally break differently, so acceptance is
        # floored, not pinned at 1.0. tfpt < 1.0 is the structural gate:
        # plain decode is exactly 1.0, any acceptance at all beats it.
        assert tfpt < 1.0, (name, tfpt)
        assert st["spec_acceptance_rate"] >= 0.3, (name, st)
    else:
        # k-means-only draft: acceptance is low, but speculation must
        # never cost MORE target forwards than plain decode
        assert tfpt <= 1.0, (name, tfpt)
        assert st["spec_tokens_accepted"] >= 0
    assert st["spec_tokens_emitted"] == plain_st["decode_tokens"], (st, plain_st)

    return {
        "scenario": name,
        "requests": N_REQ,
        "n_slots": N_SLOTS,
        "prefill_chunk": PREFILL_CHUNK,
        "max_tokens": MAX_TOKENS,
        "spec_gamma": st["spec_gamma"],
        "greedy_parity": True,
        "steps": st["steps"],
        "decode_tokens": st["decode_tokens"],
        "prefill_tokens": st["prefill_tokens"],
        "prefill_forwards": st["prefill_forwards"],
        "shape_cache_hits": st["shape_cache_hits"],
        "spec_rounds": st["spec_rounds"],
        "spec_slot_rounds": st["spec_slot_rounds"],
        "spec_draft_forwards": st["spec_draft_forwards"],
        "spec_verify_forwards": st["spec_verify_forwards"],
        "spec_catchup_forwards": st["spec_catchup_forwards"],
        "spec_tokens_proposed": st["spec_tokens_proposed"],
        "spec_tokens_accepted": st["spec_tokens_accepted"],
        "spec_bonus_tokens": st["spec_bonus_tokens"],
        "spec_tokens_emitted": st["spec_tokens_emitted"],
        "spec_acceptance_rate": round(st["spec_acceptance_rate"], 4),
        "target_forwards_per_token": round(tfpt, 4),
        "plain_decode_forwards": plain_st["decode_forwards"],
        "wall_s": round(wall_s, 3),
    }


def _two_plan_artifact(td: pathlib.Path):
    """Dense init -> k-means LUT_TRAIN -> two-plan artifact on disk."""
    from repro.serving.artifact import load_artifact

    from repro.data import MarkovLM

    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
    dense = build_model(arch, Mode.DENSE)
    dparams = dense.init(jax.random.PRNGKey(0))
    data = MarkovLM(vocab=arch.vocab, seq_len=SEQ, batch=KMEANS_BATCH)
    batches = [data.batch_at(100 + i) for i in range(2)]
    blut, lparams = convert.convert_dense_to_lut_train(
        dense, dparams, batches, jax.random.PRNGKey(7), kmeans_iters=4
    )
    trained = effective_plan(arch)
    convert.deploy_to_artifact(
        blut, lparams, td / "art",
        target_plan=trained.keeping_dense("attn/*"),
        extra_plans={"draft": trained},
    )
    target = load_artifact(td / "art", restore_autotune=False)
    draft = load_artifact(td / "art", plan="draft", restore_autotune=False)
    return target, draft


def main(json_path: str | pathlib.Path | None = None) -> list[dict]:
    rows = []
    cols = ["scenario", "spec_acceptance_rate", "target_forwards_per_token",
            "spec_rounds", "spec_draft_forwards", "spec_bonus_tokens",
            "greedy_parity"]
    print(",".join(cols))

    def emit(row):
        rows.append(row)
        print(",".join(str(row[c]) for c in cols))

    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
    bundle = build_model(arch, Mode.LUT_INFER)
    params = bundle.init(jax.random.PRNGKey(0))
    emit(_scenario("self_draft", bundle, params))

    with tempfile.TemporaryDirectory() as td:
        target, draft = _two_plan_artifact(pathlib.Path(td))
        emit(_scenario("shared_draft", target.bundle, target.params,
                       draft=(draft.bundle, draft.params)))

    if json_path is not None:
        payload = {
            "schema": "serving_spec.v1",
            "arch": "qwen3_1p7b(reduced,L=2)",
            "mode": "lut_infer",
            "backend": jax.default_backend(),
            "rows": rows,
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=1))
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    warnings.filterwarnings("default")
    _JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_spec.json"
    main(json_path=_JSON if "--json" in sys.argv else None)

"""Scalar quantization of lookup tables (paper section 3.3) + QAT.

Symmetric range-based linear quantization  r = s * q, with
s = max|r| / (2^(n-1) - 1) and zero-point fixed at 0. During soft-PQ training
the forward pass sees the quantized table while the backward pass updates the
real-valued table (straight-through), exactly as in the paper (Jacob et al.
style QAT). At deployment the table is materialized as int8 (or int4-in-int8)
plus a per-(codebook, column-block) fp32 scale.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QuantizedTable(NamedTuple):
    """Deployed LUT: int8 codes + scales.

    q     : (C, K, M) int8 codes (int4 also stored in int8, range [-7, 7])
    scale : (C, 1, 1) or (C, 1, M) fp32 — per-codebook (paper) or per-column
    """

    q: jax.Array
    scale: jax.Array

    def dequant(self, dtype=jnp.bfloat16) -> jax.Array:
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)


def _qmax(bits: int) -> float:
    return float(2 ** (bits - 1) - 1)


def table_scale(
    T: jax.Array, *, bits: int = 8, per_column: bool = False, m_shared: bool = False
) -> jax.Array:
    """Symmetric scale. Paper: one scale per table; per_column is our
    finer-grained beyond-paper variant (free accuracy, same int8 bytes).
    m_shared: one scale per OUTPUT column shared across codebooks,
    (1, 1, M) — the layout that lets the deployed path run a single
    int8 x int8 -> int32 MXU contraction over (C*K) and rescale once
    (DESIGN.md section 2 / EXPERIMENTS.md section Perf, decode iteration)."""
    if m_shared:
        absmax = jnp.max(jnp.abs(T), axis=(0, 1), keepdims=True)  # (1, 1, M)
    elif per_column:
        absmax = jnp.max(jnp.abs(T), axis=1, keepdims=True)       # (C, 1, M)
    else:
        absmax = jnp.max(jnp.abs(T), axis=(1, 2), keepdims=True)  # (C, 1, 1)
    return jnp.maximum(absmax.astype(jnp.float32), 1e-8) / _qmax(bits)


def quantize_table(
    T: jax.Array, *, bits: int = 8, per_column: bool = False, m_shared: bool = False
) -> QuantizedTable:
    scale = table_scale(T, bits=bits, per_column=per_column, m_shared=m_shared)
    q = jnp.clip(jnp.round(T.astype(jnp.float32) / scale), -_qmax(bits), _qmax(bits))
    return QuantizedTable(q=q.astype(jnp.int8), scale=scale)


def fake_quant(
    T: jax.Array, *, bits: int = 8, per_column: bool = False, m_shared: bool = False
) -> jax.Array:
    """QAT fake-quantization with a straight-through estimator.

    forward : quantize-dequantize(T)   (what inference will see)
    backward: identity                 (real-valued table keeps adjusting)
    """
    scale = table_scale(T, bits=bits, per_column=per_column, m_shared=m_shared)
    t32 = T.astype(jnp.float32)
    qdq = jnp.clip(jnp.round(t32 / scale), -_qmax(bits), _qmax(bits)) * scale
    out = t32 + jax.lax.stop_gradient(qdq - t32)
    return out.astype(T.dtype)

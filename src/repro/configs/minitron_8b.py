"""Minitron-8B — pruned Nemotron-4 [arXiv:2407.14679; hf].

Squared-ReLU non-gated MLP (Nemotron family), 256k vocab.
"""
from repro.configs import ArchSpec

ARCH = ArchSpec(
    name="minitron_8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=16384,
    vocab=256000,
    act="relu2",
    mlp_gated=False,
    rope_theta=500_000.0,
)

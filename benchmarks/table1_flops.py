"""Paper Table 1 + Table 2: FLOPs and size formulas for LUT-NN vs dense.

Validates our implementation's cost accounting against the paper's closed
forms and prints the Table-2-style grid for the paper's models AND the 10
assigned architectures (per-layer sites enumerated from the real configs).
"""

from __future__ import annotations

import time

from repro.configs import ARCH_IDS, build_model, get_arch
from repro.core.amm import LUTConfig, Mode, dense_bytes, dense_flops, lut_flops, lut_table_bytes


PAPER_MODELS = {
    # name: (layers as (N, D, M) matmuls) — representative single ops
    "bert_ffn_up": (128 * 512, 768, 3072),
    "bert_ffn_down": (128 * 512, 3072, 768),
    "resnet18_conv3x3_l2": (56 * 56, 64 * 9, 64),
}


def table1_rows():
    rows = []
    for name, (n, d, m) in PAPER_MODELS.items():
        kv = (16, 32) if "bert" in name else (16, 9)
        cfg = LUTConfig(k=kv[0], v=kv[1] if d % kv[1] == 0 else 8)
        fl_d, fl_l = dense_flops(n, d, m), lut_flops(n, d, m, cfg)
        sz_d, sz_l = dense_bytes(d, m), lut_table_bytes(d, m, cfg)
        rows.append((name, cfg.k, cfg.v, fl_d / fl_l, sz_d / sz_l))
    return rows


def arch_rows():
    """Aggregate model-level FLOPs/size reduction over every LUT site."""
    rows = []
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        m = build_model(arch, Mode.LUT_INFER)
        n_tok = 4096  # per-token-batch FLOPs ratio is size-independent
        fl_d = fl_l = sz_d = sz_l = 0
        def walk(cfg_obj):
            nonlocal fl_d, fl_l, sz_d, sz_l
            from repro.models.common import SiteCfg
            import dataclasses as dc

            if isinstance(cfg_obj, SiteCfg):
                if cfg_obj.mode == Mode.LUT_INFER:
                    fl_d += dense_flops(n_tok, cfg_obj.d_in, cfg_obj.d_out)
                    fl_l += lut_flops(n_tok, cfg_obj.d_in, cfg_obj.d_out, cfg_obj.lut)
                    sz_d += dense_bytes(cfg_obj.d_in, cfg_obj.d_out, 2)   # bf16 dense
                    sz_l += lut_table_bytes(cfg_obj.d_in, cfg_obj.d_out, cfg_obj.lut)
                return
            if dc.is_dataclass(cfg_obj):
                for f in dc.fields(cfg_obj):
                    v = getattr(cfg_obj, f.name)
                    if dc.is_dataclass(v):
                        walk(v)
                    elif isinstance(v, tuple):
                        for item in v:
                            if isinstance(item, tuple) and len(item) == 2:
                                count, blk = item
                                # weight each block by its layer count
                                before = [fl_d, fl_l, sz_d, sz_l]
                                walk(blk)
                                after = [fl_d, fl_l, sz_d, sz_l]
                                fl_d = before[0] + (after[0] - before[0]) * count
                                fl_l = before[1] + (after[1] - before[1]) * count
                                sz_d = before[2] + (after[2] - before[2]) * count
                                sz_l = before[3] + (after[3] - before[3]) * count

        walk(m.cfg)
        if fl_l:
            rows.append((aid, fl_d / fl_l, sz_d / sz_l))
    return rows


def main(csv: bool = True) -> None:
    t0 = time.time()
    print("# Table 1/2 analog: per-op and per-arch LUT-NN cost reduction")
    print("op,K,V,flops_reduction,size_reduction")
    for name, k, v, fr, sr in table1_rows():
        print(f"{name},{k},{v},{fr:.2f},{sr:.2f}")
    print("arch,flops_reduction_model,size_reduction_model")
    for aid, fr, sr in arch_rows():
        print(f"{aid},{fr:.2f},{sr:.2f}")
    us = (time.time() - t0) * 1e6
    print(f"table1_flops,{us:.0f},analytic")


if __name__ == "__main__":
    main()

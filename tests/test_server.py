"""HTTP front end (DESIGN.md §11.2): routes, streaming, drain semantics,
pump death. Talks real HTTP over a loopback socket — no framework, no mocks
between the client bytes and the server."""

import asyncio
import json

import jax
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultInjector, FaultSpec
from repro.serving.server import (
    EXIT_STRANDED,
    EnginePump,
    FrontEnd,
    metrics_text,
)


@pytest.fixture(scope="module")
def small():
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=1)
    bundle = build_model(arch, Mode.DENSE)
    return bundle, bundle.init(jax.random.PRNGKey(0))


def _pump(small, **kw):
    bundle, params = small
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("autotune_lut", False)
    return EnginePump(ServingEngine(bundle, params, **kw))


async def _http(port, method, path, body=None):
    """One HTTP/1.1 exchange; returns (status_code, raw_body_bytes)."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps(body).encode() if body is not None else b""
    writer.write(
        f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
        f"Content-Length: {len(payload)}\r\n\r\n".encode() + payload
    )
    await writer.drain()
    raw = await reader.read()                     # server sends Connection: close
    writer.close()
    head, _, rest = raw.partition(b"\r\n\r\n")
    return int(head.split()[1]), rest


class StubBackend:
    """Minimal backend for drain tests: controllable pending count."""

    def __init__(self, pending=0):
        self.n = pending
        self.aborted = 0
        self.closed = False
        self.healthy = True

    def pending(self):
        return self.n

    def abort_pending(self):
        self.aborted, self.n = self.n, 0
        return self.aborted

    def stats(self):
        return {"pending": self.n, "queue_depth": 0}

    def cancel(self, rid):
        return False

    def close(self):
        self.closed = True


# ---------------------------------------------------------------------------
# routes
# ---------------------------------------------------------------------------

def test_routes_and_blocking_generate(small):
    pump = _pump(small)

    async def scenario():
        fe = FrontEnd(pump, port=0)
        await fe.start()
        p = fe.port
        assert (await _http(p, "GET", "/healthz"))[0] == 200
        assert (await _http(p, "GET", "/readyz"))[0] == 200
        code, body = await _http(p, "GET", "/nope")
        assert code == 404
        assert (await _http(p, "GET", "/generate"))[0] == 405
        code, body = await _http(p, "POST", "/generate", {"prompt": "bad"})
        assert code == 400 and b"list of ints" in body
        code, body = await _http(p, "POST", "/generate",
                                 {"prompt": [1], "priority": "high"})
        assert code == 400 and b"priority must be an int" in body
        code, body = await _http(p, "POST", "/generate",
                                 {"prompt": [1], "deadline_s": "soon"})
        assert code == 400 and b"deadline_s must be a number" in body
        code, body = await _http(p, "POST", "/generate",
                                 {"prompt": [1, 2, 3], "max_tokens": 3})
        assert code == 200
        resp = json.loads(body)
        assert resp["status"] == "ok" and resp["n_tokens"] == 3
        assert len(resp["tokens"]) == 3
        code, body = await _http(p, "GET", "/stats")
        st = json.loads(body)
        assert st["backend"] == "local" and st["completed"] == 1
        code, body = await _http(p, "GET", "/metrics")
        assert code == 200
        assert b"lutnn_serving_completed 1" in body
        assert b"lutnn_serving_queue_depth" in body
        code, body = await _http(p, "POST", "/cancel", {"rid": 999})
        assert code == 200 and json.loads(body) == {"cancelled": False}
        assert (await _http(p, "POST", "/cancel", {"x": 1}))[0] == 400
        fe.request_shutdown()
        assert await fe.serve_forever() == 0

    asyncio.run(scenario())


def test_streaming_generate(small):
    pump = _pump(small)

    async def scenario():
        fe = FrontEnd(pump, port=0)
        await fe.start()
        code, body = await _http(
            fe.port, "POST", "/generate",
            {"prompt": [5, 6, 7], "max_tokens": 4, "stream": True},
        )
        assert code == 200
        lines = [json.loads(ln) for ln in body.decode().splitlines()]
        assert "rid" in lines[0]
        streamed = [ln["token"] for ln in lines[1:-1]]
        final = lines[-1]
        assert final["status"] == "ok"
        assert streamed == final["tokens"]        # per-token lines == final list
        assert final["n_tokens"] == 4
        fe.request_shutdown()
        assert await fe.serve_forever() == 0

    asyncio.run(scenario())


def test_shed_maps_to_429(small):
    # queue of 1 + a slot pinned by slow (spike-injected) decode: the next
    # arrival at equal priority is shed at submit and surfaces as HTTP 429
    bundle, params = small
    eng = ServingEngine(
        bundle, params, n_slots=1, max_seq=64, prefill_chunk=4,
        autotune_lut=False, max_queue=1,
        faults=FaultInjector(FaultSpec(spike_p=1.0, spike_s=0.1)),
    )
    pump = EnginePump(eng)

    async def scenario():
        fe = FrontEnd(pump, port=0)
        await fe.start()
        p = fe.port
        occupants = [asyncio.create_task(_http(
            p, "POST", "/generate", {"prompt": [1, 2], "max_tokens": 60}))]
        await asyncio.sleep(0.5)                  # rid 0 admitted to the slot
        occupants.append(asyncio.create_task(_http(
            p, "POST", "/generate", {"prompt": [3, 4], "max_tokens": 60})))
        await asyncio.sleep(0.3)                  # rid 1 queued: queue is full
        code, body = await _http(
            p, "POST", "/generate", {"prompt": [7, 8], "max_tokens": 2})
        assert code == 429
        assert json.loads(body)["status"] == "shed"
        # cancel the pinned occupants so the drain below is instant
        for rid in (0, 1):
            code, body = await _http(p, "POST", "/cancel", {"rid": rid})
            assert json.loads(body)["cancelled"] is True
        for t in occupants:
            code, body = await t
            assert json.loads(body)["status"] == "cancelled"
        fe.request_shutdown()
        assert await fe.serve_forever() == 0

    asyncio.run(scenario())


# ---------------------------------------------------------------------------
# drain
# ---------------------------------------------------------------------------

def test_drain_clean_exit():
    stub = StubBackend(pending=0)

    async def scenario():
        fe = FrontEnd(stub, port=0)
        await fe.start()
        fe.request_shutdown()
        return await fe.serve_forever()

    assert asyncio.run(scenario()) == 0
    assert stub.closed and stub.aborted == 0


def test_drain_refuses_traffic_then_finishes():
    stub = StubBackend(pending=1)

    async def scenario():
        fe = FrontEnd(stub, port=0, drain_timeout_s=10.0)
        await fe.start()
        fe.request_shutdown()
        await asyncio.sleep(0.05)                 # drain loop is now waiting
        code, body = await _http(fe.port, "GET", "/readyz")
        assert code == 503 and b"draining" in body
        code, body = await _http(fe.port, "POST", "/generate", {"prompt": [1]})
        assert code == 503
        assert (await _http(fe.port, "GET", "/healthz"))[0] == 200  # still alive
        stub.n = 0                                # in-flight work completes
        return await fe.serve_forever()

    assert asyncio.run(scenario()) == 0
    assert stub.aborted == 0


def test_drain_timeout_aborts_and_exits_stranded():
    stub = StubBackend(pending=2)

    async def scenario():
        fe = FrontEnd(stub, port=0, drain_timeout_s=0.1)
        await fe.start()
        fe.request_shutdown()
        return await fe.serve_forever()

    assert asyncio.run(scenario()) == EXIT_STRANDED
    assert stub.aborted == 2                      # stranded rids resolved, not lost
    assert stub.closed


# ---------------------------------------------------------------------------
# pump death (unsupervised backend)
# ---------------------------------------------------------------------------

def test_pump_death_resolves_requests_and_refuses_new(small):
    bundle, params = small
    eng = ServingEngine(bundle, params, n_slots=1, max_seq=64, prefill_chunk=4,
                        autotune_lut=False,
                        faults=FaultInjector(FaultSpec(kill_at_step=0)))
    pump = EnginePump(eng)
    events = []
    done = __import__("threading").Event()

    def on_event(ev):
        events.append(ev)
        if ev[0] == "done":
            done.set()

    pump.submit({"prompt": [1, 2, 3], "max_tokens": 4}, on_event)
    assert done.wait(timeout=30)
    status, _tokens = events[-1][1]
    assert status == "error"                      # resolved, not silently lost
    assert not pump.healthy
    assert pump.pending() == 0
    with pytest.raises(RuntimeError, match="engine died"):
        pump.submit({"prompt": [1], "max_tokens": 1})
    pump.close()


# ---------------------------------------------------------------------------
# metrics formatting
# ---------------------------------------------------------------------------

def test_metrics_text_numeric_only():
    text = metrics_text({"a": 1, "b": 2.5, "skip": "str", "flag": True})
    assert "lutnn_serving_a 1" in text
    assert "lutnn_serving_b 2.5" in text
    assert "# TYPE lutnn_serving_a gauge" in text
    assert "skip" not in text and "flag" not in text


def test_metrics_text_per_replica_labels():
    # EngineRouter stats carry a per_replica sub-dict: rendered as labelled
    # lutnn_replica_* gauges, one TYPE line per metric family
    text = metrics_text({
        "routed": 3,
        "per_replica": {
            "0": {"routed": 2, "queue_depth": 1, "backend": "supervised"},
            "1": {"routed": 1, "queue_depth": 0},
        },
    })
    assert "lutnn_serving_routed 3" in text
    assert 'lutnn_replica_routed{replica="0"} 2' in text
    assert 'lutnn_replica_routed{replica="1"} 1' in text
    assert 'lutnn_replica_queue_depth{replica="0"} 1' in text
    assert text.count("# TYPE lutnn_replica_routed gauge") == 1
    assert "backend" not in text                  # strings never render
    assert "per_replica " not in text             # the dict itself is not a gauge

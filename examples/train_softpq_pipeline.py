"""End-to-end driver (deliverable b): dense pretrain -> convert -> soft-PQ
QAT fine-tune -> int8 deploy -> eval + LUTArtifact, on a real (reduced)
registry arch.

  PYTHONPATH=src python examples/train_softpq_pipeline.py [--steps 200]

This is the same flow `python -m repro.launch.train --lut` runs; kept as a
standalone script so it can be stepped through. The emitted artifact serves
with `python -m repro.launch.serve --artifact <dir>` (examples/
deploy_and_serve.py shows the full loop).
"""

import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3_1p7b")
    args = ap.parse_args()
    train_main([
        "--arch", args.arch, "--steps", str(args.steps), "--lut",
        "--d-model", "256", "--layers", "4",
    ])

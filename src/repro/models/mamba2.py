"""Mamba-2 (SSD, state-space duality — arXiv:2405.21060) block in pure JAX.

Training/prefill uses the chunked SSD algorithm (quadratic within Q-sized
chunks, linear recurrence across chunks via lax.scan); decode is the O(1)
recurrent state update — this is what makes the `long_500k` shape runnable
for the SSM/hybrid archs while pure-attention archs are skipped.

LUT-NN sites: in_proj and out_proj (the only static weight-activation
contractions). The SSD scan itself is activation-activation (no weights) and
is not LUT-replaceable — documented in DESIGN.md section 4.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import Params, SiteCfg, linear, linear_init, rmsnorm_init


@dataclasses.dataclass(frozen=True)
class Mamba2Cfg:
    d_model: int
    d_inner: int           # expand * d_model
    n_heads: int           # d_inner // head_dim
    head_dim: int
    ssm_state: int         # N
    n_groups: int = 1      # B/C groups (GQA analogue)
    conv_width: int = 4
    chunk: int = 256
    in_proj: SiteCfg = None   # d_model -> 2*d_inner + 2*G*N + H
    out_proj: SiteCfg = None  # d_inner -> d_model

    @property
    def d_xbc(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.ssm_state

    @property
    def d_in_proj(self) -> int:
        return 2 * self.d_inner + 2 * self.n_groups * self.ssm_state + self.n_heads


def mamba2_init(key: jax.Array, cfg: Mamba2Cfg, *, dtype=jnp.float32) -> Params:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    # dt bias: softplus^-1 of dt ~ U[1e-3, 1e-1]  (mamba2 reference init)
    dt = jnp.exp(
        jax.random.uniform(k3, (cfg.n_heads,), jnp.float32)
        * (jnp.log(0.1) - jnp.log(1e-3))
        + jnp.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": linear_init(k1, cfg.in_proj, dtype=dtype),
        "out_proj": linear_init(k2, cfg.out_proj, dtype=dtype),
        "conv_w": (jax.random.normal(k4, (cfg.conv_width, cfg.d_xbc), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((cfg.d_xbc,), dtype),
        "dt_bias": dt_bias,
        "A_log": jnp.log(
            jax.random.uniform(k5, (cfg.n_heads,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        "D": jnp.ones((cfg.n_heads,), jnp.float32),
        "norm": rmsnorm_init(cfg.d_inner, dtype),
    }


def _gated_rmsnorm(scale: jax.Array, y: jax.Array, z: jax.Array, eps: float = 1e-5) -> jax.Array:
    y32 = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _causal_conv(w: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    """Depthwise causal conv, width W. x: (B, S, Ch), w: (W, Ch)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return out + b[None, None, :]


def _segsum(dA: jax.Array) -> jax.Array:
    """dA: (..., Q) -> (..., Q, Q) lower-tri decay exponents sum_{j<k<=i} dA_k."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]        # (..., i, j) = sum_(j,i]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,     # (B, S, H, P)
    dt: jax.Array,    # (B, S, H)  (already softplus'd, positive)
    A: jax.Array,     # (H,)       (negative)
    B_: jax.Array,    # (B, S, H, N) (already group-expanded)
    C_: jax.Array,    # (B, S, H, N)
    *,
    chunk: int,
    h0: jax.Array | None = None,   # (B, H, P, N) initial state
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), final state (B,H,P,N))."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    q = min(chunk, s)
    while s % q:
        q -= 1
    nc = s // q

    f32 = jnp.float32
    xc = x.reshape(b, nc, q, h, p).astype(f32)
    dtc = dt.reshape(b, nc, q, h).astype(f32)
    bc = B_.reshape(b, nc, q, h, n).astype(f32)
    cc = C_.reshape(b, nc, q, h, n).astype(f32)
    dA = dtc * A[None, None, None, :]                 # (B, nc, Q, H)

    seg = jnp.cumsum(dA, axis=2)                      # (B, nc, Q, H)
    # intra-chunk (quadratic within chunk)
    L = jnp.exp(_segsum(dA.swapaxes(2, 3)))           # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcihn,bcjhn->bchij", cc, bc) * L
    y_intra = jnp.einsum("bchij,bcjh,bcjhp->bcihp", scores, dtc, xc)

    # chunk summary states: decay from j to end of chunk
    decay_out = jnp.exp(seg[:, :, -1:, :] - seg)      # (B, nc, Q, H)
    states = jnp.einsum("bcqh,bcqh,bcqhn,bcqhp->bchpn", decay_out, dtc, bc, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(seg[:, :, -1, :])           # (B, nc, H)
    init = jnp.zeros((b, h, p, n), f32) if h0 is None else h0.astype(f32)

    def scan_fn(hprev, inp):
        st, dec = inp                                  # (B,H,P,N), (B,H)
        hnew = hprev * dec[:, :, None, None] + st
        return hnew, hprev

    hfinal, hprevs = jax.lax.scan(
        scan_fn,
        init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
    )
    hprevs = hprevs.swapaxes(0, 1)                     # (B, nc, H, P, N)

    decay_in = jnp.exp(seg)                            # (B, nc, Q, H)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", cc, hprevs, decay_in)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), hfinal


def mamba2_cache_specs(b: int, cfg: Mamba2Cfg, dtype=jnp.bfloat16) -> Params:
    return {
        "conv": jax.ShapeDtypeStruct((b, cfg.conv_width - 1, cfg.d_xbc), dtype),
        "ssm": jax.ShapeDtypeStruct((b, cfg.n_heads, cfg.head_dim, cfg.ssm_state), jnp.float32),
    }


def mamba2_init_cache(b: int, cfg: Mamba2Cfg, dtype=jnp.bfloat16) -> Params:
    return {
        "conv": jnp.zeros((b, cfg.conv_width - 1, cfg.d_xbc), dtype),
        "ssm": jnp.zeros((b, cfg.n_heads, cfg.head_dim, cfg.ssm_state), jnp.float32),
    }


def mamba2(
    cfg: Mamba2Cfg,
    p: Params,
    x: jax.Array,                  # (B, S, D)
    *,
    cache: Params | None = None,
) -> tuple[jax.Array, Params | None]:
    b, s, _ = x.shape
    h, pd, n, g = cfg.n_heads, cfg.head_dim, cfg.ssm_state, cfg.n_groups
    di = cfg.d_inner

    zxbcdt = linear(cfg.in_proj, p["in_proj"], x)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, di + cfg.d_xbc], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if cache is None or s > 1:
        xbc_conv = jax.nn.silu(_causal_conv(p["conv_w"], p["conv_b"], xbc))
        xs, bmat, cmat = jnp.split(xbc_conv, [di, di + g * n], axis=-1)
        xs = xs.reshape(b, s, h, pd)
        rep = h // g
        bmat = jnp.repeat(bmat.reshape(b, s, g, n), rep, axis=2)
        cmat = jnp.repeat(cmat.reshape(b, s, g, n), rep, axis=2)
        y, hfinal = ssd_chunked(xs, dt, A, bmat, cmat, chunk=cfg.chunk)
        if cache is None:
            new_cache = None
        else:
            # prefill: hand the decode loop the final SSM state + conv tail
            w1 = cfg.conv_width - 1
            new_cache = {
                "conv": xbc[:, -w1:, :].astype(cache["conv"].dtype),
                "ssm": hfinal,
            }
    else:
        # O(1) decode: roll the conv window, update the SSM state
        assert s == 1
        conv_in = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)], axis=1)
        w = p["conv_w"]
        xbc1 = jax.nn.silu(
            jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32), w.astype(jnp.float32))
            + p["conv_b"].astype(jnp.float32)
        )[:, None, :].astype(x.dtype)
        xs, bmat, cmat = jnp.split(xbc1, [di, di + g * n], axis=-1)
        xs = xs.reshape(b, h, pd)
        rep = h // g
        bmat = jnp.repeat(bmat.reshape(b, g, n), rep, axis=1).astype(jnp.float32)
        cmat = jnp.repeat(cmat.reshape(b, g, n), rep, axis=1).astype(jnp.float32)
        dt1 = dt[:, 0, :]                                          # (B, H)
        decay = jnp.exp(dt1 * A[None, :])                          # (B, H)
        hs = cache["ssm"] * decay[:, :, None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt1, xs.astype(jnp.float32), bmat
        )
        y = jnp.einsum("bhpn,bhn->bhp", hs, cmat)[:, None].astype(x.dtype)
        y = y.reshape(b, 1, h, pd)
        xs = xs[:, None]
        new_cache = {"conv": conv_in[:, 1:], "ssm": hs}

    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = _gated_rmsnorm(p["norm"]["scale"], y, z)
    return linear(cfg.out_proj, p["out_proj"], y), new_cache

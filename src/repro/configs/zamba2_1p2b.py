"""Zamba2-1.2B — Mamba2 backbone + shared attention block [arXiv:2411.15242].

38 mamba2 layers (d_inner=4096, 64 heads of 64, N=64); one shared
attention+MLP block (32 MHA heads of 64, d_ff=8192) invoked every 6 layers
with concat(hidden, embedding) fusion.
"""
from repro.configs import ArchSpec

ARCH = ArchSpec(
    name="zamba2_1p2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    tie_embeddings=True,
    sub_quadratic=True,
    rope_theta=10_000.0,
)

"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, T_frames, d_model). The transformer
backbone is real: bidirectional encoder, causal decoder with cross
attention. Cross-attention K/V are computed once from the encoder output and
cached for decode (the natural LUT-NN fit: those projections are table
lookups amortized over the whole generation).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models.common import (
    Params,
    SiteCfg,
    embed,
    embed_init,
    linear,
    rmsnorm,
    rmsnorm_init,
)
from repro.models.attention import AttnCfg, attn_init, flash_attention
from repro.models.transformer import BlockCfg, block_init, block_apply


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    vocab: int
    d_model: int
    n_enc_layers: int
    n_dec_layers: int
    enc_frames: int                 # stub frontend sequence length
    enc_block: BlockCfg             # dense block, causal=False
    dec_self: AttnCfg               # causal self-attention
    dec_cross: AttnCfg              # cross-attention (causal=False, no rope)
    dec_mlp: mlp_mod.MLPCfg
    remat: bool = True
    unroll: bool = False            # python-loop layers (activation capture)


def _dec_block_init(key: jax.Array, cfg: EncDecCfg, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "self": attn_init(ks[0], cfg.dec_self, dtype=dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
        "cross": attn_init(ks[1], cfg.dec_cross, dtype=dtype),
        "norm3": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_mod.mlp_init(ks[2], cfg.dec_mlp, dtype=dtype),
    }


def encdec_init(key: jax.Array, cfg: EncDecCfg, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_dec_layers)
    return {
        "embed": embed_init(ks[2], cfg.vocab, cfg.d_model, dtype),
        "encoder": jax.vmap(lambda k: block_init(k, cfg.enc_block, dtype=dtype))(enc_keys),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "decoder": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype=dtype))(dec_keys),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }


def encdec_caches(cfg: EncDecCfg, b: int, s_max: int, dtype=jnp.bfloat16, abstract: bool = False,
                  paged: attn_mod.PagedSpec | None = None):
    """Self-attn KV cache + precomputed cross K/V, both stacked over layers.

    Only the self-attention cache pages (cross K/V is a fixed enc_frames
    extent computed once per request — paging it buys nothing)."""
    L = cfg.n_dec_layers
    if abstract:
        self_c = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((L, *s.shape), s.dtype),
            attn_mod.paged_cache_specs(paged, cfg.dec_self, dtype) if paged is not None
            else attn_mod.cache_specs(b, s_max, cfg.dec_self, dtype),
        )
        cross_c = {
            "k": jax.ShapeDtypeStruct((L, b, cfg.enc_frames, cfg.dec_cross.n_kv_heads, cfg.dec_cross.d_head), dtype),
            "v": jax.ShapeDtypeStruct((L, b, cfg.enc_frames, cfg.dec_cross.n_kv_heads, cfg.dec_cross.d_head), dtype),
        }
    else:
        self_c = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (L, *a.shape)).copy(),
            attn_mod.paged_init_cache(paged, cfg.dec_self, dtype) if paged is not None
            else attn_mod.init_cache(b, s_max, cfg.dec_self, dtype),
        )
        cross_c = {
            "k": jnp.zeros((L, b, cfg.enc_frames, cfg.dec_cross.n_kv_heads, cfg.dec_cross.d_head), dtype),
            "v": jnp.zeros((L, b, cfg.enc_frames, cfg.dec_cross.n_kv_heads, cfg.dec_cross.d_head), dtype),
        }
    return {"self": self_c, "cross": cross_c}


def encode(cfg: EncDecCfg, params: Params, frames: jax.Array, *, compute_dtype=jnp.float32) -> jax.Array:
    """frames: (B, T, D) stub embeddings -> encoder output (B, T, D)."""
    b, t, _ = frames.shape
    pos = jnp.arange(t, dtype=jnp.int32)[None, :].repeat(b, 0)
    x = frames.astype(compute_dtype)

    if cfg.unroll:
        from repro.models.common import set_tape_prefix

        for j in range(cfg.n_enc_layers):
            set_tape_prefix(f"encoder/{j}")
            pl_ = jax.tree.map(lambda a: a[j], params["encoder"])
            x, _, _ = block_apply(cfg.enc_block, pl_, x, pos=pos)
        return rmsnorm(params["enc_norm"], x)

    def body(xc, pl_):
        y, _, _ = block_apply(cfg.enc_block, pl_, xc, pos=pos)
        return y, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["encoder"])
    return rmsnorm(params["enc_norm"], x)


def cross_kv(cfg: EncDecCfg, params: Params, enc_out: jax.Array) -> Params:
    """Precompute cross-attention K/V for all decoder layers: (L, B, T, KV, Dh)."""
    b, t, _ = enc_out.shape
    a = cfg.dec_cross

    def one(pl_):
        k = linear(a.k, pl_["cross"]["k"], enc_out).reshape(b, t, a.n_kv_heads, a.d_head)
        v = linear(a.v, pl_["cross"]["v"], enc_out).reshape(b, t, a.n_kv_heads, a.d_head)
        return {"k": k, "v": v}

    if cfg.unroll:
        from repro.models.common import set_tape_prefix

        outs = []
        for j in range(cfg.n_dec_layers):
            set_tape_prefix(f"decoder/{j}")
            outs.append(one(jax.tree.map(lambda x: x[j], params["decoder"])))
        return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *outs)

    return jax.lax.map(one, params["decoder"])


def _cross_attend(a: AttnCfg, pl_: Params, x: jax.Array, kv: Params) -> jax.Array:
    b, s, _ = x.shape
    t = kv["k"].shape[1]
    q = linear(a.q, pl_["q"], x).reshape(b, s, a.n_heads, a.d_head)
    pos_q = jnp.zeros((b, s), jnp.int32)
    pos_k = jnp.zeros((b, t), jnp.int32)
    out = flash_attention(
        q, kv["k"].astype(x.dtype), kv["v"].astype(x.dtype),
        q_pos=pos_q, kv_pos=pos_k, causal=False,
    )
    return linear(a.o, pl_["o"], out.reshape(b, s, a.n_heads * a.d_head))


def _dec_block(
    cfg: EncDecCfg, pl_: Params, x: jax.Array, *,
    pos, self_cache, cache_len, cross: Params,
    block_tables=None, write_len=None,
) -> tuple[jax.Array, Params | None]:
    a, new_cache = attn_mod.attention(
        cfg.dec_self, pl_["self"], rmsnorm(pl_["norm1"], x),
        pos=pos, cache=self_cache, cache_len=cache_len,
        block_tables=block_tables, write_len=write_len,
    )
    x = x + a
    x = x + _cross_attend(cfg.dec_cross, pl_["cross"], rmsnorm(pl_["norm2"], x), cross)
    x = x + mlp_mod.mlp(cfg.dec_mlp, pl_["mlp"], rmsnorm(pl_["norm3"], x))
    return x, new_cache


def decode(
    cfg: EncDecCfg,
    params: Params,
    *,
    tokens: jax.Array,               # (B, S)
    pos: jax.Array,                  # (B, S)
    enc_out: jax.Array | None = None,      # train/prefill path
    caches: Params | None = None,          # serve path (includes cross KV)
    cache_len: jax.Array | None = None,
    compute_dtype=jnp.float32,
    block_tables: jax.Array | None = None,
    write_len: jax.Array | None = None,
) -> tuple[jax.Array, Params | None]:
    x = embed(params["embed"], tokens).astype(compute_dtype)

    if caches is None:
        cross = cross_kv(cfg, params, enc_out)

        if cfg.unroll:
            from repro.models.common import set_tape_prefix

            for j in range(cfg.n_dec_layers):
                set_tape_prefix(f"decoder/{j}")
                pl_, cr = jax.tree.map(lambda a: a[j], (params["decoder"], cross))
                x, _ = _dec_block(cfg, pl_, x, pos=pos, self_cache=None,
                                  cache_len=None, cross=cr)
            new_caches = None
        else:
            def body(xc, layer_in):
                pl_, cr = layer_in
                y, _ = _dec_block(cfg, pl_, xc, pos=pos, self_cache=None, cache_len=None, cross=cr)
                return y, None

            fn = jax.checkpoint(body) if cfg.remat else body
            x, _ = jax.lax.scan(fn, x, (params["decoder"], cross))
            new_caches = None
    else:
        def body(xc, layer_in):
            pl_, sc, cr = layer_in
            y, nc = _dec_block(cfg, pl_, xc, pos=pos, self_cache=sc, cache_len=cache_len,
                               cross=cr, block_tables=block_tables, write_len=write_len)
            return y, nc

        x, new_self = jax.lax.scan(
            body, x, (params["decoder"], caches["self"], caches["cross"])
        )
        new_caches = {"self": new_self, "cross": caches["cross"]}

    x = rmsnorm(params["final_norm"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype))
    return logits, new_caches

"""Slot-based continuous-batching serving engine.

vLLM-style control plane scaled to this repo: a fixed pool of B slots backed
by batched KV caches. Scheduler state machine (DESIGN.md §6):

    queue --admit--> PREFILL --(prompt consumed)--> DECODE --(done)--> retired

* **Batched admission** — every free slot is filled from the queue at the
  top of `step()`; all admitted (and still-prefilling) slots share ONE
  padded `(n_slots, prefill_chunk)` prefill forward per step, row-masked so
  untouched slots' caches never move (select-merge on per-slot `cache_len`).
* **Chunked prefill** — prompts longer than `prefill_chunk` consume exactly
  one fixed-size chunk per engine step, interleaved with the decode step of
  already-active slots, so decode latency stays bounded by one chunk
  forward. Every forward the engine ever issues therefore has one of
  exactly two token shapes — `(n_slots, prefill_chunk)` and `(n_slots, 1)`
  — which caps jit compile-cache growth at O(1) and lets the autotuner
  warm-up match runtime LUT shapes exactly (N = n_slots·prefill_chunk and
  N = n_slots).
* **Sampling** — per-request temperature/top-k/top-p/greedy with a
  deterministic per-request PRNG stream (repro.serving.sampling); the first
  token is sampled from the final prefill chunk's logits and checked
  against max_tokens/EOS immediately, so `max_tokens=1` returns exactly one
  token.
* **Observability** — `stats()` reports prefill/decode token and forward
  counts, wall-clock split, mean decode batch occupancy, and token-shape
  cache hits.
* **Request lifecycle** (DESIGN.md §11.1) — every request resolves to a
  terminal `status` in {ok, timeout, cancelled, shed, error}. `submit()`
  takes a `priority` and a relative `deadline_s`; expired or cancelled
  requests retire at the top of the next `step()` without burning another
  forward. With `max_queue` set, admission past the high-water mark sheds
  the lowest-priority queued request (arrivals lose priority ties) instead
  of growing the queue without bound. `run_until_done` never silently
  strands work: exhausting `max_steps` with requests still live raises (or,
  with `on_exhausted="strand"`, retires them as `error`).

The jitted step is the same `forward_step` the multi-pod dry-run lowers —
the engine is pure host-side orchestration, so it works identically on
1 CPU device and a 512-chip mesh. Limitation: padded prefill rows assume
position-indexed caches (attention masks padding causally); SSM state is
sequential, so mamba-family bundles need chunk-aligned prompts.

* **Paged KV cache** (DESIGN.md §12) — with `paged=True` the attention
  cache leaves become a pooled `(n_pages, page_size)` page set shared by
  all slots; the scheduler owns per-slot block tables, a free-list
  allocator with refcounted prefix sharing (`serving/kv_pool.py`), and
  copy-on-write. Prompt prefixes already resident skip their prefill
  chunks entirely; pool exhaustion preempts by shedding (status "shed"),
  never by raising. Token output is byte-identical to the dense engine.
* **Speculative decoding** (DESIGN.md §14) — with `spec_decode=True` the
  width-1 decode step becomes a draft/verify round (`serving/spec_decode`):
  γ cheap draft forwards through a shared-table draft plan, then ONE target
  verify over the fixed `(n_slots, γ+1)` shape, emitting up to γ+1 tokens
  per target forward. Output is byte-identical to non-speculative decode in
  both greedy and sampled modes; rejected positions roll back by cache_len
  bookkeeping (dense) plus page rewind (paged). Bundles with per-slot
  recurrent state auto-disable with a warning, the same seam as prefix
  sharing above.
* **Mesh-sharded construction** (DESIGN.md §6.4) — pass `mesh=` (and
  optionally `rules=`) and the engine becomes tensor-parallel: params are
  device_put under `distributed.sharding`'s specs (`table_q` column-sharded
  over M on "model", `table_scale`/`centroids` replicated), KV caches shard
  on the slot/batch axis (and sequence over "model" when divisible), and
  `step_fn` is jitted with explicit in/out shardings so GSPMD emits exactly
  the column-parallel psum the replaced matmul would need. The host-side
  scheduler is unchanged — sharding is a construction-time concern only.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ModelBundle
from repro.models.attention import PagedSpec
from repro.serving.kv_pool import KVPagePool
from repro.serving.sampling import GREEDY, SamplingParams, batch_arrays, sample_tokens
from repro.serving.spec_decode import SpecDecoder

# KV-cache storage dtypes accepted by name (process-boundary friendly:
# the supervisor ships engine kwargs as JSON). Sub-bf16 entries store K/V
# in 8 bits; _attend_stats upcasts at use (models/attention.py).
KV_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
    "float8_e4m3fn": jnp.float8_e4m3fn,
    "float8_e5m2": jnp.float8_e5m2,
}


def _is_pool_leaf(path) -> bool:
    """True for paged-pool cache leaves (k_pool/v_pool) in a tree path."""
    return any(getattr(k, "key", None) in ("k_pool", "v_pool") for k in path)


def iter_lut_kernel_sites(cfg: Any, _seen: set[int] | None = None) -> Iterator[Any]:
    """Yield every LUT_INFER linear-site config under `cfg` that runs the
    fused kernel.

    Legacy duck-typed config walk (a site has d_in/d_out/mode/lut
    attributes), kept for callers that only hold a cfg; bundle-holding
    callers use the site registry (`ModelBundle.sites()`) instead.
    """
    if _seen is None:
        _seen = set()
    if cfg is None or id(cfg) in _seen:
        return
    _seen.add(id(cfg))
    if all(hasattr(cfg, a) for a in ("d_in", "d_out", "mode", "lut")):
        if getattr(cfg.mode, "value", cfg.mode) == "lut_infer" and cfg.lut.use_kernel:
            yield cfg
        return
    if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
        children: Iterator[Any] = (
            getattr(cfg, f.name) for f in dataclasses.fields(cfg)
        )
    elif isinstance(cfg, (tuple, list)):
        children = iter(cfg)
    else:
        return
    for child in children:
        yield from iter_lut_kernel_sites(child, _seen)


def warm_lut_autotune(
    bundle: ModelBundle, token_counts: list[int], dtype: str = "float32"
) -> int:
    """Pre-tune kernel version + block sizes for every (LUT site x token
    count) pair.

    `dtype` must be the dtype the LUT sites will actually see at runtime
    (the engine's compute dtype) — the kernel keys its cache lookups on
    `str(x.dtype)`, so a mismatched dtype warms keys nobody reads.

    Default scoring is the analytic roofline model (fast: pure python); with
    REPRO_AUTOTUNE_MEASURE=1 each candidate (tiling × v1/v2/fused) is
    instead timed with compiled runs on the live backend
    (repro.kernels.measure — warmup + median-of-k), which is the honest
    mode on a real accelerator. Returns the number of (site, N) shapes
    tuned; winners persist in the autotune JSON cache.

    Record precedence (DESIGN.md §13.3): measured records — whether from a
    previous measured warmup or restored from a LUTArtifact's autotune
    snapshot — are never re-derived. Analytic records are kept as-is in
    analytic mode but are RE-TUNED when measurement is enabled: a measured
    winner always beats a projection.
    """
    from repro.core.amm import Mode
    from repro.kernels import autotune, measure

    backend = jax.default_backend()
    measure_live = measure.measure_enabled()
    cache = autotune.get_cache()
    tuned = set()
    # site registry walk (DESIGN.md §9.2): one entry per (site, layer), so
    # heterogeneous plans warm every distinct (m, c, k, v) signature
    for site in bundle.sites():
        if site.mode != Mode.LUT_INFER or site.lut is None or not site.lut.use_kernel:
            continue
        lut = site.lut
        c = site.d_in // lut.v
        for n in token_counts:
            key = ("lut_amm", n, site.d_out, c, lut.k, lut.v)
            if key in tuned:
                continue
            rec = cache.get(autotune.shape_key(*key, dtype, backend))
            if rec is not None and (not measure_live or rec.get("measured")):
                continue
            measure_fn = (
                measure.measure_lut_amm(*key[1:], dtype=dtype)
                if measure_live else None
            )
            autotune.tune(*key, dtype=dtype, save=False, measure=measure_fn)
            tuned.add(key)
    if tuned:
        try:
            autotune.get_cache().save()
        except OSError:
            # persistence is an optimization — winners stay in the
            # in-process cache; never fail serving over a cache file.
            pass
    return len(tuned)


# terminal request statuses (DESIGN.md §11.1); `status` is meaningful only
# once `done` is True — a live request always reads "ok"
STATUSES = ("ok", "timeout", "cancelled", "shed", "error")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_tokens: int = 16
    eos_id: int | None = None
    sampling: SamplingParams = GREEDY
    priority: int = 0                  # higher = evicted later under overload
    deadline: float | None = None      # absolute time.monotonic() deadline
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = "ok"
    n_prefilled: int = 0     # prompt tokens already consumed by chunk forwards
    submit_t: float = 0.0    # time.monotonic() at submit
    finish_t: float = 0.0    # time.monotonic() at terminal transition
    cancel_requested: bool = False
    spec_decode: bool | None = None   # per-request override; None = engine default

    @property
    def prefill_done(self) -> bool:
        return self.n_prefilled >= len(self.prompt)

    @property
    def ok(self) -> bool:
        return self.done and self.status == "ok"

    @property
    def latency_s(self) -> float:
        return max(self.finish_t - self.submit_t, 0.0) if self.done else 0.0

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now >= self.deadline


class ServingEngine:
    def __init__(
        self,
        bundle: ModelBundle,
        params: Any,
        *,
        n_slots: int = 4,
        max_seq: int = 256,
        prefill_chunk: int = 32,
        compute_dtype=jnp.float32,
        autotune_lut: bool = True,
        mesh: Mesh | None = None,
        rules: Any | None = None,
        max_queue: int | None = None,
        faults: Any | None = None,
        paged: bool = False,
        page_size: int = 16,
        n_pages: int | None = None,
        prefix_sharing: bool = True,
        kv_dtype: Any | None = None,
        spec_decode: bool = False,
        draft_bundle: ModelBundle | None = None,
        draft_params: Any | None = None,
        spec_gamma: int = 4,
    ):
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue={max_queue} must be >= 1 (or None)")
        if not 1 <= prefill_chunk <= max_seq:
            raise ValueError(
                f"prefill_chunk={prefill_chunk} must be in [1, max_seq={max_seq}] "
                f"— no prompt could ever be admitted"
            )
        self.bundle = bundle
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        if rules is not None and mesh is None:
            mesh = rules.mesh
        if mesh is not None and rules is None:
            from repro.distributed.sharding import ShardingRules

            rules = ShardingRules(mesh)
        self.mesh = mesh
        self.rules = rules
        # speculative decoding (DESIGN.md §14): resolved BEFORE the autotune
        # warm-up so the (n_slots, γ+1) verify shape is part of the warmed
        # token counts, and before the paged block so prefix sharing can be
        # forced off (a prefix-skipped chunk would strand the draft's dense
        # cache, which must see every prompt token).
        self.spec: SpecDecoder | None = None
        if (draft_bundle is None) != (draft_params is None):
            raise ValueError("draft_bundle and draft_params come together")
        if spec_decode:
            if mesh is not None:
                raise ValueError(
                    "spec_decode does not compose with mesh-sharded "
                    "construction yet — the draft caches are host-managed")
            # rollback is cache_len bookkeeping (+ page rewind), which only
            # works for position-indexed caches: probe exactly like the
            # prefix-sharing seam — every leaf poolable <=> pure attention KV
            probe = jax.tree_util.tree_flatten_with_path(
                bundle.init_caches(n_slots, max_seq, abstract=True,
                                   paged=PagedSpec(n_pages=2, page_size=16))
            )[0]
            if not all(_is_pool_leaf(p) for p, _ in probe):
                warnings.warn(
                    "spec_decode disabled: bundle carries per-slot recurrent "
                    "state (mamba conv/ssm, encdec cross-KV) that cannot roll "
                    "back rejected tokens by cache_len bookkeeping; serving "
                    "continues non-speculatively")
                spec_decode = False
            else:
                prefix_sharing = False
        # the engine only ever issues two token shapes — (n_slots, 1) decode
        # and (n_slots, prefill_chunk) chunked prefill — plus, under spec
        # decoding, the fixed (n_slots, γ+1) verify — so the LUT warm-up is
        # exactly those N values, no ladder needed (DESIGN.md §3.3).
        if autotune_lut:
            counts = [n_slots, n_slots * prefill_chunk]
            if spec_decode:
                counts.append(n_slots * (spec_gamma + 1))
            self.n_lut_shapes_tuned = warm_lut_autotune(
                bundle, counts, dtype=jnp.dtype(compute_dtype).name,
            )
            if spec_decode and draft_bundle is not None:
                self.n_lut_shapes_tuned += warm_lut_autotune(
                    draft_bundle, [n_slots, n_slots * prefill_chunk],
                    dtype=jnp.dtype(compute_dtype).name,
                )
        else:
            self.n_lut_shapes_tuned = 0

        # KV storage dtype: defaults to the compute dtype; sub-bf16 (fp8)
        # halves cache HBM — _attend_stats upcasts at the dot
        if kv_dtype is None:
            kv_dtype = compute_dtype
        elif isinstance(kv_dtype, str):
            if kv_dtype not in KV_DTYPES:
                raise ValueError(
                    f"kv_dtype={kv_dtype!r}: pick one of {sorted(KV_DTYPES)}")
            kv_dtype = KV_DTYPES[kv_dtype]
        self.kv_dtype = kv_dtype

        # paged KV pool (DESIGN.md §12): attention cache leaves become a
        # shared (n_pages, page_size) pool; the scheduler owns block tables
        self.paged = bool(paged)
        paged_spec = None
        if self.paged:
            if max_seq % page_size:
                raise ValueError(
                    f"page_size={page_size} must divide max_seq={max_seq} "
                    f"(the block table covers exactly max_seq positions)")
            self.n_tables = max_seq // page_size
            if n_pages is None:
                # dense-equivalent capacity by default (+ the garbage page):
                # memory wins come from passing a smaller n_pages
                n_pages = n_slots * self.n_tables + 1
            paged_spec = PagedSpec(n_pages=n_pages, page_size=page_size)
            # prefix sharing is only sound when the ENTIRE cache state lives
            # in the pool: skipping a prefill chunk also skips computing any
            # per-slot recurrent state (mamba conv/ssm, encdec cross-KV) for
            # those tokens, which pages cannot carry. Auto-disable it for
            # such bundles — paging itself (tables, COW, shed) still works.
            if prefix_sharing:
                spec_leaves = jax.tree_util.tree_flatten_with_path(
                    bundle.init_caches(n_slots, max_seq, abstract=True,
                                       dtype=self.kv_dtype, paged=paged_spec)
                )[0]
                prefix_sharing = all(_is_pool_leaf(p) for p, _ in spec_leaves)
            self.pool = KVPagePool(n_pages, page_size, prefix_sharing=prefix_sharing)
            self.block_tables = np.zeros((n_slots, self.n_tables), np.int32)
            self.slot_pages: list[list[int]] = [[] for _ in range(n_slots)]
            self._pending_copies: list[tuple[int, int]] = []
        self.caches = bundle.init_caches(
            n_slots, max_seq, dtype=self.kv_dtype, paged=paged_spec
        )
        # bytes per pool page across all layers (0 when no attention leaves,
        # e.g. a pure-SSM bundle) — drives the kv_bytes_* gauges
        if self.paged:
            pool_bytes = sum(
                int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
                for path, leaf in jax.tree_util.tree_flatten_with_path(self.caches)[0]
                if _is_pool_leaf(path)
            )
            self._page_bytes = pool_bytes // n_pages
        if rules is not None:
            # place model state once at construction (DESIGN.md §6.4):
            # tables column-sharded / codebooks replicated per param_spec,
            # caches sharded on the slot axis (+ sequence over "model")
            self._param_shardings = rules.params_shardings(
                jax.eval_shape(lambda: params), bundle=bundle
            )
            self.params = jax.device_put(params, self._param_shardings)
            self._cache_shardings = rules.cache_shardings(
                jax.eval_shape(lambda: self.caches), n_slots
            )
            self.caches = jax.device_put(self.caches, self._cache_shardings)
        self.cache_len = np.zeros((n_slots,), np.int32)
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self.max_queue = max_queue
        self.faults = faults                 # FaultInjector hook (§11.3)
        self._next_rid = 0
        self._compute_dtype = compute_dtype
        self.reset_stats()

        def step_fn(params, tokens, cache_len, caches, slot_mask,
                    block_tables=None, write_len=None):
            batch = {"tokens": tokens, "cache_len": cache_len}
            if block_tables is not None:
                batch["block_tables"] = block_tables
                batch["write_len"] = write_len
            logits, new_caches = bundle.forward_step(
                params, batch, caches, compute_dtype=compute_dtype,
            )
            # merge: only the masked slots' cache rows advance. Pool leaves
            # carry no slot axis — their writes are already masked in-kernel
            # (invalid rows route to the garbage page), so they pass through.
            def merge(path, old, new):
                if _is_pool_leaf(path):
                    return new
                # every per-slot cache leaf is layer-stacked: (L, B, ...)
                shape = [1] * old.ndim
                shape[1] = n_slots
                m = slot_mask.reshape(shape)
                return jnp.where(m, new, old)

            merged = jax.tree_util.tree_map_with_path(merge, caches, new_caches)
            return logits, merged

        # one jitted row-masked forward serves both phases; the two token
        # shapes (chunk vs 1) are its only two compile-cache entries
        if rules is not None:
            # explicit in/out shardings: token rows ride the slot axis, and
            # the caches keep their construction-time layout across steps so
            # GSPMD never re-shards state between forwards
            row = NamedSharding(mesh, P(rules.batch_dim(n_slots)))
            tok = NamedSharding(mesh, P(rules.batch_dim(n_slots), None))
            logits_sh = NamedSharding(mesh, P(rules.batch_dim(n_slots), None, None))
            in_sh = [self._param_shardings, tok, row, self._cache_shardings, row]
            if self.paged:
                in_sh += [tok, row]     # block_tables ride the slot axis too
            self._step_fn = jax.jit(
                step_fn,
                in_shardings=tuple(in_sh),
                out_shardings=(logits_sh, self._cache_shardings),
            )
        else:
            self._step_fn = jax.jit(step_fn)

        if spec_decode:
            # self-draft (no draft bundle) is valid: acceptance ~1.0, used
            # by warmup smoke paths; a real deployment loads a cheaper plan
            # from the same multi-plan artifact (load_artifact(plan=...))
            self.spec = SpecDecoder(
                self,
                bundle if draft_bundle is None else draft_bundle,
                params if draft_params is None else draft_params,
                gamma=spec_gamma,
                compute_dtype=compute_dtype,
                kv_dtype=self.kv_dtype,
            )

    # ------------------------------------------------------------------
    def reset_stats(self) -> None:
        self._counters = {
            "steps": 0,
            "prefill_forwards": 0,
            "prefill_tokens": 0,          # valid prompt tokens (padding excluded)
            "prefill_s": 0.0,
            "decode_forwards": 0,
            "decode_tokens": 0,
            "decode_s": 0.0,
            "shape_cache_hits": 0,        # forwards that reused a seen token shape
            # terminal-status counters (DESIGN.md §11.1)
            "completed": 0,               # retired with status "ok"
            "timeout": 0,
            "cancelled": 0,
            "shed": 0,
            "error": 0,
            # prompt tokens satisfied from the prefix cache (never forwarded)
            "prefill_tokens_skipped": 0,
        }
        self._shapes_seen: set[tuple[Any, ...]] = set()
        if self.paged:
            self.pool.reset_counters()
        if getattr(self, "spec", None) is not None:
            self.spec.reset_counters()

    def stats(self) -> dict[str, Any]:
        """Scheduler counters since construction / the last reset_stats()."""
        c = dict(self._counters)
        c["queue_depth"] = len(self.queue)
        c["active_slots"] = sum(s is not None for s in self.slots)
        dec_f = c["decode_forwards"]
        # each decode forward advances one token per active slot, so tokens
        # per forward IS the occupancy
        c["decode_occupancy"] = (
            c["decode_tokens"] / (dec_f * self.n_slots) if dec_f else 0.0
        )
        c["prefill_tok_s"] = c["prefill_tokens"] / c["prefill_s"] if c["prefill_s"] else 0.0
        c["decode_tok_s"] = c["decode_tokens"] / c["decode_s"] if c["decode_s"] else 0.0
        c["lut_shapes_tuned"] = self.n_lut_shapes_tuned
        if self.paged:
            # pool gauges (DESIGN.md §12.4) — numeric, so server.py /metrics
            # exports each as lutnn_serving_<key> with no extra wiring
            pool = self.pool
            c["kv_pages_total"] = pool.n_allocatable
            c["kv_pages_free"] = pool.n_free
            c["kv_pages_cached"] = pool.n_cached
            c["kv_pages_shared"] = pool.n_shared
            c["kv_pages_resident"] = pool.n_resident
            c["kv_pages_peak"] = pool.peak_resident
            c.update(pool.counters)       # prefix_hits/lookups, cow_copies, ...
            c["kv_bytes_resident"] = pool.n_resident * self._page_bytes
            c["kv_bytes_peak"] = pool.peak_resident * self._page_bytes
            # what the dense per-slot layout would pin for the same leaves
            c["kv_bytes_dense_equiv"] = (
                self._page_bytes * self.n_slots * self.n_tables)
            c["pool_utilization"] = (
                pool.n_resident / pool.n_allocatable if pool.n_allocatable else 0.0)
        if self.spec is not None:
            # acceptance-rate / tokens-per-target-forward counters (§14.4);
            # numeric, so /metrics exports them with no extra wiring
            c.update(self.spec.counters())
        return c

    # ------------------------------------------------------------------
    def warmup(self) -> None:
        """Run (and discard) one throwaway request that compiles both engine
        token shapes — (n_slots, prefill_chunk) and (n_slots, 1) — off the
        clock, then re-arm the stats counters.

        The probe prompt is longer than one chunk when the cache allows so
        the multi-chunk prefill path warms, and short enough that submit()'s
        max_tokens cap still leaves a decode forward (max_tokens=2 must
        survive, or the decode shape would compile inside the timed region).
        """
        wlen = (self.prefill_chunk + 1
                if 2 * self.prefill_chunk <= self.max_seq
                else min(self.prefill_chunk, self.max_seq - 1))
        # spec engines warm one full draft/verify round too: γ+2 tokens
        # makes round one speculate at full depth, compiling the draft's
        # width-1 chain and the (n_slots, γ+1) verify off the clock
        max_tok = 2 if self.spec is None else self.spec.gamma + 2
        self.submit(list(range(1, wlen + 1)), max_tokens=max_tok)
        self.run_until_done()
        self.finished.clear()
        self.reset_stats()

    def submit(
        self,
        prompt: list[int],
        *,
        max_tokens: int = 16,
        eos_id: int | None = None,
        sampling: SamplingParams | None = None,
        priority: int = 0,
        deadline_s: float | None = None,
        spec_decode: bool | None = None,
    ) -> int:
        """Queue a request; returns its rid.

        `deadline_s` is relative (seconds from now); a request past its
        deadline retires with status "timeout" — queued or mid-generation —
        at the top of the next step, without burning further forwards.
        `priority` orders both admission (higher first) and overload
        shedding (lower evicted first). A request shed at submit time STILL
        gets a rid: it lands in `finished` with status "shed" immediately,
        so every rid ever returned resolves to a terminal status.
        """
        prompt = list(prompt) or [0]
        # chunk padding writes cache rows up to the padded length, so the
        # PADDED prompt must fit — an over-long prompt would otherwise have
        # its scatter writes silently dropped at the max_seq boundary.
        # (Paged mode routes out-of-range padding writes to the garbage
        # page, but the block table still only covers max_seq positions.)
        padded = -(-len(prompt) // self.prefill_chunk) * self.prefill_chunk
        if padded > self.max_seq:
            raise ValueError(
                f"prompt of {len(prompt)} tokens (chunk-padded to {padded}) "
                f"exceeds max_seq={self.max_seq}"
            )
        if max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {max_tokens}")
        # per-request opt-IN needs an engine that actually has a draft;
        # opt-OUT (False) is always honored — the slot rides the verify
        # forward at γ_eff=0, token-identical to plain decode
        if spec_decode and self.spec is None:
            raise ValueError(
                "spec_decode=True requested but the engine was built without "
                "speculative decoding (spec_decode=False or auto-disabled)")
        if self.paged:
            # admission-time capacity in PAGE-POOL terms: a request that
            # could never hold enough pages even running alone must be
            # rejected here, not discovered as an endless shed loop later
            ps = self.pool.page_size
            need = -(-len(prompt) // ps)
            if need > self.pool.n_allocatable:
                raise ValueError(
                    f"prompt of {len(prompt)} tokens needs {need} pages; the "
                    f"pool only has {self.pool.n_allocatable} allocatable "
                    f"pages of {ps} (n_pages={self.pool.n_pages} incl. the "
                    f"reserved garbage page)"
                )
            # decode writes positions len(prompt) .. len(prompt)+max_tokens-2
            # (the final token is sampled but never fed back): cap against
            # the positions a lone request could actually be allocated
            cap = min(self.max_seq, self.pool.n_allocatable * ps)
            max_tokens = min(max_tokens, cap - len(prompt) + 1)
        else:
            # dense cache: every slot owns a full max_seq row
            max_tokens = min(max_tokens, self.max_seq - len(prompt) + 1)
        now = time.monotonic()
        rid = self._next_rid
        self._next_rid += 1
        req = Request(
            rid, prompt, max_tokens, eos_id, sampling or GREEDY,
            priority=priority,
            deadline=None if deadline_s is None else now + deadline_s,
            spec_decode=spec_decode,
        )
        req.submit_t = now
        # bounded queue (DESIGN.md §11.2): past the high-water mark, shed
        # the lowest-priority request — the newest among ties, so older
        # work at equal priority keeps its place and arrivals lose ties
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self._sweep_queue(now)           # expired entries free space first
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            victim = min(reversed(self.queue), key=lambda r: r.priority)
            if victim.priority >= req.priority:
                self._finish_queued(req, "shed")
                return rid
            self.queue.remove(victim)
            self._finish_queued(victim, "shed")
        self.queue.append(req)
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a live (queued or in-flight) request.

        Retires it immediately with status "cancelled" (partial out_tokens
        kept). Returns False when the rid is unknown or already terminal.
        Single-threaded like every engine call — front ends route cancels
        through the thread that owns the engine.
        """
        for req in self.queue:
            if req.rid == rid:
                self.queue.remove(req)
                self._finish_queued(req, "cancelled")
                return True
        for i, req in enumerate(self.slots):
            if req is not None and req.rid == rid:
                req.cancel_requested = True
                self._retire(i, req, "cancelled")
                return True
        return False

    def _finish_queued(self, req: Request, status: str) -> None:
        """Terminal transition for a request that never held a slot."""
        req.done = True
        req.status = status
        req.finish_t = time.monotonic()
        self._counters[status if status != "ok" else "completed"] += 1
        self.finished.append(req)

    def _sweep_queue(self, now: float) -> None:
        expired = [r for r in self.queue if r.expired(now)]
        for req in expired:
            self.queue.remove(req)
            self._finish_queued(req, "timeout")

    def _sweep(self) -> None:
        """Retire deadline-expired and cancelled requests — queued and
        in-flight alike — before any forward is issued this step."""
        now = time.monotonic()
        self._sweep_queue(now)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req.cancel_requested:
                self._retire(i, req, "cancelled")
            elif req.expired(now):
                self._retire(i, req, "timeout")

    def _admit(self) -> None:
        """Fill free slots from the queue, highest priority first (FIFO
        within a priority level). Pure bookkeeping — the admitted slots'
        prompts are consumed by the shared chunk forward in step().

        Paged mode also runs the prefix-cache lookup here: the longest
        chain of cached full-page prefixes of the prompt maps straight into
        the slot's block table and those tokens never reach a prefill
        forward — the chunked-prefill loop starts at the first unshared
        token. A fully-cached prompt is clamped to len-1 shared tokens (the
        final prompt token must still run one forward to produce the first
        output logits); its re-write into the shared final page is what
        exercises copy-on-write end-to-end."""
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = max(self.queue, key=lambda r: (r.priority, -r.rid))
                self.queue.remove(req)
                self.slots[i] = req
                self.cache_len[i] = 0
                if self.spec is not None:
                    self.spec.reset_slot(i)
                if self.paged:
                    pages = self.pool.lookup_prefix(req.prompt)
                    shared = len(pages) * self.pool.page_size
                    if shared >= len(req.prompt):
                        shared = len(req.prompt) - 1
                    self.slot_pages[i] = pages
                    self.block_tables[i, :] = 0
                    self.block_tables[i, : len(pages)] = pages
                    req.n_prefilled = shared
                    self.cache_len[i] = shared
                    self._counters["prefill_tokens_skipped"] += shared

    def _retire(self, slot: int, req: Request, status: str = "ok") -> None:
        req.done = True
        req.status = status
        req.finish_t = time.monotonic()
        self._counters[status if status != "ok" else "completed"] += 1
        self.finished.append(req)
        self.slots[slot] = None
        self.cache_len[slot] = 0
        if self.spec is not None:
            self.spec.reset_slot(slot)
        if self.paged:
            for page in self.slot_pages[slot]:
                self.pool.unref(page)     # registered pages stay evictable
            self.slot_pages[slot] = []
            self.block_tables[slot, :] = 0

    # ---------------- paged allocation (DESIGN.md §12.3) ----------------
    def _shed_for_pages(self, needy_slot: int) -> bool:
        """Preemption-by-shedding: free pages by retiring the lowest-
        priority active request (the newest among ties, matching queue-shed
        semantics) with status "shed". Returns False when the victim was
        the needy request itself — the caller must stop allocating for it."""
        live = [(i, r) for i, r in enumerate(self.slots) if r is not None]
        vi, vr = min(live, key=lambda ir: (ir[1].priority, -ir[1].rid))
        self._retire(vi, vr, "shed")
        return vi != needy_slot

    def _alloc_page_for(self, slot: int) -> int | None:
        """One page for `slot`, shedding requests on pool exhaustion until
        one frees (alloc itself already reclaims evictable prefix pages
        first). Returns None only when `slot`'s own request was shed —
        allocation failure is always a clean `shed`, never an exception."""
        while True:
            page = self.pool.alloc()
            if page is not None:
                return page
            if not self._shed_for_pages(slot):
                return None

    def _prepare_slot_writes(self, slot: int, n_new: int) -> bool:
        """Make slot's block table safely writable for the next `n_new`
        logical positions: extend it with fresh pages, and copy-on-write
        any page in the write range that other requests or the prefix
        cache can still see. Returns False when the slot's request was
        shed during allocation (the caller drops it from this forward)."""
        ps = self.pool.page_size
        start = int(self.cache_len[slot])
        need = -(-(start + n_new) // ps)              # pages covering the write
        pages = self.slot_pages[slot]
        while len(pages) < need:
            page = self._alloc_page_for(slot)
            if page is None:
                return False
            self.block_tables[slot, len(pages)] = page
            pages.append(page)
        for pi in range(start // ps, need):
            if not self.pool.needs_cow(pages[pi]):
                continue
            dst = self._alloc_page_for(slot)
            if dst is None:
                return False
            # device copy happens in one batched transfer before the
            # forward (_flush_copies); bookkeeping moves over now
            self._pending_copies.append((pages[pi], dst))
            self.pool.unref(pages[pi])
            pages[pi] = dst
            self.block_tables[slot, pi] = dst
            self.pool.counters["cow_copies"] += 1
        return True

    def _flush_copies(self) -> None:
        """Apply all pending COW page copies to the device pool in one
        batched gather/scatter per K/V leaf."""
        if not self._pending_copies:
            return
        src = jnp.asarray([s for s, _ in self._pending_copies], jnp.int32)
        dst = jnp.asarray([d for _, d in self._pending_copies], jnp.int32)
        self._pending_copies = []

        def copy(path, leaf):
            if not _is_pool_leaf(path):
                return leaf
            return leaf.at[:, dst].set(leaf[:, src])   # (L, n_pages, ps, ...)

        self.caches = jax.tree_util.tree_map_with_path(copy, self.caches)

    def _register_prefixes(self, slot: int, req: Request) -> None:
        """Publish this request's fully-prefilled prompt pages to the
        prefix cache. K/V at a position depends only on tokens at or
        before it (causal), so a page wholly covered by prompt tokens is
        exactly determined by the token-id prefix that keys it."""
        ps = self.pool.page_size
        for pi in range(req.n_prefilled // ps):
            if (pi + 1) * ps > len(req.prompt):
                break
            self.pool.register_prefix(
                tuple(req.prompt[: (pi + 1) * ps]), self.slot_pages[slot][pi]
            )

    def _record(self, tokens: np.ndarray, tag: str = "target") -> None:
        # keyed per model: the draft has its own jit fn, so its first
        # forward at a shape the target already saw is still a compile
        shape = (tag,) + tuple(tokens.shape)
        if shape in self._shapes_seen:
            self._counters["shape_cache_hits"] += 1
        self._shapes_seen.add(shape)

    def _sample(self, logits_rows: jax.Array) -> np.ndarray:
        """Batched sample over all n_slots rows; callers read only the rows
        of slots they own (other rows ride along with greedy defaults)."""
        params = [
            (self.slots[i].sampling if self.slots[i] is not None else GREEDY)
            for i in range(self.n_slots)
        ]
        if all(p.greedy for p in params):
            # hot default: skip the sort/softmax/categorical machinery —
            # sample_tokens is argmax-identical for greedy rows
            return np.asarray(jnp.argmax(logits_rows, axis=-1))
        counters = [
            len(self.slots[i].out_tokens) if self.slots[i] is not None else 0
            for i in range(self.n_slots)
        ]
        return np.asarray(sample_tokens(logits_rows, *batch_arrays(params, counters)))

    def _check_done_after_token(self, slot: int, req: Request, tok: int) -> None:
        """Done-conditions run after EVERY sampled token — including the one
        produced by prefill, fixing the max_tokens off-by-one."""
        hit_eos = req.eos_id is not None and tok == req.eos_id
        out_of_cache = self.cache_len[slot] >= self.max_seq   # defensive; capped at submit
        if hit_eos or len(req.out_tokens) >= req.max_tokens or out_of_cache:
            self._retire(slot, req)

    # ------------------------------------------------------------------
    def _prefill_step(self) -> None:
        """One shared `(n_slots, prefill_chunk)` forward consuming the next
        chunk of every prefilling slot's prompt."""
        chunk = self.prefill_chunk
        pre = [
            (i, r) for i, r in enumerate(self.slots)
            if r is not None and not r.prefill_done
        ]
        if self.paged and pre:
            # page allocation + COW before the forward; preparation for one
            # slot can shed another (or itself) on pool exhaustion, so
            # re-check slot ownership after the whole pass
            for i, r in pre:
                if self.slots[i] is not r:
                    continue
                n = min(chunk, len(r.prompt) - r.n_prefilled)
                self._prepare_slot_writes(i, n)
            pre = [(i, r) for i, r in pre if self.slots[i] is r]
            self._flush_copies()
        if not pre:
            return
        toks = np.zeros((self.n_slots, chunk), np.int32)
        cache_len = np.zeros((self.n_slots,), np.int32)
        mask = np.zeros((self.n_slots,), bool)
        write_len = np.zeros((self.n_slots,), np.int32)
        n_new = {}
        for i, r in pre:
            part = r.prompt[r.n_prefilled : r.n_prefilled + chunk]
            toks[i, : len(part)] = part
            cache_len[i] = r.n_prefilled
            mask[i] = True
            n_new[i] = len(part)
            write_len[i] = len(part)
        t0 = time.perf_counter()
        step_args = (
            self.params, jnp.asarray(toks), jnp.asarray(cache_len),
            self.caches, jnp.asarray(mask),
        )
        if self.paged:
            step_args += (jnp.asarray(self.block_tables), jnp.asarray(write_len))
        logits, self.caches = self._step_fn(*step_args)
        logits = jax.block_until_ready(logits)
        self._record(toks)
        self._counters["prefill_forwards"] += 1
        self._counters["prefill_tokens"] += sum(n_new.values())
        self._counters["prefill_s"] += time.perf_counter() - t0
        if self.spec is not None:
            # the draft's dense cache must see every prompt token: mirror
            # the chunk with the SAME pre-update arrays the target consumed
            self.spec.mirror_prefill(toks, cache_len, mask, write_len)

        # sample the first output token for every slot whose prompt just
        # completed, from that slot's last valid position in this chunk
        last_idx = np.zeros((self.n_slots,), np.int32)
        finishing = []
        for i, r in pre:
            r.n_prefilled += n_new[i]
            self.cache_len[i] = r.n_prefilled
            if self.paged:
                self._register_prefixes(i, r)
            if r.prefill_done:
                last_idx[i] = n_new[i] - 1
                finishing.append((i, r))
        if not finishing:
            return
        rows = logits[jnp.arange(self.n_slots), jnp.asarray(last_idx)]
        nxt = self._sample(rows)
        for i, r in finishing:
            tok = int(nxt[i])
            r.out_tokens.append(tok)
            self._check_done_after_token(i, r, tok)

    def _decode_step(self) -> None:
        """One `(n_slots, 1)` forward advancing every DECODE-phase slot."""
        dec = [
            (i, r) for i, r in enumerate(self.slots)
            if r is not None and r.prefill_done
        ]
        if self.paged and dec:
            for i, r in dec:
                if self.slots[i] is not r:
                    continue
                self._prepare_slot_writes(i, 1)
            dec = [(i, r) for i, r in dec if self.slots[i] is r]
            self._flush_copies()
        if not dec:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        mask = np.zeros((self.n_slots,), bool)
        write_len = np.zeros((self.n_slots,), np.int32)
        for i, r in dec:
            toks[i, 0] = r.out_tokens[-1] if r.out_tokens else r.prompt[-1]
            mask[i] = True
            write_len[i] = 1
        t0 = time.perf_counter()
        step_args = (
            self.params, jnp.asarray(toks), jnp.asarray(self.cache_len),
            self.caches, jnp.asarray(mask),
        )
        if self.paged:
            step_args += (jnp.asarray(self.block_tables), jnp.asarray(write_len))
        logits, self.caches = self._step_fn(*step_args)
        logits = jax.block_until_ready(logits)
        self._record(toks)
        self._counters["decode_forwards"] += 1
        self._counters["decode_tokens"] += len(dec)
        self._counters["decode_s"] += time.perf_counter() - t0

        nxt = self._sample(logits[:, 0, :])
        for i, r in dec:
            self.cache_len[i] += 1
            tok = int(nxt[i])
            r.out_tokens.append(tok)
            self._check_done_after_token(i, r, tok)

    def step(self) -> None:
        """One engine step: fault hook, lifecycle sweep, admit, one prefill
        chunk, one decode forward.

        Prefill consumes at most one chunk per step so long prompts cannot
        starve the decode of already-active slots (bounded decode latency).
        The sweep runs before admission so expired/cancelled requests never
        consume a forward, and a freed slot is re-admitted the same step.
        """
        if self.faults is not None:
            self.faults.on_step()        # may sleep, or raise Injected{Fault,Kill}
        self._counters["steps"] += 1
        self._sweep()
        self._admit()
        self._prefill_step()
        if self.spec is not None:
            self.spec.decode_round()
        else:
            self._decode_step()

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def run_until_done(
        self, max_steps: int = 1000, *, on_exhausted: str = "raise"
    ) -> list[Request]:
        """Step until all requests are terminal, or `max_steps` is spent.

        Exhausting `max_steps` with requests still live is a scheduler bug
        or an undersized budget — never silent: `on_exhausted="raise"` (the
        default) raises RuntimeError naming the stranded rids;
        `"strand"` retires them with status "error" and returns, so every
        rid still resolves to a terminal status.
        """
        if on_exhausted not in ("raise", "strand"):
            raise ValueError(f"on_exhausted={on_exhausted!r}")
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
        if self.has_work():
            stranded = [r.rid for r in self.queue] + [
                r.rid for r in self.slots if r is not None
            ]
            if on_exhausted == "raise":
                raise RuntimeError(
                    f"run_until_done exhausted max_steps={max_steps} with "
                    f"{len(stranded)} request(s) still live: rids {stranded}"
                )
            self.abort_all("error")
        return self.finished

    def abort_all(self, status: str = "error") -> list[Request]:
        """Retire every live request with a terminal `status` (no forward).

        Used by front ends when the engine itself dies (status "error") and
        by `run_until_done(on_exhausted="strand")`. Returns the aborted
        requests.
        """
        aborted = []
        while self.queue:
            req = self.queue.popleft()
            self._finish_queued(req, status)
            aborted.append(req)
        for i, req in enumerate(self.slots):
            if req is not None:
                self._retire(i, req, status)
                aborted.append(req)
        return aborted


# keys a front-end request spec may carry (HTTP body / supervisor wire format)
SPEC_KEYS = frozenset({
    "prompt", "max_tokens", "eos_id", "priority", "deadline_s",
    "temperature", "top_k", "top_p", "seed", "spec_decode",
})


def validate_spec(spec: dict[str, Any]) -> None:
    """Type-check a front-end request spec (SPEC_KEYS) without an engine.

    Shared by `submit_from_spec` and the process-boundary backends
    (`EngineSupervisor.submit`, `EngineRouter.submit`), which ship the spec
    to a worker process as-is: a malformed field must be rejected with a
    ValueError at the door — HTTP 400 — not discovered as a worker crash
    (or a confusing failure deep inside the engine) after the pipe hop.
    Raises ValueError; returns None on a well-formed spec.
    """
    if not isinstance(spec, dict):
        raise ValueError("request spec must be a JSON object")
    unknown = set(spec) - SPEC_KEYS
    if unknown:
        raise ValueError(f"unknown request fields: {sorted(unknown)}")
    prompt = spec.get("prompt")
    if not isinstance(prompt, (list, tuple)) or not all(
        isinstance(t, int) and not isinstance(t, bool) for t in prompt
    ):
        raise ValueError("prompt must be a list of ints")
    spec_decode = spec.get("spec_decode")
    if spec_decode is not None and not isinstance(spec_decode, bool):
        raise ValueError("spec_decode must be a bool")
    priority = spec.get("priority")
    if priority is not None and (
        isinstance(priority, bool) or not isinstance(priority, int)
    ):
        raise ValueError(f"priority must be an int, got {priority!r}")
    deadline_s = spec.get("deadline_s")
    if deadline_s is not None and (
        isinstance(deadline_s, bool) or not isinstance(deadline_s, (int, float))
    ):
        raise ValueError(f"deadline_s must be a number, got {deadline_s!r}")


def submit_from_spec(engine: "ServingEngine", spec: dict[str, Any]) -> int:
    """Submit a front-end request spec (a plain JSON-safe dict, SPEC_KEYS)
    to an engine. Shared by the HTTP server's pump and the supervised
    worker so both sides of the process boundary speak one format."""
    validate_spec(spec)
    sampling = None
    if any(k in spec for k in ("temperature", "top_k", "top_p", "seed")):
        sampling = SamplingParams(
            temperature=float(spec.get("temperature", 0.0)),
            top_k=int(spec.get("top_k", 0)),
            top_p=float(spec.get("top_p", 1.0)),
            seed=int(spec.get("seed", 0)),
        )
    return engine.submit(
        list(spec["prompt"]),
        max_tokens=int(spec.get("max_tokens", 16)),
        eos_id=spec.get("eos_id"),
        sampling=sampling,
        priority=spec.get("priority") or 0,
        deadline_s=spec.get("deadline_s"),
        spec_decode=spec.get("spec_decode"),
    )


class TokenTap:
    """Incremental observer of an engine's token output.

    Front ends (the HTTP server's pump thread, the supervised worker) call
    `poll()` after each `step()`; it diffs per-request `out_tokens` against
    what was already reported and returns
    `(token_events, finished_requests)` where `token_events` is a list of
    `(rid, new_tokens)` — including the final tokens of requests that
    retired this step, before their entry in `finished_requests`.

    With `consume=True`, reported entries are removed from
    `engine.finished` so a long-running server's memory stays bounded;
    leave it False when other code (e.g. `run_until_done`'s return) still
    reads the list.
    """

    def __init__(self, engine: "ServingEngine", *, consume: bool = False):
        self.engine = engine
        self.consume = consume
        self._emitted: dict[int, int] = {}
        self._drained = 0                 # index into engine.finished

    def _new_tokens(self, req: Request) -> list[int]:
        seen = self._emitted.get(req.rid, 0)
        fresh = req.out_tokens[seen:]
        if fresh:
            self._emitted[req.rid] = seen + len(fresh)
        return fresh

    def poll(self) -> tuple[list[tuple[int, list[int]]], list[Request]]:
        tokens: list[tuple[int, list[int]]] = []
        fin = self.engine.finished
        done = fin[self._drained:]
        for req in done:
            fresh = self._new_tokens(req)
            if fresh:
                tokens.append((req.rid, fresh))
            self._emitted.pop(req.rid, None)
        if self.consume:
            del fin[self._drained:]
        else:
            self._drained = len(fin)
        for req in self.engine.slots:
            if req is None:
                continue
            fresh = self._new_tokens(req)
            if fresh:
                tokens.append((req.rid, fresh))
        return tokens, done

"""GQA attention with flash-style chunked softmax, KV cache, qk-norm, M-RoPE.

Covers every attention-bearing assigned arch: llama3/minitron/command-r
(GQA), qwen3 (GQA + qk_norm), llama4/arctic (GQA inside MoE stacks), qwen2-vl
(M-RoPE), whisper (self + cross), zamba2 (shared MHA block).

Score/AV contractions are NOT LUT-replaced (paper section 8: no weights);
the Q/K/V/O projections are LUT sites.

Attention over long sequences is computed blockwise with an online softmax
(lax.scan over KV chunks) so the 32k-prefill dry-run never materializes an
S x S score matrix.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Params, SiteCfg, linear, linear_init, rmsnorm, rmsnorm_init


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    q: SiteCfg
    k: SiteCfg
    v: SiteCfg
    o: SiteCfg
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    mrope_sections: tuple[int, ...] = ()
    causal: bool = True
    use_rope: bool = True


def attn_init(key: jax.Array, cfg: AttnCfg, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {
        "q": linear_init(ks[0], cfg.q, dtype=dtype),
        "k": linear_init(ks[1], cfg.k, dtype=dtype),
        "v": linear_init(ks[2], cfg.v, dtype=dtype),
        "o": linear_init(ks[3], cfg.o, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.d_head, dtype)
        p["k_norm"] = rmsnorm_init(cfg.d_head, dtype)
    return p


def _rope(cfg: AttnCfg, x: jax.Array, pos: jax.Array) -> jax.Array:
    if not cfg.use_rope:
        return x
    if cfg.mrope_sections:
        return common.apply_mrope(x, pos, cfg.rope_theta, cfg.mrope_sections)
    return common.apply_rope(x, pos, cfg.rope_theta)


def _attend(
    qc: jax.Array,      # (B, Sq, KV, G, Dh)
    k: jax.Array,       # (B, T, KV, Dh)
    v: jax.Array,       # (B, T, KV, Dh)
    *,
    q_pos: jax.Array,   # (B, Sq)
    kv_pos: jax.Array,  # (B, T)
    causal: bool,
    kv_valid: jax.Array | None,
) -> jax.Array:
    """One q-block against the FULL KV extent.

    The KV sequence axis may be sharded over the "model" mesh axis
    (flash-decoding-style SP): the max/sum softmax reductions and the AV
    contraction over T then lower to small (B,S,H)-sized all-reduces, which
    GSPMD emits automatically — this is why we never lax.scan over the KV
    axis (scanning a sharded axis forces SPMD full rematerialization).
    """
    pv, m, l = _attend_stats(
        qc, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal, kv_valid=kv_valid
    )
    return pv / jnp.maximum(l, 1e-30)[..., None]            # (B, Sq, KV, G, Dh) f32


def _attend_stats(
    qc: jax.Array, k: jax.Array, v: jax.Array, *,
    q_pos, kv_pos, causal, kv_valid,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Unnormalized flash stats: (sum p*V, running max m, denom l)."""
    b, sq, kvh, g, dh = qc.shape
    sm = 1.0 / (dh ** 0.5)
    # cached K/V may be stored sub-bf16 (fp8 KV cache, section Perf) —
    # upcast at use; the convert fuses into the dot on TPU
    k = k.astype(qc.dtype)
    v = v.astype(qc.dtype)
    sc = jnp.einsum(
        "bskgd,btkd->bskgt", qc, k,
        preferred_element_type=jnp.float32,
    ) * sm                                                  # (B, Sq, KV, G, T)
    mask = jnp.ones((b, 1, 1, 1, k.shape[1]), bool)
    if causal:
        mask = mask & (kv_pos[:, None, :] <= q_pos[:, :, None])[:, :, None, None, :]
    if kv_valid is not None:
        mask = mask & kv_valid[:, None, None, None, :]
    neg = jnp.asarray(-1e30, sc.dtype)
    sc = jnp.where(mask, sc, neg)
    m = jnp.max(sc, axis=-1)                                # (B, Sq, KV, G)
    p = jnp.exp(sc - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    pv = jnp.einsum(
        "bskgt,btkd->bskgd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return pv, m, l


def _merge_stats(parts: list[tuple[jax.Array, jax.Array, jax.Array]]) -> jax.Array:
    """Combine flash partials from disjoint KV sources (flash-decoding)."""
    m = parts[0][1]
    for _, mi, _ in parts[1:]:
        m = jnp.maximum(m, mi)
    acc = jnp.zeros_like(parts[0][0])
    l = jnp.zeros_like(parts[0][2])
    for pv, mi, li in parts:
        corr = jnp.exp(mi - m)
        acc = acc + pv * corr[..., None]
        l = l + li * corr
    return acc / jnp.maximum(l, 1e-30)[..., None]


def flash_attention(
    q: jax.Array,       # (B, S, Hq, Dh)
    k: jax.Array,       # (B, T, KV, Dh)
    v: jax.Array,       # (B, T, KV, Dh)
    *,
    q_pos: jax.Array,   # (B, S) int32 absolute positions
    kv_pos: jax.Array,  # (B, T) int32 (entries > q_pos are masked when causal)
    causal: bool,
    kv_valid: jax.Array | None = None,  # (B, T) bool extra mask (cache fill)
    q_chunk: int = 512,
) -> jax.Array:
    """Grouped-query attention, blocked over the *query* axis.

    Scanning over Q (never KV) keeps every scanned axis unsharded; the score
    matrix peak is B x q_chunk x H x T per step instead of B x S x H x T.
    """
    b, s, hq, dh = q.shape
    kvh = k.shape[2]
    g = hq // kvh
    qg = q.reshape(b, s, kvh, g, dh)

    nq = max(1, s // q_chunk)
    while s % nq:
        nq -= 1
    qc_len = s // nq
    if nq == 1:
        out = _attend(qg, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal, kv_valid=kv_valid)
        return out.reshape(b, s, hq, dh).astype(q.dtype)

    q_blocks = qg.reshape(b, nq, qc_len, kvh, g, dh).swapaxes(0, 1)
    pos_blocks = q_pos.reshape(b, nq, qc_len).swapaxes(0, 1)

    def step(_, inp):
        qb, pb = inp
        return None, _attend(
            qb, k, v, q_pos=pb, kv_pos=kv_pos, causal=causal, kv_valid=kv_valid
        )

    _, out = jax.lax.scan(step, None, (q_blocks, pos_blocks))
    out = out.swapaxes(0, 1).reshape(b, s, hq, dh)
    return out.astype(q.dtype)


def init_cache(b: int, s_max: int, cfg: AttnCfg, dtype=jnp.bfloat16) -> Params:
    return {
        "k": jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.d_head), dtype),
    }


def cache_specs(b: int, s_max: int, cfg: AttnCfg, dtype=jnp.bfloat16) -> Params:
    return {
        "k": jax.ShapeDtypeStruct((b, s_max, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jax.ShapeDtypeStruct((b, s_max, cfg.n_kv_heads, cfg.d_head), dtype),
    }


# ---------------------------------------------------------------------------
# paged KV cache (DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# A paged cache is a pool of `n_pages` physical pages of `page_size` token
# positions each, shared by every sequence in the batch; each sequence maps
# its logical positions through a per-row block table (B, P) of page ids.
# Page 0 is the reserved *garbage* page: masked / out-of-range writes are
# routed there instead of being merged away with a select, so the jitted
# step function needs no per-slot write mask over pool leaves. Allocators
# must never hand out page 0. Pool content stays finite (zeros at init,
# activation values after), so gathered-then-masked garbage contributes
# exactly 0 to the flash softmax.

GARBAGE_PAGE = 0


@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """Layout of a paged KV pool."""
    n_pages: int
    page_size: int


def paged_init_cache(spec: PagedSpec, cfg: AttnCfg, dtype=jnp.bfloat16) -> Params:
    shape = (spec.n_pages, spec.page_size, cfg.n_kv_heads, cfg.d_head)
    return {"k_pool": jnp.zeros(shape, dtype), "v_pool": jnp.zeros(shape, dtype)}


def paged_cache_specs(spec: PagedSpec, cfg: AttnCfg, dtype=jnp.bfloat16) -> Params:
    shape = (spec.n_pages, spec.page_size, cfg.n_kv_heads, cfg.d_head)
    return {
        "k_pool": jax.ShapeDtypeStruct(shape, dtype),
        "v_pool": jax.ShapeDtypeStruct(shape, dtype),
    }


def paged_write_flat(
    block_tables: jax.Array,   # (B, P) int32 page ids
    cache_len: jax.Array,      # (B,) logical write cursor
    s: int,                    # fresh positions per row
    page_size: int,
    write_len: jax.Array,      # (B,) valid count; offsets >= write_len -> garbage
) -> jax.Array:
    """(B, s) indices into the page-flattened pool axis (n_pages*page_size)
    for the `s` fresh positions starting at cache_len. Invalid positions
    (padding rows, chunk tail past write_len, or past the table width) all
    land in GARBAGE_PAGE."""
    n_tables = block_tables.shape[1]
    off = jnp.arange(s, dtype=jnp.int32)[None, :]
    write_idx = cache_len[:, None].astype(jnp.int32) + off     # (B, s) logical
    p_idx = write_idx // page_size
    ok = (off < write_len[:, None]) & (p_idx < n_tables)
    pages = jnp.take_along_axis(block_tables, jnp.minimum(p_idx, n_tables - 1), axis=1)
    pages = jnp.where(ok, pages, GARBAGE_PAGE)
    return pages * page_size + jnp.where(ok, write_idx % page_size, 0)


def paged_gather(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Materialize each row's logical KV extent: (B, P*page_size, KV, Dh).

    The gathered layout is *identical* to the dense (B, s_max, KV, Dh) cache
    when P*page_size == s_max, which is what makes paged serving token
    parity with the dense engine exact rather than approximate."""
    b, p = block_tables.shape
    g = pool[block_tables]                     # (B, P, page_size, KV, Dh)
    return g.reshape(b, p * pool.shape[1], *pool.shape[2:])


def attention(
    cfg: AttnCfg,
    p: Params,
    x: jax.Array,                 # (B, S, D)
    *,
    pos: jax.Array,               # (B, S) or (3, B, S) for M-RoPE
    cache: Params | None = None,
    cache_len: jax.Array | None = None,  # (B,) tokens already in cache
    x_kv: jax.Array | None = None,       # cross-attention memory (B, T, D)
    kv_pos: jax.Array | None = None,
    defer_cache_write: bool = False,
    block_tables: jax.Array | None = None,  # (B, P) page ids (paged cache)
    write_len: jax.Array | None = None,     # (B,) valid fresh tokens per row
) -> tuple[jax.Array, Params | None]:
    """Returns (output (B, S, D), updated cache).

    defer_cache_write (decode fast path, section Perf): attend over the
    STALE cache and the fresh K/V slab as two flash partials and return
    {"k_slab", "v_slab"} instead of a rewritten cache — the caller scatters
    all layers' slabs into the stacked cache in one O(tokens) write, so the
    per-layer functional cache copy disappears from the scan.

    Paged caches ({"k_pool", "v_pool"}, DESIGN.md §12) route through the
    same entry point: writes scatter into the page-flattened pool via the
    block table (invalid positions land in the garbage page), reads gather
    the row's pages back into the dense logical layout and reuse the exact
    dense masks, so outputs are bit-identical to the dense cache path.
    """
    b, s, _ = x.shape
    src = x if x_kv is None else x_kv
    q = linear(cfg.q, p["q"], x).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = linear(cfg.k, p["k"], src).reshape(b, src.shape[1], cfg.n_kv_heads, cfg.d_head)
    v = linear(cfg.v, p["v"], src).reshape(b, src.shape[1], cfg.n_kv_heads, cfg.d_head)

    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)

    flat_pos = pos if pos.ndim == 2 else pos[0]   # (B, S) scalar stream for masks
    q = _rope(cfg, q, pos)
    if x_kv is None:
        k = _rope(cfg, k, pos if kv_pos is None else kv_pos)

    paged = cache is not None and "k_pool" in cache
    if paged:
        if block_tables is None:
            raise ValueError("paged cache requires block_tables")
        if write_len is None:
            write_len = jnp.full((b,), s, jnp.int32)

    if cache is None:
        out = flash_attention(
            q, k, v,
            q_pos=flat_pos,
            kv_pos=flat_pos if kv_pos is None or kv_pos.ndim != 2 else kv_pos,
            causal=cfg.causal,
        )
        new_cache = None
    elif paged and defer_cache_write:
        # flash-decoding over (stale gathered pages) + (fresh slab); the
        # segment-level scatter writes the slab into the pool afterwards
        page_size = cache["k_pool"].shape[1]
        s_logical = block_tables.shape[1] * page_size
        kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(b, s, kvh, g, cfg.d_head)
        all_pos = jnp.arange(s_logical, dtype=jnp.int32)[None, :].repeat(b, 0)
        stale_valid = all_pos < cache_len[:, None]
        part_cache = _attend_stats(
            qg,
            paged_gather(cache["k_pool"], block_tables),
            paged_gather(cache["v_pool"], block_tables),
            q_pos=flat_pos, kv_pos=all_pos, causal=cfg.causal, kv_valid=stale_valid,
        )
        slab_pos = (cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :])
        part_slab = _attend_stats(
            qg, k, v, q_pos=flat_pos, kv_pos=slab_pos, causal=cfg.causal, kv_valid=None,
        )
        out = _merge_stats([part_cache, part_slab]).reshape(b, s, cfg.n_heads, cfg.d_head)
        out = out.astype(q.dtype)
        new_cache = {
            "k_slab": k.astype(cache["k_pool"].dtype),
            "v_slab": v.astype(cache["v_pool"].dtype),
        }
    elif paged:
        # scatter fresh K/V into the page-flattened pool (garbage-routed
        # masking), then gather this row's pages and attend densely
        n_pages, page_size = cache["k_pool"].shape[:2]
        flat = paged_write_flat(block_tables, cache_len, s, page_size, write_len)
        flat_shape = (n_pages * page_size, cfg.n_kv_heads, cfg.d_head)
        ck = (cache["k_pool"].reshape(flat_shape)
              .at[flat].set(k.astype(cache["k_pool"].dtype))
              .reshape(cache["k_pool"].shape))
        cv = (cache["v_pool"].reshape(flat_shape)
              .at[flat].set(v.astype(cache["v_pool"].dtype))
              .reshape(cache["v_pool"].shape))
        new_cache = {"k_pool": ck, "v_pool": cv}
        s_logical = block_tables.shape[1] * page_size
        all_pos = jnp.arange(s_logical, dtype=jnp.int32)[None, :].repeat(b, 0)
        valid = all_pos < (cache_len + s)[:, None]
        out = flash_attention(
            q,
            paged_gather(ck, block_tables),
            paged_gather(cv, block_tables),
            q_pos=flat_pos,
            kv_pos=all_pos,
            causal=cfg.causal,
            kv_valid=valid,
        )
    elif defer_cache_write:
        # flash-decoding over (stale cache) + (fresh slab), no cache rewrite
        s_max = cache["k"].shape[1]
        kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(b, s, kvh, g, cfg.d_head)
        all_pos = jnp.arange(s_max, dtype=jnp.int32)[None, :].repeat(b, 0)
        stale_valid = all_pos < cache_len[:, None]
        part_cache = _attend_stats(
            qg, cache["k"], cache["v"],
            q_pos=flat_pos, kv_pos=all_pos, causal=cfg.causal, kv_valid=stale_valid,
        )
        slab_pos = (cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :])
        part_slab = _attend_stats(
            qg, k, v, q_pos=flat_pos, kv_pos=slab_pos, causal=cfg.causal, kv_valid=None,
        )
        out = _merge_stats([part_cache, part_slab]).reshape(b, s, cfg.n_heads, cfg.d_head)
        out = out.astype(q.dtype)
        new_cache = {
            "k_slab": k.astype(cache["k"].dtype),
            "v_slab": v.astype(cache["v"].dtype),
        }
    else:
        # scatter new K/V at per-sequence cursors, then attend over the cache
        s_max = cache["k"].shape[1]
        write_idx = (cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :])  # (B, S)
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        ck = cache["k"].at[bidx, write_idx].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[bidx, write_idx].set(v.astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        all_pos = jnp.arange(s_max, dtype=jnp.int32)[None, :].repeat(b, 0)
        valid = all_pos < (cache_len + s)[:, None]
        out = flash_attention(
            q, ck, cv,
            q_pos=flat_pos,
            kv_pos=all_pos,
            causal=cfg.causal,
            kv_valid=valid,
        )

    y = linear(cfg.o, p["o"], out.reshape(b, s, cfg.n_heads * cfg.d_head))
    return y, new_cache

"""End-to-end driver (deliverable b): the LUT-NN training lifecycle as a
first-class `Recipe` (DESIGN.md §10) over a HETEROGENEOUS per-site LUTPlan
(DESIGN.md §9):

  * MLP sites:       K=16 tables
  * attention sites: K=8 tables (cheaper encode, the paper's K ablation)
  * first and last layers: kept dense (the paper's accuracy-critical ends)

and a custom stage list — dense pretrain, k-means centroid init, soft-PQ
fine-tune *with dense-teacher distillation* (KL vs the frozen pretrained
model, DESIGN.md §10.3), int8 deploy, and an eval gate that fails the run
if the deployed model regresses more than 1.0 nats past the teacher.

  PYTHONPATH=src python examples/train_softpq_pipeline.py [--steps 200]

The run is resumable: kill it at any point and re-run with the same
--ckpt-dir — the pipeline manifest (<ckpt_dir>/recipe_run.json) resumes at
the recorded stage and checkpoint step. The emitted artifact (manifest v2,
plan + executed recipe included) serves with
`python -m repro.launch.serve --artifact <dir>` and introspects with
`python -m repro.serving.artifact <dir>`. For the plain flag-built default
recipe use `python -m repro.launch.train --lut`.
"""

import argparse
import dataclasses

from repro.configs import LUTPlan, effective_plan, get_arch, reduce_arch, rule
from repro.core.amm import Mode
from repro.data import MarkovLM
from repro.train.recipe import (
    CentroidInit,
    Deploy,
    DensePretrain,
    Eval,
    OptimSpec,
    Recipe,
    SoftPQ,
)
from repro.train.train_step import DistillSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3_1p7b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_plan_run")
    ap.add_argument("--artifact-dir", default="/tmp/repro_plan_artifact")
    args = ap.parse_args()

    plan = LUTPlan(rules=(
        rule(kinds=("mlp/*",), k=16),
        rule(kinds=("attn/*",), k=8),
        rule(layers="set", layer_set=(0, args.layers - 1), replace=False),
    ))
    arch = reduce_arch(
        get_arch(args.arch),
        d_model=256, n_layers=args.layers, vocab=512, d_ff=512,
    )
    arch = dataclasses.replace(arch, lut_plan=plan)
    print(f"replacement plan: {effective_plan(arch).describe()}")

    recipe = Recipe(stages=(
        DensePretrain(
            steps=args.steps,
            optim=OptimSpec(lr=3e-3, schedule="cosine", warmup_steps=20),
            ckpt_every=max(50, args.steps // 4), log_every=50,
        ),
        CentroidInit(sample_batches=2, sample_start=10_000),
        SoftPQ(
            steps=args.steps,
            optim=OptimSpec(lr=1e-3, schedule="cosine", warmup_steps=10,
                            rules="distill"),
            distill=DistillSpec(weight=0.5, temperature=2.0),
            ckpt_every=max(50, args.steps // 4), log_every=50,
        ),
        Deploy(artifact_dir=args.artifact_dir),
        Eval(batch_step=99_999, max_regression=1.0),
    )).validate()
    print(f"recipe: {recipe.describe()}")

    data = MarkovLM(vocab=arch.vocab, seq_len=64, batch=16)
    result = recipe.run(arch, data, ckpt_dir=args.ckpt_dir)

    # the registry shows how the plan resolved every site
    print("per-site resolution (layer 1):")
    for s in result.lut_bundle.sites():
        if s.layer == 1 and s.stack_index is not None:
            lut = f"K={s.lut.k} V={s.lut.v}" if s.mode != Mode.DENSE else "dense"
            print(f"  {s.kind:12s} {s.d_in:4d}->{s.d_out:<4d} {lut}")

    ev = result.stage_result("eval") or {}
    print(f"deployed INT8 LUT eval loss: {ev.get('deployed_loss'):.4f} "
          f"(dense teacher {ev.get('dense_loss'):.4f})")
    print(f"wrote LUTArtifact (manifest v2, plan + recipe) to {args.artifact_dir}\n"
          f"  inspect: python -m repro.serving.artifact {args.artifact_dir}\n"
          f"  serve:   python -m repro.launch.serve --artifact {args.artifact_dir}")


if __name__ == "__main__":
    main()

"""Paper Fig. 7 analog: per-operator cost, dense vs LUT-NN, v1 vs v2 vs fused.

Real TPU wall-clock is unavailable here, so this reports THREE views per op:

  * measured CPU wall-clock of the XLA paths — dense matmul, fp32 one-hot
    LUT, int8-dot LUT (honest but CPU-flavored);
  * measured wall-clock of the Pallas kernels — v1, v2, and the fused
    encode→lookup decode kernel (DESIGN.md §13) — in interpret mode on an
    N-capped slice (interpret executes the kernel body through XLA — it
    exercises the exact kernel dataflow but does NOT model MXU int8
    throughput, so off-TPU these columns track emulation cost only; each
    row records its truncation in `kernel_n_cap`);
  * the autotuner's analytic v5e roofline projection for the FULL shape,
    v1 vs v2 vs fused, each at its own best tiling (DESIGN.md §3/§13) — the
    numbers a real TPU run regresses against.

Each row also records the autotune verdict for its shape: the winning
kernel version (`tuned_version`), its blocks, and `tuned_measured` (0/1) —
the measured-vs-analytic flag. With REPRO_AUTOTUNE_MEASURE=1 the tuning
sweep times compiled runs on the live backend (repro.kernels.measure)
instead of scoring the roofline model.

With `json_path` set (benchmarks/run.py --json) the rows are written to
BENCH_kernels.json so future PRs have a perf trajectory to regress against;
`benchmarks/check_regression.py` gates the structural keys. `--smoke`
restricts the run to the two small CI shapes (fast enough for the
kernel-parity job); the big rows are marked best-effort in the gate so a
smoke artifact still diffs cleanly.
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.core import pq, quant
from repro.kernels import autotune, measure, ops
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

OPS = [
    # (name, N, D, M, K, V)
    ("bert_ffn_up", 512, 768, 3072, 16, 32),
    ("llama3_qproj", 256, 4096, 4096, 16, 32),
    ("llama3_ffn_gate", 256, 4096, 14336, 16, 32),
]

# small shapes the CI kernel-parity job can regenerate in seconds; part of
# the full run too, so the committed artifact always carries them
SMOKE_OPS = [
    ("smoke_ffn", 32, 64, 128, 16, 8),
    ("smoke_proj", 16, 128, 64, 16, 16),
]

# interpret-mode kernels run the grid as emulated XLA steps on CPU — cap the
# row count so the measured v1/v2/fused comparison stays cheap. The
# full-shape numbers come from the analytic roofline projection.
KERNEL_N_CAP = 64


def _time(fn, *args, iters: int = 3) -> float:
    """Median-free mean wall-clock per call; exactly one warmup execution."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_op(name: str, n: int, d: int, m: int, k: int, v: int) -> dict:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d), jnp.float32)
    w = jax.random.normal(key, (d, m), jnp.float32)
    P = jax.random.normal(key, (d // v, k, v))
    table = pq.build_table(P, w, stop_weight_grad=False)
    qt = quant.quantize_table(table)
    qt_sh = quant.quantize_table(table, m_shared=True)

    dense_fn = jax.jit(lambda x, w: x @ w)

    def lut_fn(x, P, tq, ts):
        tbl = tq.astype(jnp.float32) * ts
        enc = pq.hard_encode(pq.pairwise_sq_dists(pq.split_subvectors(x, v), P))
        return pq.lut_contract(enc, tbl)

    def lut_i8_fn(x, P, tq, ts):
        enc = pq.hard_encode(pq.pairwise_sq_dists(pq.split_subvectors(x, v), P))
        return pq.lut_contract_int8(enc, tq, ts)

    t_dense = _time(dense_fn, x, w) * 1e3
    t_lut = _time(jax.jit(lut_fn), x, P, qt.q, qt.scale) * 1e3
    t_lut_i8 = _time(jax.jit(lut_i8_fn), x, P, qt_sh.q, qt_sh.scale) * 1e3

    # the autotune verdict for this shape: version axis swept (v1/v2/fused),
    # measured on the live backend when REPRO_AUTOTUNE_MEASURE=1
    c = d // v
    measure_fn = (
        measure.measure_lut_amm(n, m, c, k, v) if measure.measure_enabled()
        else None
    )
    blk, rec = autotune.tune("lut_amm", n, m, c, k, v, save=False,
                             measure=measure_fn)

    # per-version analytic best tilings — each generation judged at ITS
    # blocks, not the winner's (a fused (bn, bm, C) tiling is not a
    # meaningful v1/v2 config)
    blk_v2, v2_us = autotune.best_analytic("lut_amm", n, m, c, k, v, version=2)
    _, v1_us = autotune.best_analytic("lut_amm", n, m, c, k, v, version=1)
    blk_f, fused_us = autotune.best_analytic("lut_amm", n, m, c, k, v, version=3)

    # Pallas v1 vs v2 vs fused, measured (interpret off-TPU) on the N-capped
    # slice at each generation's analytic-best blocks.
    nk = min(n, KERNEL_N_CAP)
    xk = x[:nk]
    bn, bm, bc = min(blk_v2.block_n, nk), blk_v2.block_m, blk_v2.block_c
    t_v1 = _time(
        lambda *a: ops.lut_amm_v1(*a, block_n=bn, block_m=bm, block_c=bc),
        xk, P, qt_sh.q, jnp.broadcast_to(qt_sh.scale, (c, 1, m)),
        iters=2,
    ) * 1e3
    t_v2 = _time(
        lambda *a: ops.lut_amm(*a, version=2, block_n=bn, block_m=bm, block_c=bc),
        xk, P, qt_sh.q, qt_sh.scale,
        iters=2,
    ) * 1e3
    if blk_f is not None:
        t_fused = _time(
            lambda *a: ops.lut_amm_fused(
                *a, block_n=min(blk_f.block_n, nk), block_m=blk_f.block_m),
            xk, P, qt_sh.q, qt_sh.scale,
            iters=2,
        ) * 1e3
    else:
        t_fused = math.nan                   # fused working set over budget

    # v5e roofline (decode regime: weight/table bytes dominate)
    dense_bytes_ = d * m * 2 + (n * d + n * m) * 2
    lut_bytes_ = c * k * m + c * k * v * 4 + (n * d + n * m) * 2
    dense_flops_ = 2 * n * d * m
    lut_flops_ = 2 * n * d * k + 2 * n * c * k * m   # one-hot MXU path
    t_roof_dense = max(dense_bytes_ / HBM_BW, dense_flops_ / PEAK_FLOPS) * 1e6
    t_roof_lut = max(lut_bytes_ / HBM_BW, lut_flops_ / PEAK_FLOPS) * 1e6

    return {
        "op": name,
        "n": n, "d": d, "m": m, "k": k, "v": v,
        "cpu_dense_ms": t_dense,
        "cpu_lut_ms": t_lut,
        "cpu_lut_int8_ms": t_lut_i8,
        "kernel_n": nk,
        "kernel_n_cap": KERNEL_N_CAP,        # truncation recorded per row
        "kernel_backend": "tpu" if jax.default_backend() == "tpu" else "interpret",
        "pallas_v1_ms": t_v1,
        "pallas_v2_ms": t_v2,
        "fused_ms": t_fused,
        "tuned_version": rec.get("version", 2),
        "tuned_measured": int(bool(rec.get("measured"))),   # measured-vs-analytic
        "tuned_block_n": blk.block_n,
        "tuned_block_m": blk.block_m,
        "tuned_block_c": blk.block_c,
        "v1_model_us": v1_us,
        "v2_model_us": v2_us,
        "fused_model_us": fused_us if blk_f is not None else math.nan,
        "tpu_roofline_dense_us": t_roof_dense,
        "tpu_roofline_lut_us": t_roof_lut,
        "decode_byte_ratio": (d * m * 2) / (c * k * m),
    }


COLUMNS = (
    "op", "cpu_dense_ms", "cpu_lut_ms", "cpu_lut_int8_ms",
    "pallas_v1_ms", "pallas_v2_ms", "fused_ms",
    "tuned_version", "tuned_measured",
    "tuned_block_n", "tuned_block_m", "tuned_block_c",
    "v1_model_us", "v2_model_us", "fused_model_us",
    "tpu_roofline_dense_us", "tpu_roofline_lut_us", "decode_byte_ratio",
)


def main(
    json_path: str | pathlib.Path | None = None, *, smoke: bool = False
) -> list[dict]:
    t0 = time.time()
    print("# Fig. 7 analog: per-op dense vs LUT (xla/int8/pallas v1/v2/fused)")
    print(",".join(COLUMNS))
    rows = []
    todo = SMOKE_OPS if smoke else OPS + SMOKE_OPS
    for name, n, d, m, k, v in todo:
        r = bench_op(name, n, d, m, k, v)
        rows.append(r)
        print(",".join(
            f"{r[c]:.3f}" if isinstance(r[c], float) else str(r[c])
            for c in COLUMNS
        ))
    if json_path is not None:
        payload = {
            "benchmark": "op_microbench",
            "backend": jax.default_backend(),
            "kernel_n_cap": KERNEL_N_CAP,
            "measured_autotune": measure.measure_enabled(),
            "rows": rows,
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=1))
        print(f"# wrote {json_path}")
    print(f"op_microbench,{(time.time()-t0)*1e6:.0f},cpu+roofline")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_kernels.json at the repo root")
    ap.add_argument("--json-out", default=None,
                    help="write the payload to this explicit path instead "
                         "(CI fresh-dir flow for check_regression)")
    ap.add_argument("--smoke", action="store_true",
                    help="only the two small smoke shapes (CI kernel-parity)")
    args = ap.parse_args()
    # anchor at the repo root (same path run.py and roofline_table.py use),
    # independent of the invocation cwd
    _JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_kernels.json"
    out = args.json_out if args.json_out else (_JSON if args.json else None)
    main(json_path=out, smoke=args.smoke)

"""Paper Fig. 12: centroid count K and sub-vector length V vs accuracy+FLOPs.

More centroids -> better accuracy, more FLOPs; longer sub-vectors -> fewer
FLOPs, worse accuracy.
"""

from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks._mlp import MLPSpec, attach_pq, evaluate, finetune_softpq, train_dense
from repro.core.amm import LUTConfig, dense_flops, lut_flops
from repro.data import ClusteredTask


def main(steps: int = 150) -> None:
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    base_spec = MLPSpec(d_in=64, width=128, depth=3, n_out=10)
    task = ClusteredTask(d_in=base_spec.d_in, n_classes=10)
    dense = train_dense(key, base_spec, task, steps=300)
    layer_ids = list(range(1, base_spec.depth + 1))

    print("# Fig. 12 analog: (K, V) sweep")
    print("K,V,acc,flops_ratio")
    rows = {}
    for k in (8, 16, 32):
        for v in (4, 8, 16):
            spec = dataclasses.replace(base_spec, lut=LUTConfig(k=k, v=v))
            p0 = attach_pq(key, dense, spec, task, layer_ids, kind="pq")
            p, _ = finetune_softpq(key, p0, spec, task, layer_ids, steps=steps)
            acc = evaluate(p, spec, task, modes=[
                ("pq" if i in layer_ids else None) for i in range(base_spec.depth + 1)
            ])
            fr = lut_flops(1, 128, 128, spec.lut) / dense_flops(1, 128, 128)
            rows[(k, v)] = acc
            print(f"{k},{v},{acc:.4f},{fr:.3f}")
    # paper claims: acc increases with K, decreases with V
    print(f"claim_more_centroids_help,{rows[(32, 8)] >= rows[(8, 8)] - 0.02}")
    print(f"claim_longer_subvec_hurts,{rows[(16, 16)] <= rows[(16, 4)] + 0.02}")
    print(f"fig12_kv_sweep,{(time.time()-t0)*1e6:.0f},sweep")


if __name__ == "__main__":
    main()

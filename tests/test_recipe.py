"""Recipe pipeline API: serialization round-trips, validation, parity with
the legacy imperative driver, distillation, the eval gate, grad-compression
opt-in, and kill-and-resume (subprocess SIGKILL mid-soft-PQ)."""

import json
import os
import pathlib
import signal
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.data import MarkovLM
from repro.train.recipe import (
    CentroidInit,
    Deploy,
    DensePretrain,
    Eval,
    OptimSpec,
    Recipe,
    RecipeError,
    SoftPQ,
    default_recipe,
)
from repro.train.train_step import DistillSpec

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def tiny_arch():
    return reduce_arch(
        get_arch("qwen3_1p7b"), n_layers=2, vocab=64, d_model=48, d_ff=96
    )


def tiny_data(arch):
    return MarkovLM(vocab=arch.vocab, seq_len=16, batch=8, branching=4)


def tiny_recipe(art_dir, *, dense_steps=6, softpq_steps=6, distill=None,
                ckpt_every=3, eval_max_loss=None, eval_max_regression=None,
                grad_compression=False):
    return Recipe(stages=(
        DensePretrain(steps=dense_steps, ckpt_every=ckpt_every, log_every=0,
                      grad_compression=grad_compression),
        CentroidInit(sample_batches=1, sample_start=500, max_rows=512),
        SoftPQ(steps=softpq_steps, ckpt_every=ckpt_every, log_every=0,
               distill=distill,
               optim=OptimSpec(lr=1e-3, schedule="cosine", warmup_steps=2,
                               rules="distill" if distill else "soft_pq")),
        Deploy(artifact_dir=str(art_dir)),
        Eval(batch_step=999, max_loss=eval_max_loss,
             max_regression=eval_max_regression),
    )).validate()


# ---------------------------------------------------------------------------
# serialization + validation
# ---------------------------------------------------------------------------

def test_round_trip_default_recipe():
    r = default_recipe(steps=100, lut=True, artifact_dir="/tmp/a",
                       distill_weight=0.25, distill_tau=3.0,
                       eval_max_regression=0.7)
    d = r.to_dict()
    assert Recipe.from_dict(d) == r
    assert Recipe.from_dict(d).to_dict() == d          # exact dict round trip
    assert Recipe.from_json(r.to_json()) == r


def test_round_trip_through_file(tmp_path):
    r = tiny_recipe(tmp_path / "art", distill=DistillSpec(weight=0.5),
                    grad_compression=True, eval_max_regression=1.0)
    p = tmp_path / "recipe.json"
    r.save(p)
    assert Recipe.load(p) == r
    # json on disk is plain data (editable by hand)
    raw = json.loads(p.read_text())
    assert raw["stages"][0]["stage"] == "dense_pretrain"
    assert raw["stages"][2]["distill"] == {"weight": 0.5, "temperature": 2.0}


def test_dense_only_recipe():
    r = default_recipe(steps=10, lut=False)
    assert len(r.stages) == 1 and isinstance(r.stages[0], DensePretrain)
    assert Recipe.from_dict(r.to_dict()) == r


def test_validation_rejects_bad_recipes():
    with pytest.raises(RecipeError, match="no stages"):
        Recipe(stages=()).validate()
    with pytest.raises(RecipeError, match="unique"):
        Recipe(stages=(DensePretrain(), DensePretrain())).validate()
    with pytest.raises(RecipeError, match="requires an earlier"):
        Recipe(stages=(SoftPQ(),)).validate()
    with pytest.raises(RecipeError, match="requires an earlier"):
        Recipe(stages=(DensePretrain(), SoftPQ())).validate()   # no centroid init
    with pytest.raises(RecipeError, match="unknown stage kind"):
        Recipe.from_dict({"version": 1, "stages": [{"stage": "nope"}]})
    with pytest.raises(RecipeError, match="version"):
        Recipe.from_dict({"version": 99, "stages": []})


def test_direct_pq_deploy_is_valid():
    # deploying straight after centroid init (no fine-tune) is the paper's
    # direct-PQ baseline and must validate
    Recipe(stages=(DensePretrain(), CentroidInit(), Deploy())).validate()


# ---------------------------------------------------------------------------
# execution: parity with the legacy imperative driver
# ---------------------------------------------------------------------------

def test_default_recipe_reproduces_legacy_pipeline(tmp_path):
    """The flag-built default recipe must replay the historical
    launch/train.py --lut driver: same stage sequence, same losses at a
    fixed seed, and the artifact manifest must carry the recipe."""
    import jax.numpy as jnp

    from repro.core import convert
    from repro.optim import SOFT_PQ_RULES, AdamW, lut_frozen_mask
    from repro.optim.schedule import cosine_with_warmup
    from repro.train.train_step import make_train_step
    from repro.train.trainer import Trainer, TrainerConfig

    steps = 6
    arch = tiny_arch()
    data = tiny_data(arch)

    # --- legacy imperative sequence (pre-recipe launch/train.py) ---
    key = jax.random.PRNGKey(0)
    bundle = build_model(arch, Mode.DENSE)
    params = bundle.init(key)
    opt = AdamW(lr=cosine_with_warmup(3e-3, total_steps=steps, warmup_steps=20))
    tr = Trainer(
        step_fn=jax.jit(make_train_step(bundle, opt, compute_dtype=jnp.float32)),
        batch_at=data.batch_at,
        cfg=TrainerConfig(total_steps=steps, ckpt_every=max(50, steps // 4),
                          ckpt_dir=str(tmp_path / "legacy_dense"), log_every=0),
    )
    params, _ = tr.fit(params, opt.init(params), start_step=0)
    legacy_dense_loss = tr.history[-1]["loss"]

    samples = [data.batch_at(10_000 + i) for i in range(2)]
    blut, lparams = convert.convert_dense_to_lut_train(bundle, params, samples, key)
    frozen = lut_frozen_mask(lparams)
    opt2 = AdamW(lr=cosine_with_warmup(1e-3, total_steps=steps, warmup_steps=10),
                 rules=SOFT_PQ_RULES)
    tr2 = Trainer(
        step_fn=jax.jit(make_train_step(blut, opt2, frozen_mask=frozen,
                                        compute_dtype=jnp.float32)),
        batch_at=data.batch_at,
        cfg=TrainerConfig(total_steps=steps, ckpt_every=max(50, steps // 4),
                          ckpt_dir=str(tmp_path / "legacy_lut"), log_every=0),
    )
    lparams, _ = tr2.fit(lparams, opt2.init(lparams, frozen), start_step=0)
    legacy_softpq_loss = tr2.history[-1]["loss"]
    binf, iparams = convert.deploy_lut_train_params(blut, lparams)
    legacy_eval = float(binf.loss(iparams, data.batch_at(99_999),
                                  compute_dtype=jnp.float32))

    # --- the same pipeline as a Recipe ---
    art = tmp_path / "artifact"
    recipe = default_recipe(steps=steps, lut=True, artifact_dir=str(art))
    assert [s.KIND for s in recipe.stages] == [
        "dense_pretrain", "centroid_init", "soft_pq", "deploy", "eval"
    ]
    res = recipe.run(arch, data, ckpt_dir=tmp_path / "run", seed=0, verbose=False)

    dense_final = res.stage_result("dense")["final_loss"]
    softpq_final = res.stage_result("soft_pq")["final_loss"]
    eval_loss = res.stage_result("eval")["deployed_loss"]
    np.testing.assert_allclose(dense_final, legacy_dense_loss, rtol=1e-6)
    np.testing.assert_allclose(softpq_final, legacy_softpq_loss, rtol=1e-6)
    np.testing.assert_allclose(eval_loss, legacy_eval, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(iparams), jax.tree.leaves(res.inf_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)

    # provenance: the artifact manifest carries the recipe, exactly
    manifest = json.loads((art / "manifest.json").read_text())
    assert manifest["recipe"] == recipe.to_dict()
    assert Recipe.from_dict(manifest["recipe"]) == recipe


# ---------------------------------------------------------------------------
# distillation
# ---------------------------------------------------------------------------

def test_distill_recipe_end_to_end(tmp_path):
    arch = tiny_arch()
    data = tiny_data(arch)
    recipe = tiny_recipe(tmp_path / "art", distill=DistillSpec(weight=0.5,
                                                               temperature=2.0))
    res = recipe.run(arch, data, ckpt_dir=tmp_path / "run", verbose=False)

    hist = res.histories["soft_pq"]
    assert hist, "soft-PQ stage produced no history"
    for rec in hist:
        assert "distill_kl" in rec and "ce" in rec
        assert np.isfinite(rec["distill_kl"]) and rec["distill_kl"] >= 0
        # the mixed loss really is the advertised blend
        np.testing.assert_allclose(
            rec["loss"], 0.5 * rec["ce"] + 0.5 * rec["distill_kl"], rtol=1e-5
        )
    sp = res.stage_result("soft_pq")
    assert "distill_kl" in sp and "t_mean" in sp
    # the recorded recipe round-trips with the distill spec intact
    m = json.loads((tmp_path / "art" / "manifest.json").read_text())
    r2 = Recipe.from_dict(m["recipe"])
    assert r2.stages[2].distill == DistillSpec(weight=0.5, temperature=2.0)


def test_distill_spec_validated_at_construction():
    """An out-of-range DistillSpec fails at recipe authoring time (and so
    at from_dict), never hours later when the SoftPQ stage starts."""
    with pytest.raises(ValueError, match="weight"):
        DistillSpec(weight=1.5)
    with pytest.raises(ValueError, match="temperature"):
        DistillSpec(weight=0.5, temperature=0.0)
    with pytest.raises(ValueError, match="weight"):
        Recipe.from_dict({
            "version": 1,
            "stages": [
                {"stage": "dense_pretrain", "name": "dense", "steps": 1,
                 "optim": OptimSpec().to_dict(), "ckpt_every": 1,
                 "log_every": 0, "grad_accum": 1, "compute_dtype": "float32",
                 "grad_compression": False},
                {"stage": "centroid_init", "name": "ci", "sample_batches": 1,
                 "sample_start": 0, "kmeans_iters": 1, "max_rows": 64},
                {"stage": "soft_pq", "name": "sp", "steps": 1,
                 "optim": OptimSpec().to_dict(),
                 "distill": {"weight": 2.0, "temperature": 1.0},
                 "ckpt_every": 1, "log_every": 0, "compute_dtype": "float32"},
            ],
        })


def test_optim_spec_validated_at_construction():
    """Schedule/rule-set typos fail at authoring/from_dict time, not after
    earlier stages have already run."""
    with pytest.raises(RecipeError, match="unknown schedule"):
        OptimSpec(schedule="cos")
    with pytest.raises(RecipeError, match="unknown rule set"):
        OptimSpec(rules="soft-pq")         # typo for soft_pq
    bad = default_recipe(steps=2).to_dict()
    bad["stages"][2]["optim"]["rules"] = "soft-pq"
    with pytest.raises(RecipeError, match="unknown rule set"):
        Recipe.from_dict(bad)


def test_resume_guard_checks_data_fingerprint(tmp_path):
    """Dataclass data sources are fingerprinted into the run manifest: a
    resume with different data flags (seq/batch/...) is refused."""
    arch = tiny_arch()
    recipe = Recipe(stages=(DensePretrain(steps=2, ckpt_every=1, log_every=0),))
    recipe.run(arch, tiny_data(arch), ckpt_dir=tmp_path / "run", verbose=False)
    other = MarkovLM(vocab=arch.vocab, seq_len=8, batch=4, branching=4)
    with pytest.raises(RecipeError, match="DIFFERENT data"):
        recipe.run(arch, other, ckpt_dir=tmp_path / "run", verbose=False)


def test_grad_compression_rejects_grad_accum():
    with pytest.raises(RecipeError, match="grad_accum"):
        DensePretrain(grad_accum=2, grad_compression=True)


def test_resume_guard_checks_arch_and_seed(tmp_path):
    """Re-invoking the same ckpt-dir with a different arch or seed must be
    refused, not silently resumed into a mismatched tree."""
    import dataclasses as dc

    arch = tiny_arch()
    data = tiny_data(arch)
    recipe = Recipe(stages=(DensePretrain(steps=2, ckpt_every=1, log_every=0),))
    recipe.run(arch, data, ckpt_dir=tmp_path / "run", verbose=False)
    with pytest.raises(RecipeError, match="DIFFERENT seed"):
        recipe.run(arch, data, ckpt_dir=tmp_path / "run", seed=1, verbose=False)
    other = dc.replace(arch, d_model=32, n_heads=2, n_kv_heads=2, d_head=16)
    with pytest.raises(RecipeError, match="DIFFERENT arch"):
        recipe.run(other, data, ckpt_dir=tmp_path / "run", verbose=False)


# ---------------------------------------------------------------------------
# eval gate
# ---------------------------------------------------------------------------

def test_eval_gate_fails_run_and_marks_manifest(tmp_path):
    arch = tiny_arch()
    data = tiny_data(arch)
    recipe = tiny_recipe(tmp_path / "art", dense_steps=2, softpq_steps=2,
                         eval_max_loss=0.01)      # unreachable: gate must trip
    with pytest.raises(RecipeError, match="eval gate"):
        recipe.run(arch, data, ckpt_dir=tmp_path / "run", verbose=False)
    manifest = json.loads((tmp_path / "run" / "recipe_run.json").read_text())
    by_name = {e["name"]: e for e in manifest["stages"]}
    assert by_name["eval"]["status"] == "failed"
    assert "eval gate" in by_name["eval"]["result"]["error"]
    assert by_name["soft_pq"]["status"] == "done"    # earlier stages committed
    # the rejected deployment is retracted: nothing downstream can serve it
    assert not (tmp_path / "art" / "manifest.json").exists()

    # re-running the SAME recipe resumes: only the failed stage re-executes
    # (it fails again — the gate is deterministic)
    with pytest.raises(RecipeError, match="eval gate"):
        recipe.run(arch, data, ckpt_dir=tmp_path / "run", verbose=False)

    # changing a DONE stage's config is refused (its committed outputs were
    # produced under the recorded config)
    retrained = tiny_recipe(tmp_path / "art", dense_steps=4, softpq_steps=2,
                            eval_max_loss=0.01)
    with pytest.raises(RecipeError, match="DIFFERENT recipe"):
        retrained.run(arch, data, ckpt_dir=tmp_path / "run", verbose=False)

    # but loosening the FAILED gate resumes in place: done stages restore,
    # only eval re-runs — no retrain forced by a gate trip
    relaxed = tiny_recipe(tmp_path / "art", dense_steps=2, softpq_steps=2,
                          eval_max_loss=100.0)
    res = relaxed.run(arch, data, ckpt_dir=tmp_path / "run", verbose=False)
    assert res.stage_result("eval")["deployed_loss"] <= 100.0
    assert res.histories == {}            # nothing retrained
    manifest = json.loads((tmp_path / "run" / "recipe_run.json").read_text())
    assert manifest["recipe"] == relaxed.to_dict()   # reconciled in place
    # the passing gate re-deployed the retracted artifact
    assert (tmp_path / "art" / "manifest.json").exists()


def test_eval_regression_gate_passes_when_close(tmp_path):
    arch = tiny_arch()
    data = tiny_data(arch)
    recipe = tiny_recipe(tmp_path / "art", eval_max_regression=5.0)
    res = recipe.run(arch, data, ckpt_dir=tmp_path / "run", verbose=False)
    ev = res.stage_result("eval")
    assert ev["deployed_loss"] <= ev["dense_loss"] + 5.0


# ---------------------------------------------------------------------------
# grad compression opt-in (experimental)
# ---------------------------------------------------------------------------

def test_grad_compression_dense_stage(tmp_path):
    arch = tiny_arch()
    data = tiny_data(arch)
    recipe = Recipe(stages=(
        DensePretrain(steps=8, ckpt_every=4, log_every=0, grad_compression=True),
    )).validate()
    res = recipe.run(arch, data, ckpt_dir=tmp_path / "run", verbose=False)
    hist = res.histories["dense"]
    assert len(hist) == 8
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert hist[-1]["loss"] < hist[0]["loss"]        # still learns through int8
    # the compression residual rides inside the checkpointed state: a fresh
    # run over the same dir restores it (stage reports done, params equal)
    res2 = recipe.run(arch, data, ckpt_dir=tmp_path / "run", verbose=False)
    for a, b in zip(jax.tree.leaves(res.dense_params),
                    jax.tree.leaves(res2.dense_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# kill-and-resume (the crash-recovery acceptance test)
# ---------------------------------------------------------------------------

_CHILD = r"""
import json, os, signal, sys
import jax
from repro.configs import get_arch, reduce_arch
from repro.data import MarkovLM
from repro.train.recipe import (CentroidInit, Deploy, DensePretrain, Eval,
                                OptimSpec, Recipe, SoftPQ)

kill_at_call = int(sys.argv[1])        # batch_at call index to SIGKILL at (-1: never)
ckpt_dir = sys.argv[2]
out_json = sys.argv[3]

arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, vocab=64, d_model=48, d_ff=96)
base = MarkovLM(vocab=arch.vocab, seq_len=16, batch=8, branching=4)

calls = {"n": 0}
class KillingData:
    def batch_at(self, step):
        calls["n"] += 1
        if kill_at_call >= 0 and calls["n"] >= kill_at_call:
            os.kill(os.getpid(), signal.SIGKILL)   # hard kill, no cleanup
        return base.batch_at(step)

recipe = Recipe(stages=(
    DensePretrain(steps=8, ckpt_every=4, log_every=0),
    CentroidInit(sample_batches=1, sample_start=500, max_rows=512),
    SoftPQ(steps=10, ckpt_every=3, log_every=0),
    Deploy(artifact_dir=ckpt_dir + "/art"),
    Eval(batch_step=999),
)).validate()
res = recipe.run(arch, KillingData(), ckpt_dir=ckpt_dir, verbose=False)

out = {
    "dense_steps": [h["step"] for h in res.histories.get("dense", [])],
    "softpq_steps": [h["step"] for h in res.histories.get("soft_pq", [])],
    "softpq_final_loss": res.stage_result("soft_pq")["final_loss"],
    "eval_loss": res.stage_result("eval")["deployed_loss"],
    "stages": [[e["name"], e["status"], e["step"]] for e in res.manifest["stages"]],
}
with open(out_json, "w") as f:
    json.dump(out, f)
"""


def _run_child(tmp_path, name, kill_at_call, ckpt_dir, *, expect_kill):
    out_json = tmp_path / f"{name}.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, str(kill_at_call), str(ckpt_dir),
         str(out_json)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    if expect_kill:
        assert proc.returncode == -signal.SIGKILL, (
            f"child should have been SIGKILLed:\n{proc.stdout}\n{proc.stderr}"
        )
        assert not out_json.exists()
        return None
    assert proc.returncode == 0, f"child failed:\n{proc.stdout}\n{proc.stderr}"
    return json.loads(out_json.read_text())


def test_kill_mid_softpq_resumes_at_stage_and_step(tmp_path):
    """SIGKILL the pipeline mid-soft-PQ; re-invoking with the same ckpt_dir
    must resume at the recorded stage/step (never from 0) and converge to a
    loss byte-identical to an uninterrupted run."""
    # batch_at call schedule: dense steps 1..8, centroid sample = 9,
    # soft-PQ steps start at call 10 -> call 16 is soft-PQ step 6 (> one
    # ckpt_every=3 commit at step 3, plus the step-6 commit racing the kill)
    ref = _run_child(tmp_path, "ref", -1, tmp_path / "ref_run", expect_kill=False)
    _run_child(tmp_path, "killed", 16, tmp_path / "kill_run", expect_kill=True)

    # the manifest recorded the mid-flight state
    manifest = json.loads((tmp_path / "kill_run" / "recipe_run.json").read_text())
    by_name = {e["name"]: e for e in manifest["stages"]}
    assert by_name["dense"]["status"] == "done"
    assert by_name["soft_pq"]["status"] == "running"
    assert by_name["soft_pq"]["step"] in (3, 6)      # committed checkpoints

    resumed = _run_child(tmp_path, "resumed", -1, tmp_path / "kill_run",
                         expect_kill=False)

    # regression guard (launch/train.py used to hardcode start_step=0):
    # nothing re-runs from step 0 — the dense stage is restored (no steps),
    # and soft-PQ resumes at its committed checkpoint
    assert resumed["dense_steps"] == []
    assert resumed["softpq_steps"][0] > 0
    assert resumed["softpq_steps"][0] == min(resumed["softpq_steps"])
    assert dict((n, s) for n, s, _ in resumed["stages"])["eval"] == "done"

    # deterministic replay: byte-identical to the uninterrupted run
    assert float(resumed["softpq_final_loss"]).hex() == \
        float(ref["softpq_final_loss"]).hex()
    assert float(resumed["eval_loss"]).hex() == float(ref["eval_loss"]).hex()

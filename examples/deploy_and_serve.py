"""The full LUT-NN lifecycle in one script (DESIGN.md §8, §10):

  dense pretrain -> k-means convert -> soft-PQ fine-tune -> int8 deploy
  -> LUTArtifact on disk -> serve the DEPLOYED tables from the artifact.

This is the train half (`launch/train.py --lut` — a thin CLI over the
resumable `Recipe` pipeline of DESIGN.md §10, reduced to ~2 minutes on a
laptop CPU) handing off to the serve half (`launch/serve.py --artifact`)
through the self-describing artifact directory — no pytree plumbing between
the two processes. The artifact's manifest records the executed recipe;
inspect it with `python -m repro.serving.artifact <dir>`.

  PYTHONPATH=src python examples/deploy_and_serve.py

For tensor-parallel serving of the same artifact over 2 (forced host)
devices, re-run the serve half alone:

  XLA_FLAGS=--xla_force_host_platform_device_count=2 PYTHONPATH=src \\
  python -m repro.launch.serve --artifact /tmp/repro_example_artifact --tp 2
"""

import tempfile

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main

if __name__ == "__main__":
    artifact_dir = "/tmp/repro_example_artifact"
    with tempfile.TemporaryDirectory() as ckpt_dir:
        train_main([
            "--arch", "qwen3_1p7b", "--d-model", "64", "--layers", "2",
            "--vocab", "128", "--seq", "32", "--batch", "8", "--steps", "20",
            "--lut", "--ckpt-dir", ckpt_dir, "--artifact-dir", artifact_dir,
        ])
    serve_main([
        "--artifact", artifact_dir, "--requests", "8", "--slots", "4",
        "--max-seq", "64", "--prefill-chunk", "8", "--max-tokens", "12",
    ])

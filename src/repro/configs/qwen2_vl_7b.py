"""Qwen2-VL-7B backbone — M-RoPE, dynamic-resolution ViT frontend is a STUB
(input_specs provides patch embeddings) [arXiv:2409.12191; hf]."""
from repro.configs import ArchSpec

ARCH = ArchSpec(
    name="qwen2_vl_7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28, n_kv_heads=4, d_head=128,
    d_ff=18944,
    vocab=152064,
    mrope_sections=(16, 24, 24),            # t/h/w splits of d_head/2
    takes_embeds=True,
    rope_theta=1_000_000.0,
)

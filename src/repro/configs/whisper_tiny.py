"""Whisper-tiny — enc-dec, conv audio frontend STUBBED (input_specs provides
frame embeddings) [arXiv:2212.04356]."""
from repro.configs import ArchSpec

ARCH = ArchSpec(
    name="whisper_tiny",
    family="audio",
    n_layers=4,                              # decoder layers
    n_enc_layers=4,
    enc_frames=1500,
    d_model=384,
    n_heads=6, n_kv_heads=6, d_head=64,
    d_ff=1536,
    vocab=51865,
    act="gelu",
    mlp_gated=False,
    tie_embeddings=True,
    takes_embeds=False,                      # decoder takes tokens; encoder takes stub frames
    rope_theta=10_000.0,
)

"""Encode-only Pallas kernel: closest-centroid search (paper section 5.1).

Returns int32 indices (N, C). Used where the encoding is shared across
several table reads — e.g. MoE layers encode each token once and every
expert's table consumes the same indices (DESIGN.md §4).

The codebook tile is centroid-stationary in VMEM (index_map ignores the N
grid axis), mirroring the paper's cache-resident codebook loop. Block sizes
default to the shape-keyed autotuner (repro.kernels.autotune, DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune


def _encode_kernel(x_ref, p_ref, o_ref):
    a = x_ref[...].astype(jnp.float32)          # (bn, bc, V)
    p = p_ref[...].astype(jnp.float32)          # (bc, K, V)
    cross = jax.lax.dot_general(
        a, p, (((2,), (2,)), ((1,), (0,))), preferred_element_type=jnp.float32
    )                                           # (bc, bn, K)
    a_nrm = jnp.sum(a * a, axis=-1).T[:, :, None]
    p_nrm = jnp.sum(p * p, axis=-1)[:, None, :]
    dists = a_nrm - 2.0 * cross + p_nrm
    o_ref[...] = jnp.argmin(dists, axis=-1).astype(jnp.int32).T   # (bn, bc)


@functools.partial(jax.jit, static_argnames=("block_n", "block_c", "interpret"))
def _encode_call(x_sub, centroids, *, block_n, block_c, interpret):
    np_, c, v = x_sub.shape
    k = centroids.shape[1]
    bn, bc = block_n, block_c
    return pl.pallas_call(
        _encode_kernel,
        grid=(np_ // bn, c // bc),
        in_specs=[
            pl.BlockSpec((bn, bc, v), lambda i, cc: (i, cc, 0)),
            pl.BlockSpec((bc, k, v), lambda i, cc: (cc, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bc), lambda i, cc: (i, cc)),
        out_shape=jax.ShapeDtypeStruct((np_, c), jnp.int32),
        interpret=interpret,
    )(x_sub, centroids.astype(jnp.float32))


def encode_pallas(
    x: jax.Array,          # (N, D)
    centroids: jax.Array,  # (C, K, V)
    *,
    block_n: int | None = None,
    block_c: int | None = None,
    interpret: bool = False,
) -> jax.Array:            # (N, C) int32
    n, d = x.shape
    c, k, v = centroids.shape
    bn, _, bc = autotune.resolve_blocks(
        "encode", n, 0, c, k, v, str(x.dtype), block_n, 0, block_c
    )
    pad_n = (-n) % bn
    xp = jnp.pad(x, ((0, pad_n), (0, 0))) if pad_n else x
    np_ = n + pad_n
    out = _encode_call(
        xp.reshape(np_, c, v), centroids,
        block_n=bn, block_c=bc, interpret=interpret,
    )
    return out[:n]

"""Speculative decoding (DESIGN.md §14): the draft/verify scheduler must be
an invisible optimization — emitted tokens byte-identical to plain decode in
greedy AND sampled modes (the emitted-token rule draws every token from the
target's logits with the non-spec PRNG counters), with per-slot rollback
across dense and paged KV, auto-disable on recurrent-state bundles, and
per-request opt-out.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.engine import ServingEngine, submit_from_spec
from repro.serving.sampling import SamplingParams

PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9], [11, 12], [20, 21, 22, 23]]
MAX_TOK = 6


@pytest.fixture(scope="module")
def lm():
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, d_model=64,
                       vocab=128, d_ff=128)
    bundle = build_model(arch, Mode.LUT_INFER)
    params = bundle.init(jax.random.PRNGKey(0))
    # a DIVERGENT draft: same architecture, independently initialized —
    # proposals rarely match the target, exercising rejection + rollback
    draft_params = bundle.init(jax.random.PRNGKey(9))
    return bundle, params, draft_params


def _serve(bundle, params, *, sampling=None, spec_flags=None, **eng_kw):
    eng = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                        prefill_chunk=4, autotune_lut=False,
                        compute_dtype=jnp.float32, **eng_kw)
    for i, p in enumerate(PROMPTS):
        flag = None if spec_flags is None else spec_flags[i]
        eng.submit(p, max_tokens=MAX_TOK, sampling=sampling, spec_decode=flag)
    done = sorted(eng.run_until_done(max_steps=2000), key=lambda r: r.rid)
    assert all(r.status == "ok" for r in done), done
    return [r.out_tokens for r in done], eng.stats()


def test_greedy_parity_divergent_draft(lm):
    """Rejections dominate with an independent draft, yet output is exact."""
    bundle, params, draft_params = lm
    plain, _ = _serve(bundle, params)
    spec, st = _serve(bundle, params, spec_decode=True, spec_gamma=3,
                      draft_bundle=bundle, draft_params=draft_params)
    assert spec == plain
    assert st["spec_tokens_proposed"] > 0
    # the divergent draft must actually get rejected sometimes, or this
    # test isn't exercising rollback
    assert st["spec_tokens_accepted"] < st["spec_tokens_proposed"]
    assert st["target_forwards_per_token"] <= 1.0


def test_greedy_parity_self_draft(lm):
    """Draft == target: near-total acceptance, tokens still identical."""
    bundle, params, _ = lm
    plain, _ = _serve(bundle, params)
    spec, st = _serve(bundle, params, spec_decode=True, spec_gamma=3)
    assert spec == plain
    assert st["spec_tokens_accepted"] > 0
    assert st["target_forwards_per_token"] < 1.0
    assert st["spec_gamma"] == 3


def test_greedy_parity_paged_rewind(lm):
    """Paged KV: rejected positions roll back via page pop/unref, and the
    block tables stay consistent (output parity is the proof)."""
    bundle, params, draft_params = lm
    plain, _ = _serve(bundle, params, paged=True, page_size=4,
                      prefix_sharing=False)
    spec, st = _serve(bundle, params, paged=True, page_size=4,
                      spec_decode=True, spec_gamma=3,
                      draft_bundle=bundle, draft_params=draft_params)
    assert spec == plain
    assert st["spec_pages_rewound"] > 0     # rejections crossed page edges


def test_sampled_parity(lm):
    """Sampled mode: the emitted-token rule keys every verify position with
    the non-spec stream counter, so seeded sampling is reproduced exactly —
    not just in distribution."""
    bundle, params, draft_params = lm
    sampling = SamplingParams(temperature=0.9, top_k=20, seed=42)
    plain, _ = _serve(bundle, params, sampling=sampling)
    spec, st = _serve(bundle, params, sampling=sampling,
                      spec_decode=True, spec_gamma=3,
                      draft_bundle=bundle, draft_params=draft_params)
    assert spec == plain
    assert st["spec_tokens_proposed"] > 0


def test_per_request_opt_out(lm):
    """spec_decode=False requests ride the verify forward at width 1 —
    plain decode semantics inside a speculating engine."""
    bundle, params, _ = lm
    plain, _ = _serve(bundle, params)
    flags = [False, None, False, None]      # mix opt-outs with defaults
    spec, st = _serve(bundle, params, spec_decode=True, spec_gamma=3,
                      spec_flags=flags)
    assert spec == plain


def test_spec_request_on_plain_engine_raises(lm):
    bundle, params, _ = lm
    eng = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                        prefill_chunk=4, autotune_lut=False)
    with pytest.raises(ValueError, match="spec_decode"):
        eng.submit([1, 2], max_tokens=2, spec_decode=True)
    # opting OUT is always legal — it's the no-op default
    eng.submit([1, 2], max_tokens=2, spec_decode=False)
    assert all(r.status == "ok" for r in eng.run_until_done())


def test_submit_from_spec_validates(lm):
    bundle, params, _ = lm
    eng = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                        prefill_chunk=4, autotune_lut=False,
                        spec_decode=True, spec_gamma=2)
    rid = submit_from_spec(eng, {"prompt": [1, 2], "max_tokens": 2,
                                 "spec_decode": True})
    assert isinstance(rid, int)
    with pytest.raises(ValueError, match="spec_decode must be a bool"):
        submit_from_spec(eng, {"prompt": [1, 2], "spec_decode": 1})
    with pytest.raises(ValueError, match="unknown request fields"):
        submit_from_spec(eng, {"prompt": [1, 2], "draft_gamma": 3})
    assert all(r.status == "ok" for r in eng.run_until_done())


def test_draft_must_be_interchangeable(lm):
    """A draft with a different vocab can't propose tokens for the target."""
    bundle, params, _ = lm
    arch2 = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, d_model=64,
                        vocab=64, d_ff=128)
    b2 = build_model(arch2, Mode.LUT_INFER)
    with pytest.raises(ValueError, match="vocab"):
        ServingEngine(bundle, params, n_slots=2, max_seq=32,
                      prefill_chunk=4, autotune_lut=False,
                      spec_decode=True, draft_bundle=b2,
                      draft_params=b2.init(jax.random.PRNGKey(1)))


def test_draft_bundle_requires_params(lm):
    bundle, params, _ = lm
    with pytest.raises(ValueError, match="draft"):
        ServingEngine(bundle, params, n_slots=2, max_seq=32,
                      prefill_chunk=4, autotune_lut=False,
                      spec_decode=True, draft_bundle=bundle)


def test_hybrid_auto_disables_with_warning():
    """Bundles with per-slot recurrent state (hybrid SSM) can't rewind a
    Mamba hidden state to an arbitrary earlier position — the engine must
    fall back to plain decode, loudly, and still serve correctly."""
    arch = reduce_arch(get_arch("zamba2_1p2b"), n_layers=2, d_model=64,
                       vocab=128, d_ff=128)
    bundle = build_model(arch, Mode.LUT_INFER)
    params = bundle.init(jax.random.PRNGKey(0))
    with pytest.warns(UserWarning, match="spec_decode disabled"):
        eng = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                            prefill_chunk=4, autotune_lut=False,
                            spec_decode=True, spec_gamma=3)
    assert eng.spec is None
    # and a spec request on the auto-disabled engine is rejected like any
    # other non-spec engine
    with pytest.raises(ValueError, match="spec_decode"):
        eng.submit([1, 2], max_tokens=2, spec_decode=True)
    eng.submit([1, 2, 3], max_tokens=3)
    done = eng.run_until_done(max_steps=2000)
    assert [r.status for r in done] == ["ok"]


def test_stats_counters_flow(lm):
    """Every §14.4 counter surfaces through stats() after a spec run and
    resets with reset_stats()."""
    bundle, params, _ = lm
    eng = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                        prefill_chunk=4, autotune_lut=False,
                        spec_decode=True, spec_gamma=2)
    eng.submit([1, 2, 3], max_tokens=4)
    eng.run_until_done(max_steps=2000)
    st = eng.stats()
    for k in ("spec_rounds", "spec_slot_rounds", "spec_draft_forwards",
              "spec_verify_forwards", "spec_tokens_proposed",
              "spec_tokens_accepted", "spec_bonus_tokens",
              "spec_tokens_emitted", "spec_acceptance_rate",
              "target_forwards_per_token", "spec_gamma"):
        assert k in st, k
    # prefill samples token 1 of 4; the spec rounds emit the other three
    assert st["spec_tokens_emitted"] == st["decode_tokens"] == 3
    eng.reset_stats()
    st2 = eng.stats()
    assert st2["spec_rounds"] == 0 and st2["spec_tokens_emitted"] == 0

"""Llama-4 Maverick 400B-A17B — MoE 128e top-1 + shared expert
[hf:meta-llama/Llama-4-*]. The modality early-fusion frontend is out of
scope for the LM shapes (text tokens only here).
"""
from repro.configs import ArchSpec

ARCH = ArchSpec(
    name="llama4_maverick_400b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192,
    vocab=202048,
    n_experts=128, top_k=1,
    moe_shared_expert=True,
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    grad_accum=2,
)

"""Slot-based continuous-batching serving engine.

vLLM-style control plane scaled to this repo: a fixed pool of B slots backed
by batched KV caches; requests are admitted into free slots, prefilled with
a row-masked forward (other slots' caches untouched via a select-merge),
then all active slots decode together one token per engine step. Finished
slots (EOS or max_tokens) are freed and refilled from the queue.

The jitted prefill/decode steps are the same `forward_step` the multi-pod
dry-run lowers — the engine is pure host-side orchestration, so it works
identically on 1 CPU device and a 512-chip mesh.

When the bundle's LUT sites run the fused Pallas kernel
(`LUTConfig.use_kernel`), the engine warms the block-size autotuner at
construction for the decode token count (N = n_slots) and a geometric
ladder of prefill chunk multiples up to max_seq, so the steady-state decode
loop and common prefill lengths hit tuned shapes; anything uncovered falls
back to the heuristic tiling (DESIGN.md §3.3).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelBundle


def iter_lut_kernel_sites(cfg: Any, _seen: set[int] | None = None) -> Iterator[Any]:
    """Yield every LUT_INFER linear-site config under `cfg` that runs the
    fused kernel.

    Walks the nested dataclass/tuple config tree duck-typed (a site has
    d_in/d_out/mode/lut attributes) so this stays import-cycle-free with the
    model zoo.
    """
    if _seen is None:
        _seen = set()
    if cfg is None or id(cfg) in _seen:
        return
    _seen.add(id(cfg))
    if all(hasattr(cfg, a) for a in ("d_in", "d_out", "mode", "lut")):
        if getattr(cfg.mode, "value", cfg.mode) == "lut_infer" and cfg.lut.use_kernel:
            yield cfg
        return
    if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
        children: Iterator[Any] = (
            getattr(cfg, f.name) for f in dataclasses.fields(cfg)
        )
    elif isinstance(cfg, (tuple, list)):
        children = iter(cfg)
    else:
        return
    for child in children:
        yield from iter_lut_kernel_sites(child, _seen)


def warm_lut_autotune(
    bundle: ModelBundle, token_counts: list[int], dtype: str = "float32"
) -> int:
    """Pre-tune kernel block sizes for every (LUT site x token count) pair.

    `dtype` must be the dtype the LUT sites will actually see at runtime
    (the engine's compute dtype) — the kernel keys its cache lookups on
    `str(x.dtype)`, so a mismatched dtype warms keys nobody reads.

    Uses the analytic roofline model off-accelerator (fast: pure python),
    real wall-clock on TPU is wired by the benchmarks. Returns the number of
    (site, N) shapes tuned; winners persist in the autotune JSON cache.
    """
    from repro.kernels import autotune

    tuned = set()
    for site in iter_lut_kernel_sites(bundle.cfg):
        lut = site.lut
        c = site.d_in // lut.v
        for n in token_counts:
            key = ("lut_amm", n, site.d_out, c, lut.k, lut.v)
            if key in tuned:
                continue
            autotune.tune(*key, dtype=dtype, save=False)
            tuned.add(key)
    if tuned:
        try:
            autotune.get_cache().save()
        except OSError:
            # persistence is an optimization — winners stay in the
            # in-process cache; never fail serving over a cache file.
            pass
    return len(tuned)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_tokens: int = 16
    eos_id: int | None = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        bundle: ModelBundle,
        params: Any,
        *,
        n_slots: int = 4,
        max_seq: int = 256,
        prefill_chunk: int = 32,
        compute_dtype=jnp.float32,
        autotune_lut: bool = True,
    ):
        self.bundle = bundle
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        # decode hot path: every step is an (n_slots, 1)-token forward, so
        # the LUT kernels see N = n_slots. Prefill pads prompts up to a
        # multiple of prefill_chunk (see _do_prefill), so warm a geometric
        # ladder of chunk multiples up to max_seq (bounded work even for
        # long contexts); uncovered lengths fall back to the heuristic
        # tiling — a perf miss, never a correctness issue.
        if autotune_lut:
            n_chunks = max(1, -(-max_seq // prefill_chunk))
            mults: list[int] = []
            i = 1
            while i < n_chunks:
                mults.append(i)
                i *= 2
            mults.append(n_chunks)
            counts = [n_slots] + [n_slots * prefill_chunk * i for i in mults]
            self.n_lut_shapes_tuned = warm_lut_autotune(
                bundle, counts, dtype=jnp.dtype(compute_dtype).name
            )
        else:
            self.n_lut_shapes_tuned = 0
        self.caches = bundle.init_caches(n_slots, max_seq, dtype=compute_dtype)
        self.cache_len = np.zeros((n_slots,), np.int32)
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._next_rid = 0
        self._compute_dtype = compute_dtype

        def prefill(params, tokens, cache_len, caches, slot_mask):
            logits, new_caches = bundle.forward_step(
                params,
                {"tokens": tokens, "cache_len": cache_len},
                caches,
                compute_dtype=compute_dtype,
            )
            # merge: only the prefilled slot's cache rows advance
            def merge(old, new):
                # every cache leaf is layer-stacked: (L, B, ...) -> batch dim 1
                shape = [1] * old.ndim
                shape[1] = n_slots
                m = slot_mask.reshape(shape)
                return jnp.where(m, new, old)

            merged = jax.tree.map(merge, caches, new_caches)
            return logits, merged

        self._prefill = jax.jit(prefill)

        def decode(params, tokens, cache_len, caches, active):
            logits, new_caches = bundle.forward_step(
                params,
                {"tokens": tokens, "cache_len": cache_len},
                caches,
                compute_dtype=compute_dtype,
            )
            def merge(old, new):
                shape = [1] * old.ndim
                shape[1] = n_slots
                m = active.reshape(shape)
                return jnp.where(m, new, old)

            return logits, jax.tree.map(merge, caches, new_caches)

        self._decode = jax.jit(decode)

    # ------------------------------------------------------------------
    def submit(self, prompt: list[int], *, max_tokens: int = 16, eos_id: int | None = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, list(prompt), max_tokens, eos_id))
        return rid

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self._do_prefill(i, req)

    def _do_prefill(self, slot: int, req: Request) -> None:
        prompt = req.prompt or [0]
        chunk = len(prompt) + ((-len(prompt)) % self.prefill_chunk)
        toks = np.zeros((self.n_slots, chunk), np.int32)
        toks[slot, : len(prompt)] = prompt
        cache_len = np.zeros((self.n_slots,), np.int32)
        cache_len[slot] = 0
        mask = np.zeros((self.n_slots,), bool)
        mask[slot] = True
        logits, self.caches = self._prefill(
            self.params,
            jnp.asarray(toks),
            jnp.asarray(cache_len),
            self.caches,
            jnp.asarray(mask),
        )
        self.cache_len[slot] = len(prompt)
        nxt = int(jnp.argmax(logits[slot, len(prompt) - 1]))
        req.out_tokens.append(nxt)

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine step: admit waiting requests, decode all active slots."""
        self._admit()
        active = np.array([r is not None for r in self.slots])
        if not active.any():
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        for i, r in enumerate(self.slots):
            if r is not None:
                toks[i, 0] = r.out_tokens[-1] if r.out_tokens else (r.prompt[-1] if r.prompt else 0)
        logits, self.caches = self._decode(
            self.params,
            jnp.asarray(toks),
            jnp.asarray(self.cache_len),
            self.caches,
            jnp.asarray(active),
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            self.cache_len[i] += 1
            tok = int(nxt[i])
            r.out_tokens.append(tok)
            hit_eos = r.eos_id is not None and tok == r.eos_id
            if hit_eos or len(r.out_tokens) >= r.max_tokens or self.cache_len[i] >= self.max_seq - 1:
                r.done = True
                self.finished.append(r)
                self.slots[i] = None
                self.cache_len[i] = 0

    def run_until_done(self, max_steps: int = 1000) -> list[Request]:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        return self.finished

"""Recipe: the LUT-NN training lifecycle as a first-class, resumable object.

The paper's accuracy story is a *pipeline*, not a loop: dense pretrain ->
activation-tape k-means centroid init (Eq. 1) -> soft-PQ fine-tune with a
learned temperature (section 3.2, optionally distilling against the frozen
dense teacher) -> int8 table deploy -> eval gate. This module makes that
pipeline a serializable object (DESIGN.md §10), completing the object model

    LUTPlan (what to replace, §9)  ->  Recipe (how to train it, §10)
        ->  LUTArtifact (what ships, §8)

A `Recipe` is an ordered tuple of `Stage` dataclasses, each with its own
optimizer/schedule/steps config and its own checkpoint namespace
(`<ckpt_dir>/<ii>_<name>/`). `Recipe.run(arch, data, ckpt_dir=...)`
executes the stages in order, carrying params across stage boundaries, and
maintains an atomic pipeline manifest (`<ckpt_dir>/recipe_run.json`, same
tmp-then-replace discipline as the Checkpointer) recording per-stage
status + step — a killed run re-invoked with the same ckpt_dir resumes at
the recorded stage, and *within* a training stage at the newest committed
checkpoint step (never from 0). The whole recipe round-trips through JSON
(`to_dict`/`from_dict`), and `Deploy` serializes the executed recipe into
the LUTArtifact manifest for provenance.

Stages:
  * DensePretrain — dense baseline / teacher training (opt-in experimental
    int8 error-feedback gradient compression for the data-parallel reduce)
  * CentroidInit  — tape capture + k-means via `convert.kmeans_init_lut`
  * SoftPQ        — differentiable centroid learning; `distill=` adds a
    KL term against the frozen dense teacher (DistillSpec)
  * Deploy        — int8 tables -> LUTArtifact (+ recipe provenance)
  * Eval          — deployed-loss gate: fail the run on regression
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer, atomic_write_json
from repro.configs import ArchSpec, build_model
from repro.core import convert
from repro.core.amm import Mode
from repro.optim import DISTILL_RULES, SOFT_PQ_RULES, AdamW, lut_frozen_mask
from repro.optim.schedule import constant, cosine_with_warmup
from repro.train.train_step import (
    DistillSpec,
    init_compressed_state,
    make_compressed_train_step,
    make_distill_loss_fn,
    make_train_step,
)
from repro.train.trainer import Trainer, TrainerConfig

MANIFEST_NAME = "recipe_run.json"
RUN_FORMAT = "lut-recipe-run"
RUN_VERSION = 1

_RULE_SETS = {"none": (), "soft_pq": SOFT_PQ_RULES, "distill": DISTILL_RULES}
_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


class RecipeError(RuntimeError):
    """Invalid recipe, corrupt run directory, or a failed Eval gate."""


# ---------------------------------------------------------------------------
# per-stage optimizer spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OptimSpec:
    """Serializable AdamW + schedule config for one training stage."""

    lr: float = 1e-3
    schedule: str = "cosine"             # "cosine" | "constant"
    warmup_steps: int = 0
    weight_decay: float = 0.0
    rules: str = "none"                  # named GroupRule set (_RULE_SETS)
    clip_norm: float | None = 1.0

    def __post_init__(self):
        # fail at authoring/from_dict time, not hours later when the stage
        # finally calls build() (same early-validation contract as DistillSpec)
        if self.schedule not in ("cosine", "constant"):
            raise RecipeError(f"unknown schedule {self.schedule!r} "
                              f"(have cosine, constant)")
        if self.rules not in _RULE_SETS:
            raise RecipeError(
                f"unknown rule set {self.rules!r} (have {sorted(_RULE_SETS)})"
            )

    def build(self, total_steps: int) -> AdamW:
        if self.schedule == "cosine":
            lr = cosine_with_warmup(
                self.lr, total_steps=total_steps, warmup_steps=self.warmup_steps
            )
        else:
            lr = constant(self.lr)
        return AdamW(
            lr=lr, weight_decay=self.weight_decay,
            rules=_RULE_SETS[self.rules], clip_norm=self.clip_norm,
        )

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "OptimSpec":
        return cls(**d)


# ---------------------------------------------------------------------------
# stages
# ---------------------------------------------------------------------------

def _dtype(name: str):
    try:
        return _DTYPES[name]
    except KeyError:
        raise RecipeError(f"unknown compute dtype {name!r}") from None


@dataclasses.dataclass(frozen=True)
class _Stage:
    """Shared stage machinery: serialization + checkpoint namespace."""

    KIND = ""

    def to_dict(self) -> dict[str, Any]:
        out: dict[str, Any] = {"stage": self.KIND}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if dataclasses.is_dataclass(v):
                v = v.to_dict()
            out[f.name] = v
        return out

    # restore-time helper: the stage's committed output params
    def _restore_params(self, ctx: "_RunContext", index: int, specs: Any) -> Any:
        ck = Checkpointer(ctx.stage_dir(index, self))
        _, tree = ck.restore({"params": specs})
        return tree["params"]

    # shared by the stages whose committed output is the LUT_TRAIN tree
    def _restore_lut(self, ctx: "_RunContext", index: int) -> None:
        blut = build_model(ctx.arch, Mode.LUT_TRAIN)
        ctx.lut_bundle = blut
        ctx.lut_params = self._restore_params(ctx, index, blut.param_specs())


@dataclasses.dataclass(frozen=True)
class DensePretrain(_Stage):
    KIND = "dense_pretrain"

    name: str = "dense"
    steps: int = 200
    optim: OptimSpec = OptimSpec(lr=3e-3, schedule="cosine", warmup_steps=20)
    ckpt_every: int = 50
    log_every: int = 25
    grad_accum: int = 1
    compute_dtype: str = "float32"
    # EXPERIMENTAL (DESIGN.md §10.4): int8 error-feedback gradient reduce
    # over a data mesh spanning all local devices. Changes step numerics.
    grad_compression: bool = False

    def __post_init__(self):
        if self.grad_compression and self.grad_accum > 1:
            raise RecipeError(
                "grad_compression does not support grad_accum > 1 — the "
                "compressed data-parallel step reduces full-batch grads"
            )

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "DensePretrain":
        d = {k: v for k, v in d.items() if k != "stage"}
        d["optim"] = OptimSpec.from_dict(d["optim"])
        return cls(**d)

    def _build(self, ctx: "_RunContext", index: int):
        bundle = build_model(ctx.arch, Mode.DENSE)
        opt = self.optim.build(self.steps)
        if self.grad_compression:
            import numpy as np
            from jax.sharding import Mesh

            mesh = Mesh(np.array(jax.devices()), ("data",))
            step = make_compressed_train_step(
                bundle, opt, mesh, compute_dtype=_dtype(self.compute_dtype)
            )
            state_of = lambda p: init_compressed_state(opt, p)
        else:
            step = make_train_step(
                bundle, opt, compute_dtype=_dtype(self.compute_dtype),
                grad_accum=self.grad_accum,
            )
            state_of = opt.init
        return bundle, jax.jit(step), state_of

    def run(self, ctx: "_RunContext", index: int) -> dict[str, Any]:
        bundle, step_fn, state_of = self._build(ctx, index)
        params = bundle.init(ctx.init_key)
        n = sum(x.size for x in jax.tree.leaves(params))
        ctx.log(f"[{self.name}] {ctx.arch.name}: {n/1e6:.1f}M params, "
                f"dense pretrain {self.steps} steps"
                + (" [int8 compressed grads]" if self.grad_compression else ""))
        trainer = Trainer(
            step_fn=step_fn, batch_at=ctx.data.batch_at,
            cfg=TrainerConfig(
                total_steps=self.steps, ckpt_every=self.ckpt_every,
                ckpt_dir=str(ctx.stage_dir(index, self)), log_every=self.log_every,
            ),
            on_checkpoint=ctx.step_hook(index),
        )
        params, _ = trainer.fit(params, state_of(params))   # resumes if killed
        ctx.dense_bundle, ctx.dense_params = bundle, params
        ctx.histories[self.name] = trainer.history
        final = trainer.history[-1]["loss"] if trainer.history else None
        return {"final_loss": final}

    def restore(self, ctx: "_RunContext", index: int) -> None:
        bundle = build_model(ctx.arch, Mode.DENSE)
        ctx.dense_bundle = bundle
        ctx.dense_params = self._restore_params(ctx, index, bundle.param_specs())


@dataclasses.dataclass(frozen=True)
class CentroidInit(_Stage):
    KIND = "centroid_init"

    name: str = "centroid_init"
    sample_batches: int = 2
    sample_start: int = 10_000      # batch_at index of the first sample batch
    kmeans_iters: int = 25
    max_rows: int = 4096

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "CentroidInit":
        return cls(**{k: v for k, v in d.items() if k != "stage"})

    def run(self, ctx: "_RunContext", index: int) -> dict[str, Any]:
        ctx.log(f"[{self.name}] k-means centroid init from "
                f"{self.sample_batches} activation sample batches ...")
        samples = [ctx.data.batch_at(self.sample_start + i)
                   for i in range(self.sample_batches)]
        blut, lparams = convert.convert_dense_to_lut_train(
            ctx.dense_bundle, ctx.dense_params, samples, ctx.init_key,
            kmeans_iters=self.kmeans_iters, max_rows=self.max_rows,
        )
        # commit the initialized tree so resume never re-runs the tape
        Checkpointer(ctx.stage_dir(index, self), keep_last=1).save(
            0, {"params": lparams}, blocking=True
        )
        ctx.lut_bundle, ctx.lut_params = blut, lparams
        return {"lut_sites": len({s.path for s in blut.lut_sites()})}

    def restore(self, ctx: "_RunContext", index: int) -> None:
        self._restore_lut(ctx, index)


@dataclasses.dataclass(frozen=True)
class SoftPQ(_Stage):
    KIND = "soft_pq"

    name: str = "soft_pq"
    steps: int = 200
    optim: OptimSpec = OptimSpec(
        lr=1e-3, schedule="cosine", warmup_steps=10, rules="soft_pq"
    )
    distill: DistillSpec | None = None
    ckpt_every: int = 50
    log_every: int = 25
    compute_dtype: str = "float32"

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "SoftPQ":
        d = {k: v for k, v in d.items() if k != "stage"}
        d["optim"] = OptimSpec.from_dict(d["optim"])
        if d.get("distill") is not None:
            d["distill"] = DistillSpec.from_dict(d["distill"])
        return cls(**d)

    def run(self, ctx: "_RunContext", index: int) -> dict[str, Any]:
        blut, lparams = ctx.lut_bundle, ctx.lut_params
        frozen = lut_frozen_mask(lparams)
        opt = self.optim.build(self.steps)
        dt = _dtype(self.compute_dtype)
        if self.distill is not None and self.distill.weight > 0.0:
            ctx.log(f"[{self.name}] soft-PQ fine-tune {self.steps} steps, "
                    f"distilling vs frozen dense teacher "
                    f"(w={self.distill.weight}, tau={self.distill.temperature})")
            teacher_bundle, distill = ctx.dense_bundle, self.distill

            def step_with_teacher(params, opt_state, batch, teacher_params):
                # the teacher enters as a traced argument — a closure would
                # make jit bake the whole teacher tree into the executable
                # as constants (a second device-resident copy at scale)
                inner = make_train_step(
                    blut, opt, frozen_mask=frozen, compute_dtype=dt,
                    loss_fn=make_distill_loss_fn(
                        blut, distill, teacher_bundle, teacher_params,
                        compute_dtype=dt,
                    ),
                )
                return inner(params, opt_state, batch)

            jitted = jax.jit(step_with_teacher)
            teacher = ctx.dense_params
            step_fn = lambda p, s, b: jitted(p, s, b, teacher)
        else:
            ctx.log(f"[{self.name}] soft-PQ fine-tune {self.steps} steps")
            step_fn = jax.jit(make_train_step(
                blut, opt, frozen_mask=frozen, compute_dtype=dt,
            ))
        trainer = Trainer(
            step_fn=step_fn, batch_at=ctx.data.batch_at,
            cfg=TrainerConfig(
                total_steps=self.steps, ckpt_every=self.ckpt_every,
                ckpt_dir=str(ctx.stage_dir(index, self)), log_every=self.log_every,
            ),
            on_checkpoint=ctx.step_hook(index),
        )
        lparams, _ = trainer.fit(lparams, opt.init(lparams, frozen))
        ctx.lut_params = lparams
        ctx.histories[self.name] = trainer.history
        result = {}
        if trainer.history:
            last = trainer.history[-1]
            result = {k: last[k] for k in ("loss", "t_mean", "t_min", "distill_kl")
                      if k in last}
            result["final_loss"] = result.pop("loss")
        return result

    def restore(self, ctx: "_RunContext", index: int) -> None:
        self._restore_lut(ctx, index)


@dataclasses.dataclass(frozen=True)
class Deploy(_Stage):
    """Deploy stage; `target_plan` / `extra_plans` make the artifact
    multi-plan (DESIGN.md §14.1). Each value is JSON-round-trippable:
    a LUTPlan.to_dict payload, the sentinel "trained" (the arch's own
    effective plan), or {"keeping_dense": [kind patterns]} (the trained
    plan with those kinds kept dense). Every plan must be a sub-plan of
    the trained one — the spec-decode pairing is
    target_plan={"keeping_dense": ["attn/*"]}, extra_plans={"draft":
    "trained"}."""

    KIND = "deploy"

    name: str = "deploy"
    artifact_dir: str | None = None      # default: <ckpt_dir>/artifact
    target_plan: dict[str, Any] | str | None = None
    extra_plans: dict[str, dict[str, Any] | str] | None = None

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "Deploy":
        return cls(**{k: v for k, v in d.items() if k != "stage"})

    def _dir(self, ctx: "_RunContext") -> str:
        return self.artifact_dir or str(ctx.ckpt_dir / "artifact")

    @staticmethod
    def _plan(spec, arch):
        from repro.configs import effective_plan
        from repro.core.plan import LUTPlan

        if spec is None:
            return None
        if spec == "trained":
            return effective_plan(arch)
        if isinstance(spec, dict) and "keeping_dense" in spec:
            return effective_plan(arch).keeping_dense(*spec["keeping_dense"])
        return LUTPlan.from_dict(spec)

    def run(self, ctx: "_RunContext", index: int) -> dict[str, Any]:
        adir = self._dir(ctx)
        arch = ctx.lut_bundle.arch
        extras = {
            name: self._plan(spec, arch)
            for name, spec in (self.extra_plans or {}).items()
        }
        plans = " + ".join(["target"] + sorted(extras)) if extras else "target"
        ctx.log(f"[{self.name}] building + quantizing int8 tables "
                f"({plans}) -> {adir}")
        binf, iparams = convert.deploy_to_artifact(
            ctx.lut_bundle, ctx.lut_params, adir, recipe=ctx.recipe.to_dict(),
            target_plan=self._plan(self.target_plan, arch),
            extra_plans=extras or None,
        )
        ctx.inf_bundle, ctx.inf_params = binf, iparams
        ctx.artifact_dir = adir
        return {"artifact_dir": adir, "plans": ["target"] + sorted(extras)}

    def restore(self, ctx: "_RunContext", index: int) -> None:
        from repro.serving.artifact import load_artifact

        try:
            art = load_artifact(self._dir(ctx), restore_autotune=False)
            ctx.inf_bundle, ctx.inf_params = art.bundle, art.params
            ctx.artifact_dir = self._dir(ctx)
        except (FileNotFoundError, ValueError):
            # artifact deleted since the run completed (e.g. retracted by a
            # tripped Eval gate): re-deploy — a pure function of the
            # committed soft-PQ params
            self.run(ctx, index)


@dataclasses.dataclass(frozen=True)
class Eval(_Stage):
    """Deployed-model acceptance gate.

    Evaluates the int8-deployed model on `data.batch_at(batch_step)` and
    fails the run (RecipeError, manifest status "failed") if the loss
    exceeds `max_loss` or regresses more than `max_regression` past the
    dense teacher's loss on the same batch.
    """

    KIND = "eval"

    name: str = "eval"
    batch_step: int = 99_999
    max_loss: float | None = None
    max_regression: float | None = None
    compute_dtype: str = "float32"

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "Eval":
        return cls(**{k: v for k, v in d.items() if k != "stage"})

    def _reject(self, ctx: "_RunContext", reason: str) -> None:
        """Gate tripped: retract the already-written artifact so nothing
        downstream (serve jobs watching --artifact-dir) ships the deployment
        the gate just rejected, then fail the run."""
        if ctx.artifact_dir is not None:
            import shutil

            for suffix in ("", ".old"):
                shutil.rmtree(str(ctx.artifact_dir) + suffix, ignore_errors=True)
            ctx.log(f"[{self.name}] gate tripped — retracted artifact at "
                    f"{ctx.artifact_dir}")
        raise RecipeError(reason)

    def run(self, ctx: "_RunContext", index: int) -> dict[str, Any]:
        dt = _dtype(self.compute_dtype)
        batch = ctx.data.batch_at(self.batch_step)
        loss = float(ctx.inf_bundle.loss(ctx.inf_params, batch, compute_dtype=dt))
        result: dict[str, Any] = {"deployed_loss": loss}
        ctx.log(f"[{self.name}] deployed INT8 LUT eval loss: {loss:.4f}")
        if self.max_regression is not None:
            ref = float(ctx.dense_bundle.loss(
                ctx.dense_params, batch, compute_dtype=dt
            ))
            result["dense_loss"] = ref
            if loss > ref + self.max_regression:
                self._reject(ctx, (
                    f"eval gate: deployed loss {loss:.4f} regresses "
                    f"{loss - ref:.4f} past dense {ref:.4f} "
                    f"(max_regression={self.max_regression})"
                ))
        if self.max_loss is not None and loss > self.max_loss:
            self._reject(ctx, (
                f"eval gate: deployed loss {loss:.4f} > max_loss {self.max_loss}"
            ))
        return result

    def restore(self, ctx: "_RunContext", index: int) -> None:
        pass                       # result lives in the manifest


STAGE_TYPES: dict[str, type] = {
    c.KIND: c for c in (DensePretrain, CentroidInit, SoftPQ, Deploy, Eval)
}

# a stage KIND -> the stage KINDs at least one of which must appear earlier
_REQUIRES: dict[str, tuple[str, ...]] = {
    CentroidInit.KIND: (DensePretrain.KIND,),
    SoftPQ.KIND: (CentroidInit.KIND,),
    # direct-PQ deploy (no fine-tune) is a legitimate paper baseline
    Deploy.KIND: (CentroidInit.KIND,),
    Eval.KIND: (Deploy.KIND,),
}


# ---------------------------------------------------------------------------
# run context + manifest
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _RunContext:
    recipe: "Recipe"
    arch: ArchSpec
    data: Any                       # needs .batch_at(step)
    ckpt_dir: pathlib.Path
    init_key: jax.Array
    manifest: "_RunManifest"
    verbose: bool = True

    dense_bundle: Any = None
    dense_params: Any = None
    lut_bundle: Any = None
    lut_params: Any = None
    inf_bundle: Any = None
    inf_params: Any = None
    artifact_dir: str | None = None
    histories: dict[str, list] = dataclasses.field(default_factory=dict)

    def stage_dir(self, index: int, stage: _Stage) -> pathlib.Path:
        return self.ckpt_dir / f"{index:02d}_{stage.name}"

    def step_hook(self, index: int) -> Callable[[int], None]:
        return lambda step: self.manifest.set_step(index, step)

    def log(self, msg: str) -> None:
        if self.verbose:
            print(msg)


class _RunManifest:
    """Atomic per-run pipeline state: stage status + step + results.

    The manifest is advisory for humans and the resume dispatcher; the
    source of truth for *within-stage* position is each stage's own
    committed Checkpointer step (the manifest's `step` is synced on every
    checkpoint commit via Trainer.on_checkpoint).
    """

    def __init__(self, path: pathlib.Path, recipe: "Recipe",
                 arch_dict: dict[str, Any], seed: int,
                 data_fingerprint: str | None):
        self.path = path
        if path.exists():
            self.state = json.loads(path.read_text())
            if self.state.get("format") != RUN_FORMAT:
                raise RecipeError(f"{path} is not a recipe-run manifest")
            checks = [
                ("arch", arch_dict, "arch"),
                ("seed", seed, "seed"),
            ]
            # best-effort: only comparable when both sides have a stable
            # fingerprint (dataclass data sources like MarkovLM)
            if data_fingerprint is not None and self.state.get("data") is not None:
                checks.append(("data", data_fingerprint, "data configuration"))
            for field, want, what in checks:
                if self.state.get(field) != want:
                    raise RecipeError(
                        f"{path.parent} holds a run of a DIFFERENT {what} — "
                        "refusing to resume (use a fresh --ckpt-dir, or "
                        "re-invoke with the original arguments)"
                    )
            self._reconcile_recipe(recipe)
        else:
            self.state = {
                "format": RUN_FORMAT,
                "version": RUN_VERSION,
                "recipe": recipe.to_dict(),
                "arch": arch_dict,
                "seed": seed,
                "data": data_fingerprint,
                "stages": [
                    {"name": s.name, "kind": s.KIND, "status": "pending",
                     "step": None, "result": None}
                    for s in recipe.stages
                ],
            }
            self._write()

    def _write(self) -> None:
        atomic_write_json(self.path, self.state)

    def _reconcile_recipe(self, recipe: "Recipe") -> None:
        """Accept an invoked recipe that differs from the recorded one ONLY
        at stages that contributed no committed state (pending/failed) — so
        e.g. loosening a failed Eval gate resumes in place instead of
        forcing a full retrain. Stages already `done` (their outputs were
        produced under their recorded config) or `running` (their
        checkpoints replay under it) must match exactly."""
        new = recipe.to_dict()
        old = self.state["recipe"]
        if new == old:
            return
        entries = self.state["stages"]
        olds, news = old.get("stages", []), new["stages"]
        compatible = (
            old.get("version") == new["version"]
            and len(olds) == len(news) == len(entries)
            and all(o["stage"] == n["stage"] and o["name"] == n["name"]
                    for o, n in zip(olds, news))
            and all(o == n for o, n, e in zip(olds, news, entries)
                    if e["status"] in ("done", "running"))
        )
        if not compatible:
            raise RecipeError(
                f"{self.path.parent} holds a run of a DIFFERENT recipe — "
                "refusing to resume: only stages with no committed state "
                "(pending/failed) may change between invocations (use a "
                "fresh --ckpt-dir for a different pipeline)"
            )
        self.state["recipe"] = new
        self._write()

    def status(self, index: int) -> str:
        return self.state["stages"][index]["status"]

    def set_status(self, index: int, status: str,
                   result: dict[str, Any] | None = None) -> None:
        e = self.state["stages"][index]
        e["status"] = status
        if result is not None:
            e["result"] = result
        self._write()

    def set_step(self, index: int, step: int) -> None:
        self.state["stages"][index]["step"] = step
        self._write()


# ---------------------------------------------------------------------------
# the recipe
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecipeResult:
    """What `Recipe.run` hands back: the carried trees + the run record."""

    manifest: dict[str, Any]
    dense_bundle: Any = None
    dense_params: Any = None
    lut_bundle: Any = None
    lut_params: Any = None
    inf_bundle: Any = None
    inf_params: Any = None
    histories: dict[str, list] = dataclasses.field(default_factory=dict)

    def stage_result(self, name: str) -> dict[str, Any] | None:
        for e in self.manifest["stages"]:
            if e["name"] == name:
                return e["result"]
        return None


@dataclasses.dataclass(frozen=True)
class Recipe:
    stages: tuple[_Stage, ...]

    # ---------------- validation ----------------
    def validate(self) -> "Recipe":
        if not self.stages:
            raise RecipeError("recipe has no stages")
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise RecipeError(f"stage names must be unique, got {names}")
        for n in names:
            if not n or "/" in n or n != n.strip():
                raise RecipeError(f"invalid stage name {n!r}")
        seen: set[str] = set()
        for s in self.stages:
            need = _REQUIRES.get(s.KIND, ())
            if need and not any(k in seen for k in need):
                raise RecipeError(
                    f"stage {s.name!r} ({s.KIND}) requires an earlier "
                    f"{' or '.join(need)} stage"
                )
            seen.add(s.KIND)
        return self

    # ---------------- serialization ----------------
    def to_dict(self) -> dict[str, Any]:
        return {"version": 1, "stages": [s.to_dict() for s in self.stages]}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "Recipe":
        if d.get("version") != 1:
            raise RecipeError(f"unknown recipe version {d.get('version')!r}")
        stages = []
        for sd in d["stages"]:
            kind = sd.get("stage")
            if kind not in STAGE_TYPES:
                raise RecipeError(
                    f"unknown stage kind {kind!r} (have {sorted(STAGE_TYPES)})"
                )
            stages.append(STAGE_TYPES[kind].from_dict(sd))
        return cls(stages=tuple(stages)).validate()

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "Recipe":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str | os.PathLike) -> "Recipe":
        return cls.from_json(pathlib.Path(path).read_text())

    def save(self, path: str | os.PathLike) -> None:
        atomic_write_json(path, self.to_dict())

    def describe(self) -> str:
        bits = []
        for s in self.stages:
            extra = ""
            if isinstance(s, (DensePretrain, SoftPQ)):
                extra = f"[{s.steps}]"
                if isinstance(s, SoftPQ) and s.distill is not None:
                    extra += f"+distill(w={s.distill.weight})"
                if isinstance(s, DensePretrain) and s.grad_compression:
                    extra += "+int8grads"
            bits.append(f"{s.name}{extra}")
        return " -> ".join(bits)

    # ---------------- execution ----------------
    def run(
        self,
        arch: ArchSpec,
        data: Any,
        *,
        ckpt_dir: str | os.PathLike,
        seed: int = 0,
        verbose: bool = True,
    ) -> RecipeResult:
        """Execute (or resume) the pipeline under `ckpt_dir`.

        `data` supplies deterministic `batch_at(step)` batches — the same
        contract the Trainer's restart replay relies on, extended here to
        stage granularity: a killed run re-invoked with the same arguments
        resumes at the manifest's first unfinished stage, and inside a
        training stage at its newest committed checkpoint.
        """
        self.validate()
        from repro.configs import arch_to_dict

        ckpt_dir = pathlib.Path(ckpt_dir)
        ckpt_dir.mkdir(parents=True, exist_ok=True)
        # dataclass data sources (MarkovLM etc.) have a deterministic repr
        # capturing vocab/seq/batch/seed — fingerprint them so a resume with
        # different data flags is refused instead of silently diverging
        fp = (repr(data) if dataclasses.is_dataclass(data)
              and not isinstance(data, type) else None)
        manifest = _RunManifest(
            ckpt_dir / MANIFEST_NAME, self, arch_to_dict(arch), seed, fp
        )
        ctx = _RunContext(
            recipe=self, arch=arch, data=data, ckpt_dir=ckpt_dir,
            init_key=jax.random.PRNGKey(seed), manifest=manifest,
            verbose=verbose,
        )
        for i, stage in enumerate(self.stages):
            if manifest.status(i) == "done":
                stage.restore(ctx, i)
                ctx.log(f"[{stage.name}] already done — restored")
                continue
            manifest.set_status(i, "running")
            try:
                result = stage.run(ctx, i)
            except RecipeError as e:
                manifest.set_status(i, "failed", {"error": str(e)})
                raise
            manifest.set_status(i, "done", result)
        return RecipeResult(
            manifest=manifest.state,
            dense_bundle=ctx.dense_bundle, dense_params=ctx.dense_params,
            lut_bundle=ctx.lut_bundle, lut_params=ctx.lut_params,
            inf_bundle=ctx.inf_bundle, inf_params=ctx.inf_params,
            histories=ctx.histories,
        )


# ---------------------------------------------------------------------------
# the default recipe (what `launch/train.py` flags resolve to)
# ---------------------------------------------------------------------------

def default_recipe(
    *,
    steps: int = 200,
    lut: bool = True,
    artifact_dir: str | None = None,
    distill_weight: float = 0.0,
    distill_tau: float = 2.0,
    grad_accum: int = 1,
    grad_compression: bool = False,
    eval_max_regression: float | None = None,
    spec_draft: str | None = None,
) -> Recipe:
    """The historical `launch/train.py` pipeline as a Recipe: identical
    stage sequence and hyperparameters, so a fixed seed reproduces the
    pre-recipe driver's losses exactly.

    `spec_draft` bakes a two-plan deploy for speculative serving
    (DESIGN.md §14.1): the TRAINED plan ships as the "draft" and the
    target keeps the named kinds dense (comma-separated glob patterns,
    e.g. "attn/*") — one checkpoint, two plans, shared tables."""
    ckpt_every = max(50, steps // 4)
    dense = DensePretrain(
        steps=steps,
        optim=OptimSpec(lr=3e-3, schedule="cosine", warmup_steps=20),
        ckpt_every=ckpt_every, log_every=25,
        grad_accum=grad_accum, grad_compression=grad_compression,
    )
    if not lut:
        return Recipe(stages=(dense,)).validate()
    distill = (DistillSpec(weight=distill_weight, temperature=distill_tau)
               if distill_weight > 0.0 else None)
    deploy = Deploy(artifact_dir=artifact_dir)
    if spec_draft:
        kinds = [k.strip() for k in spec_draft.split(",") if k.strip()]
        deploy = dataclasses.replace(
            deploy,
            target_plan={"keeping_dense": kinds},
            extra_plans={"draft": "trained"},
        )
    return Recipe(stages=(
        dense,
        CentroidInit(sample_batches=2, sample_start=10_000),
        SoftPQ(
            steps=steps,
            optim=OptimSpec(
                lr=1e-3, schedule="cosine", warmup_steps=10,
                rules="distill" if distill else "soft_pq",
            ),
            distill=distill, ckpt_every=ckpt_every, log_every=25,
        ),
        deploy,
        Eval(batch_step=99_999, max_regression=eval_max_regression),
    )).validate()

"""Deterministic fault-injection harness for the serving stack (DESIGN.md §11.3).

Robustness code that is never exercised is decoration, so every failure mode
the serving layer claims to survive — slow steps, transient step exceptions,
a dead worker process — is injectable on demand, deterministically:

  * `FaultSpec`     — a declarative, JSON-round-trippable schedule of faults
                      (latency spikes, step exceptions, one worker kill).
  * `FaultInjector` — the live hook object built from a spec. The engine
                      calls `on_step()` at the top of every `step()`; the
                      injector sleeps (spike), raises `InjectedFault`
                      (transient error — retryable by `StepGuard` /
                      restartable by the supervisor), or raises
                      `InjectedKill` (simulated hard crash — a
                      `BaseException` so no `except Exception` guard can
                      accidentally absorb it; the supervised worker converts
                      it to `os._exit`).

Determinism: probabilistic faults are drawn from `random.Random` seeded with
`(spec.seed, call_index)`, where the call index is the injector's own
monotonic counter — NOT the engine step counter. A retried step therefore
advances to the next draw, which is exactly what a transient fault should
look like (fail once, succeed on retry), while the full draw sequence stays
byte-reproducible for a given seed. Tests and `benchmarks/serving_faults.py`
rely on this to compare faulty runs against fault-free ones.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Any


class InjectedFault(RuntimeError):
    """A transient, retryable step failure (classified retryable by
    `repro.distributed.fault_tolerance.is_retryable`)."""


class InjectedKill(BaseException):
    """A simulated hard worker crash.

    Deliberately a `BaseException` (like `KeyboardInterrupt`): retry guards
    catching `Exception` must not absorb a dead process. The supervised
    worker turns it into `os._exit`; in-process harnesses catch it
    explicitly.
    """


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative fault schedule. All-zero defaults inject nothing."""

    seed: int = 0
    spike_p: float = 0.0                 # P(latency spike) per on_step call
    spike_s: float = 0.02                # spike duration (sleep)
    error_p: float = 0.0                 # P(InjectedFault) per on_step call
    error_steps: tuple[int, ...] = ()    # explicit call indices that raise
    kill_at_step: int | None = None      # call index that raises InjectedKill

    def __post_init__(self) -> None:
        for name in ("spike_p", "error_p"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name}={p} must be a probability")
        if self.spike_s < 0:
            raise ValueError(f"spike_s={self.spike_s} must be >= 0")

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["error_steps"] = list(self.error_steps)
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "FaultSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in known}
        if "error_steps" in kw:
            kw["error_steps"] = tuple(kw["error_steps"])
        return cls(**kw)

    @property
    def active(self) -> bool:
        return bool(
            self.spike_p or self.error_p or self.error_steps
            or self.kill_at_step is not None
        )


class FaultInjector:
    """Live hook object; one per engine/worker incarnation.

    `events` records every injected fault as `(call_index, kind)` so tests
    and benchmarks can assert exactly what fired.
    """

    def __init__(self, spec: FaultSpec, *, sleep=time.sleep):
        self.spec = spec
        self.calls = 0
        self.events: list[tuple[int, str]] = []
        self._sleep = sleep

    def _draw(self, n: int, channel: str) -> float:
        # independent stream per (seed, call, channel): a spike draw never
        # perturbs the error draw sequence
        return random.Random((self.spec.seed, n, channel)).random()

    def on_step(self) -> None:
        """Engine hook, called at the top of every `ServingEngine.step()`.

        May sleep (latency spike), raise `InjectedFault` (transient), or
        raise `InjectedKill` (hard crash). At most one fault fires per call;
        kill > error > spike when schedules collide.
        """
        n = self.calls
        self.calls += 1
        s = self.spec
        if s.kill_at_step is not None and n == s.kill_at_step:
            self.events.append((n, "kill"))
            raise InjectedKill(f"injected worker kill at call {n}")
        if n in s.error_steps or (s.error_p and self._draw(n, "err") < s.error_p):
            self.events.append((n, "error"))
            raise InjectedFault(f"injected step fault at call {n}")
        if s.spike_p and self._draw(n, "spike") < s.spike_p:
            self.events.append((n, "spike"))
            self._sleep(s.spike_s)

    def counts(self) -> dict[str, int]:
        out = {"kill": 0, "error": 0, "spike": 0}
        for _, kind in self.events:
            out[kind] += 1
        return out

"""Trip-count-aware cost model over optimized (post-SPMD) HLO text.

XLA's built-in `compiled.cost_analysis()` counts each `while` body ONCE —
for scan-over-layers models that undercounts flops/bytes/collectives by the
layer count (verified empirically; see EXPERIMENTS.md section Roofline,
"methodology"). This walker parses the per-device optimized HLO and:

  * multiplies every computation reached through `while(...)` by the loop's
    `backend_config={"known_trip_count":{"n":...}}`,
  * charges dot/convolution MACs exactly (2 * prod(out) * prod(contract)),
  * charges elementwise/reduce ops 1 flop/element,
  * charges HBM traffic at fusion boundaries (operands + outputs of
    top-level ops; fusion-internal ops count flops only),
  * accumulates collective wire bytes (ring model: all-reduce 2x) with the
    loop multiplier applied.

This is a static roofline model, not a simulator: no overlap, no cache
reuse between ops. It is the measurement tool the perf loop (section Perf)
iterates against.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)\(([^)]*)\)(.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_ZERO_COST = (
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "custom-call",
    "bitcast-convert",
)


def _dims(shape_str: str) -> list[tuple[str, list[int]]]:
    return [
        (dt, [int(x) for x in dims.split(",") if x])
        for dt, dims in _SHAPE_RE.findall(shape_str)
    ]


def _numel(shape_str: str) -> float:
    total = 0.0
    for _, dims in _dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _dims(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost") -> "Cost":
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, {k: v * m for k, v in self.coll.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    kind: str
    operands: list[str]
    attrs: str


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[_Op]] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._cache: dict[str, Cost] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: str | None = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line)
            if mc:
                cur = mc.group(1)
                self.comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            mo = _OP_RE.match(line)
            if mo:
                name, shape, kind, operands, attrs = mo.groups()
                ops = [o.strip().lstrip("%") for o in operands.split(",") if o.strip().startswith("%")]
                self.comps[cur].append(_Op(name, shape, kind, ops, attrs))

    # ------------------------------------------------------------------
    def _op_table(self, comp: str) -> dict[str, _Op]:
        return {op.name: op for op in self.comps[comp]}

    def _dot_flops(self, op: _Op, table: dict[str, _Op]) -> float:
        out_n = _numel(op.shape)
        contract = 1
        m = _LHS_C_RE.search(op.attrs)
        if m and op.operands:
            lhs = table.get(op.operands[0])
            if lhs is not None:
                ds = _dims(lhs.shape)
                if ds:
                    dims = ds[0][1]
                    for i in (int(x) for x in m.group(1).split(",") if x):
                        if i < len(dims):
                            contract *= dims[i]
        return 2.0 * out_n * contract

    def _fusion_param_bytes(self, comp: str) -> dict[int, float]:
        """For each parameter of a fused computation that is ONLY touched by
        slice-like ops, the bytes actually read (region size), not the full
        operand — a scan slicing one layer from a stacked tree must not be
        charged the whole stack."""
        ops = self.comps[comp]
        param_idx: dict[str, int] = {}
        # parameter order of appearance == operand index order in HLO text
        order = [op.name for op in ops if op.kind == "parameter"]
        for i, nm in enumerate(order):
            param_idx[nm] = i
        consumers: dict[str, list[_Op]] = {}
        for op in ops:
            for o in op.operands:
                consumers.setdefault(o, []).append(op)
        out: dict[int, float] = {}
        slice_kinds = ("dynamic-slice", "gather", "dynamic-update-slice")
        for nm, i in param_idx.items():
            cons = consumers.get(nm, [])
            if cons and all(k.kind in slice_kinds for k in cons):
                total = 0.0
                for k in cons:
                    if k.kind == "dynamic-update-slice" and k.operands and k.operands[0] == nm:
                        continue  # aliased in-place destination
                    total += _bytes(k.shape)
                out[i] = total
        return out

    def _fusion_alias(self, comp: str) -> tuple[float | None, dict[int, float]]:
        """Detect in-place loop-buffer updates inside a fusion: a dus/scatter
        whose destination traces (through convert/bitcast/copy) to a fusion
        parameter. The buffer aliases in place on TPU, so both the fusion
        output and that parameter cost only the update-region bytes."""
        ops = self.comps[comp]
        table = self._op_table(comp)
        order = [op.name for op in ops if op.kind == "parameter"]
        pidx = {nm: i for i, nm in enumerate(order)}

        def trace(name: str) -> str | None:
            seen = 0
            while name in table and seen < 10:
                o = table[name]
                if o.kind == "parameter":
                    return o.name
                if o.kind in ("convert", "bitcast", "copy", "reshape") and o.operands:
                    name = o.operands[0]
                    seen += 1
                    continue
                return None
            return None

        out_override = None
        alias_params: dict[int, float] = {}
        for op in ops:
            if op.kind not in ("dynamic-update-slice", "scatter", "scatter-add"):
                continue
            un = (
                op.operands[1]
                if op.kind == "dynamic-update-slice" and len(op.operands) > 1
                else (op.operands[-1] if op.operands else None)
            )
            u = table.get(un) if un else None
            upd_b = _bytes(u.shape) if u is not None else _bytes(op.shape) * 0.05
            dest = trace(op.operands[0]) if op.operands else None
            if dest is not None and dest in pidx:
                alias_params[pidx[dest]] = 2.0 * upd_b
                out_override = (out_override or 0.0) + 2.0 * upd_b
        return out_override, alias_params

    def comp_cost(self, comp: str, *, count_bytes: bool = True) -> Cost:
        key = f"{comp}|{count_bytes}"
        if key in self._cache:
            return self._cache[key]
        total = Cost()
        table = self._op_table(comp)
        for op in self.comps[comp]:
            total += self._op_cost(op, table, count_bytes=count_bytes)
        self._cache[key] = total
        return total

    def _op_cost(self, op: _Op, table: dict[str, _Op], *, count_bytes: bool) -> Cost:
        kind = op.kind
        c = Cost()
        if kind in _ZERO_COST:
            return c

        def boundary_bytes() -> float:
            b = _bytes(op.shape)
            for o in op.operands:
                src = table.get(o)
                if src is not None and src.kind not in ("constant",):
                    b += _bytes(src.shape)
            return b

        if kind == "while":
            mb = _BODY_RE.search(op.attrs)
            mc = _COND_RE.search(op.attrs)
            mt = _TRIP_RE.search(op.attrs)
            trips = float(mt.group(1)) if mt else 1.0
            inner = Cost()
            if mb and mb.group(1) in self.comps:
                inner += self.comp_cost(mb.group(1), count_bytes=count_bytes)
            if mc and mc.group(1) in self.comps:
                inner += self.comp_cost(mc.group(1), count_bytes=count_bytes)
            return inner.scaled(trips)

        if kind == "conditional":
            mb = _BRANCHES_RE.search(op.attrs)
            if mb:
                branch_costs = []
                for name in mb.group(1).split(","):
                    name = name.strip().lstrip("%")
                    if name in self.comps:
                        branch_costs.append(self.comp_cost(name, count_bytes=count_bytes))
                if branch_costs:
                    worst = max(branch_costs, key=lambda x: x.flops + x.bytes)
                    c += worst
            return c

        if kind == "fusion":
            mcalls = _CALLS_RE.search(op.attrs)
            called = mcalls.group(1) if mcalls and mcalls.group(1) in self.comps else None
            if called:
                inner = self.comp_cost(called, count_bytes=False)
                c.flops += inner.flops
                for k, v in inner.coll.items():
                    c.coll[k] = c.coll.get(k, 0.0) + v
            if count_bytes:
                out_b = _bytes(op.shape)
                touched: dict[int, float] = {}
                if called:
                    out_override, alias_params = self._fusion_alias(called)
                    if out_override is not None:
                        out_b = min(out_b, out_override)
                    touched.update(self._fusion_param_bytes(called))
                    touched.update(alias_params)
                c.bytes += out_b
                for i, o in enumerate(op.operands):
                    src = table.get(o)
                    if src is None or src.kind == "constant":
                        continue
                    full = _bytes(src.shape)
                    c.bytes += min(full, touched[i]) if i in touched else full
            return c

        if kind == "call":
            mcalls = _CALLS_RE.search(op.attrs) or _BODY_RE.search(op.attrs)
            target = None
            m2 = re.search(r"to_apply=%?([\w.\-]+)", op.attrs)
            if m2:
                target = m2.group(1)
            elif mcalls:
                target = mcalls.group(1)
            if target and target in self.comps:
                c += self.comp_cost(target, count_bytes=count_bytes)
            return c

        if any(kind.startswith(col) for col in COLLECTIVES):
            if kind.endswith("-done"):
                return c
            base = kind.replace("-start", "")
            wire = _bytes(op.shape)
            if base == "all-reduce":
                wire *= 2.0
            c.coll[base] = c.coll.get(base, 0.0) + wire
            if count_bytes:
                c.bytes += boundary_bytes()
            return c

        if kind in ("dot", "dot-general"):
            c.flops += self._dot_flops(op, table)
            if count_bytes:
                c.bytes += boundary_bytes()
            return c

        if kind == "convolution":
            # rough: 2 * out_elems * (in_channels * kernel_elems) — parse window
            c.flops += 2.0 * _numel(op.shape) * 1.0
            if count_bytes:
                c.bytes += boundary_bytes()
            return c

        if kind in ("dynamic-slice", "gather"):
            # touches only the sliced/gathered region, not the whole operand
            c.flops += _numel(op.shape)
            if count_bytes:
                c.bytes += 2.0 * _bytes(op.shape)
            return c

        if kind in ("dynamic-update-slice", "scatter", "scatter-add"):
            # reads the update + indices, writes the updated region;
            # the big operand aliases in place (donation).
            # dus operands: (operand, update, idx...); scatter: (operand,
            # indices, updates)
            upd_name = None
            if kind == "dynamic-update-slice" and len(op.operands) >= 2:
                upd_name = op.operands[1]
            elif op.operands:
                upd_name = op.operands[-1]
            upd = table.get(upd_name) if upd_name else None
            upd_b = _bytes(upd.shape) if upd is not None else _bytes(op.shape)
            c.flops += _numel(upd.shape) if upd is not None else _numel(op.shape)
            if count_bytes:
                c.bytes += 2.0 * upd_b
            return c

        if kind == "convert":
            # dtype converts fuse into their consumers on TPU (and exist on
            # the CPU backend only because CPU dots can't consume bf16)
            c.flops += _numel(op.shape)
            return c

        if kind == "reduce" or kind.startswith("reduce-window"):
            inp = table.get(op.operands[0]) if op.operands else None
            c.flops += _numel(inp.shape) if inp is not None else _numel(op.shape)
            if count_bytes:
                c.bytes += boundary_bytes()
            return c

        # elementwise / data movement default
        c.flops += _numel(op.shape)
        if count_bytes and kind not in ("broadcast", "reshape", "transpose", "copy-start", "copy-done"):
            c.bytes += boundary_bytes()
        if count_bytes and kind == "copy":
            c.bytes += 2 * _bytes(op.shape)
        return c

    # ------------------------------------------------------------------
    def total(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze_hlo_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()


_META_RE = re.compile(r'op_name="([^"]+)"')


def hotspots(hlo_text: str, *, top: int = 25, depth: int = 4) -> list[tuple[str, Cost]]:
    """Aggregate cost by (truncated) jax op_name metadata — the 'profile'
    the section-Perf hypothesis loop reads. Loop multipliers applied."""
    model = HloCostModel(hlo_text)
    sums: dict[str, Cost] = {}

    def visit(comp: str, mult: float):
        table = model._op_table(comp)
        for op in model.comps[comp]:
            if op.kind == "while":
                mb = _BODY_RE.search(op.attrs)
                mc = _COND_RE.search(op.attrs)
                mt = _TRIP_RE.search(op.attrs)
                trips = float(mt.group(1)) if mt else 1.0
                for m in (mb, mc):
                    if m and m.group(1) in model.comps:
                        visit(m.group(1), mult * trips)
                continue
            if op.kind == "call":
                m2 = re.search(r"to_apply=%?([\w.\-]+)", op.attrs)
                if m2 and m2.group(1) in model.comps:
                    visit(m2.group(1), mult)
                continue
            c = model._op_cost(op, table, count_bytes=True)
            mm = _META_RE.search(op.attrs)
            if mm is None and op.kind == "fusion":
                # attribute the fusion to its root op's metadata
                mcalls = _CALLS_RE.search(op.attrs)
                if mcalls and mcalls.group(1) in model.comps:
                    for inner in model.comps[mcalls.group(1)]:
                        m2 = _META_RE.search(inner.attrs)
                        if m2:
                            mm = m2
            name = mm.group(1) if mm else f"<{op.kind}>"
            key = "/".join(name.split("/")[:depth])
            agg = sums.setdefault(key, Cost())
            agg.flops += c.flops * mult
            agg.bytes += c.bytes * mult
            for k, v in c.coll.items():
                agg.coll[k] = agg.coll.get(k, 0.0) + v * mult

    assert model.entry
    visit(model.entry, 1.0)
    ranked = sorted(sums.items(), key=lambda kv: -(kv[1].bytes + kv[1].coll_bytes * 16))
    return ranked[:top]

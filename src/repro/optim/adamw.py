"""AdamW with path-based parameter groups (pure JAX, no optax).

Soft-PQ training needs three groups (paper Table 3):
  * centroids      — the "centroid learning rate" (1e-3 / 1e-4)
  * log_t          — the temperature learning rate (1e-1), no weight decay
  * frozen weights — the dense weights of replaced layers get NO optimizer
                     state and NO updates (their table-rebuild gradient is
                     already stop_grad'ed; skipping m/v saves 8 bytes/param,
                     which matters at 400B scale)

Group membership for lr/wd is regex-over-path; frozen-ness is *structural*:
a "w"/"b" leaf is frozen iff its parent dict also holds "centroids" (i.e. it
is the dense weight a LUT site was built from). Frozen leaves carry
zero-size (0,) placeholder moments so the opt-state pytree structure stays
static for jit and checkpointing.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GroupRule:
    """First matching rule wins. `pattern` is a regex over the 'a/b/c' path."""

    pattern: str
    lr_scale: float = 1.0
    weight_decay: float | None = None       # None -> optimizer default


# paper Table 3: temperature lr = 1e-1 while centroid lr = 1e-3  (100x), wd=0
# on temperature and norm scales.
SOFT_PQ_RULES = (
    GroupRule(pattern=r"log_t$", lr_scale=100.0, weight_decay=0.0),
    GroupRule(pattern=r"(scale|norm|bias|_b|/b)$", weight_decay=0.0),
)

# Distillation fine-tune (recipe SoftPQ(distill=...), DESIGN.md §10.3): the
# soft-PQ groups plus a slow group for the token embedding and output head.
# The KL target is the frozen dense teacher's logit distribution; letting the
# head/embedding chase the KL term at the full centroid lr drifts the
# student's logit scale away from the teacher it is being matched to, so
# those leaves move at 0.1x (and keep wd=0: they are shared with the CE
# term's calibration).
DISTILL_RULES = SOFT_PQ_RULES + (
    GroupRule(pattern=r"(embed|lm_head)", lr_scale=0.1, weight_decay=0.0),
)


def lut_frozen_mask(params: Any) -> Any:
    """True for dense weights that live alongside centroids (LUT_TRAIN)."""

    def walk(node, frozen: bool):
        if isinstance(node, dict):
            has_c = "centroids" in node
            return {
                k: walk(v, frozen or (has_c and k in ("w", "b")))
                for k, v in node.items()
            }
        if isinstance(node, (list, tuple)):
            out = [walk(v, frozen) for v in node]
            return type(node)(out)
        return frozen

    return walk(params, False)


def _path_of(keypath) -> str:
    parts = []
    for k in keypath:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    rules: tuple[GroupRule, ...] = ()
    clip_norm: float | None = 1.0
    state_dtype: Any = jnp.float32          # bf16 for the giant archs

    def _rule(self, path: str) -> GroupRule:
        for r in self.rules:
            if re.search(r.pattern, path):
                return r
        return GroupRule(pattern="")

    def init(self, params: Any, frozen: Any | None = None) -> AdamWState:
        if frozen is None:
            frozen = jax.tree.map(lambda _: False, params)

        def mk(p, fz):
            if fz:
                return jnp.zeros((0,), self.state_dtype)
            return jnp.zeros(p.shape, self.state_dtype)

        m = jax.tree.map(mk, params, frozen)
        v = jax.tree.map(mk, params, frozen)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v)

    def update(
        self, grads: Any, state: AdamWState, params: Any, frozen: Any | None = None
    ):
        if frozen is None:
            frozen = jax.tree.map(lambda _: False, params)
        step = state.step + 1
        lr_t = self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

        if self.clip_norm is not None:
            sq = jax.tree.map(
                lambda g, fz: jnp.zeros((), jnp.float32) if fz
                else jnp.sum(g.astype(jnp.float32) ** 2),
                grads, frozen,
            )
            gnorm = jnp.sqrt(jax.tree.reduce(jnp.add, sq))
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        else:
            gnorm = jnp.zeros((), jnp.float32)
            scale = jnp.ones((), jnp.float32)

        bc1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(kp, p, g, m, v, fz):
            if fz:
                return p, m, v
            rule = self._rule(_path_of(kp))
            g32 = g.astype(jnp.float32) * scale
            m_new = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g32
            v_new = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g32 * g32
            mh = m_new / bc1
            vh = v_new / bc2
            wd = self.weight_decay if rule.weight_decay is None else rule.weight_decay
            delta = mh / (jnp.sqrt(vh) + self.eps) + wd * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr_t * rule.lr_scale * delta
            return (
                p_new.astype(p.dtype),
                m_new.astype(self.state_dtype),
                v_new.astype(self.state_dtype),
            )

        flat = jax.tree_util.tree_map_with_path(upd, params, grads, state.m, state.v, frozen)
        is3 = lambda t: isinstance(t, tuple) and len(t) == 3 and isinstance(t[0], jax.Array)
        new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=is3)
        new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=is3)
        new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=is3)
        return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm

"""Mesh-sharded ServingEngine (DESIGN.md §6.4), under forced host devices:
params land on `distributed.sharding`'s specs (table_q column-sharded over
"model"), caches shard on the slot axis, and decode output is token-identical
to the unsharded engine — including when the params come from a LUTArtifact."""

import textwrap

from tests._subproc import run_with_devices


def test_sharded_engine_matches_unsharded_tp2():
    out = run_with_devices(
        textwrap.dedent(
            """
            import jax
            from repro.configs import build_model, get_arch, reduce_arch
            from repro.core.amm import Mode
            from repro.launch.mesh import make_host_mesh
            from repro.serving.engine import ServingEngine

            arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
            bundle = build_model(arch, Mode.LUT_INFER)
            params = bundle.init(jax.random.PRNGKey(0))

            ref = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                                prefill_chunk=4, autotune_lut=False)
            mesh = make_host_mesh(data=1, model=2)
            assert mesh.shape["model"] == 2
            eng = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                                prefill_chunk=4, autotune_lut=False, mesh=mesh)

            from repro.checkpoint.checkpointer import tree_paths

            def paths(tree):
                return dict(zip(tree_paths(tree),
                                jax.tree_util.tree_leaves(tree)))

            # every param leaf carries exactly the spec sharding.py assigns;
            # column-parallel LUT sites are M-sharded over "model"
            tq = [(p, l) for p, l in paths(eng.params).items()
                  if p.endswith("table_q")]
            assert tq
            n_col = 0
            for p, l in tq:
                spec = l.sharding.spec
                assert spec == eng.rules.param_spec(p, l.shape), (p, spec)
                n_col += spec[-1] == "model"
            assert n_col > 0, "no table_q leaf column-sharded over model"
            # scales/centroids of column-parallel sites stay replicated
            for p, l in paths(eng.params).items():
                if p.endswith("table_scale"):
                    assert all(s is None for s in l.sharding.spec), (p, l.sharding)

            # KV caches shard on the slot/batch axis (dim 1 of (L,B,S,KV,Dh))
            for p, l in paths(eng.caches).items():
                assert l.sharding.spec[1] == "data", (p, l.sharding.spec)

            # decode parity: chunked prefill + decode, multiple slots
            for e in (ref, eng):
                e.submit([1, 2, 3, 4, 5, 6, 7], max_tokens=6)
                e.submit([9, 8, 7], max_tokens=6)
            o_ref = [r.out_tokens for r in
                     sorted(ref.run_until_done(), key=lambda r: r.rid)]
            o_tp = [r.out_tokens for r in
                    sorted(eng.run_until_done(), key=lambda r: r.rid)]
            assert o_ref == o_tp, (o_ref, o_tp)
            print("SHARDED_ENGINE_OK")
            """
        ),
        n_devices=2,
    )
    assert "SHARDED_ENGINE_OK" in out


def test_paged_sharded_engine_matches_dense_tp2():
    """Paged engine under a 2-device mesh: pool leaves shard on the page
    axis (KV heads over "model"), and tokens are byte-identical to the
    unsharded dense engine — including requests that hit the prefix cache
    and the fully-cached-prompt COW path."""
    out = run_with_devices(
        textwrap.dedent(
            """
            import jax
            from repro.configs import build_model, get_arch, reduce_arch
            from repro.core.amm import Mode
            from repro.launch.mesh import make_host_mesh
            from repro.serving.engine import ServingEngine

            arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
            bundle = build_model(arch, Mode.LUT_INFER)
            params = bundle.init(jax.random.PRNGKey(0))

            ref = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                                prefill_chunk=4, autotune_lut=False)
            mesh = make_host_mesh(data=1, model=2)
            eng = ServingEngine(bundle, params, n_slots=2, max_seq=32,
                                prefill_chunk=4, autotune_lut=False,
                                mesh=mesh, paged=True, page_size=4)

            from repro.checkpoint.checkpointer import tree_paths

            pool = [(p, l) for p, l in zip(tree_paths(eng.caches),
                                           jax.tree_util.tree_leaves(eng.caches))
                    if p.endswith("_pool")]
            assert pool, "paged engine has no pool leaves"
            for p, l in pool:
                want = eng.rules.cache_spec(p, l.shape, 2)
                assert l.sharding.spec == want, (p, l.sharding.spec, want)
                assert l.sharding.spec[3] == "model", (p, l.sharding.spec)

            # same prompt twice -> prefix hit; the page-aligned prompt is
            # fully cached on resubmit -> clamp + copy-on-write
            prompts = [[1, 2, 3, 4, 5, 6, 7], [9, 8, 7],
                       [1, 2, 3, 4, 5, 6, 7], [1, 2, 3, 4]]
            for e in (ref, eng):
                for p in prompts:
                    e.submit(p, max_tokens=5)
            o_ref = [(r.rid, r.out_tokens) for r in
                     sorted(ref.run_until_done(), key=lambda r: r.rid)]
            o_tp = [(r.rid, r.out_tokens) for r in
                    sorted(eng.run_until_done(), key=lambda r: r.rid)]
            assert o_ref == o_tp, (o_ref, o_tp)
            st = eng.stats()
            assert st["prefill_tokens_skipped"] > 0, st
            print("PAGED_TP_OK")
            """
        ),
        n_devices=2,
    )
    assert "PAGED_TP_OK" in out


def test_artifact_to_sharded_engine_tp2(tmp_path):
    """The full deploy hand-off onto a mesh: artifact saved single-device,
    loaded in a 2-device process, served tensor-parallel — same tokens."""
    out = run_with_devices(
        textwrap.dedent(
            f"""
            import jax
            from repro.configs import build_model, get_arch, reduce_arch
            from repro.core.amm import Mode
            from repro.launch.mesh import make_host_mesh
            from repro.serving.artifact import load_artifact, save_artifact
            from repro.serving.engine import ServingEngine

            arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
            bundle = build_model(arch, Mode.LUT_INFER)
            params = bundle.init(jax.random.PRNGKey(0))
            save_artifact({str(tmp_path)!r} + "/art", bundle, params)
            art = load_artifact({str(tmp_path)!r} + "/art")

            mesh = make_host_mesh(data=1, model=2)
            engines = [
                ServingEngine(bundle, params, n_slots=2, max_seq=32,
                              prefill_chunk=4, autotune_lut=False),
                ServingEngine(art.bundle, art.params, n_slots=2, max_seq=32,
                              prefill_chunk=4, autotune_lut=False, mesh=mesh),
            ]
            outs = []
            for e in engines:
                e.submit([1, 2, 3, 4, 5], max_tokens=5)
                outs.append([r.out_tokens for r in e.run_until_done()])
            assert outs[0] == outs[1], outs
            print("ARTIFACT_TP_OK")
            """
        ),
        n_devices=2,
    )
    assert "ARTIFACT_TP_OK" in out

"""Architecture registry: 10 assigned archs + the paper's BERT-base.

Each `configs/<id>.py` defines `ARCH: ArchSpec` with the exact published
dims. `build_model(arch, mode)` assembles the model (LM / hybrid / enc-dec)
with every linear site resolved to dense or LUT per the paper's replacement
policy; `input_specs(arch, shape)` produces ShapeDtypeStruct stand-ins for
the four assigned input shapes (train_4k / prefill_32k / decode_32k /
long_500k).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.amm import LUTConfig, Mode
from repro.models import attention as attn_mod
from repro.models import encdec as encdec_mod
from repro.models import hybrid as hybrid_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import transformer as tf_mod
from repro.models.common import SiteCfg


# ---------------------------------------------------------------------------
# arch spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    act: str = "silu"
    mlp_gated: bool = True
    qk_norm: bool = False
    use_bias: bool = False
    causal: bool = True
    rope_theta: float = 500_000.0
    mrope_sections: tuple[int, ...] = ()
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_shared_expert: bool = False
    moe_dense_residual: bool = False
    moe_group_tokens: int = 1024        # routing-group size (section Perf M1)
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 256
    # hybrid
    attn_every: int = 0
    # enc-dec (audio)
    n_enc_layers: int = 0
    enc_frames: int = 0
    takes_embeds: bool = False       # stub frontend provides embeddings
    # LUT-NN settings (paper defaults: K=16, V aligned to site width, INT8)
    lut_k: int = 16
    lut_v: int = 32
    lut_bits: int = 8
    lut_int8_dot: bool = False          # integer one-hot contraction (section Perf)
    lut_use_kernel: bool = False        # fused Pallas v2 kernel at LUT sites (DESIGN.md §2.3)
    lut_policy: str = "all_but_first"   # or "last_n:<n>" (BERT, Fig. 13), "all"
    # scale/precision policy for the production dry-run
    param_dtype: str = "float32"        # giants use bfloat16 (DESIGN.md section 5)
    kv_cache_dtype: str = "bfloat16"    # "float8_e4m3fn" halves decode cache reads
    sub_quadratic: bool = False         # eligible for long_500k
    grad_accum: int = 1                 # microbatching for the train dry-run
    notes: str = ""

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model


# ---------------------------------------------------------------------------
# shapes (assigned to all LM-family archs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

ARCH_IDS = (
    "mamba2_370m",
    "llama3_8b",
    "minitron_8b",
    "qwen3_1p7b",
    "command_r_35b",
    "llama4_maverick_400b",
    "arctic_480b",
    "qwen2_vl_7b",
    "whisper_tiny",
    "zamba2_1p2b",
)
EXTRA_IDS = ("bert_base",)           # paper's own model, benchmarks only


def get_arch(name: str) -> ArchSpec:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.ARCH


# ---------------------------------------------------------------------------
# arch-spec serialization (deployment artifacts, DESIGN.md §8.1)
# ---------------------------------------------------------------------------

def arch_to_dict(arch: ArchSpec) -> dict[str, Any]:
    """JSON-safe dict of every ArchSpec field (tuples become lists)."""
    out = dataclasses.asdict(arch)
    for k, v in out.items():
        if isinstance(v, tuple):
            out[k] = list(v)
    return out


def arch_from_dict(d: dict[str, Any]) -> ArchSpec:
    """Rebuild an ArchSpec from `arch_to_dict` output.

    Unknown keys (written by a newer repo) are ignored so old readers stay
    forward-compatible; list-valued fields are restored to tuples.
    """
    fields = {f.name: f for f in dataclasses.fields(ArchSpec)}
    kw: dict[str, Any] = {}
    for k, v in d.items():
        if k not in fields:
            continue
        if isinstance(v, list):
            v = tuple(v)
        kw[k] = v
    missing = [
        n for n, f in fields.items()
        if n not in kw
        and f.default is dataclasses.MISSING
        and f.default_factory is dataclasses.MISSING
    ]
    if missing:
        raise ValueError(f"arch dict missing required fields: {missing}")
    return ArchSpec(**kw)


def all_archs() -> list[ArchSpec]:
    return [get_arch(n) for n in ARCH_IDS]


def shape_applicable(arch: ArchSpec, shape: str) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason if skipped (DESIGN.md §4)."""
    if shape == "long_500k" and not arch.sub_quadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic mixing"
    return True, ""


def reduce_arch(arch: ArchSpec, **overrides: Any) -> ArchSpec:
    """Shrink an arch to a CPU-smoke-testable config of the same family.

    Keeps every structural feature (GQA ratio, qk-norm, MoE top-k, SSD,
    shared block, enc-dec, M-RoPE) while cutting width/depth/vocab.
    """
    small: dict[str, Any] = dict(
        n_layers=min(arch.n_layers, 4),
        d_model=128,
        d_ff=0 if arch.d_ff == 0 else 256,
        vocab=512,
        param_dtype="float32",
        grad_accum=1,
    )
    if arch.n_heads:
        small.update(n_heads=4, n_kv_heads=min(arch.n_kv_heads, 2) if arch.n_kv_heads < arch.n_heads else 4, d_head=32)
    if arch.n_experts:
        small.update(n_experts=4, top_k=arch.top_k)
    if arch.ssm_state:
        small.update(ssm_state=16, ssm_head_dim=16, ssd_chunk=8)
    if arch.attn_every:
        small.update(attn_every=2)
    if arch.n_enc_layers:
        small.update(n_enc_layers=2, enc_frames=8)
    if arch.mrope_sections:
        small.update(mrope_sections=(4, 6, 6))
    small.update(lut_v=16)
    small.update(overrides)
    return dataclasses.replace(arch, **small)


# ---------------------------------------------------------------------------
# model assembly
# ---------------------------------------------------------------------------

def _lut(arch: ArchSpec, d_in: int) -> LUTConfig:
    v = arch.lut_v
    while d_in % v:
        v //= 2
    return LUTConfig(
        k=arch.lut_k, v=v, bits=arch.lut_bits,
        int8_dot=arch.lut_int8_dot, use_kernel=arch.lut_use_kernel,
    )


def _site(arch: ArchSpec, d_in: int, d_out: int, mode: Mode, name: str = "") -> SiteCfg:
    return SiteCfg(d_in=d_in, d_out=d_out, mode=mode, lut=_lut(arch, d_in),
                   bias=arch.use_bias, name=name)


def _attn_cfg(arch: ArchSpec, mode: Mode, *, causal=None, cross=False) -> attn_mod.AttnCfg:
    d, h, kv, dh = arch.d_model, arch.n_heads, arch.n_kv_heads, arch.d_head
    return attn_mod.AttnCfg(
        d_model=d, n_heads=h, n_kv_heads=kv, d_head=dh,
        q=_site(arch, d, h * dh, mode, "attn/q"),
        k=_site(arch, d, kv * dh, mode, "attn/k"),
        v=_site(arch, d, kv * dh, mode, "attn/v"),
        o=_site(arch, h * dh, d, mode, "attn/o"),
        qk_norm=arch.qk_norm,
        rope_theta=arch.rope_theta,
        mrope_sections=arch.mrope_sections,
        causal=arch.causal if causal is None else causal,
        use_rope=not cross,
    )


def _mlp_cfg(arch: ArchSpec, mode: Mode) -> mlp_mod.MLPCfg:
    d, f = arch.d_model, arch.d_ff
    return mlp_mod.MLPCfg(
        d_model=d, d_ff=f,
        gate=_site(arch, d, f, mode, "mlp/gate"),
        up=_site(arch, d, f, mode, "mlp/up"),
        down=_site(arch, f, d, mode, "mlp/down"),
        act=arch.act,
        gated=arch.mlp_gated,
    )


def _moe_cfg(arch: ArchSpec, mode: Mode) -> moe_mod.MoECfg:
    d, f, e = arch.d_model, arch.d_ff, arch.n_experts

    def esite(d_in, d_out):
        return moe_mod.ExpertSiteCfg(
            n_experts=e, d_in=d_in, d_out=d_out, mode=mode, lut=_lut(arch, d_in)
        )

    return moe_mod.MoECfg(
        d_model=d, d_ff=f, n_experts=e, top_k=arch.top_k,
        router=_site(arch, d, e, Mode.DENSE),        # router stays exact
        gate=esite(d, f), up=esite(d, f), down=esite(f, d),
        shared=_mlp_cfg(arch, mode) if arch.moe_shared_expert else None,
        act=arch.act,
        group_tokens=arch.moe_group_tokens,
    )


def _mamba_block(arch: ArchSpec, mode: Mode) -> tf_mod.BlockCfg:
    di = arch.d_inner
    h = di // arch.ssm_head_dim
    mcfg = mamba_mod.Mamba2Cfg(
        d_model=arch.d_model, d_inner=di, n_heads=h, head_dim=arch.ssm_head_dim,
        ssm_state=arch.ssm_state, n_groups=arch.ssm_groups,
        conv_width=arch.conv_width, chunk=arch.ssd_chunk,
        in_proj=_site(arch, arch.d_model,
                      2 * di + 2 * arch.ssm_groups * arch.ssm_state + h, mode,
                      "mamba/in_proj"),
        out_proj=_site(arch, di, arch.d_model, mode, "mamba/out_proj"),
    )
    return tf_mod.BlockCfg(kind="mamba", d_model=arch.d_model, mamba=mcfg)


def _block(arch: ArchSpec, mode: Mode) -> tf_mod.BlockCfg:
    if arch.family == "ssm":
        return _mamba_block(arch, mode)
    if arch.family == "moe":
        return tf_mod.BlockCfg(
            kind="moe", d_model=arch.d_model,
            attn=_attn_cfg(arch, mode),
            moe=_moe_cfg(arch, mode),
            residual_mlp=_mlp_cfg(arch, mode) if arch.moe_dense_residual else None,
        )
    return tf_mod.BlockCfg(
        kind="dense", d_model=arch.d_model,
        attn=_attn_cfg(arch, mode), mlp=_mlp_cfg(arch, mode),
    )


def _segments(arch: ArchSpec, mode: Mode) -> tuple[tuple[int, tf_mod.BlockCfg], ...]:
    """Apply the paper's replacement policy as uniform-mode layer runs."""
    L = arch.n_layers
    if mode == Mode.DENSE or arch.lut_policy == "all":
        return ((L, _block(arch, mode)),)
    if arch.lut_policy == "all_but_first":
        return ((1, _block(arch, Mode.DENSE)), (L - 1, _block(arch, mode)))
    if arch.lut_policy.startswith("last_n:"):
        n = int(arch.lut_policy.split(":")[1])
        return ((L - n, _block(arch, Mode.DENSE)), (n, _block(arch, mode)))
    raise ValueError(arch.lut_policy)


# ---------------------------------------------------------------------------
# unified model bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelBundle:
    arch: ArchSpec
    mode: Mode
    kind: str                    # "lm" | "hybrid" | "encdec"
    cfg: Any

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.arch.param_dtype == "bfloat16" else jnp.float32

    def init(self, key: jax.Array):
        if self.kind == "lm":
            return tf_mod.lm_init(key, self.cfg, dtype=self.param_dtype)
        if self.kind == "hybrid":
            return hybrid_mod.hybrid_init(key, self.cfg, dtype=self.param_dtype)
        return encdec_mod.encdec_init(key, self.cfg, dtype=self.param_dtype)

    def param_specs(self, key: jax.Array | None = None):
        k = jax.random.PRNGKey(0) if key is None else key
        return jax.eval_shape(self.init, k)

    # ---------------- training ----------------
    def loss(self, params, batch, *, compute_dtype=jnp.bfloat16):
        if self.kind == "lm":
            return tf_mod.lm_loss(self.cfg, params, batch, compute_dtype=compute_dtype)
        if self.kind == "hybrid":
            b, s = batch["labels"].shape
            pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
            logits, _, _ = hybrid_mod.hybrid_apply(
                self.cfg, params, tokens=batch["tokens"], pos=pos,
                compute_dtype=compute_dtype,
            )
            from repro.models.common import cross_entropy

            return cross_entropy(logits, batch["labels"])
        # encdec
        enc_out = encdec_mod.encode(self.cfg, params, batch["frames"],
                                    compute_dtype=compute_dtype)
        b, s = batch["labels"].shape
        pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
        logits, _ = encdec_mod.decode(
            self.cfg, params, tokens=batch["tokens"], pos=pos, enc_out=enc_out,
            compute_dtype=compute_dtype,
        )
        from repro.models.common import cross_entropy

        return cross_entropy(logits, batch["labels"])

    # ---------------- serving ----------------
    def init_caches(self, b: int, s_max: int, *, abstract=False, dtype=jnp.bfloat16):
        if self.kind == "lm":
            return tf_mod.init_caches(self.cfg, b, s_max, dtype, abstract=abstract)
        if self.kind == "hybrid":
            return hybrid_mod.hybrid_caches(self.cfg, b, s_max, dtype, abstract=abstract)
        return encdec_mod.encdec_caches(self.cfg, b, s_max, dtype, abstract=abstract)

    def forward_step(self, params, batch, caches, *, compute_dtype=jnp.bfloat16):
        """One serving step (prefill if S>1, decode if S==1).

        batch: tokens/embeds (+ optional frames for encdec prefill),
        cache_len (B,). Returns (logits for the new positions, new caches).
        """
        cache_len = batch["cache_len"]
        if self.kind == "encdec":
            caches = dict(caches)
            if "frames" in batch:                      # prefill: run encoder
                enc_out = encdec_mod.encode(self.cfg, params, batch["frames"],
                                            compute_dtype=compute_dtype)
                caches["cross"] = jax.tree.map(
                    lambda a: a.astype(compute_dtype),
                    encdec_mod.cross_kv(self.cfg, params, enc_out),
                )
            b, s = batch["tokens"].shape
            pos = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            logits, new_caches = encdec_mod.decode(
                self.cfg, params, tokens=batch["tokens"], pos=pos,
                caches=caches, cache_len=cache_len, compute_dtype=compute_dtype,
            )
            return logits, new_caches

        if self.kind == "hybrid":
            b, s = batch["tokens"].shape
            pos = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            logits, new_caches, _ = hybrid_mod.hybrid_apply(
                self.cfg, params, tokens=batch["tokens"], pos=pos,
                caches=caches, cache_len=cache_len, compute_dtype=compute_dtype,
            )
            return logits, new_caches

        tok = batch.get("tokens")
        emb = batch.get("embeds")
        ref = tok if tok is not None else emb
        b, s = ref.shape[0], ref.shape[1]
        pos = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
        if self.arch.mrope_sections:
            pos = jnp.broadcast_to(pos[None], (3, b, s))
        logits, new_caches, _ = tf_mod.lm_apply(
            self.cfg, params, tokens=tok, embeds=emb, pos=pos,
            caches=caches, cache_len=cache_len, compute_dtype=compute_dtype,
        )
        return logits, new_caches


def build_model(arch: ArchSpec | str, mode: Mode | str = Mode.DENSE) -> ModelBundle:
    if isinstance(arch, str):
        arch = get_arch(arch)
    if isinstance(mode, str):
        mode = Mode(mode)

    if arch.family == "hybrid":
        d = arch.d_model
        cfg = hybrid_mod.HybridCfg(
            vocab=arch.vocab, d_model=d, n_layers=arch.n_layers,
            attn_every=arch.attn_every,
            mamba_block=_mamba_block(arch, mode),
            shared_attn=_attn_cfg(arch, mode),
            shared_mlp=_mlp_cfg(arch, mode),
            fuse=_site(arch, 2 * d, d, Mode.DENSE),
            out=_site(arch, d, d, mode),
        )
        return ModelBundle(arch=arch, mode=mode, kind="hybrid", cfg=cfg)

    if arch.family == "audio":
        enc_block = tf_mod.BlockCfg(
            kind="dense", d_model=arch.d_model,
            attn=_attn_cfg(arch, mode, causal=False),
            mlp=_mlp_cfg(arch, mode),
        )
        cfg = encdec_mod.EncDecCfg(
            vocab=arch.vocab, d_model=arch.d_model,
            n_enc_layers=arch.n_enc_layers, n_dec_layers=arch.n_layers,
            enc_frames=arch.enc_frames,
            enc_block=enc_block,
            dec_self=_attn_cfg(arch, mode, causal=True),
            dec_cross=_attn_cfg(arch, mode, causal=False, cross=True),
            dec_mlp=_mlp_cfg(arch, mode),
        )
        return ModelBundle(arch=arch, mode=mode, kind="encdec", cfg=cfg)

    d = arch.d_model
    cfg = tf_mod.LMCfg(
        vocab=arch.vocab, d_model=d,
        segments=_segments(arch, mode),
        lm_head=None if arch.tie_embeddings else _site(arch, d, arch.vocab, Mode.DENSE),
        takes_embeds=arch.takes_embeds,
    )
    return ModelBundle(arch=arch, mode=mode, kind="lm", cfg=cfg)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(arch: ArchSpec | str, shape: str) -> dict[str, Any]:
    """Abstract model inputs for one (arch x shape) dry-run cell."""
    if isinstance(arch, str):
        arch = get_arch(arch)
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16

    def tok(bb, ss):
        return jax.ShapeDtypeStruct((bb, ss), i32)

    if sp.kind == "train":
        batch: dict[str, Any] = {"labels": tok(b, s)}
        if arch.family == "vlm":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, arch.d_model), bf16)
            batch["pos"] = jax.ShapeDtypeStruct((3, b, s), i32)
        elif arch.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((b, arch.enc_frames, arch.d_model), bf16)
            batch["tokens"] = tok(b, s)
        else:
            batch["tokens"] = tok(b, s)
        return batch

    if sp.kind == "prefill":
        batch = {"cache_len": jax.ShapeDtypeStruct((b,), i32)}
        if arch.family == "vlm":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, arch.d_model), bf16)
        elif arch.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((b, arch.enc_frames, arch.d_model), bf16)
            batch["tokens"] = tok(b, s)
        else:
            batch["tokens"] = tok(b, s)
        return batch

    # decode: one new token against a seq_len-deep cache
    batch = {"cache_len": jax.ShapeDtypeStruct((b,), i32)}
    if arch.family == "vlm":
        batch["embeds"] = jax.ShapeDtypeStruct((b, 1, arch.d_model), bf16)
    else:
        batch["tokens"] = tok(b, 1)
    return batch

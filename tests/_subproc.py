"""Run a snippet in a subprocess with N forced host devices."""

from __future__ import annotations

import os
import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_with_devices(code: str, n_devices: int, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout

"""LR schedules (paper Table 3: cosine annealing; BERT: constant)."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def cosine_with_warmup(
    base_lr: float, *, total_steps: int, warmup_steps: int = 0, min_lr: float = 0.0
) -> Callable[[jax.Array], jax.Array]:
    def fn(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / jnp.maximum(1.0, float(warmup_steps)))
        prog = jnp.clip(
            (s - warmup_steps) / max(1.0, float(total_steps - warmup_steps)), 0.0, 1.0
        )
        cos = min_lr + 0.5 * (base_lr - min_lr) * (1.0 + jnp.cos(jnp.pi * prog))
        return warm * cos

    return fn


def constant(base_lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.asarray(base_lr, jnp.float32)

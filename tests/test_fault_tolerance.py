"""Unit tests for distributed.fault_tolerance: StragglerMonitor EMA/flagging,
HeartbeatFile contents, Backoff schedule, StepGuard retry classification."""

from __future__ import annotations

import json

import pytest

from repro.distributed.fault_tolerance import (
    Backoff,
    HeartbeatFile,
    StepGuard,
    StragglerMonitor,
    is_retryable,
)


# ---------------------------------------------------------------------------
# StragglerMonitor
# ---------------------------------------------------------------------------

class TestStragglerMonitor:
    def test_warmup_never_flags(self):
        mon = StragglerMonitor(threshold=1.01, warmup_steps=5)
        # grossly slow steps inside warmup must not flag: the EMA is still
        # calibrating and has no baseline to compare against
        for step in range(5):
            assert mon.record(step, 100.0 * (step + 1)) is False
        assert mon.events == []

    def test_warmup_seeds_ema(self):
        mon = StragglerMonitor(decay=0.9, warmup_steps=3)
        mon.record(0, 2.0)
        assert mon.ema == pytest.approx(2.0)   # first sample seeds directly
        mon.record(1, 4.0)
        assert mon.ema == pytest.approx(0.9 * 2.0 + 0.1 * 4.0)

    def test_flags_above_threshold(self):
        mon = StragglerMonitor(threshold=2.0, decay=0.9, warmup_steps=2)
        for step in range(2):
            mon.record(step, 1.0)
        ema = mon.ema
        assert mon.record(2, 2.0 * ema + 0.01) is True
        assert len(mon.events) == 1
        ev = mon.events[0]
        assert ev["step"] == 2 and ev["ema"] == pytest.approx(ema)

    def test_straggler_does_not_poison_ema(self):
        mon = StragglerMonitor(threshold=2.0, decay=0.9, warmup_steps=2)
        for step in range(2):
            mon.record(step, 1.0)
        ema = mon.ema
        mon.record(2, 100.0)                   # flagged -> EMA unchanged
        assert mon.ema == pytest.approx(ema)
        assert mon.record(3, 1.0) is False     # normal step still normal

    def test_normal_steps_track_ema(self):
        mon = StragglerMonitor(threshold=2.0, decay=0.5, warmup_steps=1)
        mon.record(0, 1.0)
        mon.record(1, 1.5)                     # below threshold: folded in
        assert mon.ema == pytest.approx(0.5 * 1.0 + 0.5 * 1.5)


# ---------------------------------------------------------------------------
# HeartbeatFile
# ---------------------------------------------------------------------------

class TestHeartbeatFile:
    def test_beat_writes_one_json_record(self, tmp_path):
        hb = HeartbeatFile(tmp_path / "hb.json")
        hb.beat(3)
        lines = (tmp_path / "hb.json").read_text().splitlines()
        assert len(lines) == 1                 # liveness breadcrumb, not a log
        rec = json.loads(lines[0])
        assert rec["step"] == 3 and rec["t"] > 0

    def test_beat_overwrites_with_latest(self, tmp_path):
        hb = HeartbeatFile(tmp_path / "hb.json")
        for step in range(4):
            hb.beat(step)
        lines = (tmp_path / "hb.json").read_text().splitlines()
        assert len(lines) == 1
        assert json.loads(lines[0])["step"] == 3

    def test_extra_fields_round_trip(self, tmp_path):
        hb = HeartbeatFile(tmp_path / "hb.json")
        hb.beat(7, loss=0.5, phase="distill")
        rec = json.loads((tmp_path / "hb.json").read_text())
        assert rec["loss"] == 0.5 and rec["phase"] == "distill"

    def test_creates_parent_dirs(self, tmp_path):
        hb = HeartbeatFile(tmp_path / "a" / "b" / "hb.json")
        hb.beat(0)
        assert hb.path.exists()


# ---------------------------------------------------------------------------
# Backoff
# ---------------------------------------------------------------------------

class TestBackoff:
    def test_exponential_then_capped(self):
        b = Backoff(base_s=0.1, factor=2.0, cap_s=1.0)
        assert b.delay(0) == pytest.approx(0.1)
        assert b.delay(1) == pytest.approx(0.2)
        assert b.delay(2) == pytest.approx(0.4)
        assert b.delay(10) == 1.0              # capped

    def test_validation(self):
        with pytest.raises(ValueError):
            Backoff(base_s=-1.0)
        with pytest.raises(ValueError):
            Backoff(factor=0.5)
        with pytest.raises(ValueError):
            Backoff(cap_s=-0.1)


# ---------------------------------------------------------------------------
# StepGuard + is_retryable
# ---------------------------------------------------------------------------

class TestStepGuard:
    def test_transient_error_retries_then_succeeds(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("transient device error")
            return "ok"

        seen = []
        guard = StepGuard(max_retries=2,
                          on_failure=lambda e, a: seen.append(a))
        assert guard.run(flaky) == "ok"
        assert calls["n"] == 3 and seen == [0, 1]

    def test_fatal_error_raises_immediately(self):
        calls = {"n": 0}

        def broken():
            calls["n"] += 1
            raise ValueError("incompatible shapes")

        with pytest.raises(ValueError):
            StepGuard(max_retries=5).run(broken)
        assert calls["n"] == 1                 # no retry on programming errors

    def test_exhausted_retries_raise_runtime_error(self):
        def always():
            raise RuntimeError("flaky forever")

        with pytest.raises(RuntimeError, match="after 2 attempts"):
            StepGuard(max_retries=1).run(always)

    def test_is_retryable_classification(self):
        assert is_retryable(RuntimeError("connection reset"))
        assert not is_retryable(TypeError("bad arg"))
        assert not is_retryable(RuntimeError("invalid argument: rank"))

"""End-to-end driver (deliverable b): dense pretrain -> convert -> soft-PQ
QAT fine-tune -> int8 deploy -> eval + LUTArtifact, on a real (reduced)
registry arch — wired through a HETEROGENEOUS per-site LUTPlan (DESIGN.md
§9) instead of the legacy lut_policy string:

  * MLP sites:       K=16 tables
  * attention sites: K=8 tables (cheaper encode, the paper's K ablation)
  * first and last layers: kept dense (the paper's accuracy-critical ends)

  PYTHONPATH=src python examples/train_softpq_pipeline.py [--steps 200]

The emitted artifact (manifest v2, plan included) serves with
`python -m repro.launch.serve --artifact <dir>` (examples/deploy_and_serve.py
shows the full loop). For the plain string-policy pipeline use
`python -m repro.launch.train --lut`.
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import LUTPlan, build_model, effective_plan, get_arch, reduce_arch, rule
from repro.core import convert
from repro.core.amm import Mode
from repro.data import MarkovLM
from repro.optim import SOFT_PQ_RULES, AdamW, lut_frozen_mask
from repro.optim.schedule import cosine_with_warmup
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3_1p7b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--artifact-dir", default="/tmp/repro_plan_artifact")
    args = ap.parse_args()

    plan = LUTPlan(rules=(
        rule(kinds=("mlp/*",), k=16),
        rule(kinds=("attn/*",), k=8),
        rule(layers="set", layer_set=(0, args.layers - 1), replace=False),
    ))
    arch = reduce_arch(
        get_arch(args.arch),
        d_model=256, n_layers=args.layers, vocab=512, d_ff=512,
    )
    arch = dataclasses.replace(arch, lut_plan=plan)
    print(f"replacement plan: {effective_plan(arch).describe()}")

    data = MarkovLM(vocab=arch.vocab, seq_len=64, batch=16)
    key = jax.random.PRNGKey(0)

    dense = build_model(arch, Mode.DENSE)
    params = dense.init(key)
    opt = AdamW(lr=cosine_with_warmup(3e-3, total_steps=args.steps, warmup_steps=20))
    trainer = Trainer(
        step_fn=jax.jit(make_train_step(dense, opt, compute_dtype=jnp.float32)),
        batch_at=data.batch_at,
        cfg=TrainerConfig(total_steps=args.steps, ckpt_every=10**9,
                          ckpt_dir="/tmp/repro_plan_ckpt", log_every=50),
    )
    params, _ = trainer.fit(params, opt.init(params), start_step=0)
    print(f"dense pretrain final loss {trainer.history[-1]['loss']:.4f}")

    print("converting: k-means centroid init from activation samples ...")
    samples = [data.batch_at(10_000 + i) for i in range(2)]
    blut, lparams = convert.convert_dense_to_lut_train(dense, params, samples, key)

    # the registry shows how the plan resolved every site
    print("per-site resolution (layer 1):")
    for s in blut.sites():
        if s.layer == 1 and s.stack_index is not None:
            lut = f"K={s.lut.k} V={s.lut.v}" if s.mode != Mode.DENSE else "dense"
            print(f"  {s.kind:12s} {s.d_in:4d}->{s.d_out:<4d} {lut}")

    frozen = lut_frozen_mask(lparams)
    opt2 = AdamW(lr=cosine_with_warmup(1e-3, total_steps=args.steps, warmup_steps=10),
                 rules=SOFT_PQ_RULES)
    trainer2 = Trainer(
        step_fn=jax.jit(make_train_step(blut, opt2, frozen_mask=frozen,
                                        compute_dtype=jnp.float32)),
        batch_at=data.batch_at,
        cfg=TrainerConfig(total_steps=args.steps, ckpt_every=10**9,
                          ckpt_dir="/tmp/repro_plan_ckpt_lut", log_every=50),
    )
    lparams, _ = trainer2.fit(lparams, opt2.init(lparams, frozen), start_step=0)
    print(f"soft-PQ fine-tune final loss {trainer2.history[-1]['loss']:.4f}")

    binf, iparams = convert.deploy_to_artifact(blut, lparams, args.artifact_dir)
    eval_loss = binf.loss(iparams, data.batch_at(99_999), compute_dtype=jnp.float32)
    print(f"deployed INT8 LUT eval loss: {float(eval_loss):.4f}")
    print(f"wrote LUTArtifact (manifest v2 + plan) to {args.artifact_dir} "
          f"(serve: python -m repro.launch.serve --artifact {args.artifact_dir})")


if __name__ == "__main__":
    main()

"""Shape-keyed block autotuner: budget model, cost model, cache round-trip,
and the serving-engine warmup wiring (DESIGN.md §3)."""

import dataclasses
import json

import numpy as np
import pytest

from repro.kernels import autotune


def test_candidates_respect_vmem_budget():
    cands = list(autotune.enumerate_candidates("lut_amm", 4096, 14336, 128, 16, 32))
    assert cands, "must always yield at least one tiling"
    for c in cands:
        assert autotune.vmem_bytes(c.block_n, c.block_m, c.block_c, 16, 32) \
            <= autotune.VMEM_BUDGET
        assert 128 % c.block_c == 0


def test_predict_v2_never_slower_than_v1():
    """The analytic model must encode v2's advantage: no per-step dequant
    pass, doubled int8 MXU rate."""
    for (n, m, c, k, v) in [(256, 4096, 128, 16, 32), (8, 512, 16, 16, 8)]:
        for cand in autotune.enumerate_candidates("lut_amm", n, m, c, k, v):
            t1 = autotune.predict_us("lut_amm", n, m, c, k, v,
                                     cand.block_n, cand.block_m, cand.block_c,
                                     version=1)
            t2 = autotune.predict_us("lut_amm", n, m, c, k, v,
                                     cand.block_n, cand.block_m, cand.block_c,
                                     version=2)
            assert t2 <= t1


def test_lookup_heuristic_on_cache_miss(tmp_path):
    cache = autotune.AutotuneCache(tmp_path / "c.json")
    cfg = autotune.lookup("lut_amm", 100, 300, 8, 16, 8, cache=cache)
    assert cfg == autotune.heuristic("lut_amm", 100, 300, 8, 16, 8)


def test_tune_cache_roundtrip(tmp_path):
    """tune() persists the winner; a fresh cache object loads it back and
    lookup() serves it instead of the heuristic."""
    path = tmp_path / "cache.json"
    cache = autotune.AutotuneCache(path)
    shape = ("lut_amm", 64, 256, 16, 16, 8)
    best, rec = autotune.tune(*shape, dtype="float32", backend="cpu", cache=cache)
    assert path.exists()
    assert rec["source"] == "roofline_model" and not rec["measured"]

    fresh = autotune.AutotuneCache(path)
    got = autotune.lookup(*shape, dtype="float32", backend="cpu", cache=fresh)
    assert got == best

    # raw JSON sanity: versioned schema with the documented key format
    raw = json.loads(path.read_text())
    assert raw["version"] == 1
    key = autotune.shape_key("lut_amm", 64, 256, 16, 16, 8, "float32", "cpu")
    assert set(raw["entries"]) == {key}
    assert raw["entries"][key]["block_n"] == best.block_n


def test_tune_picks_measured_winner(tmp_path):
    """With a measure callable the tuner minimizes wall-clock, not the model."""
    cache = autotune.AutotuneCache(tmp_path / "m.json")
    target = autotune.BlockConfig(16, 128, 2)

    def measure(cfg):
        return 1e-6 if cfg == target else 1e-3

    best, rec = autotune.tune("lut_amm", 64, 256, 4, 16, 8,
                              cache=cache, measure=measure)
    assert best == target and rec["measured"] and rec["source"] == "wallclock"


def test_tune_sweeps_version_axis(tmp_path):
    """Kernel version is a tunable axis (DESIGN.md §13.2): the analytic
    sweep on a big decode shape picks the fused kernel (encode charged once,
    not per M block) and records it."""
    cache = autotune.AutotuneCache(tmp_path / "v.json")
    best, rec = autotune.tune("lut_amm", 256, 4096, 128, 16, 32, cache=cache)
    assert rec["version"] == autotune.VERSION_FUSED
    assert best.block_c == 128          # fused keeps the whole codebook axis
    assert not rec["measured"]


def test_tune_measured_version_wins_over_analytic_ranking(tmp_path):
    """A (cfg, version) measure callable overrides the model: if v1 times
    fastest on the live backend, the record says v1 — measured, so the
    engine/snapshot precedence never downgrades it to the analytic pick."""
    cache = autotune.AutotuneCache(tmp_path / "mv.json")

    def measure(cfg, version):
        return {1: 1e-6, 2: 1e-3, 3: 1e-3}[version]

    best, rec = autotune.tune("lut_amm", 64, 256, 4, 16, 8,
                              cache=cache, measure=measure)
    assert rec["version"] == 1 and rec["measured"]
    assert rec["source"] == "wallclock"


def test_tune_all_inf_measure_falls_back_to_analytic(tmp_path):
    """Every measured candidate failing (backend can't run kernels) must
    degrade to the analytic ranking, flagged measured=False."""
    cache = autotune.AutotuneCache(tmp_path / "inf.json")
    best, rec = autotune.tune("lut_amm", 64, 256, 4, 16, 8, cache=cache,
                              measure=lambda cfg, ver: float("inf"))
    assert best is not None and not rec["measured"]
    assert rec["source"] == "roofline_model"


def test_kernel_choice_record_wins(tmp_path):
    cache = autotune.AutotuneCache(tmp_path / "kc.json")
    key = autotune.shape_key("lut_amm", 8, 128, 4, 16, 8, "float32", "cpu")
    cache.put(key, {"block_n": 8, "block_m": 128, "block_c": 4,
                    "version": 3, "measured": True})
    ver, cfg, from_rec = autotune.kernel_choice(
        8, 128, 4, 16, 8, backend="cpu", interpret=True, cache=cache)
    assert (ver, from_rec) == (3, True)
    assert cfg == autotune.BlockConfig(8, 128, 4)
    # legacy record without a "version" key means v2
    cache.put(key, {"block_n": 8, "block_m": 128, "block_c": 4})
    ver, _, _ = autotune.kernel_choice(
        8, 128, 4, 16, 8, backend="cpu", interpret=True, cache=cache)
    assert ver == 2


def test_kernel_choice_fallback_rules(tmp_path):
    """No record: interpret small-M -> v1 (the measured v2 regression);
    compiled or big-M -> fused when it fits, else v2."""
    cache = autotune.AutotuneCache(tmp_path / "fb.json")
    ver, _, from_rec = autotune.kernel_choice(
        8, 128, 4, 16, 8, backend="cpu", interpret=True, cache=cache)
    assert (ver, from_rec) == (1, False)
    ver, cfg, _ = autotune.kernel_choice(
        8, 4096, 4, 16, 8, backend="cpu", interpret=True, cache=cache)
    assert ver == autotune.VERSION_FUSED and cfg.block_c == 4
    ver, _, _ = autotune.kernel_choice(
        8, 128, 4, 16, 8, backend="tpu", interpret=False, cache=cache)
    assert ver == autotune.VERSION_FUSED
    # fused working set over budget (huge C*K*V codebook) -> v2
    ver, _, _ = autotune.kernel_choice(
        8, 4096, 4096, 16, 64, backend="tpu", interpret=False, cache=cache)
    assert ver == 2


def test_best_analytic_per_version():
    """best_analytic scores ONE version at its own legal tilings; fused
    reports (None, inf) when no all-of-C tiling fits VMEM."""
    cfg2, t2 = autotune.best_analytic("lut_amm", 256, 4096, 128, 16, 32,
                                      version=2)
    cfg3, t3 = autotune.best_analytic("lut_amm", 256, 4096, 128, 16, 32,
                                      version=3)
    assert cfg2 is not None and cfg3 is not None and t3 < t2
    cfg_bad, t_bad = autotune.best_analytic("lut_amm", 8, 4096, 4096, 16, 64,
                                            version=3)
    assert cfg_bad is None and t_bad == float("inf")


def test_corrupt_cache_degrades_gracefully(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text("{not json")
    cache = autotune.AutotuneCache(path)
    assert cache.get("anything") is None
    cfg = autotune.lookup("encode", 32, 0, 4, 16, 8, cache=cache)
    assert cfg == autotune.heuristic("encode", 32, 0, 4, 16, 8)


def test_engine_warmup_populates_cache(key, tmp_path, monkeypatch):
    """ServingEngine with a use_kernel bundle pre-tunes the decode/prefill
    LUT shapes into the autotune cache (DESIGN.md §3.3)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "warm.json"))
    from repro.configs import build_model, get_arch, reduce_arch
    from repro.core.amm import Mode
    from repro.serving.engine import ServingEngine, iter_lut_kernel_sites

    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, lut_use_kernel=True)
    bundle = build_model(arch, Mode.LUT_INFER)
    assert len(list(iter_lut_kernel_sites(bundle.cfg))) > 0

    params = bundle.init(key)
    eng = ServingEngine(bundle, params, n_slots=2, max_seq=32, prefill_chunk=8)
    assert eng.n_lut_shapes_tuned > 0
    raw = json.loads((tmp_path / "warm.json").read_text())
    assert len(raw["entries"]) == eng.n_lut_shapes_tuned
    # decode shape (N = n_slots) is among the tuned keys
    assert any("|n=2|" in k for k in raw["entries"])

    # and the engine still serves correctly through the kernel path
    eng.submit([1, 2, 3], max_tokens=3)
    done = eng.run_until_done()
    assert len(done) == 1 and len(done[0].out_tokens) == 3
    assert all(np.isfinite(t) for t in done[0].out_tokens)


def test_engine_warmup_measured_mode(key, tmp_path, monkeypatch):
    """REPRO_AUTOTUNE_MEASURE=1: warmup times candidates via
    repro.kernels.measure (stubbed here — no wall-clock in unit tests),
    marks records measured, and never re-tunes a measured record."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "meas.json"))
    monkeypatch.setenv("REPRO_AUTOTUNE_MEASURE", "1")
    from repro.configs import build_model, get_arch, reduce_arch
    from repro.core.amm import Mode
    from repro.kernels import measure
    from repro.serving.engine import ServingEngine, warm_lut_autotune

    built = []

    def fake_measure_lut_amm(n, m, c, k, v, **kw):
        built.append((n, m))
        # prefer v1 at one specific tiling so the winner is recognizable
        return lambda cfg, ver: (1e-6 if ver == 1 else 1e-3)

    monkeypatch.setattr(measure, "measure_lut_amm", fake_measure_lut_amm)

    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, lut_use_kernel=True)
    bundle = build_model(arch, Mode.LUT_INFER)
    params = bundle.init(key)
    eng = ServingEngine(bundle, params, n_slots=2, max_seq=32, prefill_chunk=8)
    assert eng.n_lut_shapes_tuned > 0 and built

    raw = json.loads((tmp_path / "meas.json").read_text())
    assert all(rec["measured"] and rec["source"] == "wallclock"
               and rec["version"] == 1
               for rec in raw["entries"].values())

    # measured records are terminal: a second warmup re-measures nothing
    built.clear()
    assert warm_lut_autotune(bundle, [2, 16]) == 0
    assert built == []


def test_engine_warmup_measured_retunes_analytic_records(key, tmp_path, monkeypatch):
    """Precedence: an analytic record is RE-tuned once measurement is
    available — a measured winner always beats a projection."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "up.json"))
    from repro.configs import build_model, get_arch, reduce_arch
    from repro.core.amm import Mode
    from repro.kernels import measure
    from repro.serving.engine import warm_lut_autotune

    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, lut_use_kernel=True)
    bundle = build_model(arch, Mode.LUT_INFER)
    n_analytic = warm_lut_autotune(bundle, [2])     # analytic pass
    assert n_analytic > 0
    raw = json.loads((tmp_path / "up.json").read_text())
    assert all(not rec["measured"] for rec in raw["entries"].values())

    monkeypatch.setenv("REPRO_AUTOTUNE_MEASURE", "1")
    monkeypatch.setattr(measure, "measure_lut_amm",
                        lambda *a, **kw: (lambda cfg, ver: 1e-6))
    assert warm_lut_autotune(bundle, [2]) == n_analytic
    raw = json.loads((tmp_path / "up.json").read_text())
    assert all(rec["measured"] for rec in raw["entries"].values())


def test_blockconfig_is_hashable_frozen():
    cfg = autotune.BlockConfig(8, 128, 1)
    assert hash(cfg) == hash(autotune.BlockConfig(8, 128, 1))
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.block_n = 16

"""Shape-keyed block-size + kernel-version autotuner for the LUT Pallas
kernels (DESIGN.md §3, §13).

The lut_amm kernels tile over a (N/bn, M/bm, C/bc) grid (v1/v2) or a
(N/bn, M/bm) grid with the whole codebook axis VMEM-resident (fused, v3);
the block sizes trade VMEM residency against HBM re-streaming:

  * bigger bn  -> the int8 table tile is re-read fewer times (N/bn sweeps)
  * bigger bm  -> the activation tile is re-read fewer times (M/bm sweeps)
  * bigger bc  -> fewer grid steps (less per-step overhead), bigger VMEM tiles

All three are capped by the per-step VMEM working set (`vmem_bytes`), which
must fit in 16 MB with double buffering — the budget model is documented in
DESIGN.md §3.1/§13.1 and enforced by `enumerate_candidates`.

The kernel *version* is a tunable axis alongside the block sizes
(DESIGN.md §13.2): `tune` sweeps v1 (fp32 dequant per step), v2 (int8-native
scratch accumulation) and v3 (fused encode→lookup decode,
`repro.kernels.fused_decode`) for every `lut_amm` shape and records the
winner in the cache entry (`"version"`). `kernel_choice` is the hot-path
consumer: record (measured or analytic) wins; with no record a fallback
rule applies (v1 for small-M interpret-mode shapes — the measured regime
where v2's emulation overhead loses — else the fused kernel when its
all-of-C working set fits VMEM, else v2).

Tuning modes:

  * measured  — a `measure(cfg[, version]) -> seconds` callable (real
    wall-clock on the live backend; `repro.kernels.measure` builds one:
    compiled runs, warmup + median-of-k).
  * analytic  — no accelerator present: candidates are scored with the
    roofline model in `predict_us` (HBM traffic / compute / per-step
    overhead), using the v5e constants from repro.roofline.analysis.

Winners persist to an on-disk JSON cache (DESIGN.md §3.2) keyed by
(kind, N, M, C, K, V, dtype, backend) and are consumed by `lut_amm_pallas`,
`encode_pallas`, `ops.lut_amm` dispatch, the serving engine warmup, and the
benchmarks; records carry `measured: bool` so a wall-clock winner is never
silently replaced by an analytic one (precedence: measured > artifact
snapshot > analytic — DESIGN.md §13.3). Cache path: $REPRO_AUTOTUNE_CACHE,
else ~/.cache/repro/autotune.json.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
import pathlib
import tempfile
from typing import Any, Callable, Iterator

from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

# ---------------------------------------------------------------------------
# hardware model constants (DESIGN.md §3.1)
# ---------------------------------------------------------------------------

VMEM_BYTES = 16 * 2**20          # per-core VMEM (v4/v5 generations)
VMEM_BUDGET = 12 * 2**20         # usable budget: leave headroom for spills
MXU_F32 = PEAK_FLOPS             # dense fp32/bf16 MXU rate (paper constants)
MXU_I8 = 2 * PEAK_FLOPS          # int8 MXU rate: 2x the bf16 rate on v5e
VMEM_BW = 8 * HBM_BW             # rough on-chip bandwidth for VPU passes
STEP_OVERHEAD_S = 1e-6           # fixed per-grid-step cost (DMA setup, sync)

_CACHE_VERSION = 1
_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"

# lut_amm kernel generations swept by `tune` (DESIGN.md §13.2):
#   1 = lut_amm_pallas_v1 (fp32 dequant per codebook step)
#   2 = lut_amm_pallas    (int8-native, VMEM scratch accumulation)
#   3 = fused_decode_pallas (encode once per N tile, codes VMEM-resident)
KERNEL_VERSIONS = (1, 2, 3)
VERSION_FUSED = 3

# fallback rule threshold (no cache record): in interpret mode — the only
# mode without an accelerator to measure on — BENCH_kernels.json shows v1
# beating v2 on small-M rows (the scratch/epilogue machinery costs more than
# the dequant it saves under emulation), so small-M interpret shapes default
# to v1 rather than pinning a losing version.
_SMALL_M_V1 = 512


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """One tiling choice for a fused LUT kernel."""

    block_n: int
    block_m: int
    block_c: int

    def as_dict(self) -> dict[str, int]:
        return dataclasses.asdict(self)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _divisors(c: int) -> list[int]:
    return [d for d in range(1, c + 1) if c % d == 0]


# ---------------------------------------------------------------------------
# VMEM budget model (DESIGN.md §3.1)
# ---------------------------------------------------------------------------

def vmem_bytes(
    bn: int, bm: int, bc: int, k: int, v: int, *, kind: str = "lut_amm"
) -> int:
    """Per-step VMEM working set of the fused kernel at one tiling.

    Input tiles are charged twice (the pipeline emitter double-buffers HBM
    streams); the scratch accumulator and the output tile are single-buffered
    because their BlockSpec index maps ignore the innermost grid axis.

    kind="fused" (DESIGN.md §13.1): bc must equal C — the fused decode
    kernel keeps the whole codebook axis resident so the encode runs once
    per N tile. Its working set adds the int8 code scratch (bn·C·K) and a
    contraction temporary, but drops the per-step accumulator (each output
    tile is written in a single grid step).
    """
    x_tile = bn * bc * v * 4                 # fp32 activations
    p_tile = bc * k * v * 4                  # fp32 codebook
    if kind == "encode":
        out = bn * bc * 4                    # int32 indices
        return 2 * (x_tile + p_tile) + out
    t_tile = bc * k * bm                     # int8 table — stays int8 (v2)
    s_tile = bc * bm * 4                     # scale tile upper bound
    b_tile = bm * 4                          # fused bias row
    out = bn * bm * 4                        # fp32 output tile
    if kind == "fused":
        codes = bn * bc * k                  # int8 one-hot scratch (all of C)
        tmp = bn * bm * 8                    # int32 + fp32 contraction temp
        return 2 * (x_tile + t_tile) + p_tile + s_tile + b_tile + codes + out + tmp
    acc = bn * bm * 4                        # int32/f32 scratch accumulator
    return 2 * (x_tile + p_tile + t_tile + s_tile + b_tile) + acc + out


# ---------------------------------------------------------------------------
# analytic cost model
# ---------------------------------------------------------------------------

def predict_us(
    kind: str,
    n: int, m: int, c: int, k: int, v: int,
    bn: int, bm: int, bc: int,
    *,
    version: int = 2,
) -> float:
    """Roofline latency estimate (microseconds) for one tiling.

    HBM traffic counts tile re-streaming exactly as the BlockSpec index maps
    imply: the activation tile ignores the M grid axis (re-fetched per
    M-block revisit), the table tile ignores the N grid axis (re-fetched per
    N-block sweep), and the codebook tile is re-fetched whenever the C
    coordinate cycles. Compute charges the encode matmul per M-block (the
    fused kernel recomputes the argmin for every output tile) and the table
    contraction once; v1 additionally pays a per-step fp32 dequantization of
    the table tile on the VPU and contracts at the fp32 MXU rate, v2
    contracts int8 at the doubled int8 MXU rate (DESIGN.md §2.3). The v1
    dequant is charged additively (not under the roofline max): it is a
    serial VPU pass between the DMA and the MXU contraction that consumes
    its output, so it overlaps with neither.

    version=3 models the fused decode kernel (DESIGN.md §13.1): the encode
    matmul is charged ONCE per token (codes persist in VMEM scratch across
    the M sweep instead of being recomputed per M block), the activation
    tile is read once (its index map ignores the M axis), the codebook is
    resident for the whole grid, and codes never round-trip through HBM.
    """
    gn, gm = _ceil_div(n, bn), (1 if kind == "encode" else _ceil_div(m, bm))
    gc = _ceil_div(c, bc)

    if kind != "encode" and version >= VERSION_FUSED:
        hbm = (
            n * c * v * 4                    # x read once (index map ignores M)
            + c * k * v * 4                  # codebook resident across the grid
            + c * k * m * gn                 # int8 table, re-read per N sweep
            + n * m * 4                      # output written exactly once
        )
        t_comp = (
            2.0 * n * c * v * k / MXU_F32    # encode: once, not per M block
            + 2.0 * n * c * k * m / MXU_I8   # int8 table contraction
        )
        t_steps = gn * gm * STEP_OVERHEAD_S
        return (max(hbm / HBM_BW, t_comp) + t_steps) * 1e6

    x_bytes = n * c * v * 4 * gm
    p_bytes = c * k * v * 4 * gn * gm
    enc_flops = 2.0 * n * c * v * k * gm

    t_serial = 0.0
    if kind == "encode":
        hbm = x_bytes + p_bytes + n * c * 4
        t_comp = enc_flops / MXU_F32
    else:
        t_bytes = c * k * m * gn             # int8 table, re-read per N sweep
        o_bytes = n * m * 4                  # written exactly once (v2)
        hbm = x_bytes + p_bytes + t_bytes + o_bytes
        lut_flops = 2.0 * n * c * k * m
        if version >= 2:
            t_comp = enc_flops / MXU_F32 + lut_flops / MXU_I8
        else:
            # v1: int8 -> fp32 dequant materialization per codebook step
            # (read int8 + write fp32 in VMEM), then an fp32 contraction.
            t_comp = enc_flops / MXU_F32 + lut_flops / MXU_F32
            t_serial = 5.0 * c * k * m * gn / VMEM_BW

    t_mem = hbm / HBM_BW
    t_steps = gn * gm * gc * STEP_OVERHEAD_S
    return (max(t_mem, t_comp) + t_serial + t_steps) * 1e6


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------

_BN_CHOICES = (8, 16, 32, 64, 128, 256, 512)
_BM_CHOICES = (128, 256, 512, 1024)


def enumerate_candidates(
    kind: str, n: int, m: int, c: int, k: int, v: int,
    *, budget: int = VMEM_BUDGET,
) -> Iterator[BlockConfig]:
    """All tilings under the VMEM budget. Always yields at least one, except
    kind="fused", where bc is pinned to C (the whole codebook axis must be
    VMEM-resident) — an empty sweep there means the fused kernel is not a
    legal choice for this shape and the version sweep falls back to v1/v2."""
    bns = sorted({min(b, n) for b in _BN_CHOICES})
    if kind == "encode":
        bms = [0]
    else:
        bms = sorted({min(b, m) for b in _BM_CHOICES})
    bcs = [c] if kind == "fused" else _divisors(c)
    emitted = False
    for bn in bns:
        for bm in bms:
            for bc in bcs:
                if vmem_bytes(bn, max(bm, 1), bc, k, v, kind=kind) > budget:
                    continue
                emitted = True
                yield BlockConfig(bn, bm, bc)
    if not emitted and kind != "fused":       # degenerate: smallest tiling
        yield BlockConfig(min(8, n), 0 if kind == "encode" else min(128, m), 1)


def heuristic(kind: str, n: int, m: int, c: int, k: int, v: int) -> BlockConfig:
    """Cache-miss default — the pre-autotuner hardcoded tiling.

    kind="fused": bc is pinned to C; bn/bm halve until the all-of-C working
    set fits the budget (feasibility is pre-checked by `kernel_choice`)."""
    if kind == "fused":
        bn, bm = min(128, n), min(512, m)
        while bn > 8 and vmem_bytes(bn, bm, c, k, v, kind="fused") > VMEM_BUDGET:
            bn //= 2
        while bm > 128 and vmem_bytes(bn, bm, c, k, v, kind="fused") > VMEM_BUDGET:
            bm //= 2
        return BlockConfig(bn, bm, c)
    bn = min(512 if kind == "encode" else 256, n)
    bm = 0 if kind == "encode" else min(512, m)
    bc = max(1, min(c, 2048 // max(v, 1)))
    while c % bc:
        bc -= 1
    return BlockConfig(bn, bm, bc)


# ---------------------------------------------------------------------------
# on-disk cache (DESIGN.md §3.2)
# ---------------------------------------------------------------------------

def shape_key(
    kind: str, n: int, m: int, c: int, k: int, v: int,
    dtype: str, backend: str,
) -> str:
    return f"{kind}|n={n}|m={m}|c={c}|k={k}|v={v}|dtype={dtype}|backend={backend}"


def default_cache_path() -> pathlib.Path:
    env = os.environ.get(_ENV_CACHE)
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "autotune.json"


class AutotuneCache:
    """JSON-backed winner store; safe against concurrent/partial writes."""

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = pathlib.Path(path) if path is not None else default_cache_path()
        self._entries: dict[str, dict[str, Any]] | None = None

    def load(self) -> dict[str, dict[str, Any]]:
        if self._entries is None:
            try:
                raw = json.loads(self.path.read_text())
                ok = isinstance(raw, dict) and raw.get("version") == _CACHE_VERSION
                self._entries = dict(raw["entries"]) if ok else {}
            except (OSError, ValueError, KeyError):
                self._entries = {}
        return self._entries

    def get(self, key: str) -> dict[str, Any] | None:
        return self.load().get(key)

    def put(self, key: str, record: dict[str, Any]) -> None:
        self.load()[key] = record
        _memo_clear()

    def save(self) -> None:
        payload = {"version": _CACHE_VERSION, "entries": self.load()}
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
            raise


_DEFAULT_CACHE: AutotuneCache | None = None
_MEMO: dict[str, BlockConfig] = {}
_MEMO_CHOICE: dict[str, tuple[int, BlockConfig, bool]] = {}


def get_cache() -> AutotuneCache:
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None or _DEFAULT_CACHE.path != default_cache_path():
        _DEFAULT_CACHE = AutotuneCache()
    return _DEFAULT_CACHE


def _memo_clear() -> None:
    _MEMO.clear()
    _MEMO_CHOICE.clear()


def _backend() -> str:
    import jax

    return jax.default_backend()


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def lookup(
    kind: str, n: int, m: int, c: int, k: int, v: int,
    *, dtype: str = "float32", backend: str | None = None,
    cache: AutotuneCache | None = None,
) -> BlockConfig:
    """Cheap hot-path lookup: cached winner, else the heuristic tiling.

    Never runs tuning inline — `tune` (benchmarks / engine warmup) populates
    the cache out-of-band.
    """
    backend = backend or _backend()
    key = shape_key(kind, n, m, c, k, v, dtype, backend)
    memo_key = None
    if cache is None:
        cache = get_cache()
        # memo keyed by cache path too: switching $REPRO_AUTOTUNE_CACHE
        # (e.g. per-test isolation) must not serve another cache's winners
        memo_key = f"{cache.path}|{key}"
        if memo_key in _MEMO:
            return _MEMO[memo_key]
    rec = cache.get(key)
    if rec is not None:
        cfg = BlockConfig(rec["block_n"], rec["block_m"], rec["block_c"])
    else:
        cfg = heuristic(kind, n, m, c, k, v)
    if memo_key is not None:
        _MEMO[memo_key] = cfg
    return cfg


def resolve_blocks(
    kind: str, n: int, m: int, c: int, k: int, v: int, dtype: str,
    block_n: int | None, block_m: int | None, block_c: int | None,
) -> tuple[int, int, int]:
    """Fill unspecified block sizes from the cache (or heuristic), then
    clamp to legal values for this shape — the one block-resolution path
    shared by `lut_amm_pallas` and `encode_pallas`."""
    if block_n is None or block_m is None or block_c is None:
        tuned = lookup(kind, n, m, c, k, v, dtype=dtype)
        block_n = block_n if block_n is not None else tuned.block_n
        block_m = block_m if block_m is not None else tuned.block_m
        block_c = block_c if block_c is not None else tuned.block_c
    bn = max(1, min(block_n, n))
    bm = max(1, min(block_m, m)) if m else 0
    bc = max(1, min(block_c, c))
    while c % bc:
        bc -= 1
    return bn, bm, bc


def _measure_accepts_version(measure: Callable) -> bool:
    """Whether a measure callable takes (cfg, version) or just (cfg)."""
    import inspect

    try:
        params = list(inspect.signature(measure).parameters.values())
    except (TypeError, ValueError):
        return False
    if any(p.kind == inspect.Parameter.VAR_POSITIONAL for p in params):
        return True
    positional = [p for p in params if p.kind in (
        inspect.Parameter.POSITIONAL_ONLY,
        inspect.Parameter.POSITIONAL_OR_KEYWORD,
    )]
    return len(positional) >= 2


def best_analytic(
    kind: str, n: int, m: int, c: int, k: int, v: int, *, version: int = 2,
) -> tuple[BlockConfig | None, float]:
    """Best roofline-scored tiling for ONE kernel version; (None, inf) when
    no legal tiling exists (fused over VMEM budget). Used by the benchmarks
    for per-version model projections without touching the cache."""
    cand_kind = "fused" if (kind == "lut_amm" and version >= VERSION_FUSED) else kind
    best_cfg, best_t = None, math.inf
    for cand in enumerate_candidates(cand_kind, n, m, c, k, v):
        t_us = predict_us(kind, n, m, c, k, v,
                          cand.block_n, cand.block_m, cand.block_c,
                          version=version)
        if t_us < best_t:
            best_cfg, best_t = cand, t_us
    return best_cfg, best_t


def tune(
    kind: str, n: int, m: int, c: int, k: int, v: int,
    *, dtype: str = "float32", backend: str | None = None,
    cache: AutotuneCache | None = None,
    measure: Callable[..., float] | None = None,
    versions: tuple[int, ...] | None = None,
    save: bool = True,
) -> tuple[BlockConfig, dict[str, Any]]:
    """Pick the best (version, tiling) for one shape and persist it.

    measure: optional wall-clock callable — `(cfg, version) -> seconds`
    (or legacy `(cfg) -> seconds`); when absent the analytic `predict_us`
    model scores candidates (the only option without an accelerator).
    Candidates that raise or return inf never win, so illegal tilings on
    the live backend are skipped rather than fatal.

    versions: kernel generations to sweep; defaults to KERNEL_VERSIONS for
    kind="lut_amm" (v1/v2/fused is a tunable axis — DESIGN.md §13.2) and a
    single version otherwise. The winning version lands in the record.
    """
    backend = backend or _backend()
    cache = cache or get_cache()
    key = shape_key(kind, n, m, c, k, v, dtype, backend)
    if versions is None:
        versions = KERNEL_VERSIONS if kind == "lut_amm" else (2,)
    measured = measure is not None
    pass_version = measured and _measure_accepts_version(measure)

    best_cfg, best_t, best_ver = None, math.inf, versions[0]
    for ver in versions:
        cand_kind = "fused" if (kind == "lut_amm" and ver >= VERSION_FUSED) else kind
        for cand in enumerate_candidates(cand_kind, n, m, c, k, v):
            if measure is not None:
                try:
                    t_us = (measure(cand, ver) if pass_version
                            else measure(cand)) * 1e6
                except Exception:
                    continue
            else:
                t_us = predict_us(kind, n, m, c, k, v,
                                  cand.block_n, cand.block_m, cand.block_c,
                                  version=ver)
            if t_us < best_t:
                best_cfg, best_t, best_ver = cand, t_us, ver

    if best_cfg is None or not math.isfinite(best_t):
        # every measured candidate failed (e.g. backend can't run the
        # kernels at all) — fall back to the analytic ranking rather than
        # persisting nothing
        measured = False
        for ver in versions:
            cand_kind = "fused" if (kind == "lut_amm" and ver >= VERSION_FUSED) else kind
            for cand in enumerate_candidates(cand_kind, n, m, c, k, v):
                t_us = predict_us(kind, n, m, c, k, v,
                                  cand.block_n, cand.block_m, cand.block_c,
                                  version=ver)
                if t_us < best_t:
                    best_cfg, best_t, best_ver = cand, t_us, ver

    assert best_cfg is not None, f"no legal tiling for {key}"
    record = {
        **best_cfg.as_dict(),
        "predicted_us": best_t,
        "measured": measured,
        "source": "wallclock" if measured else "roofline_model",
    }
    if kind == "lut_amm":
        record["version"] = best_ver
    cache.put(key, record)
    if save:
        cache.save()
    return best_cfg, record


def kernel_choice(
    n: int, m: int, c: int, k: int, v: int,
    *, dtype: str = "float32", backend: str | None = None,
    interpret: bool = False,
    cache: AutotuneCache | None = None,
) -> tuple[int, BlockConfig, bool]:
    """Hot-path (version, blocks, from_record) selection for `ops.lut_amm`.

    Precedence (DESIGN.md §13.3): the cache record — measured or analytic,
    including records restored from an artifact snapshot — always wins, so
    callers never pin a version the tuner has seen lose. Records written
    before the version axis existed (no "version" key) mean v2, the default
    those callers ran. With no record at all, the fallback rule:

      * interpret mode and M <= 512  -> v1 (BENCH_kernels.json shows v2
        losing to v1 under emulation on small-M rows);
      * fused working set fits VMEM  -> v3 (analytically dominant: encode
        runs once instead of once per M block);
      * otherwise                    -> v2.
    """
    backend = backend or _backend()
    key = shape_key("lut_amm", n, m, c, k, v, dtype, backend)
    memo_key = None
    if cache is None:
        cache = get_cache()
        memo_key = f"{cache.path}|interpret={interpret}|{key}"
        if memo_key in _MEMO_CHOICE:
            return _MEMO_CHOICE[memo_key]
    rec = cache.get(key)
    if rec is not None:
        out = (
            int(rec.get("version", 2)),
            BlockConfig(rec["block_n"], rec["block_m"], rec["block_c"]),
            True,
        )
    elif interpret and m <= _SMALL_M_V1:
        out = (1, heuristic("lut_amm", n, m, c, k, v), False)
    elif next(iter(enumerate_candidates("fused", n, m, c, k, v)), None) is not None:
        out = (VERSION_FUSED, heuristic("fused", n, m, c, k, v), False)
    else:
        out = (2, heuristic("lut_amm", n, m, c, k, v), False)
    if memo_key is not None:
        _MEMO_CHOICE[memo_key] = out
    return out

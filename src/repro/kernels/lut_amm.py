"""Fused LUT-AMM Pallas TPU kernel: encode + table read + accumulate.

TPU adaptation of the paper's section-5 inference design (see DESIGN.md §2):

  * closest-centroid search  -> MXU dot(a_blk, P^T) per codebook block, with
    the codebook block pinned in VMEM across the whole N sweep
    (centroid-stationary: the BlockSpec index_map for `P` ignores the N grid
    coordinate, so the pipeline emitter keeps the same tile resident).
  * argmin                   -> VPU lane reduction (no sequential RAW hazard)
  * shuffle-instruction read -> one-hot x table matmul on the MXU
  * INT16/INT32 mixed accum  -> int8 table dequantized in-VMEM, fp32 MXU accum

Grid = (N/bn, M/bm, C/bc) with the codebook axis innermost so the (bn, bm)
output tile accumulates in place across codebook steps.

VMEM working set per step:
  x tile     bn * bc * V * 4
  P tile     bc * K * V * 4
  T tile     bc * K * bm   (int8)
  out tile   bn * bm * 4
Defaults (bn=256, bm=512, bc*V<=2048, K=16) stay under ~4 MB, leaving room
for double buffering in 16 MB of VMEM. bn is a multiple of 8 (f32 sublane),
bm a multiple of 128 (lane width), K=16 packs two one-hot groups per MXU
128-lane contraction slice.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lut_amm_kernel(x_ref, p_ref, t_ref, s_ref, o_ref, *, n_c_blocks: int):
    c_step = pl.program_id(2)

    a = x_ref[...].astype(jnp.float32)          # (bn, bc, V)
    p = p_ref[...].astype(jnp.float32)          # (bc, K, V)

    # squared distances: batch over codebooks on the MXU
    # (bc, bn, K) <- (bn, bc, V) x (bc, K, V)
    cross = jax.lax.dot_general(
        a, p,
        dimension_numbers=(((2,), (2,)), ((1,), (0,))),
        preferred_element_type=jnp.float32,
    )
    a_nrm = jnp.sum(a * a, axis=-1).T[:, :, None]        # (bc, bn, 1)
    p_nrm = jnp.sum(p * p, axis=-1)[:, None, :]          # (bc, 1, K)
    dists = a_nrm - 2.0 * cross + p_nrm                  # (bc, bn, K)

    # vectorized argmin over the K lane axis, then one-hot re-expansion
    idx = jnp.argmin(dists, axis=-1)                     # (bc, bn)
    k = dists.shape[-1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, dists.shape, 2)
    onehot = (lanes == idx[:, :, None]).astype(jnp.float32)   # (bc, bn, K)

    # dequantized table read as a one-hot MXU contraction
    table = t_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    # (bc, bn, bm) <- (bc, bn, K) x (bc, K, bm)
    part = jax.lax.dot_general(
        onehot, table,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    acc = jnp.sum(part, axis=0)                          # (bn, bm)

    @pl.when(c_step == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(c_step != 0)
    def _accum():
        o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_m", "block_c", "interpret"),
)
def lut_amm_pallas(
    x: jax.Array,          # (N, D)
    centroids: jax.Array,  # (C, K, V) fp32
    table_q: jax.Array,    # (C, K, M) int8
    scale: jax.Array,      # (C, 1, 1) or (C, 1, M) fp32
    *,
    block_n: int = 256,
    block_m: int = 512,
    block_c: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    n, d = x.shape
    c, k, v = centroids.shape
    m = table_q.shape[-1]
    if d != c * v:
        raise ValueError(f"D={d} != C*V={c}*{v}")

    bn = min(block_n, n)
    bm = min(block_m, m)
    bc = block_c if block_c is not None else max(1, min(c, 2048 // v))
    while c % bc:
        bc -= 1

    # pad N / M to block multiples (table M padding is cheap: int8 zeros)
    pad_n, pad_m = (-n) % bn, (-m) % bm
    xp = jnp.pad(x, ((0, pad_n), (0, 0))) if pad_n else x
    tp = jnp.pad(table_q, ((0, 0), (0, 0), (0, pad_m))) if pad_m else table_q
    sp = (
        jnp.pad(scale, ((0, 0), (0, 0), (0, pad_m)))
        if (pad_m and scale.shape[-1] != 1)
        else scale
    )
    np_, mp_ = n + pad_n, m + pad_m

    x_sub = xp.reshape(np_, c, v)
    grid = (np_ // bn, mp_ // bm, c // bc)
    s_m = 1 if scale.shape[-1] == 1 else bm

    out = pl.pallas_call(
        functools.partial(_lut_amm_kernel, n_c_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bc, v), lambda i, j, cc: (i, cc, 0)),
            pl.BlockSpec((bc, k, v), lambda i, j, cc: (cc, 0, 0)),
            pl.BlockSpec((bc, k, bm), lambda i, j, cc: (cc, 0, j)),
            pl.BlockSpec(
                (bc, 1, s_m),
                (lambda i, j, cc: (cc, 0, j)) if s_m != 1 else (lambda i, j, cc: (cc, 0, 0)),
            ),
            ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, cc: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp_), jnp.float32),
        interpret=interpret,
    )(x_sub, centroids.astype(jnp.float32), tp, sp)

    return out[:n, :m].astype(x.dtype)

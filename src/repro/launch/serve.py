"""Serving launcher: batched requests through the continuous-batching engine
with a LUT_INFER (int8 table) model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1p7b --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3_1p7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--use-kernel", action="store_true",
                    help="run LUT sites through the fused Pallas v2 kernel "
                         "(autotuner-warmed; interpret mode off-TPU)")
    args = ap.parse_args()

    arch = reduce_arch(get_arch(args.arch), lut_use_kernel=args.use_kernel)
    bundle = build_model(arch, Mode.LUT_INFER)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServingEngine(
        bundle, params, n_slots=args.slots, max_seq=args.max_seq,
        compute_dtype=jnp.float32,
    )

    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for i in range(args.requests):
        key, k = jax.random.split(key)
        plen = int(jax.random.randint(k, (), 4, 24))
        prompt = list(range(i + 1, i + 1 + plen))
        eng.submit(prompt, max_tokens=args.max_tokens)
    done = eng.run_until_done()
    dt = time.time() - t0
    total_tok = sum(len(r.out_tokens) for r in done)
    mode = "pallas-v2 kernel" if args.use_kernel else "XLA one-hot"
    print(f"{len(done)} requests, {total_tok} tokens in {dt:.1f}s "
          f"({total_tok/dt:.1f} tok/s, {args.slots} slots, LUT INT8 tables, "
          f"{mode}, {eng.n_lut_shapes_tuned} LUT shapes autotuned)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()

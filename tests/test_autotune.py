"""Shape-keyed block autotuner: budget model, cost model, cache round-trip,
and the serving-engine warmup wiring (DESIGN.md §3)."""

import dataclasses
import json

import numpy as np
import pytest

from repro.kernels import autotune


def test_candidates_respect_vmem_budget():
    cands = list(autotune.enumerate_candidates("lut_amm", 4096, 14336, 128, 16, 32))
    assert cands, "must always yield at least one tiling"
    for c in cands:
        assert autotune.vmem_bytes(c.block_n, c.block_m, c.block_c, 16, 32) \
            <= autotune.VMEM_BUDGET
        assert 128 % c.block_c == 0


def test_predict_v2_never_slower_than_v1():
    """The analytic model must encode v2's advantage: no per-step dequant
    pass, doubled int8 MXU rate."""
    for (n, m, c, k, v) in [(256, 4096, 128, 16, 32), (8, 512, 16, 16, 8)]:
        for cand in autotune.enumerate_candidates("lut_amm", n, m, c, k, v):
            t1 = autotune.predict_us("lut_amm", n, m, c, k, v,
                                     cand.block_n, cand.block_m, cand.block_c,
                                     version=1)
            t2 = autotune.predict_us("lut_amm", n, m, c, k, v,
                                     cand.block_n, cand.block_m, cand.block_c,
                                     version=2)
            assert t2 <= t1


def test_lookup_heuristic_on_cache_miss(tmp_path):
    cache = autotune.AutotuneCache(tmp_path / "c.json")
    cfg = autotune.lookup("lut_amm", 100, 300, 8, 16, 8, cache=cache)
    assert cfg == autotune.heuristic("lut_amm", 100, 300, 8, 16, 8)


def test_tune_cache_roundtrip(tmp_path):
    """tune() persists the winner; a fresh cache object loads it back and
    lookup() serves it instead of the heuristic."""
    path = tmp_path / "cache.json"
    cache = autotune.AutotuneCache(path)
    shape = ("lut_amm", 64, 256, 16, 16, 8)
    best, rec = autotune.tune(*shape, dtype="float32", backend="cpu", cache=cache)
    assert path.exists()
    assert rec["source"] == "roofline_model" and not rec["measured"]

    fresh = autotune.AutotuneCache(path)
    got = autotune.lookup(*shape, dtype="float32", backend="cpu", cache=fresh)
    assert got == best

    # raw JSON sanity: versioned schema with the documented key format
    raw = json.loads(path.read_text())
    assert raw["version"] == 1
    key = autotune.shape_key("lut_amm", 64, 256, 16, 16, 8, "float32", "cpu")
    assert set(raw["entries"]) == {key}
    assert raw["entries"][key]["block_n"] == best.block_n


def test_tune_picks_measured_winner(tmp_path):
    """With a measure callable the tuner minimizes wall-clock, not the model."""
    cache = autotune.AutotuneCache(tmp_path / "m.json")
    target = autotune.BlockConfig(16, 128, 2)

    def measure(cfg):
        return 1e-6 if cfg == target else 1e-3

    best, rec = autotune.tune("lut_amm", 64, 256, 4, 16, 8,
                              cache=cache, measure=measure)
    assert best == target and rec["measured"] and rec["source"] == "wallclock"


def test_corrupt_cache_degrades_gracefully(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text("{not json")
    cache = autotune.AutotuneCache(path)
    assert cache.get("anything") is None
    cfg = autotune.lookup("encode", 32, 0, 4, 16, 8, cache=cache)
    assert cfg == autotune.heuristic("encode", 32, 0, 4, 16, 8)


def test_engine_warmup_populates_cache(key, tmp_path, monkeypatch):
    """ServingEngine with a use_kernel bundle pre-tunes the decode/prefill
    LUT shapes into the autotune cache (DESIGN.md §3.3)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "warm.json"))
    from repro.configs import build_model, get_arch, reduce_arch
    from repro.core.amm import Mode
    from repro.serving.engine import ServingEngine, iter_lut_kernel_sites

    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, lut_use_kernel=True)
    bundle = build_model(arch, Mode.LUT_INFER)
    assert len(list(iter_lut_kernel_sites(bundle.cfg))) > 0

    params = bundle.init(key)
    eng = ServingEngine(bundle, params, n_slots=2, max_seq=32, prefill_chunk=8)
    assert eng.n_lut_shapes_tuned > 0
    raw = json.loads((tmp_path / "warm.json").read_text())
    assert len(raw["entries"]) == eng.n_lut_shapes_tuned
    # decode shape (N = n_slots) is among the tuned keys
    assert any("|n=2|" in k for k in raw["entries"])

    # and the engine still serves correctly through the kernel path
    eng.submit([1, 2, 3], max_tokens=3)
    done = eng.run_until_done()
    assert len(done) == 1 and len(done[0].out_tokens) == 3
    assert all(np.isfinite(t) for t in done[0].out_tokens)


def test_blockconfig_is_hashable_frozen():
    cfg = autotune.BlockConfig(8, 128, 1)
    assert hash(cfg) == hash(autotune.BlockConfig(8, 128, 1))
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.block_n = 16

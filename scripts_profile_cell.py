import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "/root/repo/src")
import json
from repro.launch.dryrun import lower_cell
from repro.roofline.hlo_cost import hotspots

arch, shape = sys.argv[1], sys.argv[2]
kw = json.loads(sys.argv[3]) if len(sys.argv) > 3 else {}
rec, compiled = lower_cell(arch, shape, **kw)
r = rec["roofline"]
print(f"== {arch} x {shape} {kw} ==")
print(f"mem/dev {rec['memory']['total_hbm_bytes']/2**30:.2f} GiB | "
      f"t_comp {r['t_compute_s']:.3f}s t_mem {r['t_memory_s']:.3f}s t_coll {r['t_collective_s']:.3f}s -> {r['bottleneck']}")
print("collectives by kind (GB/dev):", {k: round(v/1e9, 2) for k, v in r['collective_by_kind'].items()})
print(f"{'op_name':70s} {'GFLOP':>9s} {'GB':>9s} {'collGB':>8s}")
for name, c in hotspots(compiled.as_text(), top=22, depth=5):
    print(f"{name[:70]:70s} {c.flops/1e9:9.1f} {c.bytes/1e9:9.2f} {c.coll_bytes/1e9:8.2f}")

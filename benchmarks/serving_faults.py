"""Serving robustness under injected faults: availability + latency tails.

Four scenarios over the same tiny LUT_INFER artifact and request load
(DESIGN.md §11.3):

  * fault_free       — supervised engine, no faults: the baseline row and
                       the token-parity reference
  * transient_errors — injected step exceptions absorbed by the in-worker
                       StepGuard retry (no restart expected)
  * worker_kill      — the worker is hard-killed mid-load (InjectedKill ->
                       os._exit); the supervisor restarts from the artifact
                       and requeues, so availability stays 1.0 at the cost
                       of the requeued requests' latency
  * overload_shed    — an unsupervised engine with a tiny bounded queue and
                       tight deadlines under 4x oversubscription: overload
                       degrades by shedding low-priority work, not by
                       growing memory

Each row records availability (= fraction of submitted rids terminal
"ok" — every rid MUST be terminal, silent loss is an assertion failure),
latency p50/p99 over the ok requests, terminal-status counts, and
supervisor restart/requeue counters. The faulty scenarios also assert
byte-identical token output vs the fault_free row for every request that
completed without a retry. With `json_path` (benchmarks/run.py --json) the
rows land in BENCH_faults.json.
"""

from __future__ import annotations

import json
import pathlib
import tempfile
import time

import jax

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.artifact import save_artifact
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultSpec
from repro.serving.supervisor import EngineSupervisor

N_REQUESTS = 8
MAX_TOKENS = 8
ENGINE_KW = dict(n_slots=2, max_seq=64, prefill_chunk=8)


def _prompts() -> list[list[int]]:
    return [[(i * 7 + j) % 256 + 1 for j in range(4 + (i % 5))]
            for i in range(N_REQUESTS)]


def _percentile(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    idx = min(int(round(q * (len(xs) - 1))), len(xs) - 1)
    return xs[idx]


def _row_from_results(name: str, results: dict, wall_s: float,
                      extra: dict | None = None) -> dict:
    statuses = [r["status"] for r in results.values()]
    assert all(s is not None for s in statuses), f"{name}: silently lost rids"
    lat = [r["latency_s"] for r in results.values() if r["status"] == "ok"]
    counts = {s: statuses.count(s) for s in set(statuses)}
    row = {
        "scenario": name,
        "requests": len(results),
        "availability": round(counts.get("ok", 0) / len(results), 3),
        "p50_s": round(_percentile(lat, 0.50), 3),
        "p99_s": round(_percentile(lat, 0.99), 3),
        "ok": counts.get("ok", 0),
        "shed": counts.get("shed", 0),
        "timeout": counts.get("timeout", 0),
        "error": counts.get("error", 0),
        "wall_s": round(wall_s, 3),
    }
    row.update(extra or {})
    return row


def _run_supervised(artifact: pathlib.Path, name: str,
                    faults: FaultSpec | None) -> tuple[dict, dict]:
    sup = EngineSupervisor(
        artifact, engine_kwargs=ENGINE_KW, faults=faults, retry_budget=2,
    )
    try:
        t0 = time.perf_counter()
        submit_t: dict[int, float] = {}
        grids = []
        for p in _prompts():
            g = sup.submit({"prompt": p, "max_tokens": MAX_TOKENS})
            submit_t[g] = time.perf_counter()
            grids.append(g)
        results = {}
        for g in grids:
            st = sup.wait(g, timeout=600)
            results[g] = {
                "status": st.status,
                "tokens": list(st.tokens),
                "retries": st.retries,
                "latency_s": time.perf_counter() - submit_t[g],
            }
        wall = time.perf_counter() - t0
        sstats = sup.stats()
        extra = {"restarts": sstats.get("restarts", 0),
                 "requeued": sstats.get("requeued", 0),
                 "lost": sstats.get("lost", 0)}
    finally:
        sup.close()
    return _row_from_results(name, results, wall, extra), results


def _run_overload(bundle, params) -> dict:
    """Unsupervised engine, tiny bounded queue, 4x oversubscription, tight
    deadlines on the low-priority half: overload resolves as shed/timeout,
    never as unbounded queue growth or a hang."""
    eng = ServingEngine(bundle, params, autotune_lut=False,
                        max_queue=4, **ENGINE_KW)
    eng.warmup()
    t0 = time.perf_counter()
    rids = []
    for i in range(4 * N_REQUESTS):
        rids.append(eng.submit(
            [(i * 5 + j) % 256 + 1 for j in range(6)],
            max_tokens=MAX_TOKENS,
            priority=(1 if i % 2 else 0),
            # a slice of the surviving high-priority work carries a deadline
            # tighter than the 2-slot engine can serve it: exercises the
            # timeout sweep alongside the shed path
            deadline_s=(0.02 if i % 4 == 1 else 30.0),
        ))
    done = {r.rid: r for r in eng.run_until_done(max_steps=10_000)}
    wall = time.perf_counter() - t0
    assert set(done) == set(rids), "overload: silently lost rids"
    results = {
        rid: {"status": done[rid].status, "tokens": done[rid].out_tokens,
              "retries": 0, "latency_s": done[rid].latency_s}
        for rid in rids
    }
    st = eng.stats()
    return _row_from_results(
        "overload_shed", results, wall,
        {"queue_high_water": 4, "max_queue_depth_end": st["queue_depth"]},
    )


def main(json_path: str | pathlib.Path | None = None) -> list[dict]:
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
    bundle = build_model(arch, Mode.LUT_INFER)
    params = bundle.init(jax.random.PRNGKey(0))

    rows: list[dict] = []
    cols = ["scenario", "requests", "availability", "p50_s", "p99_s",
            "ok", "shed", "timeout", "error", "restarts", "requeued"]
    print(",".join(cols))

    def emit(row: dict) -> None:
        rows.append(row)
        print(",".join(str(row.get(c, "")) for c in cols))

    with tempfile.TemporaryDirectory() as td:
        artifact = pathlib.Path(td) / "bench_artifact"
        save_artifact(artifact, bundle, params)

        base_row, base = _run_supervised(artifact, "fault_free", None)
        emit(base_row)

        # transient step exceptions: absorbed in-worker, zero restarts
        row, res = _run_supervised(
            artifact, "transient_errors", FaultSpec(seed=7, error_steps=(2, 9)),
        )
        _assert_parity(base, res)
        emit(row)

        # one hard worker kill mid-run: restart from artifact + requeue
        row, res = _run_supervised(
            artifact, "worker_kill", FaultSpec(kill_at_step=4),
        )
        _assert_parity(base, res)
        emit(row)

    emit(_run_overload(bundle, params))

    if json_path is not None:
        payload = {
            "schema": "serving_faults.v1",
            "arch": "qwen3_1p7b(reduced,L=2)",
            "mode": "lut_infer",
            "backend": jax.default_backend(),
            "engine": ENGINE_KW,
            "rows": rows,
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=1))
        print(f"# wrote {json_path}")
    return rows


def _assert_parity(base: dict, res: dict) -> None:
    """Non-retried ok requests must be byte-identical to the fault-free
    run (deterministic sampling survives faults + restarts)."""
    for g, r in res.items():
        if r["status"] == "ok" and r["retries"] == 0:
            assert r["tokens"] == base[g]["tokens"], (
                f"request {g}: tokens diverged under faults"
            )


if __name__ == "__main__":
    import sys

    _JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_faults.json"
    main(json_path=_JSON if "--json" in sys.argv else None)

"""Paper Fig. 11: learned temperature > annealed > fixed t=1.

Same soft-PQ fine-tune, three temperature strategies, accuracy curves.
"""

from __future__ import annotations

import time

import jax

from benchmarks._mlp import MLPSpec, attach_pq, evaluate, finetune_softpq, train_dense
from repro.data import ClusteredTask


def main(steps: int = 240) -> None:
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    spec = MLPSpec(d_in=64, width=128, depth=4, n_out=10)
    task = ClusteredTask(d_in=spec.d_in, n_classes=10)
    dense = train_dense(key, spec, task, steps=300)
    layer_ids = list(range(1, spec.depth + 1))

    curves = {}
    finals = {}
    for mode in ("learned", "fixed", "anneal"):
        p0 = attach_pq(key, dense, spec, task, layer_ids, kind="pq")
        _, curve = finetune_softpq(
            key, p0, spec, task, layer_ids, steps=steps, temp_mode=mode
        )
        curves[mode] = curve
        finals[mode] = curve[-1][2]

    print("# Fig. 11 analog: temperature strategy vs accuracy during soft-PQ")
    print("step," + ",".join(curves))
    for row in zip(*curves.values()):
        print(f"{row[0][0]}," + ",".join(f"{r[2]:.4f}" for r in row))
    print("final," + ",".join(f"{finals[m]:.4f}" for m in curves))
    print(f"claim_learned_best,{finals['learned'] >= max(finals['fixed'], finals['anneal']) - 0.01}")
    print(f"fig11_temperature,{(time.time()-t0)*1e6:.0f},curves")


if __name__ == "__main__":
    main()

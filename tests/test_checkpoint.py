import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {
        "a": {"w": jax.random.normal(k1, (4, 8)) * scale},
        "b": [jnp.arange(3.0), {"c": jax.random.normal(k2, (2,)) * scale}],
    }


def test_roundtrip(tmp_path, key):
    ck = Checkpointer(tmp_path)
    t = _tree(key)
    ck.save(7, t, blocking=True)
    step, r = ck.restore(t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_keep_last(tmp_path, key):
    ck = Checkpointer(tmp_path, keep_last=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(key, s), blocking=True)
    assert ck.latest_step() == 4
    assert ck.all_steps() == [3, 4]
    _, r = ck.restore(_tree(key))
    np.testing.assert_allclose(np.asarray(r["a"]["w"]), np.asarray(_tree(key, 4)["a"]["w"]))


def test_async_save_nonblocking(tmp_path, key):
    ck = Checkpointer(tmp_path)
    t = _tree(key)
    ck.save(1, t, blocking=False)        # returns immediately
    ck.wait()
    assert ck.latest_step() == 1


def test_atomic_no_partial(tmp_path, key):
    """A .tmp dir left behind by a crash must never be picked up."""
    ck = Checkpointer(tmp_path)
    ck.save(5, _tree(key), blocking=True)
    bad = tmp_path / "step_00000009.tmp"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"junk")
    assert ck.latest_step() == 5


def test_restore_with_shardings(tmp_path, key):
    ck = Checkpointer(tmp_path)
    t = _tree(key)
    ck.save(1, t, blocking=True)
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t
    )
    _, r = ck.restore(t, shardings=shardings)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

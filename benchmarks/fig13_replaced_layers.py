"""Paper Fig. 13: BERT accuracy vs number of replaced (last-n) layers.

Uses the real bert_base config (reduced width for CPU) on the Markov LM
task: replace the FC operators of the last n layers, soft-PQ fine-tune,
report eval loss. The paper's observation: the FRONT layers are
accuracy-critical; replacing only the back layers is nearly free.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import build_model, get_arch, reduce_arch
from repro.core import convert
from repro.core.amm import Mode
from repro.data import MarkovLM
from repro.optim import SOFT_PQ_RULES, AdamW, lut_frozen_mask
from repro.train.train_step import make_train_step


def main(steps: int = 120) -> None:
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    base = reduce_arch(get_arch("bert_base"), n_layers=6, vocab=64, d_model=64, d_ff=128,
                       causal=True)     # causal LM task carrier
    data = MarkovLM(vocab=base.vocab, seq_len=24, batch=8)

    dense = build_model(dataclasses.replace(base, lut_policy="last_n:0"), Mode.DENSE)
    dparams = dense.init(key)
    opt = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(dense, opt, compute_dtype=jnp.float32))
    ostate = opt.init(dparams)
    for i in range(steps * 2):
        dparams, ostate, m = step(dparams, ostate, data.batch_at(i))
    base_loss = float(dense.loss(dparams, data.batch_at(9_999), compute_dtype=jnp.float32))

    print("# Fig. 13 analog: eval loss vs number of replaced (last-n) layers")
    print(f"n_replaced,eval_loss  (dense baseline {base_loss:.4f})")
    losses = {}
    for n in (0, 2, 4, 6):
        if n == 0:
            losses[n] = base_loss
            print(f"0,{base_loss:.4f}")
            continue
        arch = dataclasses.replace(base, lut_policy=f"last_n:{n}")
        dense_n = build_model(arch, Mode.DENSE)
        samples = [data.batch_at(50_000 + i) for i in range(2)]
        blut, lparams = convert.convert_dense_to_lut_train(dense_n, dparams, samples, key)
        frozen = lut_frozen_mask(lparams)
        opt2 = AdamW(lr=1e-3, rules=SOFT_PQ_RULES)
        step2 = jax.jit(make_train_step(blut, opt2, frozen_mask=frozen, compute_dtype=jnp.float32))
        o2 = opt2.init(lparams, frozen)
        for i in range(steps):
            lparams, o2, _ = step2(lparams, o2, data.batch_at(i))
        losses[n] = float(blut.loss(lparams, data.batch_at(9_999), compute_dtype=jnp.float32))
        print(f"{n},{losses[n]:.4f}")
    print(f"claim_back_layers_cheap,{losses[2] < losses[6] + 0.5}")
    print(f"fig13_replaced_layers,{(time.time()-t0)*1e6:.0f},loss_curve")


if __name__ == "__main__":
    main()

from repro.data.synthetic import ClusteredTask, MarkovLM, host_shard

__all__ = ["MarkovLM", "ClusteredTask", "host_shard"]

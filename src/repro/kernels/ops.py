"""jit'd public wrappers for the LUT kernels with platform dispatch.

`lut_amm` runs the fused Pallas kernel on TPU and transparently falls back to
interpret mode elsewhere (this container is CPU-only: interpret=True executes
the kernel body in Python for correctness validation; the XLA one-hot path in
repro.core.pq is the production fallback used by the distributed dry-run).
"""

from __future__ import annotations

import jax

from repro.kernels.lut_amm import lut_amm_pallas
from repro.kernels.ref import encode_ref, lut_amm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def lut_amm(
    x: jax.Array,
    centroids: jax.Array,
    table_q: jax.Array,
    scale: jax.Array,
    *,
    block_n: int = 256,
    block_m: int = 512,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused LUT-NN approximate matmul: (N, D) -> (N, M)."""
    if interpret is None:
        interpret = not _on_tpu()
    return lut_amm_pallas(
        x,
        centroids,
        table_q,
        scale,
        block_n=block_n,
        block_m=block_m,
        block_c=block_c,
        interpret=interpret,
    )


__all__ = ["lut_amm", "lut_amm_ref", "encode_ref"]

"""Command-R 35B — GQA, no biases [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs import ArchSpec

ARCH = ArchSpec(
    name="command_r_35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22528,
    vocab=256000,
    use_bias=False,
    rope_theta=8_000_000.0,
    param_dtype="bfloat16",
    grad_accum=2,
)

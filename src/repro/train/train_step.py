"""pjit-able train/serve step factories.

`make_train_step` builds the canonical production step:
  loss (bf16 compute, fp32 reductions) -> grads -> global-norm clip ->
  AdamW with param groups -> new params/opt-state + metrics.

Loss variants (recipe stages, DESIGN.md §10):
  * plain CE (dense pretrain, soft-PQ fine-tune)
  * distillation (`distill=DistillSpec(...)` + a frozen dense teacher):
    (1-w)·CE + w·τ²·KL(teacher‖student) over temperature-τ-softened
    logits — the Deep Lookup Network / TableNet recipe of training the
    LUT-constrained student directly against the dense model's outputs.
    Metrics then additionally report `ce` and `distill_kl`.

Metrics always include the learned softmax temperature summary when the
param tree has LUT sites: `t_mean`/`t_min` over every `log_t` leaf, so
centroid-learning convergence (t -> 0, the argmax limit) is observable in
the trainer's history and log lines.

Gradient accumulation (giant archs) scans over microbatches so the saved
activations of only one microbatch are live at a time; grads accumulate in
fp32. Under pjit, the gradient all-reduce across the data axes is emitted
by GSPMD from the sharding of params (replicated or FSDP) vs batch (data-
sharded) — no explicit collectives here.

`make_compressed_train_step` is the opt-in data-parallel variant wiring
`repro.train.grad_compression` (int8 error-feedback all-reduce) under the
same (params, state, batch) step contract: the compression residual rides
inside the opaque optimizer-state slot, so the Trainer checkpoints and
restores it with no special casing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import ModelBundle
from repro.optim import AdamW, AdamWState


@dataclasses.dataclass(frozen=True)
class DistillSpec:
    """Dense-teacher distillation term for the soft-PQ fine-tune.

    weight:      mix of the KL term, loss = (1-w)·CE + w·KL (0 disables)
    temperature: softening τ; the KL is scaled by τ² so its gradient
                 magnitude stays comparable across τ (Hinton et al.)
    """

    weight: float = 0.5
    temperature: float = 2.0

    def __post_init__(self):
        if not (0.0 <= self.weight <= 1.0):
            raise ValueError(f"distill weight must be in [0, 1], got {self.weight}")
        if self.temperature <= 0.0:
            raise ValueError(f"distill temperature must be > 0, got {self.temperature}")

    def to_dict(self) -> dict[str, Any]:
        return {"weight": self.weight, "temperature": self.temperature}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "DistillSpec":
        return cls(weight=d["weight"], temperature=d["temperature"])


def make_loss_fn(bundle: ModelBundle, *, compute_dtype=jnp.bfloat16):
    def loss_fn(params, batch):
        return bundle.loss(params, batch, compute_dtype=compute_dtype)

    return loss_fn


def make_distill_loss_fn(
    bundle: ModelBundle,
    distill: DistillSpec,
    teacher_bundle: ModelBundle,
    teacher_params: Any,
    *,
    compute_dtype=jnp.bfloat16,
):
    """(params, batch) -> (loss, {"ce", "distill_kl"}) against the frozen
    dense teacher's logits. Teacher and student must share the vocab (they
    are the same arch in DENSE vs LUT_TRAIN mode)."""
    tau = distill.temperature
    w = distill.weight

    def loss_fn(params, batch):
        from repro.models.common import cross_entropy
        from repro.models.transformer import LM_AUX_WEIGHT

        logits, aux = bundle.train_logits(params, batch, compute_dtype=compute_dtype)
        ce = cross_entropy(logits, batch["labels"])      # task CE only
        t_logits, _ = teacher_bundle.train_logits(
            teacher_params, batch, compute_dtype=compute_dtype
        )
        t_logits = jax.lax.stop_gradient(t_logits).astype(jnp.float32)
        s_logp = jax.nn.log_softmax(logits.astype(jnp.float32) / tau, axis=-1)
        t_logp = jax.nn.log_softmax(t_logits / tau, axis=-1)
        kl = jnp.mean(jnp.sum(jnp.exp(t_logp) * (t_logp - s_logp), axis=-1)) * tau**2
        loss = (1.0 - w) * ce + w * kl
        # MoE load-balance penalty rides OUTSIDE the CE/KL blend — scaling
        # it by (1-w) would switch router balancing off at w=1
        if bundle.kind == "lm":
            loss = loss + LM_AUX_WEIGHT * aux
        return loss, {"ce": ce, "distill_kl": kl}

    return loss_fn


def temperature_stats(params: Any) -> dict[str, jax.Array]:
    """mean/min of t = exp(log_t) over every LUT site in the tree (empty
    dict for a dense model). Trace-safe: leaf selection is by path."""
    ts = []
    for kp, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if kp and str(getattr(kp[-1], "key", "")) == "log_t":
            ts.append(jnp.exp(leaf.astype(jnp.float32)).ravel())
    if not ts:
        return {}
    t = jnp.concatenate(ts)
    return {"t_mean": jnp.mean(t), "t_min": jnp.min(t)}


def _with_aux(loss_fn: Callable) -> Callable:
    """Normalize a loss fn to the (loss, aux_dict) contract."""

    def fn(params, batch):
        out = loss_fn(params, batch)
        if isinstance(out, tuple):
            return out
        return out, {}

    return fn


def make_train_step(
    bundle: ModelBundle,
    opt: AdamW,
    *,
    frozen_mask: Any | None = None,
    compute_dtype=jnp.bfloat16,
    grad_accum: int = 1,
    loss_fn: Callable | None = None,
) -> Callable:
    """The canonical step. `loss_fn` overrides the plain CE loss (e.g. with
    `make_distill_loss_fn`); it may return a scalar or (scalar, aux_dict) —
    aux entries are merged into the step metrics."""
    loss_fn = _with_aux(
        loss_fn if loss_fn is not None
        else make_loss_fn(bundle, compute_dtype=compute_dtype)
    )

    def split_micro(batch):
        def r(a):
            if a.ndim == 0:
                return a
            b = a.shape[0]
            if a.shape[0] % grad_accum:
                raise ValueError(f"batch {b} not divisible by grad_accum {grad_accum}")
            return a.reshape(grad_accum, b // grad_accum, *a.shape[1:])

        # pos (3, B, S) splits on axis 1
        out = {}
        for k, v in batch.items():
            if k == "pos" and v.ndim == 3:
                g = v.shape[1] // grad_accum
                out[k] = v.reshape(3, grad_accum, g, v.shape[2]).swapaxes(0, 1)
            else:
                out[k] = r(v)
        return out

    def train_step(params, opt_state: AdamWState, batch):
        if grad_accum == 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            micro = split_micro(batch)

            def acc_fn(carry, mb):
                loss_acc, aux_acc, g_acc = carry
                (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                aux_acc = jax.tree.map(lambda x, y: x + y, aux_acc, a)
                return (loss_acc + l, aux_acc, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            aux_spec = jax.eval_shape(
                loss_fn, params, jax.tree.map(lambda m: m[0], micro)
            )[1]
            a0 = jax.tree.map(lambda _: jnp.zeros((), jnp.float32), aux_spec)
            (loss, aux, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), a0, g0), micro
            )
            loss = loss / grad_accum
            aux = jax.tree.map(lambda a: a / grad_accum, aux)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        new_params, new_opt, gnorm = opt.update(grads, opt_state, params, frozen_mask)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        metrics.update({k: v.astype(jnp.float32) for k, v in aux.items()})
        metrics.update(temperature_stats(new_params))
        return new_params, new_opt, metrics

    return train_step


def make_compressed_train_step(
    bundle: ModelBundle,
    opt: AdamW,
    mesh,
    *,
    axis: str = "data",
    frozen_mask: Any | None = None,
    compute_dtype=jnp.bfloat16,
) -> Callable:
    """Data-parallel step with the int8 error-feedback gradient reduce
    (repro.train.grad_compression) in place of GSPMD's implicit bf16
    all-reduce. EXPERIMENTAL (DESIGN.md §10.4): grads cross the wire as
    int8 — numerics differ from the exact step.

    State contract: `opt_state` is `{"opt": AdamWState, "residual": tree}`
    (build with `init_compressed_state`); batch dim 0 is sharded over
    `axis`. Otherwise identical to `make_train_step`'s contract, so the
    Trainer drives and checkpoints it unchanged.
    """
    from repro.train.grad_compression import make_compressed_grad_fn

    loss_fn = make_loss_fn(bundle, compute_dtype=compute_dtype)
    grad_fn = make_compressed_grad_fn(loss_fn, mesh, axis=axis)

    def train_step(params, state, batch):
        loss, grads, new_residual = grad_fn(params, state["residual"], batch)
        new_params, new_opt, gnorm = opt.update(
            grads, state["opt"], params, frozen_mask
        )
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        metrics.update(temperature_stats(new_params))
        return new_params, {"opt": new_opt, "residual": new_residual}, metrics

    return train_step


def init_compressed_state(opt: AdamW, params: Any, frozen: Any | None = None) -> dict:
    from repro.train.grad_compression import init_residual

    return {"opt": opt.init(params, frozen), "residual": init_residual(params)}


def make_serve_step(bundle: ModelBundle, *, compute_dtype=jnp.bfloat16) -> Callable:
    def serve_step(params, batch, caches):
        return bundle.forward_step(params, batch, caches, compute_dtype=compute_dtype)

    return serve_step

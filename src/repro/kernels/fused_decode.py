"""Fused encode→lookup decode kernel, v3 (DESIGN.md §13).

The v1/v2 kernels tile a (N/bn, M/bm, C/bc) grid with the codebook axis
innermost — correct, but the dist-argmin encode is recomputed for EVERY
M block (the encode matmul is charged M/bm times), and the alternative
two-pass path (`encode_pallas` then a table read) round-trips the codes
through HBM between kernels. This kernel does neither:

  * grid = (N/bn, M/bm), M innermost. The whole codebook axis is
    VMEM-resident (BlockSpec index maps for x and the centroids ignore the
    M coordinate), so the per-step working set is bounded by the VMEM
    budget model's kind="fused" branch (repro.kernels.autotune).
  * the encode — squared-distance argmin per codebook subvector — runs
    exactly once per N tile, under `pl.when(m_step == 0)`, and writes the
    int8 one-hot codes into a VMEM scratch buffer. Scratch persists across
    grid steps, so every M step of the sweep reuses the same codes. The
    codes never have an output ref: they cannot touch HBM.
  * the int8 table tile's index map depends on the innermost grid axis, so
    the pipeline emitter double-buffers its DMA: while the MXU contracts
    M-tile j, tile j+1 streams in. Decode at batch = n_slots therefore
    stays table-bandwidth/MXU-bound instead of latency-bound on encode
    recomputation.
  * dequant + bias + activation ride the single write of each output tile
    (each (bn, bm) tile is visited exactly once — no accumulator, no
    read-modify-write).

Scale layouts (repro.core.quant):

  m-shared (1,1,M) / scalar (1,1,1) — the scale factors out of the codebook
  sum: codes are kept in (bn, C·K) layout and contracted against the
  (C·K, bm) int8 table in ONE int8 MXU matmul with int32 accumulation
  (exact integer arithmetic — byte-identical to the two-pass reference),
  dequantized once per output tile.

  per-codebook (C,1,1) / per-column (C,1,M) — the scale cannot factor out:
  codes are kept in (C, bn, K) layout and contracted in C-chunks sized to
  bound the (chunk, bn, bm) int32 partial, each chunk rescaled in fp32
  before the sum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import autotune
from repro.kernels.lut_amm import ACTIVATIONS, _apply_act


def _fused_decode_kernel(
    *refs,
    shared_scale: bool,
    has_bias: bool,
    act: str,
    chunk_c: int,
):
    if has_bias:
        x_ref, p_ref, t_ref, s_ref, b_ref, o_ref, code_ref = refs
    else:
        x_ref, p_ref, t_ref, s_ref, o_ref, code_ref = refs
        b_ref = None
    m_step = pl.program_id(1)

    # ---- encode: once per N tile, codes pinned in VMEM for the M sweep ----
    @pl.when(m_step == 0)
    def _encode():
        a = x_ref[...].astype(jnp.float32)               # (bn, C, V)
        p = p_ref[...].astype(jnp.float32)               # (C, K, V)
        cross = jax.lax.dot_general(
            a, p,
            dimension_numbers=(((2,), (2,)), ((1,), (0,))),
            preferred_element_type=jnp.float32,
        )                                                # (C, bn, K)
        a_nrm = jnp.sum(a * a, axis=-1).T[:, :, None]    # (C, bn, 1)
        p_nrm = jnp.sum(p * p, axis=-1)[:, None, :]      # (C, 1, K)
        dists = a_nrm - 2.0 * cross + p_nrm              # (C, bn, K)
        idx = jnp.argmin(dists, axis=-1)                 # (C, bn)
        if shared_scale:
            # (bn, C, K) layout: reshapes to the (bn, C·K) single-matmul form
            shape = (idx.shape[1], idx.shape[0], dists.shape[-1])
            lanes = jax.lax.broadcasted_iota(jnp.int32, shape, 2)
            code_ref[...] = (lanes == idx.T[:, :, None]).astype(jnp.int8)
        else:
            # (C, bn, K) layout: feeds the per-codebook chunked contraction
            lanes = jax.lax.broadcasted_iota(jnp.int32, dists.shape, 2)
            code_ref[...] = (lanes == idx[:, :, None]).astype(jnp.int8)

    # ---- lookup: int8 codes x int8 table tile, per M step ----
    codes = code_ref[...]
    t = t_ref[...]                                       # (C, K, bm) int8
    if shared_scale:
        bn_, c_, k_ = codes.shape
        acc32 = jax.lax.dot_general(
            codes.reshape(bn_, c_ * k_), t.reshape(c_ * k_, t.shape[-1]),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )                                                # (bn, bm) exact
        acc = acc32.astype(jnp.float32) * s_ref[...].reshape(1, -1)
    else:
        c_, bn_, _ = codes.shape
        s = s_ref[...].astype(jnp.float32)               # (C, 1, 1|bm)
        acc = jnp.zeros((bn_, t.shape[-1]), jnp.float32)
        for c0 in range(0, c_, chunk_c):
            c1 = min(c_, c0 + chunk_c)
            part = jax.lax.dot_general(
                codes[c0:c1], t[c0:c1],
                dimension_numbers=(((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.int32,
            )                                            # (cc, bn, bm)
            acc = acc + jnp.sum(part.astype(jnp.float32) * s[c0:c1], axis=0)

    if has_bias:
        acc = acc + b_ref[...].astype(jnp.float32)
    o_ref[...] = _apply_act(acc, act)


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_m", "act", "interpret"),
)
def _fused_decode_call(
    x_sub, centroids, table_q, scale, bias,
    *, block_n, block_m, act, interpret,
):
    np_, c, v = x_sub.shape
    k = centroids.shape[1]
    mp_ = table_q.shape[-1]
    bn, bm = block_n, block_m
    grid = (np_ // bn, mp_ // bm)
    shared_scale = scale.shape[0] == 1
    s_m = 1 if scale.shape[-1] == 1 else bm
    s_c = 1 if shared_scale else c
    # bound the (chunk, bn, bm) int32 partial of the non-shared path to ~2 MB
    chunk_c = max(1, min(c, (1 << 21) // max(1, 4 * bn * bm)))

    s_spec = pl.BlockSpec(
        (s_c, 1, s_m),
        (lambda i, j: (0, 0, j)) if s_m != 1 else (lambda i, j: (0, 0, 0)),
    )
    in_specs = [
        pl.BlockSpec((bn, c, v), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((c, k, v), lambda i, j: (0, 0, 0)),
        pl.BlockSpec((c, k, bm), lambda i, j: (0, 0, j)),
        s_spec,
    ]
    operands = [x_sub, centroids.astype(jnp.float32), table_q, scale]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bm), lambda i, j: (0, j)))
        operands.append(bias.reshape(1, -1))

    code_shape = (bn, c, k) if shared_scale else (c, bn, k)
    return pl.pallas_call(
        functools.partial(
            _fused_decode_kernel,
            shared_scale=shared_scale,
            has_bias=bias is not None,
            act=act,
            chunk_c=chunk_c,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp_), jnp.float32),
        scratch_shapes=[pltpu.VMEM(code_shape, jnp.int8)],
        interpret=interpret,
    )(*operands)


def fused_decode_pallas(
    x: jax.Array,          # (N, D)
    centroids: jax.Array,  # (C, K, V) fp32
    table_q: jax.Array,    # (C, K, M) int8
    scale: jax.Array,      # (C|1, 1, 1) or (C|1, 1, M) fp32
    *,
    bias: jax.Array | None = None,   # (M,) fused into the epilogue
    act: str = "none",               # fused epilogue activation
    block_n: int | None = None,
    block_m: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused encode→lookup decode (v3): (N, D) -> (N, M). See module docstring.

    There is no block_c: the codebook axis is entirely VMEM-resident (that is
    the point — `autotune.kernel_choice` only routes here when it fits)."""
    n, d = x.shape
    c, k, v = centroids.shape
    m = table_q.shape[-1]
    if d != c * v:
        raise ValueError(f"D={d} != C*V={c}*{v}")
    if act not in ACTIVATIONS:
        raise ValueError(f"act={act!r} not in {ACTIVATIONS}")

    if block_n is None or block_m is None:
        h = autotune.heuristic("fused", n, m, c, k, v)
        block_n = block_n if block_n is not None else h.block_n
        block_m = block_m if block_m is not None else h.block_m
    bn = max(1, min(block_n, n))
    bm = max(1, min(block_m, m))

    pad_n, pad_m = (-n) % bn, (-m) % bm
    xp = jnp.pad(x, ((0, pad_n), (0, 0))) if pad_n else x
    tp = jnp.pad(table_q, ((0, 0), (0, 0), (0, pad_m))) if pad_m else table_q
    sp = (
        jnp.pad(scale, ((0, 0), (0, 0), (0, pad_m)))
        if (pad_m and scale.shape[-1] != 1)
        else scale
    )
    bp = None
    if bias is not None:
        bp = jnp.pad(bias, (0, pad_m)) if pad_m else bias
    np_ = n + pad_n

    out = _fused_decode_call(
        xp.reshape(np_, c, v), centroids, tp, sp, bp,
        block_n=bn, block_m=bm, act=act, interpret=interpret,
    )
    return out[:n, :m].astype(x.dtype)

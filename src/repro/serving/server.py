"""Asyncio HTTP front end over the serving engine (DESIGN.md §11.2).

Stdlib-only (the container ships no web framework): a hand-rolled HTTP/1.1
server on `asyncio.start_server`, good enough for the four routes it speaks.

    POST /generate   JSON body (engine.SPEC_KEYS: prompt, max_tokens,
                     eos_id, priority, deadline_s, temperature, top_k,
                     top_p, seed + "stream"). With "stream": true the
                     response is application/x-ndjson, one JSON object per
                     token as it is sampled; otherwise one JSON object with
                     the terminal status and full token list.
    POST /cancel     {"rid": n} -> {"cancelled": bool}
    GET  /healthz    process liveness (always 200 while the loop runs)
    GET  /readyz     traffic-readiness: 503 while draining or when the
                     backend has died, else 200
    GET  /metrics    Prometheus text format: every numeric engine.stats()
                     counter plus the lifecycle counters (shed / timeout /
                     queue_depth / ...) under the `lutnn_serving_` prefix
    GET  /stats      the same stats as raw JSON

The engine itself is synchronous (blocking jitted forwards), so it is driven
by `EnginePump` — a daemon thread stepping the engine whenever work is
queued, diffing per-request token output through `TokenTap`, and firing
per-request event callbacks. The asyncio side bridges those callbacks into
per-connection `asyncio.Queue`s via `call_soon_threadsafe`. All engine
access (submit/cancel/step/stats) happens under one lock, preserving the
engine's single-threaded discipline.

Graceful drain (SIGTERM or `FrontEnd.request_shutdown()`): stop admitting
(readyz -> 503, /generate -> 503), let in-flight requests finish, then stop
the server. `serve_forever()` returns the process exit code: 0 on a clean
drain, `EXIT_STRANDED` when `drain_timeout_s` expired with requests still
unresolved (those are aborted with status "error" so no rid is ever
silently lost).

`EngineSupervisor` (repro.serving.supervisor) implements the same backend
interface, so the front end serves a supervised multi-process engine with
zero changes — `launch/serve.py --port [--supervise]` wires both.
"""

from __future__ import annotations

import asyncio
import json
import signal
import threading
import time
from typing import Any, Callable

from repro.serving.engine import ServingEngine, TokenTap, submit_from_spec
from repro.serving.faults import InjectedKill

# event tuples fired at subscribers, from the pump/monitor thread:
#   ("tokens", list[int])            incremental output
#   ("restart", None)                generation restarted from scratch
#                                    (supervised backend, after a crash)
#   ("done", (status, out_tokens))   terminal
EventCallback = Callable[[tuple[str, Any]], None]

EXIT_STRANDED = 3


class EnginePump:
    """Drives a local ServingEngine on a daemon thread.

    Backend interface (shared with EngineSupervisor):
      submit(spec, on_event) -> rid ; cancel(rid) ; stats() ; pending() ;
      healthy ; close()
    """

    def __init__(self, engine: ServingEngine, *, idle_wait_s: float = 0.02):
        self.engine = engine
        self._tap = TokenTap(engine, consume=True)
        self._subs: dict[int, EventCallback] = {}
        self._live: set[int] = set()
        self._lock = threading.RLock()
        self._wake = threading.Event()
        self._stop = False
        self._dead: BaseException | None = None
        self._idle_wait_s = idle_wait_s
        self._thread = threading.Thread(
            target=self._run, name="engine-pump", daemon=True
        )
        self._thread.start()

    # -- backend interface -------------------------------------------------
    @property
    def healthy(self) -> bool:
        return self._dead is None and not self._stop

    def submit(self, spec: dict[str, Any], on_event: EventCallback | None = None) -> int:
        with self._lock:
            if self._dead is not None:
                raise RuntimeError(f"engine died: {self._dead!r}")
            rid = submit_from_spec(self.engine, spec)
            self._live.add(rid)
            if on_event is not None:
                self._subs[rid] = on_event
        self._wake.set()
        return rid

    def cancel(self, rid: int) -> bool:
        with self._lock:
            hit = self.engine.cancel(rid)
        if hit:
            self._wake.set()       # pump dispatches the "cancelled" done event
        return hit

    def stats(self) -> dict[str, Any]:
        with self._lock:
            s = self.engine.stats()
        s["backend"] = "local"
        s["restarts"] = 0
        s["pending"] = self.pending()
        return s

    def pending(self) -> int:
        with self._lock:
            return len(self._live)

    def close(self) -> None:
        self._stop = True
        self._wake.set()
        self._thread.join(timeout=10)

    def abort_pending(self) -> int:
        """Force-resolve every live request with status "error" (used when a
        drain deadline expires). Returns how many were aborted."""
        with self._lock:
            n = len(self.engine.abort_all("error"))
        self._wake.set()
        return n

    # -- pump loop ---------------------------------------------------------
    def _dispatch(self, events: list[tuple[int, tuple[str, Any]]]) -> None:
        for rid, ev in events:
            cb = self._subs.get(rid)
            if cb is not None:
                try:
                    cb(ev)
                except Exception:      # noqa: BLE001 — a bad subscriber
                    pass               # must not kill the pump
            if ev[0] == "done":
                self._subs.pop(rid, None)

    def _run(self) -> None:
        while not self._stop:
            out: list[tuple[int, tuple[str, Any]]] = []
            with self._lock:
                work = self.engine.has_work()
                if work and self._dead is None:
                    try:
                        self.engine.step()
                    except (Exception, InjectedKill) as e:  # noqa: BLE001
                        # unsupervised backend: an engine fault is fatal —
                        # resolve every live rid as "error", refuse new work
                        self._dead = e
                        self.engine.abort_all("error")
                tokens, done = self._tap.poll()
                out.extend((rid, ("tokens", toks)) for rid, toks in tokens)
                for req in done:
                    self._live.discard(req.rid)
                    out.append((req.rid, ("done", (req.status, req.out_tokens))))
            self._dispatch(out)
            if not work:
                self._wake.wait(self._idle_wait_s)
                self._wake.clear()


# ---------------------------------------------------------------------------
# HTTP front end
# ---------------------------------------------------------------------------

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
             405: "Method Not Allowed", 429: "Too Many Requests",
             503: "Service Unavailable"}


def metrics_text(stats: dict[str, Any], prefix: str = "lutnn_serving_") -> str:
    """Prometheus text exposition of every numeric stat.

    A `per_replica` sub-dict (EngineRouter) renders as labelled gauges —
    `lutnn_replica_<stat>{replica="i"}` — one TYPE line per family."""
    lines = []
    for k in sorted(stats):
        v = stats[k]
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        name = prefix + k
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {v}")
    per = stats.get("per_replica")
    if isinstance(per, dict):
        families: dict[str, list[str]] = {}
        for rep in sorted(per, key=lambda r: (len(r), r)):
            for k in sorted(per[rep]):
                v = per[rep][k]
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                families.setdefault(f"lutnn_replica_{k}", []).append(
                    f'lutnn_replica_{k}{{replica="{rep}"}} {v}')
        for name in sorted(families):
            lines.append(f"# TYPE {name} gauge")
            lines.extend(families[name])
    return "\n".join(lines) + "\n"


class FrontEnd:
    def __init__(
        self,
        backend: Any,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        drain_timeout_s: float = 30.0,
    ):
        self.backend = backend
        self.host = host
        self.port = port          # 0 = ephemeral; real port set by start()
        self.drain_timeout_s = drain_timeout_s
        self.draining = False
        self.exit_code = 0
        self._server: asyncio.AbstractServer | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._done = None         # asyncio.Event, created in start()

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._done = asyncio.Event()
        self._server = await asyncio.start_server(self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    def install_signal_handlers(self) -> None:
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._loop.add_signal_handler(sig, self.request_shutdown)

    def request_shutdown(self) -> None:
        """Begin a graceful drain: stop admitting, finish in-flight, exit.
        Safe to call more than once; signal-handler and test entry point."""
        if not self.draining:
            self.draining = True
            self._loop.create_task(self._drain())

    async def _drain(self) -> None:
        deadline = time.monotonic() + self.drain_timeout_s
        while self.backend.pending() and time.monotonic() < deadline:
            await asyncio.sleep(0.02)
        stranded = self.backend.pending()
        if stranded:
            self.exit_code = EXIT_STRANDED
            abort = getattr(self.backend, "abort_pending", None)
            if abort is not None:
                abort()            # stranded rids still resolve (as "error")
        self._server.close()
        await self._server.wait_closed()
        self._done.set()

    async def serve_forever(self) -> int:
        """Serve until a drain completes; returns the process exit code."""
        await self._done.wait()
        self.backend.close()
        return self.exit_code

    # -- request plumbing --------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            request = await reader.readline()
            parts = request.decode("latin-1").split()
            if len(parts) < 2:
                return
            method, path = parts[0].upper(), parts[1]
            headers = {}
            while True:
                line = await reader.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
                k, _, v = line.decode("latin-1").partition(":")
                headers[k.strip().lower()] = v.strip()
            body = b""
            n = int(headers.get("content-length", "0") or 0)
            if n:
                body = await reader.readexactly(n)
            await self._route(method, path, body, writer)
        except (asyncio.IncompleteReadError, ConnectionError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, RuntimeError):
                pass

    def _respond(self, writer: asyncio.StreamWriter, code: int, payload: Any,
                 content_type: str = "application/json") -> None:
        body = (json.dumps(payload).encode()
                if content_type == "application/json"
                else payload.encode())
        writer.write(
            f"HTTP/1.1 {code} {_REASONS.get(code, '')}\r\n"
            f"Content-Type: {content_type}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n".encode() + body
        )

    async def _route(self, method: str, path: str, body: bytes,
                     writer: asyncio.StreamWriter) -> None:
        if path == "/healthz":
            self._respond(writer, 200, "ok\n", "text/plain")
        elif path == "/readyz":
            ready = not self.draining and self.backend.healthy
            self._respond(writer, 200 if ready else 503,
                          ("ready\n" if ready else "draining\n"), "text/plain")
        elif path == "/metrics":
            self._respond(writer, 200, metrics_text(self.backend.stats()),
                          "text/plain; version=0.0.4")
        elif path == "/stats":
            self._respond(writer, 200, self.backend.stats())
        elif path == "/generate":
            if method != "POST":
                self._respond(writer, 405, {"error": "POST required"})
            else:
                await self._generate(body, writer)
        elif path == "/cancel":
            if method != "POST":
                self._respond(writer, 405, {"error": "POST required"})
            else:
                try:
                    rid = int(json.loads(body or b"{}")["rid"])
                except (ValueError, KeyError, TypeError):
                    self._respond(writer, 400, {"error": "body must be {'rid': int}"})
                    return
                self._respond(writer, 200, {"cancelled": self.backend.cancel(rid)})
        else:
            self._respond(writer, 404, {"error": f"no route {path}"})

    async def _generate(self, body: bytes, writer: asyncio.StreamWriter) -> None:
        if self.draining or not self.backend.healthy:
            self._respond(writer, 503, {"error": "draining" if self.draining
                                        else "engine unavailable"})
            return
        try:
            spec = json.loads(body or b"{}")
            if not isinstance(spec, dict):
                raise ValueError("body must be a JSON object")
            stream = bool(spec.pop("stream", False))
        except ValueError as e:
            self._respond(writer, 400, {"error": str(e)})
            return

        q: asyncio.Queue = asyncio.Queue()
        loop = self._loop

        def on_event(ev: tuple[str, Any]) -> None:
            loop.call_soon_threadsafe(q.put_nowait, ev)

        try:
            rid = self.backend.submit(spec, on_event)
        except (ValueError, TypeError) as e:
            self._respond(writer, 400, {"error": str(e)})
            return
        except RuntimeError as e:           # backend died between checks
            self._respond(writer, 503, {"error": str(e)})
            return

        if stream:
            await self._stream_events(rid, q, writer)
        else:
            tokens: list[int] = []
            restarts = 0
            while True:
                kind, payload = await q.get()
                if kind == "tokens":
                    tokens.extend(payload)
                elif kind == "restart":
                    tokens.clear()
                    restarts += 1
                elif kind == "done":
                    status, out_tokens = payload
                    resp = {"rid": rid, "status": status, "tokens": out_tokens,
                            "n_tokens": len(out_tokens)}
                    if restarts:
                        resp["restarts"] = restarts
                    code = {"ok": 200, "shed": 429}.get(status, 200)
                    self._respond(writer, code, resp)
                    return

    async def _stream_events(self, rid: int, q: asyncio.Queue,
                             writer: asyncio.StreamWriter) -> None:
        def line(obj: dict) -> bytes:
            return (json.dumps(obj) + "\n").encode()

        writer.write(
            b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: application/x-ndjson\r\n"
            b"Cache-Control: no-store\r\n"
            b"Connection: close\r\n\r\n"
        )
        writer.write(line({"rid": rid}))
        try:
            await writer.drain()
            while True:
                kind, payload = await q.get()
                if kind == "tokens":
                    for tok in payload:
                        writer.write(line({"rid": rid, "token": tok}))
                elif kind == "restart":
                    # supervised backend restarted generation from scratch:
                    # the client must discard tokens streamed so far
                    writer.write(line({"rid": rid, "restart": True}))
                elif kind == "done":
                    status, out_tokens = payload
                    writer.write(line({"rid": rid, "status": status,
                                       "tokens": out_tokens,
                                       "n_tokens": len(out_tokens)}))
                    await writer.drain()
                    return
                await writer.drain()
        except (ConnectionError, RuntimeError):
            # client went away mid-stream: cancel so the request stops
            # burning decode steps (best effort — it may already be done)
            self.backend.cancel(rid)


async def run_server(
    backend: Any,
    host: str = "127.0.0.1",
    port: int = 8000,
    *,
    drain_timeout_s: float = 30.0,
    signals: bool = True,
    on_started: Callable[["FrontEnd"], None] | None = None,
) -> int:
    """Start a FrontEnd and serve until SIGTERM/SIGINT drains it.
    Returns the process exit code (see module docstring)."""
    fe = FrontEnd(backend, host, port, drain_timeout_s=drain_timeout_s)
    await fe.start()
    if signals:
        fe.install_signal_handlers()
    if on_started is not None:
        on_started(fe)
    return await fe.serve_forever()

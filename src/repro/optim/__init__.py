from repro.optim.adamw import (
    DISTILL_RULES,
    SOFT_PQ_RULES,
    AdamW,
    AdamWState,
    GroupRule,
    lut_frozen_mask,
)
from repro.optim.schedule import constant, cosine_with_warmup

__all__ = [
    "AdamW",
    "AdamWState",
    "GroupRule",
    "DISTILL_RULES",
    "SOFT_PQ_RULES",
    "lut_frozen_mask",
    "cosine_with_warmup",
    "constant",
]

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: params, caches
and inputs are ShapeDtypeStructs (zero allocation); `.lower().compile()`
must succeed on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh;
memory_analysis / cost_analysis / the optimized HLO feed EXPERIMENTS.md
sections Dry-run and Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--mode ...]
Results are appended to results/dryrun/<cell>.json.
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (
    ARCH_IDS,
    SHAPES,
    build_model,
    get_arch,
    input_specs,
    shape_applicable,
)
from repro.core.amm import Mode
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_production_mesh
from repro.optim import SOFT_PQ_RULES, AdamW, lut_frozen_mask
from repro.optim.schedule import cosine_with_warmup
from repro.roofline.analysis import analyze_compiled, memory_stats
from repro.train.train_step import make_serve_step, make_train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _tree_bytes(tree) -> float:
    return sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(tree)
    )


def _count_params(tree) -> int:
    return int(sum(leaf.size for leaf in jax.tree.leaves(tree)))


def lower_cell(
    arch_name: str,
    shape: str,
    *,
    multi_pod: bool = False,
    mode: str | None = None,
    fsdp: bool | None = None,
    remat: bool | None = None,
    arch_overrides: dict | None = None,
    row_parallel: bool = True,
):
    """Lower+compile one cell; returns (record dict, compiled)."""
    import dataclasses as _dc

    arch = get_arch(arch_name)
    if arch_overrides:
        arch = _dc.replace(arch, **arch_overrides)
    sp = SHAPES[shape]
    ok, why = shape_applicable(arch, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape, "skipped": why}, None

    if mode is None:
        mode = Mode.LUT_TRAIN if sp.kind == "train" else Mode.LUT_INFER
    else:
        mode = Mode(mode)
    bundle = build_model(arch, mode)
    if remat is not None and bundle.kind == "lm":
        import dataclasses as dc

        bundle = dc.replace(bundle, cfg=dc.replace(bundle.cfg, remat=remat))

    mesh = make_production_mesh(multi_pod=multi_pod)
    # 2D expert sharding replaced the fsdp default (section Perf, MoE iter 2:
    # naive 2D weight sharding triggers SPMD involuntary rematerialization)
    use_fsdp = bool(fsdp)
    rules = ShardingRules(mesh, fsdp=use_fsdp, row_parallel=row_parallel)

    params_specs = bundle.param_specs()
    n_params = _count_params(params_specs)
    p_shard = rules.params_shardings(params_specs, bundle=bundle)
    batch_specs = input_specs(arch, shape)
    b_shard = rules.batch_shardings(batch_specs)

    t0 = time.time()
    if sp.kind == "train":
        opt = AdamW(
            lr=cosine_with_warmup(1e-3, total_steps=10_000, warmup_steps=200),
            rules=SOFT_PQ_RULES,
            state_dtype=jnp.bfloat16 if arch.param_dtype == "bfloat16" else jnp.float32,
        )
        frozen = lut_frozen_mask(params_specs) if mode == Mode.LUT_TRAIN else None
        opt_specs = jax.eval_shape(lambda p: opt.init(p, frozen), params_specs)
        o_shard = rules.opt_shardings(opt_specs)
        step_fn = make_train_step(
            bundle, opt, frozen_mask=frozen, grad_accum=arch.grad_accum
        )
        with mesh:
            lowered = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            ).lower(params_specs, opt_specs, batch_specs)
            compiled = lowered.compile()
    else:
        cache_b = sp.global_batch
        cache_dtype = getattr(jnp, arch.kv_cache_dtype)
        cache_specs = bundle.init_caches(cache_b, sp.seq_len, abstract=True, dtype=cache_dtype)
        c_shard = rules.cache_shardings(cache_specs, cache_b)
        step_fn = make_serve_step(bundle)
        with mesh:
            lowered = jax.jit(
                step_fn,
                in_shardings=(p_shard, b_shard, c_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(2,),
            ).lower(params_specs, batch_specs, cache_specs)
            compiled = lowered.compile()
    compile_s = time.time() - t0

    roof = analyze_compiled(compiled)
    mem = memory_stats(compiled)
    rec = {
        "arch": arch_name,
        "shape": shape,
        "mode": mode.value,
        "mesh": list(mesh.devices.shape),
        "multi_pod": multi_pod,
        "fsdp": use_fsdp,
        "n_params": n_params,
        "param_bytes_global": _tree_bytes(params_specs),
        "compile_s": compile_s,
        "memory": mem,
        "roofline": roof.as_dict(),
        "tokens_per_step": sp.global_batch * (sp.seq_len if sp.kind != "decode" else 1),
    }
    return rec, compiled


def run_cell(arch_name: str, shape: str, **kw) -> dict:
    tag = kw.pop("tag", "")
    rec, _ = lower_cell(arch_name, shape, **kw)
    RESULTS.mkdir(parents=True, exist_ok=True)
    suffix = "mp" if kw.get("multi_pod") else "sp"
    name = f"{arch_name}__{shape}__{suffix}" + (f"__{tag}" if tag else "")
    (RESULTS / f"{name}.json").write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", choices=[m.value for m in Mode], default=None)
    ap.add_argument("--fsdp", type=int, default=None)
    args = ap.parse_args()

    cells = (
        [(a, s) for a in ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = []
    for arch_name, shape in cells:
        try:
            rec = run_cell(
                arch_name,
                shape,
                multi_pod=args.multi_pod,
                mode=args.mode,
                fsdp=None if args.fsdp is None else bool(args.fsdp),
            )
            if rec.get("skipped"):
                print(f"[skip] {arch_name} x {shape}: {rec['skipped']}")
                continue
            r = rec["roofline"]
            print(
                f"[ok] {arch_name} x {shape} ({rec['mode']}, mesh={rec['mesh']}) "
                f"compile={rec['compile_s']:.1f}s "
                f"mem/dev={rec['memory']['total_hbm_bytes']/2**30:.2f}GiB "
                f"t_comp={r['t_compute_s']:.4f}s t_mem={r['t_memory_s']:.4f}s "
                f"t_coll={r['t_collective_s']:.4f}s -> {r['bottleneck']}"
            )
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            failures.append((arch_name, shape, repr(e)))
            print(f"[FAIL] {arch_name} x {shape}: {e!r}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {[(a, s) for a, s, _ in failures]}")


if __name__ == "__main__":
    main()

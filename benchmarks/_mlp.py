"""Small MLP classifier/regressor harness for the paper-claim benchmarks.

The paper's CNN/BERT accuracy experiments (Fig. 3, Table 4, Figs. 11-12)
compare *operator replacement strategies* on a trained network. An MLP
stack of FC layers is the minimal faithful carrier for those comparisons
(the paper itself treats conv as matmul via im2col): we train a dense MLP
on the clustered-feature task (repro.data.ClusteredTask — inputs cluster
exactly the way PQ assumes), then replace layers with PQ/MADDNESS/LUT-NN
variants and measure accuracy.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kmeans, maddness, pq, quant
from repro.core.amm import LUTConfig, Mode, lut_linear
from repro.core.lut_layer import init_dense
from repro.core.temperature import init_log_temperature, temperature
from repro.data import ClusteredTask
from repro.optim import SOFT_PQ_RULES, AdamW, lut_frozen_mask


@dataclasses.dataclass
class MLPSpec:
    d_in: int = 64
    width: int = 128
    depth: int = 5                      # hidden linear layers
    n_out: int = 10
    lut: LUTConfig = dataclasses.field(default_factory=lambda: LUTConfig(k=16, v=8))


def mlp_init(key, spec: MLPSpec):
    dims = [spec.d_in] + [spec.width] * spec.depth + [spec.n_out]
    keys = jax.random.split(key, len(dims) - 1)
    return [init_dense(k, a, b) for k, a, b in zip(keys, dims[:-1], dims[1:])]


def mlp_apply(params, x, *, spec: MLPSpec, modes=None, temps=None, bits=8):
    """modes: per-layer None(dense) | 'pq' | 'maddness' | 'ste'."""
    h = x
    for i, p in enumerate(params):
        mode = None if modes is None else modes[i]
        has_pq = "centroids" in p or "tree" in p
        if mode is None or not has_pq:
            h = h @ p["w"]
        elif mode == "ste":
            tbl = pq.build_table(p["centroids"], p["w"])
            tbl = quant.fake_quant(tbl, bits=bits)
            d = pq.pairwise_sq_dists(pq.split_subvectors(h, spec.lut.v), p["centroids"])
            enc = pq.ste_encode(d, temperature(p["log_t"]))
            h = pq.lut_contract(enc, tbl)
        elif mode == "pq":
            tbl = pq.build_table(p["centroids"], p["w"], stop_weight_grad=False)
            d = pq.pairwise_sq_dists(pq.split_subvectors(h, spec.lut.v), p["centroids"])
            h = pq.lut_contract(pq.hard_encode(d), tbl)
        elif mode == "maddness":
            tbl = pq.build_table(p["protos"], p["w"], stop_weight_grad=False)
            idx = maddness.maddness_encode(h, p["tree"], spec.lut.v)
            h = pq.gather_lut(idx, tbl)
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


def train_dense(key, spec: MLPSpec, task: ClusteredTask, *, steps=300, batch=256, lr=1e-3):
    params = mlp_init(key, spec)
    opt = AdamW(lr=lr, clip_norm=None)
    state = opt.init(params)

    @jax.jit
    def step(params, state, xb, yb):
        def loss_fn(p):
            logits = mlp_apply(p, xb, spec=spec)
            if task.regression:
                return jnp.mean(jnp.abs(logits[:, 0] - yb))
            lse = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, yb[:, None], 1)[:, 0]
            return jnp.mean(lse - gold)

        l, g = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.update(g, state, params)
        return params, state, l

    for i in range(steps):
        b = task.sample(i, batch)
        params, state, l = step(params, state, b["x"], b["y"])
    return params


def evaluate(params, spec: MLPSpec, task: ClusteredTask, *, modes=None, n=2048):
    b = task.sample(10_000, n)
    logits = mlp_apply(params, b["x"], spec=spec, modes=modes)
    if task.regression:
        return float(jnp.mean(jnp.abs(logits[:, 0] - b["y"])))      # MAE
    return float(jnp.mean(jnp.argmax(logits, -1) == b["y"]))        # acc


def attach_pq(key, params, spec: MLPSpec, task: ClusteredTask, layer_ids, *, kind="pq"):
    """k-means (or MADDNESS tree) init for the given layers, from captured
    layer inputs under the dense model."""
    b = task.sample(20_000, 1024)
    h = b["x"]
    acts = []
    for p in params:
        acts.append(h)
        h = jax.nn.relu(h @ p["w"]) if p is not params[-1] else h @ p["w"]
    out = [dict(p) for p in params]
    for li in layer_ids:
        a = acts[li]
        if kind == "maddness":
            tree = maddness.fit_hash_trees(np.asarray(a), k=spec.lut.k, v=spec.lut.v)
            out[li]["tree"] = tree
            out[li]["protos"] = maddness.bucket_prototypes(
                np.asarray(a), tree, k=spec.lut.k, v=spec.lut.v
            )
        else:
            key, sub = jax.random.split(key)
            out[li]["centroids"] = kmeans.kmeans_per_codebook(
                sub, a, k=spec.lut.k, v=spec.lut.v
            )
            out[li]["log_t"] = init_log_temperature()
    return out


def finetune_softpq(key, params, spec: MLPSpec, task: ClusteredTask, layer_ids,
                    *, steps=300, batch=256, lr=1e-3, temp_mode="learned", bits=8):
    """Soft-PQ QAT fine-tune (paper section 3). temp_mode: learned|fixed|anneal."""
    modes = [("ste" if i in layer_ids else None) for i in range(len(params))]
    rules = SOFT_PQ_RULES if temp_mode == "learned" else ()
    opt = AdamW(lr=lr, rules=rules, clip_norm=1.0)
    frozen = lut_frozen_mask(params)
    state = opt.init(params, frozen)

    def loss_fn(p, xb, yb):
        logits = mlp_apply(p, xb, spec=spec, modes=modes, bits=bits)
        if task.regression:
            return jnp.mean(jnp.abs(logits[:, 0] - yb))
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, yb[:, None], 1)[:, 0]
        return jnp.mean(lse - gold)

    @jax.jit
    def step(params, state, xb, yb, t_override):
        p_used = params
        if temp_mode != "learned":
            p_used = [
                (dict(p, log_t=jnp.log(t_override)) if "log_t" in p else p)
                for p in params
            ]
        l, g = jax.value_and_grad(loss_fn)(p_used, xb, yb)
        params, state, _ = opt.update(g, state, params, frozen)
        return params, state, l

    curve = []
    for i in range(steps):
        b = task.sample(i, batch)
        if temp_mode == "anneal":
            t_i = 1.0 * (0.1 / 1.0) ** (i / max(1, steps - 1))
        else:
            t_i = 1.0
        params, state, l = step(params, state, b["x"], b["y"], jnp.asarray(t_i))
        if i % 20 == 0 or i == steps - 1:
            acc = evaluate(params, spec, task, modes=[
                ("pq" if j in layer_ids else None) for j in range(len(params))
            ])
            curve.append((i, float(l), acc))
    return params, curve

"""Paper Fig. 13 as a LUTPlan sweep: accuracy/latency vs replacement plan.

Uses the real bert_base config (reduced width for CPU) on the Markov LM
task. Each row is a `LUTPlan` — the last-n sweep reproduces the paper's
observation that the FRONT layers are accuracy-critical, and the
heterogeneous row exercises what the old `lut_policy` string could not
express: per-site-kind K (MLP sites K=16, attention sites K=8) with the
first and last layers kept dense.

Every plan goes through the full lifecycle (convert -> soft-PQ fine-tune
-> int8 deploy), and reports:

  eval_loss       soft-PQ (LUT_TRAIN) eval loss
  deployed_loss   eval loss of the deployed int8-table model
  infer_us        wall-clock of one jitted deployed forward (8x24 batch)

With `json_path` set (benchmarks/run.py --json) the rows land in
BENCH_plans.json so future PRs have a replaced-layer accuracy/latency
trajectory to regress against.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import PAPER_DEFAULT, LUTPlan, SitePolicy, build_model, get_arch, reduce_arch, rule
from repro.core import convert
from repro.core.amm import Mode
from repro.data import MarkovLM
from repro.optim import SOFT_PQ_RULES, AdamW, lut_frozen_mask
from repro.train.train_step import make_train_step

N_LAYERS = 6


def _plans() -> list[tuple[str, LUTPlan | None]]:
    rows: list[tuple[str, LUTPlan | None]] = [("dense", None)]
    rows += [(f"last_n:{n}", LUTPlan.last_n(n, v=16)) for n in (2, 4, 6)]
    rows.append((
        "hetero_mlp16_attn8_ends_dense",
        LUTPlan(
            rules=(
                rule(kinds=("mlp/*",), k=16),
                rule(kinds=("attn/*",), k=8),
                rule(layers="set", layer_set=(0, N_LAYERS - 1), replace=False),
            ),
            default=SitePolicy(v=16).merged_over(PAPER_DEFAULT),
        ),
    ))
    return rows


def _timed_loss(bundle, params, batch, iters: int = 5) -> tuple[float, float]:
    fn = jax.jit(lambda p, b: bundle.loss(p, b, compute_dtype=jnp.float32))
    loss = float(jax.block_until_ready(fn(params, batch)))       # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(params, batch))
    return loss, (time.perf_counter() - t0) / iters * 1e6


def main(steps: int = 120, json_path: str | pathlib.Path | None = None) -> list[dict]:
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    base = reduce_arch(get_arch("bert_base"), n_layers=N_LAYERS, vocab=64,
                       d_model=64, d_ff=128, causal=True)   # causal LM task carrier
    data = MarkovLM(vocab=base.vocab, seq_len=24, batch=8)

    dense = build_model(dataclasses.replace(base, lut_plan=LUTPlan.none()), Mode.DENSE)
    dparams = dense.init(key)
    opt = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(dense, opt, compute_dtype=jnp.float32))
    ostate = opt.init(dparams)
    for i in range(steps * 2):
        dparams, ostate, m = step(dparams, ostate, data.batch_at(i))
    eval_batch = data.batch_at(9_999)
    base_loss, base_us = _timed_loss(dense, dparams, eval_batch)

    print("# Fig. 13 analog: eval loss vs replacement plan")
    print(f"plan,eval_loss,deployed_loss,infer_us  (dense baseline {base_loss:.4f})")
    plans = _plans()
    rows = []
    losses = {}
    for name, plan in plans:
        if plan is None:
            losses[name] = base_loss
            rows.append({"plan": name, "eval_loss": base_loss,
                         "deployed_loss": base_loss, "infer_us": base_us})
            print(f"{name},{base_loss:.4f},{base_loss:.4f},{base_us:.0f}")
            continue
        arch = dataclasses.replace(base, lut_plan=plan)
        dense_n = build_model(arch, Mode.DENSE)
        samples = [data.batch_at(50_000 + i) for i in range(2)]
        blut, lparams = convert.convert_dense_to_lut_train(dense_n, dparams, samples, key)
        frozen = lut_frozen_mask(lparams)
        opt2 = AdamW(lr=1e-3, rules=SOFT_PQ_RULES)
        step2 = jax.jit(make_train_step(blut, opt2, frozen_mask=frozen, compute_dtype=jnp.float32))
        o2 = opt2.init(lparams, frozen)
        for i in range(steps):
            lparams, o2, _ = step2(lparams, o2, data.batch_at(i))
        losses[name] = float(blut.loss(lparams, eval_batch, compute_dtype=jnp.float32))
        binf, iparams = convert.deploy_lut_train_params(blut, lparams)
        dep_loss, dep_us = _timed_loss(binf, iparams, eval_batch)
        rows.append({"plan": name, "eval_loss": losses[name],
                     "deployed_loss": dep_loss, "infer_us": dep_us})
        print(f"{name},{losses[name]:.4f},{dep_loss:.4f},{dep_us:.0f}")
    print(f"claim_back_layers_cheap,{losses['last_n:2'] < losses['last_n:6'] + 0.5}")

    if json_path is not None:
        payload = {
            "benchmark": "fig13_replaced_layers",
            "arch": "bert_base (reduced)",
            "n_layers": N_LAYERS,
            "steps": steps,
            "plans": {name: (plan.to_dict() if plan is not None else None)
                      for name, plan in plans},
            "rows": rows,
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=1))
        print(f"# wrote {json_path}")
    print(f"fig13_replaced_layers,{(time.time()-t0)*1e6:.0f},plan_sweep")
    return rows


if __name__ == "__main__":
    _JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_plans.json"
    main(json_path=_JSON if "--json" in sys.argv else None)

"""Training launcher: a thin CLI over `repro.train.recipe.Recipe`.

Runs the full LUT-NN lifecycle on any registered arch at a CPU-feasible
reduction:

  dense pretrain -> convert (k-means init) -> soft-PQ QAT fine-tune
  [optionally distilling vs the frozen dense teacher] -> int8 deploy ->
  eval gate -> LUTArtifact written to --artifact-dir
  (the train half of the train -> deploy -> serve lifecycle; the serve
  half is `launch/serve.py --artifact <dir>`).

The pipeline itself is a first-class `Recipe` (DESIGN.md §10): pass
`--recipe recipe.json` to run a custom stage list, or let the flags build
the default recipe (`--dump-recipe` writes that default out as a starting
point). Either way the run is resumable — killing the process and
re-invoking with the same --ckpt-dir resumes at the recorded stage and
checkpoint step, and the executed recipe is serialized into the artifact
manifest for provenance.

Example (the (b) end-to-end driver; ~100M-param model for a few hundred
steps):

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_1p7b \
      --d-model 512 --layers 8 --steps 300 --lut
"""

from __future__ import annotations

import argparse

from repro.configs import ARCH_IDS, get_arch, reduce_arch
from repro.data import MarkovLM
from repro.train.recipe import Recipe, default_recipe


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ("bert_base",), default="qwen3_1p7b")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lut", action="store_true", help="run the full LUT pipeline")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--artifact-dir", default=None,
                    help="where the deployed LUTArtifact is written at the "
                         "end of the --lut pipeline (default: "
                         "<ckpt-dir>_artifact); serve it with "
                         "launch/serve.py --artifact <dir>")
    ap.add_argument("--recipe", default=None, metavar="RECIPE_JSON",
                    help="run this serialized Recipe instead of the "
                         "flag-built default (stage/optimizer flags are "
                         "then ignored)")
    ap.add_argument("--dump-recipe", default=None, metavar="PATH",
                    help="write the flag-built default recipe as JSON and "
                         "exit (edit it, then re-run with --recipe)")
    ap.add_argument("--distill-weight", type=float, default=0.0,
                    help="> 0 adds a KL term vs the frozen dense teacher "
                         "to the soft-PQ stage (DESIGN.md §10.3)")
    ap.add_argument("--distill-tau", type=float, default=2.0,
                    help="distillation softening temperature")
    ap.add_argument("--grad-compression", action="store_true",
                    help="EXPERIMENTAL: int8 error-feedback gradient "
                         "reduce in the dense stage (DESIGN.md §10.4)")
    ap.add_argument("--eval-max-regression", type=float, default=None,
                    help="fail the run if the deployed loss regresses more "
                         "than this past the dense teacher's")
    ap.add_argument("--spec-draft", default=None, metavar="KINDS",
                    help="deploy a TWO-plan artifact for speculative "
                         "serving: the trained plan ships as the 'draft' "
                         "and the target keeps these comma-separated kind "
                         "patterns dense (e.g. 'attn/*'); serve with "
                         "launch/serve.py --spec-decode (DESIGN.md §14)")
    args = ap.parse_args(argv)

    artifact_dir = args.artifact_dir or args.ckpt_dir + "_artifact"
    if args.recipe is not None and args.dump_recipe is not None:
        ap.error("--dump-recipe writes the flag-built default recipe; "
                 "combining it with --recipe is a no-op copy — drop one")
    if not args.lut and args.recipe is None and (
            args.distill_weight > 0.0 or args.eval_max_regression is not None
            or args.spec_draft is not None):
        ap.error("--distill-weight/--eval-max-regression/--spec-draft "
                 "configure the LUT pipeline stages — they require --lut")
    if args.recipe is not None:
        recipe = Recipe.load(args.recipe)
    else:
        recipe = default_recipe(
            steps=args.steps, lut=args.lut, artifact_dir=artifact_dir,
            distill_weight=args.distill_weight, distill_tau=args.distill_tau,
            grad_compression=args.grad_compression,
            eval_max_regression=args.eval_max_regression,
            spec_draft=args.spec_draft,
        )
    if args.dump_recipe is not None:
        recipe.save(args.dump_recipe)
        print(f"wrote recipe ({recipe.describe()}) to {args.dump_recipe}")
        return

    arch = reduce_arch(
        get_arch(args.arch),
        d_model=args.d_model,
        n_layers=args.layers,
        vocab=args.vocab,
        d_ff=0 if get_arch(args.arch).d_ff == 0 else 2 * args.d_model,
    )
    data = MarkovLM(vocab=arch.vocab, seq_len=args.seq, batch=args.batch)

    if args.lut or args.recipe:
        from repro.configs import effective_plan

        print(f"replacement plan: {effective_plan(arch).describe()}")
    print(f"recipe: {recipe.describe()}")

    result = recipe.run(arch, data, ckpt_dir=args.ckpt_dir, seed=args.seed)

    if result.inf_bundle is not None:
        deploy = next((e["result"] for e in result.manifest["stages"]
                       if e["kind"] == "deploy" and e["result"]), {})
        adir = deploy.get("artifact_dir", artifact_dir)
        print(f"wrote LUTArtifact to {adir} "
              f"(inspect: python -m repro.serving.artifact {adir}; "
              f"serve: python -m repro.launch.serve --artifact {adir})")


if __name__ == "__main__":
    main()

"""Analytic irreducible-work model per (arch x shape x mode) cell.

Defines the "useful" numerator of the roofline fraction reported in
EXPERIMENTS.md:

  fraction = max(useful_flops/peak, useful_bytes/HBM_bw) / max(t_c, t_m, t_l)

useful_flops = the algorithm's own minimal compute:
  * LUT sites:  N*(D*K + C*K*M_mxu) with M_mxu contraction on the one-hot
    path charged at C*K (the TPU-native cost; DESIGN.md §2) — i.e. the LUT
    algorithm run perfectly, plus
  * attention/SSD mixing flops, embeddings/lm_head, and 3x for backward.

useful_bytes = what MUST stream from HBM once per step:
  * every parameter byte (int8 tables in LUT mode, bf16 dense otherwise)
  * decode: the KV/SSM cache bytes for the batch
  * activations are assumed cache-resident (ideal), so this is a lower
    bound — fractions are conservative (real kernels re-read activations).
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.configs import SHAPES, ArchSpec, build_model, get_arch
from repro.core.amm import Mode
from repro.models.common import SiteCfg
from repro.models.moe import ExpertSiteCfg

PEAK = 197e12
HBM = 819e9


def _walk_sites(cfg_obj, mult: float, out: list):
    import dataclasses as dc

    if isinstance(cfg_obj, SiteCfg):
        out.append(("site", cfg_obj, mult))
        return
    if isinstance(cfg_obj, ExpertSiteCfg):
        out.append(("expert", cfg_obj, mult))
        return
    if dc.is_dataclass(cfg_obj):
        for f in dc.fields(cfg_obj):
            v = getattr(cfg_obj, f.name)
            if dc.is_dataclass(v):
                _walk_sites(v, mult, out)
            elif isinstance(v, tuple):
                for item in v:
                    if (
                        isinstance(item, tuple)
                        and len(item) == 2
                        and isinstance(item[0], int)
                    ):
                        _walk_sites(item[1], mult * item[0], out)


def cell_useful(arch_name: str, shape: str, mode: str, n_chips: int) -> dict[str, float]:
    arch = get_arch(arch_name)
    sp = SHAPES[shape]
    bundle = build_model(arch, Mode(mode))
    sites: list = []
    _walk_sites(bundle.cfg, 1.0, sites)

    tokens = sp.global_batch * (sp.seq_len if sp.kind != "decode" else 1)
    ctx = sp.seq_len

    flops = 0.0
    pbytes = 0.0
    for kind, s, mult in sites:
        e = s.n_experts if kind == "expert" else 1
        act_e = (arch.top_k / arch.n_experts) if kind == "expert" else 1.0
        d, m = s.d_in, s.d_out
        if s.mode == Mode.LUT_INFER:
            c = d // s.lut.v
            flops += mult * tokens * act_e * e * (2 * d * s.lut.k / e + 2 * c * s.lut.k * m) if kind == "expert" else \
                     mult * tokens * (2 * d * s.lut.k + 2 * c * s.lut.k * m)
            pbytes += mult * e * (c * s.lut.k * m + c * s.lut.k * s.lut.v * 4)
        elif s.mode == Mode.LUT_TRAIN:
            c = d // s.lut.v
            # fwd: encode + contract + table rebuild; bwd ~ 2x fwd
            fwd = tokens * act_e * e * (2 * d * s.lut.k / max(e, 1) + 2 * c * s.lut.k * m) \
                if kind == "expert" else tokens * (2 * d * s.lut.k + 2 * c * s.lut.k * m)
            rebuild = e * 2 * c * s.lut.k * s.lut.v * m
            flops += mult * (3 * fwd + rebuild)
            pbytes += mult * e * d * m * 4
        else:  # dense
            f1 = tokens * act_e * e * 2 * d * m if kind == "expert" else tokens * 2 * d * m
            flops += mult * f1 * (3 if sp.kind == "train" else 1)
            pbytes += mult * e * d * m * (4 if sp.kind == "train" else 2)

    # sequence mixing (not LUT-replaceable)
    if arch.n_heads:
        n_attn = arch.n_layers if arch.family != "hybrid" else len(
            range(arch.attn_every, arch.n_layers + 1, arch.attn_every)
        )
        if arch.family == "audio":
            n_attn = arch.n_layers + arch.n_enc_layers
        attn_ctx = ctx if sp.kind != "train" else sp.seq_len / 2
        f_attn = 4 * tokens * attn_ctx * arch.n_heads * arch.d_head * n_attn
        flops += f_attn * (3 if sp.kind == "train" else 1)
    if arch.ssm_state:
        di = arch.d_inner
        h = di // arch.ssm_head_dim
        f_ssd = tokens * (2 * di * arch.ssm_state * 2 + 2 * h * arch.ssm_head_dim * arch.ssm_state * 2)
        flops += f_ssd * arch.n_layers * (3 if sp.kind == "train" else 1)

    # embeddings / lm head
    flops += tokens * 2 * arch.d_model * arch.vocab * (3 if sp.kind == "train" else 1)
    pbytes += arch.vocab * arch.d_model * (4 if sp.kind == "train" else 2)
    if not arch.tie_embeddings:
        pbytes += arch.vocab * arch.d_model * (4 if sp.kind == "train" else 2)

    # decode: cache streams once per step
    cbytes = 0.0
    if sp.kind == "decode":
        b = sp.global_batch
        if arch.n_heads and arch.family != "hybrid":
            n_attn = arch.n_layers + (arch.n_enc_layers if arch.family == "audio" else 0)
            cbytes += n_attn * b * ctx * arch.n_kv_heads * arch.d_head * 2 * 2
        if arch.family == "hybrid":
            n_inv = len(range(arch.attn_every, arch.n_layers + 1, arch.attn_every))
            cbytes += n_inv * b * ctx * arch.n_kv_heads * arch.d_head * 2 * 2
        if arch.ssm_state:
            di = arch.d_inner
            h = di // arch.ssm_head_dim
            cbytes += arch.n_layers * b * h * arch.ssm_head_dim * arch.ssm_state * 4

    # train: optimizer state + grads traffic (params read+write + m,v)
    obytes = 0.0
    if sp.kind == "train":
        obytes = pbytes * 2  # moments; grads transient

    useful_flops = flops / n_chips
    useful_bytes = (pbytes + cbytes + obytes) / n_chips
    t_useful = max(useful_flops / PEAK, useful_bytes / HBM)
    return {
        "useful_flops_per_dev": useful_flops,
        "useful_bytes_per_dev": useful_bytes,
        "t_useful_s": t_useful,
    }

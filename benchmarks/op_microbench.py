"""Paper Fig. 7 analog: per-operator cost, dense vs LUT-NN, v1 vs v2 kernel.

Real TPU wall-clock is unavailable here, so this reports THREE views per op:

  * measured CPU wall-clock of the XLA paths — dense matmul, fp32 one-hot
    LUT, int8-dot LUT (honest but CPU-flavored);
  * measured wall-clock of the Pallas kernels, v1 vs v2, in interpret mode
    on an N-capped slice (interpret executes the kernel body through XLA —
    it exercises the exact kernel dataflow but does NOT model MXU int8
    throughput, so off-TPU these columns track emulation cost only);
  * the autotuner's analytic v5e roofline projection for the FULL shape,
    v1 vs v2, at the autotuned block sizes (DESIGN.md §3) — the number a
    real TPU run regresses against.

With `json_path` set (benchmarks/run.py --json) the rows are written to
BENCH_kernels.json so future PRs have a perf trajectory to regress against.
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import pq, quant
from repro.kernels import autotune, ops
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

OPS = [
    # (name, N, D, M, K, V)
    ("bert_ffn_up", 512, 768, 3072, 16, 32),
    ("llama3_qproj", 256, 4096, 4096, 16, 32),
    ("llama3_ffn_gate", 256, 4096, 14336, 16, 32),
]

# interpret-mode kernels run the grid as emulated XLA steps on CPU — cap the
# row count so the measured v1/v2 comparison stays cheap. The full-shape
# numbers come from the analytic roofline projection.
KERNEL_N_CAP = 64


def _time(fn, *args, iters: int = 3) -> float:
    """Median-free mean wall-clock per call; exactly one warmup execution."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_op(name: str, n: int, d: int, m: int, k: int, v: int) -> dict:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d), jnp.float32)
    w = jax.random.normal(key, (d, m), jnp.float32)
    P = jax.random.normal(key, (d // v, k, v))
    table = pq.build_table(P, w, stop_weight_grad=False)
    qt = quant.quantize_table(table)
    qt_sh = quant.quantize_table(table, m_shared=True)

    dense_fn = jax.jit(lambda x, w: x @ w)

    def lut_fn(x, P, tq, ts):
        tbl = tq.astype(jnp.float32) * ts
        enc = pq.hard_encode(pq.pairwise_sq_dists(pq.split_subvectors(x, v), P))
        return pq.lut_contract(enc, tbl)

    def lut_i8_fn(x, P, tq, ts):
        enc = pq.hard_encode(pq.pairwise_sq_dists(pq.split_subvectors(x, v), P))
        return pq.lut_contract_int8(enc, tq, ts)

    t_dense = _time(dense_fn, x, w) * 1e3
    t_lut = _time(jax.jit(lut_fn), x, P, qt.q, qt.scale) * 1e3
    t_lut_i8 = _time(jax.jit(lut_i8_fn), x, P, qt_sh.q, qt_sh.scale) * 1e3

    # Pallas v1 vs v2, measured (interpret off-TPU) on the N-capped slice
    # with autotuned v2 blocks.
    nk = min(n, KERNEL_N_CAP)
    c = d // v
    blk, _ = autotune.tune("lut_amm", n, m, c, k, v, save=False)
    bn, bm, bc = min(blk.block_n, nk), blk.block_m, blk.block_c
    xk = x[:nk]
    t_v1 = _time(
        lambda *a: ops.lut_amm_v1(*a, block_n=bn, block_m=bm, block_c=bc),
        xk, P, qt_sh.q, jnp.broadcast_to(qt_sh.scale, (c, 1, m)),
        iters=2,
    ) * 1e3
    t_v2 = _time(
        lambda *a: ops.lut_amm(*a, block_n=bn, block_m=bm, block_c=bc),
        xk, P, qt_sh.q, qt_sh.scale,
        iters=2,
    ) * 1e3

    # full-shape analytic roofline projection at the tuned blocks
    v1_us = autotune.predict_us("lut_amm", n, m, c, k, v,
                                blk.block_n, blk.block_m, blk.block_c, version=1)
    v2_us = autotune.predict_us("lut_amm", n, m, c, k, v,
                                blk.block_n, blk.block_m, blk.block_c, version=2)

    # v5e roofline (decode regime: weight/table bytes dominate)
    dense_bytes_ = d * m * 2 + (n * d + n * m) * 2
    lut_bytes_ = c * k * m + c * k * v * 4 + (n * d + n * m) * 2
    dense_flops_ = 2 * n * d * m
    lut_flops_ = 2 * n * d * k + 2 * n * c * k * m   # one-hot MXU path
    t_roof_dense = max(dense_bytes_ / HBM_BW, dense_flops_ / PEAK_FLOPS) * 1e6
    t_roof_lut = max(lut_bytes_ / HBM_BW, lut_flops_ / PEAK_FLOPS) * 1e6

    return {
        "op": name,
        "n": n, "d": d, "m": m, "k": k, "v": v,
        "cpu_dense_ms": t_dense,
        "cpu_lut_ms": t_lut,
        "cpu_lut_int8_ms": t_lut_i8,
        "kernel_n": nk,
        "kernel_backend": "tpu" if jax.default_backend() == "tpu" else "interpret",
        "pallas_v1_ms": t_v1,
        "pallas_v2_ms": t_v2,
        "tuned_block_n": blk.block_n,
        "tuned_block_m": blk.block_m,
        "tuned_block_c": blk.block_c,
        "v1_model_us": v1_us,
        "v2_model_us": v2_us,
        "tpu_roofline_dense_us": t_roof_dense,
        "tpu_roofline_lut_us": t_roof_lut,
        "decode_byte_ratio": (d * m * 2) / (c * k * m),
    }


COLUMNS = (
    "op", "cpu_dense_ms", "cpu_lut_ms", "cpu_lut_int8_ms",
    "pallas_v1_ms", "pallas_v2_ms",
    "tuned_block_n", "tuned_block_m", "tuned_block_c",
    "v1_model_us", "v2_model_us",
    "tpu_roofline_dense_us", "tpu_roofline_lut_us", "decode_byte_ratio",
)


def main(json_path: str | pathlib.Path | None = None) -> list[dict]:
    t0 = time.time()
    print("# Fig. 7 analog: per-op dense vs LUT (xla/int8/pallas-v1/pallas-v2)")
    print(",".join(COLUMNS))
    rows = []
    for name, n, d, m, k, v in OPS:
        r = bench_op(name, n, d, m, k, v)
        rows.append(r)
        print(",".join(
            f"{r[c]:.3f}" if isinstance(r[c], float) else str(r[c])
            for c in COLUMNS
        ))
    if json_path is not None:
        payload = {
            "benchmark": "op_microbench",
            "backend": jax.default_backend(),
            "kernel_n_cap": KERNEL_N_CAP,
            "rows": rows,
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=1))
        print(f"# wrote {json_path}")
    print(f"op_microbench,{(time.time()-t0)*1e6:.0f},cpu+roofline")
    return rows


if __name__ == "__main__":
    # anchor at the repo root (same path run.py and roofline_table.py use),
    # independent of the invocation cwd
    _JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_kernels.json"
    main(json_path=_JSON if "--json" in sys.argv else None)

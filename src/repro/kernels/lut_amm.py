"""Fused LUT-AMM Pallas TPU kernel family: encode + table read + accumulate.

TPU adaptation of the paper's section-5 inference design (DESIGN.md §2):

  * closest-centroid search  -> MXU dot(a_blk, P^T) per codebook block, with
    the codebook block pinned in VMEM across the whole N sweep
    (centroid-stationary: the BlockSpec index_map for `P` ignores the N grid
    coordinate, so the pipeline emitter keeps the same tile resident).
  * argmin                   -> VPU lane reduction (no sequential RAW hazard)
  * shuffle-instruction read -> one-hot x table matmul on the MXU
  * INT16/INT32 mixed accum  -> int8 one-hot x int8 table with int32
    accumulation (v2, DESIGN.md §2.3)

Grid = (N/bn, M/bm, C/bc) with the codebook axis innermost.

Two generations are kept side by side for benchmarking:

  `lut_amm_pallas` (v2, default) — int8-native: the table tile enters the MXU
  as int8 (`preferred_element_type=jnp.int32`), partial sums accumulate in a
  VMEM scratch buffer across codebook steps, and the output tile is written
  exactly once on the final step through a fused dequantize + bias +
  activation epilogue. With the m-shared (1,1,M) scale layout the whole tile
  is dequantized exactly once; per-codebook scale layouts rescale the int32
  partials per step into an fp32 scratch but still never materialize an fp32
  table (DESIGN.md §2.3).

  `lut_amm_pallas_v1` — the original kernel: dequantizes the int8 table to
  fp32 in VMEM on every codebook step, contracts in fp32 and read-modify-
  writes the output tile across the innermost grid axis.

Block sizes default to the shape-keyed autotuner (repro.kernels.autotune);
the VMEM budget model for legal tilings is documented in DESIGN.md §3.1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import autotune

ACTIVATIONS = ("none", "relu", "silu", "gelu", "relu2")


def _apply_act(acc: jax.Array, act: str) -> jax.Array:
    if act == "none":
        return acc
    if act == "relu":
        return jnp.maximum(acc, 0.0)
    if act == "silu":
        return jax.nn.silu(acc)
    if act == "gelu":
        return jax.nn.gelu(acc)
    if act == "relu2":
        r = jnp.maximum(acc, 0.0)
        return r * r
    raise ValueError(f"unknown epilogue activation {act!r}")


def _encode_onehot_i8(x_ref, p_ref) -> jax.Array:
    """Distance + argmin + int8 one-hot for one (bn, bc) tile -> (bc, bn, K)."""
    a = x_ref[...].astype(jnp.float32)          # (bn, bc, V)
    p = p_ref[...].astype(jnp.float32)          # (bc, K, V)
    # squared distances: batch over codebooks on the MXU
    # (bc, bn, K) <- (bn, bc, V) x (bc, K, V)
    cross = jax.lax.dot_general(
        a, p,
        dimension_numbers=(((2,), (2,)), ((1,), (0,))),
        preferred_element_type=jnp.float32,
    )
    a_nrm = jnp.sum(a * a, axis=-1).T[:, :, None]        # (bc, bn, 1)
    p_nrm = jnp.sum(p * p, axis=-1)[:, None, :]          # (bc, 1, K)
    dists = a_nrm - 2.0 * cross + p_nrm                  # (bc, bn, K)
    # vectorized argmin over the K lane axis, then one-hot re-expansion —
    # int8 so the table read below runs on the int8 MXU path.
    idx = jnp.argmin(dists, axis=-1)                     # (bc, bn)
    lanes = jax.lax.broadcasted_iota(jnp.int32, dists.shape, 2)
    return (lanes == idx[:, :, None]).astype(jnp.int8)   # (bc, bn, K)


# ---------------------------------------------------------------------------
# v2 kernel (int8-native, scratch accumulation, fused epilogue)
# ---------------------------------------------------------------------------

def _lut_amm_kernel_v2(
    *refs,
    n_c_blocks: int,
    shared_scale: bool,
    has_bias: bool,
    act: str,
):
    if has_bias:
        x_ref, p_ref, t_ref, s_ref, b_ref, o_ref, acc_ref = refs
    else:
        x_ref, p_ref, t_ref, s_ref, o_ref, acc_ref = refs
        b_ref = None
    c_step = pl.program_id(2)

    onehot = _encode_onehot_i8(x_ref, p_ref)             # (bc, bn, K) int8

    # int8 x int8 -> int32 table read on the MXU; the table tile never
    # leaves int8 (v1 materialized an fp32 copy here every step).
    # (bc, bn, bm) <- (bc, bn, K) x (bc, K, bm)
    part = jax.lax.dot_general(
        onehot, t_ref[...],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )

    if shared_scale:
        # (1,1,M) scales factor out of the codebook sum: accumulate raw
        # int32 counts, dequantize ONCE per output tile in the epilogue.
        contrib = jnp.sum(part, axis=0)                  # (bn, bm) int32
    else:
        # per-codebook scales: rescale the int32 partials of this step, but
        # the accumulator stays in scratch and o_ref is still written once.
        s = s_ref[...].astype(jnp.float32)               # (bc, 1, 1|bm)
        contrib = jnp.sum(part.astype(jnp.float32) * s, axis=0)

    @pl.when(c_step == 0)
    def _init():
        acc_ref[...] = contrib

    @pl.when(c_step != 0)
    def _accum():
        acc_ref[...] += contrib

    @pl.when(c_step == n_c_blocks - 1)
    def _epilogue():
        acc = acc_ref[...]
        if shared_scale:
            # the single dequantize of this output tile
            acc = acc.astype(jnp.float32) * s_ref[...].reshape(1, -1)
        if has_bias:
            acc = acc + b_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_act(acc, act)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_n", "block_m", "block_c", "act", "interpret",
    ),
)
def _lut_amm_call_v2(
    x_sub, centroids, table_q, scale, bias,
    *, block_n, block_m, block_c, act, interpret,
):
    np_, c, v = x_sub.shape
    k = centroids.shape[1]
    mp_ = table_q.shape[-1]
    bn, bm, bc = block_n, block_m, block_c
    grid = (np_ // bn, mp_ // bm, c // bc)
    shared_scale = scale.shape[0] == 1
    s_m = 1 if scale.shape[-1] == 1 else bm

    if shared_scale:
        s_spec = pl.BlockSpec(
            (1, 1, s_m),
            (lambda i, j, cc: (0, 0, j)) if s_m != 1 else (lambda i, j, cc: (0, 0, 0)),
        )
    else:
        s_spec = pl.BlockSpec(
            (bc, 1, s_m),
            (lambda i, j, cc: (cc, 0, j)) if s_m != 1 else (lambda i, j, cc: (cc, 0, 0)),
        )
    in_specs = [
        pl.BlockSpec((bn, bc, v), lambda i, j, cc: (i, cc, 0)),
        pl.BlockSpec((bc, k, v), lambda i, j, cc: (cc, 0, 0)),
        pl.BlockSpec((bc, k, bm), lambda i, j, cc: (cc, 0, j)),
        s_spec,
    ]
    operands = [x_sub, centroids.astype(jnp.float32), table_q, scale]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bm), lambda i, j, cc: (0, j)))
        operands.append(bias.reshape(1, -1))

    acc_dtype = jnp.int32 if shared_scale else jnp.float32
    return pl.pallas_call(
        functools.partial(
            _lut_amm_kernel_v2,
            n_c_blocks=grid[2],
            shared_scale=shared_scale,
            has_bias=bias is not None,
            act=act,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, cc: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bm), acc_dtype)],
        interpret=interpret,
    )(*operands)


def lut_amm_pallas(
    x: jax.Array,          # (N, D)
    centroids: jax.Array,  # (C, K, V) fp32
    table_q: jax.Array,    # (C, K, M) int8
    scale: jax.Array,      # (C|1, 1, 1) or (C|1, 1, M) fp32
    *,
    bias: jax.Array | None = None,   # (M,) fused into the epilogue
    act: str = "none",               # fused epilogue activation
    block_n: int | None = None,
    block_m: int | None = None,
    block_c: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """v2 fused LUT-AMM: (N, D) -> (N, M). See module docstring."""
    n, d = x.shape
    c, k, v = centroids.shape
    m = table_q.shape[-1]
    if d != c * v:
        raise ValueError(f"D={d} != C*V={c}*{v}")
    if act not in ACTIVATIONS:
        raise ValueError(f"act={act!r} not in {ACTIVATIONS}")

    bn, bm, bc = autotune.resolve_blocks(
        "lut_amm", n, m, c, k, v, str(x.dtype), block_n, block_m, block_c
    )

    # pad N / M to block multiples (table M padding is cheap: int8 zeros)
    pad_n, pad_m = (-n) % bn, (-m) % bm
    xp = jnp.pad(x, ((0, pad_n), (0, 0))) if pad_n else x
    tp = jnp.pad(table_q, ((0, 0), (0, 0), (0, pad_m))) if pad_m else table_q
    sp = (
        jnp.pad(scale, ((0, 0), (0, 0), (0, pad_m)))
        if (pad_m and scale.shape[-1] != 1)
        else scale
    )
    bp = None
    if bias is not None:
        bp = jnp.pad(bias, (0, pad_m)) if pad_m else bias
    np_ = n + pad_n

    out = _lut_amm_call_v2(
        xp.reshape(np_, c, v), centroids, tp, sp, bp,
        block_n=bn, block_m=bm, block_c=bc, act=act, interpret=interpret,
    )
    return out[:n, :m].astype(x.dtype)


# ---------------------------------------------------------------------------
# v1 kernel (kept for dense-vs-v1-vs-v2 benchmarking)
# ---------------------------------------------------------------------------

def _lut_amm_kernel_v1(x_ref, p_ref, t_ref, s_ref, o_ref):
    c_step = pl.program_id(2)

    onehot = _encode_onehot_i8(x_ref, p_ref).astype(jnp.float32)

    # fp32 dequantized table materialized EVERY codebook step (v2 fixes this)
    table = t_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    # (bc, bn, bm) <- (bc, bn, K) x (bc, K, bm)
    part = jax.lax.dot_general(
        onehot, table,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    acc = jnp.sum(part, axis=0)                          # (bn, bm)

    @pl.when(c_step == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(c_step != 0)
    def _accum():
        o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_m", "block_c", "interpret"),
)
def lut_amm_pallas_v1(
    x: jax.Array,          # (N, D)
    centroids: jax.Array,  # (C, K, V) fp32
    table_q: jax.Array,    # (C, K, M) int8
    scale: jax.Array,      # (C, 1, 1) or (C, 1, M) fp32
    *,
    block_n: int = 256,
    block_m: int = 512,
    block_c: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    n, d = x.shape
    c, k, v = centroids.shape
    m = table_q.shape[-1]
    if d != c * v:
        raise ValueError(f"D={d} != C*V={c}*{v}")

    bn = min(block_n, n)
    bm = min(block_m, m)
    bc = block_c if block_c is not None else max(1, min(c, 2048 // v))
    while c % bc:
        bc -= 1

    pad_n, pad_m = (-n) % bn, (-m) % bm
    xp = jnp.pad(x, ((0, pad_n), (0, 0))) if pad_n else x
    tp = jnp.pad(table_q, ((0, 0), (0, 0), (0, pad_m))) if pad_m else table_q
    sp = (
        jnp.pad(scale, ((0, 0), (0, 0), (0, pad_m)))
        if (pad_m and scale.shape[-1] != 1)
        else scale
    )
    np_, mp_ = n + pad_n, m + pad_m

    x_sub = xp.reshape(np_, c, v)
    grid = (np_ // bn, mp_ // bm, c // bc)
    s_m = 1 if scale.shape[-1] == 1 else bm

    out = pl.pallas_call(
        _lut_amm_kernel_v1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bc, v), lambda i, j, cc: (i, cc, 0)),
            pl.BlockSpec((bc, k, v), lambda i, j, cc: (cc, 0, 0)),
            pl.BlockSpec((bc, k, bm), lambda i, j, cc: (cc, 0, j)),
            pl.BlockSpec(
                (bc, 1, s_m),
                (lambda i, j, cc: (cc, 0, j)) if s_m != 1 else (lambda i, j, cc: (cc, 0, 0)),
            ),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, cc: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp_), jnp.float32),
        interpret=interpret,
    )(x_sub, centroids.astype(jnp.float32), tp, sp)

    return out[:n, :m].astype(x.dtype)

"""jit'd public wrappers for the LUT kernels with platform + version dispatch.

`lut_amm` runs the fused Pallas kernels on TPU and transparently falls back
to interpret mode elsewhere (this container is CPU-only: interpret=True
executes the kernel body in Python for correctness validation; the XLA
one-hot path in repro.core.pq is the production fallback used by the
distributed dry-run).

Kernel-version selection per shape comes from the autotune record
(`autotune.kernel_choice`, DESIGN.md §13.3) — measured wall-clock winners
when available, the analytic ranking otherwise, and a no-record fallback
rule (v1 for small-M interpret-mode shapes, else the fused v3 kernel when
its working set fits VMEM, else v2) — so callers never pin a losing
version. Pass `version=` (1 | 2 | 3) to force a generation; passing any
explicit block size keeps the historical v2 behavior unless `version` says
otherwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.dist_argmin import encode_pallas
from repro.kernels.fused_decode import fused_decode_pallas
from repro.kernels.lut_amm import _apply_act, lut_amm_pallas, lut_amm_pallas_v1
from repro.kernels.ref import encode_ref, lut_amm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def lut_amm(
    x: jax.Array,
    centroids: jax.Array,
    table_q: jax.Array,
    scale: jax.Array,
    *,
    bias: jax.Array | None = None,
    act: str = "none",
    block_n: int | None = None,
    block_m: int | None = None,
    block_c: int | None = None,
    version: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """LUT-NN approximate matmul, autotuned dispatch: (N, D) -> (N, M)."""
    if interpret is None:
        interpret = not _on_tpu()
    n, _ = x.shape
    c, k, v = centroids.shape
    m = table_q.shape[-1]
    if version is None:
        if block_n is None and block_m is None and block_c is None:
            version, cfg, _ = autotune.kernel_choice(
                n, m, c, k, v, dtype=str(x.dtype), interpret=interpret
            )
            block_n, block_m, block_c = cfg.block_n, cfg.block_m, cfg.block_c
        else:
            version = 2        # explicit blocks, no version: historical v2
    if version >= autotune.VERSION_FUSED:
        return fused_decode_pallas(
            x, centroids, table_q, scale, bias=bias, act=act,
            block_n=block_n, block_m=block_m, interpret=interpret,
        )
    if version == 2:
        return lut_amm_pallas(
            x, centroids, table_q, scale, bias=bias, act=act,
            block_n=block_n, block_m=block_m, block_c=block_c,
            interpret=interpret,
        )
    # v1 has no fused epilogue and wants (C, ...) scale layouts: broadcast
    # m-shared scales and apply bias/activation outside the kernel so the
    # three generations stay drop-in interchangeable.
    s = scale if scale.shape[0] == c else jnp.broadcast_to(scale, (c, 1, scale.shape[-1]))
    y = lut_amm_pallas_v1(
        x, centroids, table_q, s,
        block_n=block_n if block_n is not None else 256,
        block_m=block_m if block_m is not None else 512,
        block_c=block_c, interpret=interpret,
    )
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return _apply_act(y, act).astype(y.dtype)


def lut_amm_v1(
    x: jax.Array,
    centroids: jax.Array,
    table_q: jax.Array,
    scale: jax.Array,
    *,
    block_n: int = 256,
    block_m: int = 512,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Original fused kernel (fp32 dequant per step + o_ref accumulation)."""
    if interpret is None:
        interpret = not _on_tpu()
    return lut_amm_pallas_v1(
        x,
        centroids,
        table_q,
        scale,
        block_n=block_n,
        block_m=block_m,
        block_c=block_c,
        interpret=interpret,
    )


def encode(
    x: jax.Array,
    centroids: jax.Array,
    *,
    block_n: int | None = None,
    block_c: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Closest-centroid encode: (N, D) -> int32 (N, C)."""
    if interpret is None:
        interpret = not _on_tpu()
    return encode_pallas(
        x, centroids, block_n=block_n, block_c=block_c, interpret=interpret
    )


def lut_amm_fused(
    x: jax.Array,
    centroids: jax.Array,
    table_q: jax.Array,
    scale: jax.Array,
    *,
    bias: jax.Array | None = None,
    act: str = "none",
    block_n: int | None = None,
    block_m: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused encode→lookup decode kernel (v3), explicitly."""
    if interpret is None:
        interpret = not _on_tpu()
    return fused_decode_pallas(
        x, centroids, table_q, scale, bias=bias, act=act,
        block_n=block_n, block_m=block_m, interpret=interpret,
    )


__all__ = [
    "lut_amm", "lut_amm_v1", "lut_amm_fused", "encode",
    "lut_amm_ref", "encode_ref",
]

"""Trainer integration: learning, checkpoint/restart, failure recovery,
straggler detection, LUT fine-tuning vs direct PQ (the paper's core claim)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core import convert
from repro.core.amm import Mode
from repro.data import MarkovLM
from repro.optim import SOFT_PQ_RULES, AdamW, lut_frozen_mask
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def small_setup():
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, vocab=64, d_model=64, d_ff=128)
    data = MarkovLM(vocab=arch.vocab, seq_len=24, batch=8, branching=4)
    bundle = build_model(arch, Mode.DENSE)
    params = bundle.init(jax.random.PRNGKey(0))
    return arch, data, bundle, params


def test_loss_decreases(small_setup, tmp_path):
    arch, data, bundle, params = small_setup
    opt = AdamW(lr=3e-3)
    tr = Trainer(
        step_fn=jax.jit(make_train_step(bundle, opt, compute_dtype=jnp.float32)),
        batch_at=data.batch_at,
        cfg=TrainerConfig(total_steps=30, ckpt_every=100, ckpt_dir=str(tmp_path), log_every=0),
    )
    tr.fit(params, opt.init(params), start_step=0)
    first = np.mean([h["loss"] for h in tr.history[:5]])
    last = np.mean([h["loss"] for h in tr.history[-5:]])
    assert last < first - 0.3


def test_checkpoint_restart_exact(small_setup, tmp_path):
    arch, data, bundle, params = small_setup
    opt = AdamW(lr=1e-3)

    def mk(ckpt_dir):
        return Trainer(
            step_fn=jax.jit(make_train_step(bundle, opt, compute_dtype=jnp.float32)),
            batch_at=data.batch_at,
            cfg=TrainerConfig(total_steps=12, ckpt_every=6, ckpt_dir=ckpt_dir, log_every=0),
        )

    # uninterrupted run
    t1 = mk(str(tmp_path / "a"))
    p1, _ = t1.fit(params, opt.init(params), start_step=0)

    # interrupted at step 6 (fresh trainer resumes from ckpt: deterministic data)
    t2 = mk(str(tmp_path / "b"))
    t2.cfg.total_steps = 6
    t2.fit(params, opt.init(params), start_step=0)
    t3 = mk(str(tmp_path / "b"))
    t3.cfg.total_steps = 12
    p3, _ = t3.fit(params, opt.init(params))     # resumes at 6
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_failure_recovery(small_setup, tmp_path):
    arch, data, bundle, params = small_setup
    opt = AdamW(lr=1e-3)
    tr = Trainer(
        step_fn=jax.jit(make_train_step(bundle, opt, compute_dtype=jnp.float32)),
        batch_at=data.batch_at,
        cfg=TrainerConfig(total_steps=10, ckpt_every=4, ckpt_dir=str(tmp_path), log_every=0,
                          max_retries=1),
        fail_at=6,
        fail_exc=RuntimeError("simulated preemption"),
    )
    tr.fit(params, opt.init(params), start_step=0)
    steps = [h["step"] for h in tr.history]
    assert steps[-1] == 9 and 6 in steps           # recovered and completed


def test_retry_exhaustion_restores_and_continues(small_setup, tmp_path):
    """A persistently failing step exhausts the StepGuard's retries; the
    trainer must then restore the last committed checkpoint and continue to
    completion (restore-and-continue), not crash."""
    arch, data, bundle, params = small_setup
    opt = AdamW(lr=1e-3)
    tr = Trainer(
        step_fn=jax.jit(make_train_step(bundle, opt, compute_dtype=jnp.float32)),
        batch_at=data.batch_at,
        cfg=TrainerConfig(total_steps=10, ckpt_every=4, ckpt_dir=str(tmp_path),
                          log_every=0, max_retries=1),
        fail_at=6,
        fail_times=3,                       # > max_retries + 1: exhausts the guard
        fail_exc=RuntimeError("persistent transient failure"),
    )
    p, _ = tr.fit(params, opt.init(params), start_step=0)
    steps = [h["step"] for h in tr.history]
    # guard exhausted at step 6 -> restored to the step-4 checkpoint -> 4, 5
    # replayed once, then step 6 succeeds on the remaining retry budget
    assert steps.count(4) == 2 and steps.count(5) == 2
    assert steps[-1] == 9 and steps.count(6) == 1
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(p))


def test_retry_exhaustion_without_checkpoint_raises(small_setup, tmp_path):
    """Nothing committed -> nothing to restore: exhausting the guard before
    the first checkpoint must re-raise, not replay already-advanced params
    from step 0 in an infinite loop."""
    arch, data, bundle, params = small_setup
    opt = AdamW(lr=1e-3)
    tr = Trainer(
        step_fn=jax.jit(make_train_step(bundle, opt, compute_dtype=jnp.float32)),
        batch_at=data.batch_at,
        cfg=TrainerConfig(total_steps=10, ckpt_every=100, ckpt_dir=str(tmp_path),
                          log_every=0, max_retries=1),
        fail_at=2,
        fail_times=99,                   # persistent failure
        fail_exc=RuntimeError("device lost"),
    )
    with pytest.raises(RuntimeError):
        tr.fit(params, opt.init(params), start_step=0)
    assert [h["step"] for h in tr.history] == [0, 1]   # no step replayed


def test_temperature_metrics_surface_in_history(small_setup, tmp_path):
    """Learned softmax temperature (t = exp(log_t)) is reported per step for
    LUT models (t_mean/t_min ~ 1 at init) and absent for dense models."""
    arch, data, bundle, params = small_setup
    samples = [data.batch_at(700)]
    blut, lparams = convert.convert_dense_to_lut_train(
        bundle, params, samples, jax.random.PRNGKey(3)
    )
    frozen = lut_frozen_mask(lparams)
    opt = AdamW(lr=1e-3, rules=SOFT_PQ_RULES)
    step = jax.jit(make_train_step(blut, opt, frozen_mask=frozen,
                                   compute_dtype=jnp.float32))
    _, _, metrics = step(lparams, opt.init(lparams, frozen), data.batch_at(0))
    assert 0.9 < float(metrics["t_mean"]) < 1.1       # init_t = 1.0
    assert float(metrics["t_min"]) <= float(metrics["t_mean"])

    tr = Trainer(
        step_fn=step, batch_at=data.batch_at,
        cfg=TrainerConfig(total_steps=2, ckpt_every=100, ckpt_dir=str(tmp_path),
                          log_every=0),
    )
    tr.fit(lparams, opt.init(lparams, frozen), start_step=0)
    assert all("t_mean" in h and "t_min" in h for h in tr.history)

    # dense models carry no temperature
    opt_d = AdamW(lr=1e-3)
    dstep = jax.jit(make_train_step(bundle, opt_d, compute_dtype=jnp.float32))
    _, _, dmetrics = dstep(params, opt_d.init(params), data.batch_at(0))
    assert "t_mean" not in dmetrics and "t_min" not in dmetrics


def test_straggler_monitor():
    from repro.distributed.fault_tolerance import StragglerMonitor

    m = StragglerMonitor(threshold=2.0, warmup_steps=3)
    for i in range(10):
        assert not m.record(i, 0.1)
    assert m.record(99, 0.5)                        # 5x EMA -> flagged
    assert m.events and m.events[0]["step"] == 99
    assert not m.record(100, 0.11)                  # recovery not flagged


def test_lut_finetune_beats_direct_pq(small_setup, tmp_path):
    """Paper Fig. 3 / Table 4 in miniature: direct PQ (k-means only)
    degrades the model; soft-PQ fine-tuning recovers it."""
    arch, data, bundle, params = small_setup
    opt = AdamW(lr=3e-3)
    tr = Trainer(
        step_fn=jax.jit(make_train_step(bundle, opt, compute_dtype=jnp.float32)),
        batch_at=data.batch_at,
        cfg=TrainerConfig(total_steps=40, ckpt_every=100, ckpt_dir=str(tmp_path), log_every=0),
    )
    params, _ = tr.fit(params, opt.init(params), start_step=0)
    dense_loss = float(bundle.loss(params, data.batch_at(999), compute_dtype=jnp.float32))

    samples = [data.batch_at(500 + i) for i in range(2)]
    blut, lparams = convert.convert_dense_to_lut_train(
        bundle, params, samples, jax.random.PRNGKey(1)
    )
    direct_pq_loss = float(blut.loss(lparams, data.batch_at(999), compute_dtype=jnp.float32))

    frozen = lut_frozen_mask(lparams)
    opt2 = AdamW(lr=1e-3, rules=SOFT_PQ_RULES)
    step = jax.jit(make_train_step(blut, opt2, frozen_mask=frozen, compute_dtype=jnp.float32))
    ostate = opt2.init(lparams, frozen)
    for i in range(40):
        lparams, ostate, _ = step(lparams, ostate, data.batch_at(i))
    ft_loss = float(blut.loss(lparams, data.batch_at(999), compute_dtype=jnp.float32))

    assert ft_loss < direct_pq_loss                 # soft-PQ improves on raw PQ
    assert ft_loss < dense_loss + 0.5               # and lands near the original


def test_grad_accum_equivalent(small_setup):
    """grad_accum=2 must match a single full-batch step (same grads)."""
    arch, data, bundle, params = small_setup
    opt = AdamW(lr=1e-3, clip_norm=None)
    s1 = jax.jit(make_train_step(bundle, opt, compute_dtype=jnp.float32, grad_accum=1))
    s2 = jax.jit(make_train_step(bundle, opt, compute_dtype=jnp.float32, grad_accum=2))
    batch = data.batch_at(0)
    p1, _, m1 = s1(params, opt.init(params), batch)
    p2, _, m2 = s2(params, opt.init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)

"""LUTPlan: first-class per-site replacement policy (DESIGN.md §9).

The paper's accuracy story is *per-layer* (Fig. 13 sweeps how many layers
are replaced; §6.1 tunes centroid counts per operator), so the replacement
policy is a structured plan rather than a parsed string:

  * `SitePolicy`   — a partial override of the LUT hyper-parameters
                     (k, v, bits, per_column, int8_dot, use_kernel); `None`
                     fields inherit from the plan default.
  * `SiteSelector` — which sites a rule applies to: a layer range
                     ("all" / "all_but_first" / "last_n" / an explicit
                     "set" of indices) crossed with fnmatch patterns over
                     site *kinds* ("mlp/*", "attn/q", "moe/down", ...).
  * `PlanRule`     — selector + replace/keep-dense decision + policy.
  * `LUTPlan`      — an ordered rule cascade over a fully-populated default
                     policy. Rules apply in order; the LAST matching rule
                     decides replacement, and matching rules' policy fields
                     accumulate (later rules override earlier ones).

`LUTPlan.from_policy_string` is the back-compat shim for the old
`ArchSpec.lut_policy` strings ("all", "all_but_first", "last_n:<n>") — it
produces a single-rule plan whose default policy carries the old flat
`lut_*` flags, so pre-plan configs and v1 artifacts build identical models.

Layer selectors only constrain sites that *have* a layer index. Sites whose
weights are shared across layers (the hybrid model's shared attention
block) or stacked uniformly with one config (hybrid mamba stack, enc-dec
blocks) resolve with `layer=None` and match every layer selector — exactly
the pre-plan behavior where those families ignored the policy string. Kind
patterns always apply.

`SiteSpec` is the site-registry record: `ModelBundle.sites()`
(repro.configs) enumerates one per linear site per layer across all model
families, and conversion / sharding / autotune-warmup / artifact snapshots
walk it instead of doing per-family path-string surgery.
"""

from __future__ import annotations

import dataclasses
from fnmatch import fnmatchcase
from typing import Any

from repro.core.amm import LUTConfig, Mode

_LAYER_SELECTORS = ("all", "all_but_first", "last_n", "set")

# the per-site hyper-parameter fields a policy can override
_POLICY_FIELDS = ("k", "v", "bits", "per_column", "int8_dot", "use_kernel")


@dataclasses.dataclass(frozen=True)
class SitePolicy:
    """Partial LUT hyper-parameter override; None fields inherit."""

    k: int | None = None
    v: int | None = None
    bits: int | None = None
    per_column: bool | None = None
    int8_dot: bool | None = None
    use_kernel: bool | None = None

    def merged_over(self, base: "SitePolicy") -> "SitePolicy":
        """self's non-None fields override base's."""
        return SitePolicy(**{
            f: getattr(self, f) if getattr(self, f) is not None else getattr(base, f)
            for f in _POLICY_FIELDS
        })

    @property
    def complete(self) -> bool:
        return all(getattr(self, f) is not None for f in _POLICY_FIELDS)

    def lut_config(self, d_in: int) -> LUTConfig:
        """Concrete per-site LUTConfig; V is halved until it divides d_in
        (same alignment rule the flat-flag path always applied)."""
        if not self.complete:
            raise ValueError(f"policy {self} not fully resolved — merge over a "
                             f"complete default first")
        v = self.v
        while d_in % v:
            v //= 2
        return LUTConfig(k=self.k, v=v, bits=self.bits, per_column=self.per_column,
                         int8_dot=self.int8_dot, use_kernel=self.use_kernel)


#: the paper's defaults (K=16, V=32, INT8) — the base of every plan cascade
PAPER_DEFAULT = SitePolicy(k=16, v=32, bits=8, per_column=False,
                           int8_dot=False, use_kernel=False)


@dataclasses.dataclass(frozen=True)
class SiteSelector:
    """Which (layer, kind) sites a rule applies to."""

    layers: str = "all"                  # one of _LAYER_SELECTORS
    n: int = 0                           # for "last_n"
    layer_set: tuple[int, ...] = ()      # for "set"
    kinds: tuple[str, ...] = ("*",)      # fnmatch patterns over site kind

    def selects(self, layer: int | None, kind: str, n_layers: int) -> bool:
        if not any(fnmatchcase(kind, pat) for pat in self.kinds):
            return False
        if layer is None:
            # weight-shared / uniformly-stacked site: layer selectors are
            # inapplicable and match (kind patterns still constrain)
            return True
        if self.layers == "all":
            return True
        if self.layers == "all_but_first":
            return layer >= 1
        if self.layers == "last_n":
            return layer >= n_layers - self.n
        if self.layers == "set":
            return layer in self.layer_set
        raise ValueError(f"unknown layer selector {self.layers!r}")

    def validate(self, n_layers: int) -> None:
        if self.layers not in _LAYER_SELECTORS:
            raise ValueError(
                f"unknown layer selector {self.layers!r} — "
                f"expected one of {_LAYER_SELECTORS}"
            )
        if self.layers == "last_n" and not 0 <= self.n <= n_layers:
            raise ValueError(
                f"last_n selects the final {self.n} layers but the model has "
                f"only {n_layers} — pick n in [0, {n_layers}] (n={n_layers} "
                f"replaces every layer; the paper keeps at least the first "
                f"layer dense)"
            )
        if self.layers == "set":
            bad = [i for i in self.layer_set if not 0 <= i < n_layers]
            if bad:
                raise ValueError(
                    f"layer set {self.layer_set} references layers {bad} "
                    f"outside the model's range [0, {n_layers})"
                )
        if not self.kinds:
            raise ValueError("selector needs at least one kind pattern "
                             "(use ('*',) for all kinds)")


@dataclasses.dataclass(frozen=True)
class PlanRule:
    select: SiteSelector = SiteSelector()
    replace: bool = True                 # False: force the site dense
    policy: SitePolicy = SitePolicy()


def rule(
    *,
    layers: str = "all",
    n: int = 0,
    layer_set: tuple[int, ...] | list[int] = (),
    kinds: tuple[str, ...] | list[str] = ("*",),
    replace: bool = True,
    **policy: Any,
) -> PlanRule:
    """Convenience PlanRule constructor: selector fields + policy kwargs."""
    bad = sorted(set(policy) - set(_POLICY_FIELDS))
    if bad:
        raise TypeError(f"unknown policy fields {bad} — valid: {_POLICY_FIELDS}")
    return PlanRule(
        select=SiteSelector(layers=layers, n=n, layer_set=tuple(layer_set),
                            kinds=tuple(kinds)),
        replace=replace,
        policy=SitePolicy(**policy),
    )


@dataclasses.dataclass(frozen=True)
class LUTPlan:
    """Ordered rule cascade resolving every site to dense or a LUTConfig."""

    rules: tuple[PlanRule, ...] = ()
    default: SitePolicy = PAPER_DEFAULT

    # ---------------- constructors ----------------
    @classmethod
    def all(cls, **policy: Any) -> "LUTPlan":
        return cls(rules=(rule(),), default=SitePolicy(**policy).merged_over(PAPER_DEFAULT))

    @classmethod
    def all_but_first(cls, **policy: Any) -> "LUTPlan":
        return cls(rules=(rule(layers="all_but_first"),),
                   default=SitePolicy(**policy).merged_over(PAPER_DEFAULT))

    @classmethod
    def last_n(cls, n: int, **policy: Any) -> "LUTPlan":
        return cls(rules=(rule(layers="last_n", n=n),),
                   default=SitePolicy(**policy).merged_over(PAPER_DEFAULT))

    @classmethod
    def none(cls, **policy: Any) -> "LUTPlan":
        """No replacement anywhere (dense model regardless of mode)."""
        return cls(rules=(), default=SitePolicy(**policy).merged_over(PAPER_DEFAULT))

    @classmethod
    def from_policy_string(
        cls, policy: str, default: SitePolicy = PAPER_DEFAULT
    ) -> "LUTPlan":
        """Back-compat shim for the old `ArchSpec.lut_policy` strings."""
        if not default.complete:
            default = default.merged_over(PAPER_DEFAULT)
        if policy == "all":
            sel = SiteSelector(layers="all")
        elif policy == "all_but_first":
            sel = SiteSelector(layers="all_but_first")
        elif policy.startswith("last_n:"):
            try:
                n = int(policy.split(":", 1)[1])
            except ValueError:
                raise ValueError(f"malformed lut_policy {policy!r} — "
                                 f"expected last_n:<int>") from None
            sel = SiteSelector(layers="last_n", n=n)
        else:
            raise ValueError(
                f"unknown lut_policy {policy!r} — expected 'all', "
                f"'all_but_first', 'last_n:<n>', or set ArchSpec.lut_plan"
            )
        return cls(rules=(PlanRule(select=sel),), default=default)

    # ---------------- resolution ----------------
    def resolve(self, layer: int | None, kind: str, n_layers: int) -> SitePolicy | None:
        """None = the site stays dense; else the fully-merged policy."""
        pol = self.default
        replaced = False
        for r in self.rules:
            if r.select.selects(layer, kind, n_layers):
                replaced = r.replace
                pol = r.policy.merged_over(pol)
        return pol if replaced else None

    def replaces(self, layer: int | None, kind: str, n_layers: int) -> bool:
        return self.resolve(layer, kind, n_layers) is not None

    def lut_config(
        self, layer: int | None, kind: str, d_in: int, n_layers: int
    ) -> LUTConfig | None:
        pol = self.resolve(layer, kind, n_layers)
        return None if pol is None else pol.lut_config(d_in)

    def validate(self, n_layers: int) -> "LUTPlan":
        if not self.default.complete:
            raise ValueError(f"plan default {self.default} must be fully "
                             f"populated (merge over plan.PAPER_DEFAULT)")
        for r in self.rules:
            r.select.validate(n_layers)
        return self

    def keeping_dense(self, *kinds: str) -> "LUTPlan":
        """This plan plus a final keep-dense rule over `kinds` (fnmatch
        patterns) — the mechanical way to derive a higher-fidelity SUB-plan
        from a trained plan. Every site the result replaces, self also
        replaces, so both deploy from one LUT_TRAIN checkpoint and share
        their tables byte-for-byte (the spec-decode target/draft pairing,
        DESIGN.md §14.1)."""
        if not kinds:
            raise ValueError("keeping_dense needs at least one kind pattern")
        return dataclasses.replace(
            self,
            rules=self.rules + (PlanRule(
                select=SiteSelector(kinds=tuple(kinds)), replace=False,
            ),),
        )

    def describe(self) -> str:
        """One-line human summary (launch logs / benchmark rows)."""
        if not self.rules:
            return "dense (no replacement)"
        parts = []
        for r in self.rules:
            s = r.select
            where = {"all": "all", "all_but_first": "all_but_first",
                     "last_n": f"last_{s.n}", "set": f"layers{list(s.layer_set)}"}[s.layers]
            if s.kinds != ("*",):
                where += f" kinds={list(s.kinds)}"
            ov = {f: getattr(r.policy, f) for f in _POLICY_FIELDS
                  if getattr(r.policy, f) is not None}
            parts.append(f"{'lut' if r.replace else 'dense'}@{where}"
                         + (f"{ov}" if ov else ""))
        d = self.default
        return f"[{'; '.join(parts)}] default K={d.k} V={d.v} b{d.bits}"

    # ---------------- serialization ----------------
    def to_dict(self) -> dict[str, Any]:
        return {
            "version": 1,
            "default": {f: getattr(self.default, f) for f in _POLICY_FIELDS},
            "rules": [
                {
                    "layers": r.select.layers,
                    "n": r.select.n,
                    "layer_set": list(r.select.layer_set),
                    "kinds": list(r.select.kinds),
                    "replace": r.replace,
                    "policy": {f: getattr(r.policy, f) for f in _POLICY_FIELDS
                               if getattr(r.policy, f) is not None},
                }
                for r in self.rules
            ],
        }

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "LUTPlan":
        if d.get("version") != 1:
            raise ValueError(f"unsupported LUTPlan dict version {d.get('version')!r}")
        rules = tuple(
            PlanRule(
                select=SiteSelector(
                    layers=r.get("layers", "all"),
                    n=int(r.get("n", 0)),
                    layer_set=tuple(r.get("layer_set", ())),
                    kinds=tuple(r.get("kinds", ("*",))),
                ),
                replace=bool(r.get("replace", True)),
                policy=SitePolicy(**r.get("policy", {})),
            )
            for r in d.get("rules", ())
        )
        return cls(rules=rules, default=SitePolicy(**d.get("default", {})))


# ---------------------------------------------------------------------------
# site registry record
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SiteSpec:
    """One linear site of a built model, as enumerated by ModelBundle.sites().

    path        param-tree prefix of the site's param dict
                (e.g. "segments/1/attn/q", "shared/out", "lm_head")
    layer       global layer index, or None for weight-shared sites
                (enc-dec models number encoder layers first, then decoder)
    stack_index index into the leading layer-stacked dim of the site's
                leaves, or None when the site's leaves are unstacked
    kind        plan-facing site kind ("attn/q", "mlp/down", "moe/gate",
                "self/q", "mamba/in_proj", "lm_head", ...)
    d_in/d_out  logical matmul dims of the site
    bias        whether the site carries a bias leaf
    mode        resolved Mode of the site in this bundle
    lut         the site's LUTConfig — always populated: dense-resolved and
                never-LUT sites (router, fuse, lm_head) carry the plan's
                default config as metadata, so filter LUT sites on `mode`,
                not on `lut`
    tape_key    activation-capture record key `tape_capture` sees for this
                site under an unrolled forward, or None for sites that do
                not pass through `models.common.linear` (MoE expert sites)
    """

    path: str
    layer: int | None
    stack_index: int | None
    kind: str
    d_in: int
    d_out: int
    bias: bool
    mode: Mode
    lut: LUTConfig | None
    tape_key: str | None

"""LUT-NN core: differentiable centroid learning + table-lookup AMM."""

from repro.core.amm import LUTConfig, Mode, dense_bytes, dense_flops, lut_flops, lut_linear, lut_table_bytes
from repro.core.plan import (
    PAPER_DEFAULT,
    LUTPlan,
    PlanRule,
    SitePolicy,
    SiteSelector,
    SiteSpec,
    rule,
)
from repro.core.lut_layer import (
    deploy_param_specs,
    deploy_params,
    init_dense,
    lut_train_params_from_dense,
)

__all__ = [
    "LUTConfig",
    "LUTPlan",
    "Mode",
    "PAPER_DEFAULT",
    "PlanRule",
    "SitePolicy",
    "SiteSelector",
    "SiteSpec",
    "rule",
    "lut_linear",
    "lut_flops",
    "dense_flops",
    "lut_table_bytes",
    "dense_bytes",
    "init_dense",
    "lut_train_params_from_dense",
    "deploy_params",
    "deploy_param_specs",
]

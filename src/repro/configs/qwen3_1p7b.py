"""Qwen3-1.7B — GQA + qk_norm, tied embeddings [hf:Qwen/Qwen3-1.7B]."""
from repro.configs import ArchSpec

ARCH = ArchSpec(
    name="qwen3_1p7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=6144,
    vocab=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

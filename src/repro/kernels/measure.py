"""Wall-clock measurement harness for the autotuner (DESIGN.md §13.2).

`measure_lut_amm` builds the operands for one lut_amm shape ONCE and returns
a `measure(cfg, version) -> seconds` callable that `autotune.tune` sweeps:
each candidate (tiling × kernel version) is compiled and run on the live
backend — one (or more) discarded warmup executions to absorb compile time,
then the median of k timed runs. Candidates that fail to compile or execute
(illegal tiling on the real hardware) return +inf so the sweep skips them
instead of dying.

This is what turns the autotuner's ranking from a roofline *projection* into
a measurement: `ServingEngine` warmup uses it when REPRO_AUTOTUNE_MEASURE=1,
and `benchmarks/op_microbench.py` when the same flag is set, writing records
with `measured: true` that take precedence over analytic ones everywhere
(DESIGN.md §13.3).

Knobs (env): REPRO_AUTOTUNE_MEASURE_REPS (default 5) and
REPRO_AUTOTUNE_MEASURE_WARMUP (default 1) bound the per-candidate cost.
"""

from __future__ import annotations

import math
import os
import statistics
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import autotune


def _env_int(name: str, default: int) -> int:
    try:
        return int(os.environ.get(name, default))
    except ValueError:
        return default


def measure_enabled() -> bool:
    """Whether the wall-clock measurement path is switched on (env flag)."""
    return os.environ.get("REPRO_AUTOTUNE_MEASURE", "0").lower() not in (
        "", "0", "false", "no",
    )


def measure_lut_amm(
    n: int, m: int, c: int, k: int, v: int,
    *,
    dtype: str = "float32",
    interpret: bool | None = None,
    warmup: int | None = None,
    reps: int | None = None,
    seed: int = 0,
) -> Callable[[autotune.BlockConfig, int], float]:
    """Build a timed-compiled-run measure callable for one lut_amm shape.

    Operands are synthesized once (per-shape, not per-candidate): random
    activations in `dtype`, fp32 centroids, an int8 table with the m-shared
    (1,1,M) scale layout — the layout `deploy_params` emits for kernel
    sites, so the timed path is the production dataflow.
    """
    from repro.kernels.fused_decode import fused_decode_pallas
    from repro.kernels.lut_amm import lut_amm_pallas, lut_amm_pallas_v1

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    warmup = warmup if warmup is not None else _env_int("REPRO_AUTOTUNE_MEASURE_WARMUP", 1)
    reps = reps if reps is not None else _env_int("REPRO_AUTOTUNE_MEASURE_REPS", 5)

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (n, c * v), jnp.dtype(dtype))
    P = jax.random.normal(k2, (c, k, v), jnp.float32)
    tq = jax.random.randint(k3, (c, k, m), -127, 128, jnp.int8)
    scale = jnp.full((1, 1, m), 0.02, jnp.float32)
    scale_v1 = jnp.broadcast_to(scale, (c, 1, m))        # v1 wants (C, ...) scales

    def measure(cfg: autotune.BlockConfig, version: int = 2) -> float:
        bn, bm, bc = cfg.block_n, cfg.block_m, cfg.block_c
        if version >= autotune.VERSION_FUSED:
            if bc != c:           # fused keeps all of C resident by definition
                return math.inf
            fn = lambda: fused_decode_pallas(
                x, P, tq, scale, block_n=bn, block_m=bm, interpret=interpret)
        elif version == 2:
            fn = lambda: lut_amm_pallas(
                x, P, tq, scale,
                block_n=bn, block_m=bm, block_c=bc, interpret=interpret)
        else:
            fn = lambda: lut_amm_pallas_v1(
                x, P, tq, scale_v1,
                block_n=bn, block_m=bm, block_c=bc, interpret=interpret)
        try:
            for _ in range(max(1, warmup)):
                jax.block_until_ready(fn())              # compile off the clock
            times = []
            for _ in range(max(1, reps)):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                times.append(time.perf_counter() - t0)
            return statistics.median(times)
        except Exception:
            return math.inf                              # illegal tiling: skip
    return measure

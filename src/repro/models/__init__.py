"""Pure-JAX model zoo with LUT-NN-capable linear sites throughout."""

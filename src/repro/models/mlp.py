"""Gated-linear-unit FFN (SwiGLU family). gate/up/down are LUT sites."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import Params, SiteCfg, activation, linear, linear_init


@dataclasses.dataclass(frozen=True)
class MLPCfg:
    d_model: int
    d_ff: int
    gate: SiteCfg
    up: SiteCfg
    down: SiteCfg
    act: str = "silu"
    gated: bool = True


def mlp_init(key: jax.Array, cfg: MLPCfg, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {
        "up": linear_init(ks[1], cfg.up, dtype=dtype),
        "down": linear_init(ks[2], cfg.down, dtype=dtype),
    }
    if cfg.gated:
        p["gate"] = linear_init(ks[0], cfg.gate, dtype=dtype)
    return p


def mlp(cfg: MLPCfg, p: Params, x: jax.Array) -> jax.Array:
    up = linear(cfg.up, p["up"], x)
    if cfg.gated:
        g = activation(cfg.act, linear(cfg.gate, p["gate"], x))
        h = g * up
    else:
        h = activation(cfg.act, up)
    return linear(cfg.down, p["down"], h)

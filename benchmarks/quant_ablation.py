"""Paper section 6.3 scalar-quantization ablation: FP32 / INT8 / INT4 tables.

The paper: 94.44 / 94.40 / 94.27 on CIFAR10 — QAT makes INT8 free and INT4
nearly free. Same protocol here on the MLP carrier + per-column scales
(beyond-paper variant).
"""

from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks._mlp import MLPSpec, attach_pq, evaluate, finetune_softpq, train_dense
from repro.core.amm import LUTConfig
from repro.data import ClusteredTask


def main(steps: int = 200) -> None:
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    spec = MLPSpec(d_in=64, width=128, depth=4, n_out=10)
    task = ClusteredTask(d_in=spec.d_in, n_classes=10)
    dense = train_dense(key, spec, task, steps=300)
    layer_ids = list(range(1, spec.depth + 1))
    base = evaluate(dense, spec, task)

    print("# section 6.3 analog: lookup-table scalar quantization level")
    print(f"bits,acc  (dense baseline {base:.4f})")
    accs = {}
    for bits in (32, 8, 4):
        p0 = attach_pq(key, dense, spec, task, layer_ids, kind="pq")
        p, _ = finetune_softpq(key, p0, spec, task, layer_ids, steps=steps,
                               bits=bits if bits < 32 else 16)  # 16 ~ no-op fake quant
        accs[bits] = evaluate(p, spec, task, modes=[
            ("pq" if i in layer_ids else None) for i in range(spec.depth + 1)
        ])
        print(f"{bits},{accs[bits]:.4f}")
    print(f"claim_int8_free,{abs(accs[8] - accs[32]) < 0.02}")
    print(f"claim_int4_small_cost,{accs[32] - accs[4] < 0.05}")
    print(f"quant_ablation,{(time.time()-t0)*1e6:.0f},accuracy")


if __name__ == "__main__":
    main()

"""Decoder-only LM assembly: blocks, scan-over-layers segments, heads.

Layer stacking uses jax.lax.scan over stacked per-layer params so the HLO
stays O(1) in depth (compile-time critical for the 40-cell dry-run). Layers
are grouped into *segments* of identical block structure; the paper's
"don't replace the first layer" rule (and BERT's "last 6 layers only",
Fig. 13) fall out naturally: segment 0 = 1 dense-mode block, segment 1 =
L-1 LUT-mode blocks.

Covers families: dense (llama3/minitron/qwen3/command-r), moe
(llama4/arctic incl. dense-residual), ssm (mamba2), vlm (qwen2-vl via
embeds input + M-RoPE). Hybrid (zamba2) and enc-dec (whisper) assemble
these same blocks in hybrid.py / encdec.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models.common import (
    Params,
    SiteCfg,
    cross_entropy,
    embed,
    embed_init,
    linear,
    linear_init,
    rmsnorm,
    rmsnorm_init,
)


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    kind: str                                  # "dense" | "moe" | "mamba"
    d_model: int
    attn: attn_mod.AttnCfg | None = None
    mlp: mlp_mod.MLPCfg | None = None
    moe: moe_mod.MoECfg | None = None
    mamba: mamba_mod.Mamba2Cfg | None = None
    residual_mlp: mlp_mod.MLPCfg | None = None  # arctic parallel dense branch


def block_init(key: jax.Array, cfg: BlockCfg, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    if cfg.kind == "mamba":
        return {
            "norm": rmsnorm_init(cfg.d_model, dtype),
            "mamba": mamba_mod.mamba2_init(ks[0], cfg.mamba, dtype=dtype),
        }
    p: Params = {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_mod.attn_init(ks[0], cfg.attn, dtype=dtype),
    }
    if cfg.kind == "dense":
        p["mlp"] = mlp_mod.mlp_init(ks[1], cfg.mlp, dtype=dtype)
    elif cfg.kind == "moe":
        p["moe"] = moe_mod.moe_init(ks[1], cfg.moe, dtype=dtype)
        if cfg.residual_mlp is not None:
            p["residual_mlp"] = mlp_mod.mlp_init(ks[2], cfg.residual_mlp, dtype=dtype)
    else:
        raise ValueError(cfg.kind)
    return p


def block_cache_specs(cfg: BlockCfg, b: int, s_max: int, dtype=jnp.bfloat16,
                      paged: attn_mod.PagedSpec | None = None) -> Params:
    if cfg.kind == "mamba":
        return mamba_mod.mamba2_cache_specs(b, cfg.mamba, dtype)
    if paged is not None:
        return attn_mod.paged_cache_specs(paged, cfg.attn, dtype)
    return attn_mod.cache_specs(b, s_max, cfg.attn, dtype)


def block_init_cache(cfg: BlockCfg, b: int, s_max: int, dtype=jnp.bfloat16,
                     paged: attn_mod.PagedSpec | None = None) -> Params:
    if cfg.kind == "mamba":
        return mamba_mod.mamba2_init_cache(b, cfg.mamba, dtype)
    if paged is not None:
        return attn_mod.paged_init_cache(paged, cfg.attn, dtype)
    return attn_mod.init_cache(b, s_max, cfg.attn, dtype)


def block_apply(
    cfg: BlockCfg,
    p: Params,
    x: jax.Array,
    *,
    pos: jax.Array,
    cache: Params | None = None,
    cache_len: jax.Array | None = None,
    defer_cache_write: bool = False,
    block_tables: jax.Array | None = None,
    write_len: jax.Array | None = None,
) -> tuple[jax.Array, Params | None, jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.kind == "mamba":
        h, new_cache = mamba_mod.mamba2(cfg.mamba, p["mamba"], rmsnorm(p["norm"], x), cache=cache)
        return x + h, new_cache, aux

    a, new_cache = attn_mod.attention(
        cfg.attn, p["attn"], rmsnorm(p["norm1"], x), pos=pos, cache=cache,
        cache_len=cache_len, defer_cache_write=defer_cache_write,
        block_tables=block_tables, write_len=write_len,
    )
    x = x + a
    h = rmsnorm(p["norm2"], x)
    if cfg.kind == "dense":
        f = mlp_mod.mlp(cfg.mlp, p["mlp"], h)
    else:
        f, aux = moe_mod.moe(cfg.moe, p["moe"], h)
        if cfg.residual_mlp is not None:
            f = f + mlp_mod.mlp(cfg.residual_mlp, p["residual_mlp"], h)
    return x + f, new_cache, aux


# ---------------------------------------------------------------------------
# full LM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMCfg:
    vocab: int
    d_model: int
    segments: tuple[tuple[int, BlockCfg], ...]   # (n_layers, block cfg) runs
    lm_head: SiteCfg | None = None               # None -> tied to embedding
    remat: bool = True
    takes_embeds: bool = False                   # vlm/audio stub frontends
    unroll: bool = False                         # python-loop layers (capture)

    @property
    def n_layers(self) -> int:
        return sum(n for n, _ in self.segments)


def lm_init(key: jax.Array, cfg: LMCfg, *, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, len(cfg.segments) + 3)
    segs = []
    for i, (count, bcfg) in enumerate(cfg.segments):
        seg_keys = jax.random.split(keys[i], count)
        segs.append(jax.vmap(lambda k: block_init(k, bcfg, dtype=dtype))(seg_keys))
    p: Params = {
        "embed": embed_init(keys[-3], cfg.vocab, cfg.d_model, dtype),
        "segments": segs,
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.lm_head is not None:
        p["lm_head"] = linear_init(keys[-2], cfg.lm_head, dtype=dtype)
    return p


def init_caches(cfg: LMCfg, b: int, s_max: int, dtype=jnp.bfloat16, abstract: bool = False,
                paged: attn_mod.PagedSpec | None = None) -> list:
    mk = block_cache_specs if abstract else block_init_cache
    out = []
    for count, bcfg in cfg.segments:
        one = mk(bcfg, b, s_max, dtype, paged=paged)
        if abstract:
            stacked = jax.tree.map(
                lambda sds: jax.ShapeDtypeStruct((count, *sds.shape), sds.dtype), one
            )
        else:
            stacked = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (count, *a.shape)).copy(), one)
        out.append(stacked)
    return out


def _seg_apply(
    bcfg: BlockCfg,
    seg_params: Params,
    x: jax.Array,
    *,
    pos: jax.Array,
    caches: Params | None,
    cache_len: jax.Array | None,
    remat: bool,
    unroll: bool = False,
    prefix: str = "",
    block_tables: jax.Array | None = None,
    write_len: jax.Array | None = None,
) -> tuple[jax.Array, Params | None, jax.Array]:
    """scan one segment of stacked layers."""
    if unroll:
        # eager python loop over per-layer param slices: used by the
        # dense->LUT conversion pass so the activation tape sees concrete
        # arrays (jax.lax.scan would only show it tracers).
        n_layers = jax.tree.leaves(seg_params)[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        new_caches = [] if caches is not None else None
        from repro.models.common import set_tape_prefix

        for j in range(n_layers):
            set_tape_prefix(f"{prefix}/{j}")
            pl_ = jax.tree.map(lambda a: a[j], seg_params)
            cl_ = None if caches is None else jax.tree.map(lambda a: a[j], caches)
            x, nc, a = block_apply(bcfg, pl_, x, pos=pos, cache=cl_, cache_len=cache_len)
            aux = aux + a
            if caches is not None:
                new_caches.append(nc)
        if caches is not None:
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_caches)
        return x, new_caches, aux

    def body(carry, layer_in):
        xc, aux = carry
        if caches is None:
            pl_ = layer_in
            y, _, a = block_apply(bcfg, pl_, xc, pos=pos, cache=None, cache_len=None)
            return (y, aux + a), None
        pl_, cl_ = layer_in
        y, new_c, a = block_apply(bcfg, pl_, xc, pos=pos, cache=cl_,
                                  cache_len=cache_len, defer_cache_write=defer,
                                  block_tables=block_tables, write_len=write_len)
        return (y, aux + a), new_c

    # decode fast path: attention layers return K/V slabs; one scatter into
    # the stacked cache afterwards replaces per-layer cache rewrites
    defer = (
        caches is not None
        and bcfg.kind != "mamba"
        and x.shape[1] == 1
    )
    fn = jax.checkpoint(body) if (remat and caches is None) else body
    xs = seg_params if caches is None else (seg_params, caches)
    (x, aux), new_caches = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)), xs)
    if defer and new_caches is not None:
        b = x.shape[0]
        s_new = new_caches["k_slab"].shape[2]
        if "k_pool" in caches:
            # paged: one O(L*B*s_new) scatter into the page-flattened pool;
            # masked rows route to the garbage page (no select-merge needed)
            n_pages, page_size = caches["k_pool"].shape[1:3]
            wl = write_len
            if wl is None:
                wl = jnp.full((b,), s_new, jnp.int32)
            flat = attn_mod.paged_write_flat(
                block_tables, cache_len, s_new, page_size, wl)          # (B, s)

            def scatter(pool, slab):
                fp = pool.reshape(pool.shape[0], n_pages * page_size, *pool.shape[3:])
                return fp.at[:, flat].set(slab).reshape(pool.shape)

            new_caches = {
                "k_pool": scatter(caches["k_pool"], new_caches["k_slab"]),
                "v_pool": scatter(caches["v_pool"], new_caches["v_slab"]),
            }
        else:
            write_idx = cache_len[:, None] + jnp.arange(s_new, dtype=jnp.int32)[None, :]  # (B, s)
            bidx = jnp.arange(b, dtype=jnp.int32)[:, None]                                # (B, 1)
            # one O(L*B*s_new) scatter replaces L full-cache functional rewrites
            new_caches = {
                "k": caches["k"].at[:, bidx, write_idx].set(new_caches["k_slab"]),
                "v": caches["v"].at[:, bidx, write_idx].set(new_caches["v_slab"]),
            }
    return x, new_caches, aux


def lm_apply(
    cfg: LMCfg,
    params: Params,
    *,
    tokens: jax.Array | None = None,      # (B, S) int32
    embeds: jax.Array | None = None,      # (B, S, D) stub-frontend input
    pos: jax.Array,                       # (B, S) or (3, B, S)
    caches: list | None = None,
    cache_len: jax.Array | None = None,
    compute_dtype=jnp.float32,
    block_tables: jax.Array | None = None,
    write_len: jax.Array | None = None,
) -> tuple[jax.Array, list | None, jax.Array]:
    """Returns (logits (B, S, vocab), new caches, aux loss)."""
    if cfg.takes_embeds:
        x = embeds.astype(compute_dtype)
    else:
        x = embed(params["embed"], tokens).astype(compute_dtype)

    new_caches = [] if caches is not None else None
    aux_total = jnp.zeros((), jnp.float32)
    for i, (count, bcfg) in enumerate(cfg.segments):
        c_i = caches[i] if caches is not None else None
        x, nc, aux = _seg_apply(
            bcfg, params["segments"][i], x,
            pos=pos, caches=c_i, cache_len=cache_len, remat=cfg.remat,
            unroll=cfg.unroll, prefix=f"segments/{i}",
            block_tables=block_tables, write_len=write_len,
        )
        if caches is not None:
            new_caches.append(nc)
        aux_total = aux_total + aux

    x = rmsnorm(params["final_norm"], x)
    if cfg.lm_head is not None:
        from repro.models.common import set_tape_prefix

        set_tape_prefix("")                 # registry key: bare "lm_head"
        logits = linear(cfg.lm_head, params["lm_head"], x)
    else:
        logits = jnp.einsum(
            "bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype)
        )
    return logits, new_caches, aux_total


# MoE load-balance penalty weight — the ONE definition shared by lm_loss,
# ModelBundle.loss_from_logits, and the distillation CE term
LM_AUX_WEIGHT = 0.01


def lm_loss(
    cfg: LMCfg,
    params: Params,
    batch: dict[str, jax.Array],
    *,
    compute_dtype=jnp.float32,
    aux_weight: float = LM_AUX_WEIGHT,
) -> jax.Array:
    pos = batch.get("pos")
    if pos is None:
        b, s = batch["labels"].shape[:2]
        pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    logits, _, aux = lm_apply(
        cfg,
        params,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        pos=pos,
        compute_dtype=compute_dtype,
    )
    return cross_entropy(logits, batch["labels"]) + aux_weight * aux

"""Lower + compile one production cell on the 512-chip multi-pod mesh and
print its memory/roofline analysis.

  PYTHONPATH=src python examples/multipod_dryrun.py [arch] [shape]
"""

import sys

if __name__ == "__main__":
    from repro.launch import dryrun

    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3_1p7b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"
    rec = dryrun.run_cell(arch, shape, multi_pod=True)
    import json

    print(json.dumps(rec, indent=2))

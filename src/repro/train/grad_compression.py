"""int8 error-feedback gradient compression for the data-parallel reduce.

Classic 2-phase compressed all-reduce (1-bit Adam family, here 8-bit):

  1. quantize local grads to int8 with a per-tensor fp32 scale, carrying the
     quantization residual into the next step (error feedback preserves
     convergence),
  2. all_to_all int8 chunks across the data axis (wire: 1 byte/elem),
  3. local dequant + fp32 mean of the received chunks,
  4. re-quantize the reduced chunk, all_gather int8 (wire: 1 byte/elem).

Wire bytes: 2 x 1B/elem vs 2 x 2B/elem for a bf16 ring all-reduce -> 2x
collective-term reduction (4x vs fp32 grads).

`compressed_mean_tree` is the inside-shard_map primitive;
`make_compressed_grad_fn` builds the full data-parallel gradient step
(shard_map over the dp axis: local grads -> compressed mean), which is the
trainer's opt-in replacement for GSPMD's implicit bf16 all-reduce.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def _quant(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def compressed_mean_1d(vec: jax.Array, *, axis: str, n: int) -> jax.Array:
    """Mean over the mesh axis of a flat fp32 vector (len divisible by n).

    Must be called inside shard_map; `vec` differs per shard. Both wire
    phases move int8.
    """
    chunks = vec.reshape(n, -1)
    q, s = _quant(chunks)                                    # s: per-device scalar
    # row p of recv = peer p's chunk destined for my slot
    recv = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0, tiled=True)
    scales = jax.lax.all_gather(s, axis, tiled=False)        # (n,) per-peer scales
    local_sum = jnp.einsum("nc,n->c", recv.astype(jnp.float32), scales) / n
    q2, s2 = _quant(local_sum)
    all_q = jax.lax.all_gather(q2, axis, tiled=False)        # (n, chunk) int8 wire
    all_s = jax.lax.all_gather(s2, axis, tiled=False)
    return (all_q.astype(jnp.float32) * all_s.reshape(n, 1)).reshape(-1)


def compressed_mean_tree(grads: Any, *, axis: str, n: int) -> Any:
    flat, treedef = jax.tree_util.tree_flatten(grads)
    sizes = [x.size for x in flat]
    vec = jnp.concatenate([x.astype(jnp.float32).reshape(-1) for x in flat])
    pad = (-vec.size) % n
    out = compressed_mean_1d(jnp.pad(vec, (0, pad)), axis=axis, n=n)[: vec.size]
    outs, off = [], 0
    for x, sz in zip(flat, sizes):
        outs.append(out[off : off + sz].reshape(x.shape).astype(x.dtype))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, outs)


def residual_correct(grads: Any, residual: Any) -> tuple[Any, Any]:
    """Error feedback: add carried residual; return (corrected, new_residual)."""
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual
    )

    def res(c):
        q, s = _quant(c)
        return c - q.astype(jnp.float32) * s

    new_residual = jax.tree.map(res, corrected)
    return corrected, new_residual


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressed_grad_fn(
    loss_fn: Callable[[Any, Any], jax.Array],
    mesh: Mesh,
    *,
    axis: str = "data",
) -> Callable[[Any, Any, Any], tuple[jax.Array, Any, Any]]:
    """Data-parallel value_and_grad with int8 compressed reduce.

    Returns step(params, residual, batch) -> (mean loss, mean grads,
    new residual). params replicated; batch sharded on dim0 over `axis`.
    """
    n = mesh.shape[axis]

    def local(params, residual, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        corrected, new_residual = residual_correct(grads, residual)
        reduced = compressed_mean_tree(corrected, axis=axis, n=n)
        loss = jax.lax.pmean(loss, axis)
        return loss, reduced, new_residual

    batch_spec = P(axis)
    rep = P()
    specs = dict(in_specs=(rep, rep, batch_spec), out_specs=(rep, rep, rep))
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(local, mesh=mesh, check_vma=False, **specs)
    # jax < 0.6: experimental home, and the no-replication-check kwarg is
    # spelled check_rep rather than check_vma
    from jax.experimental.shard_map import shard_map as sm

    return sm(local, mesh=mesh, check_rep=False, **specs)

"""Dense -> LUT conversion: graft fidelity, k-means init quality, deploy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core import convert
from repro.core.amm import Mode
from repro.data import MarkovLM


@pytest.fixture(scope="module")
def setup():
    arch = reduce_arch(get_arch("llama3_8b"), n_layers=3, vocab=64, d_model=64, d_ff=128)
    data = MarkovLM(vocab=arch.vocab, seq_len=16, batch=8)
    dense = build_model(arch, Mode.DENSE)
    dparams = dense.init(jax.random.PRNGKey(0))
    # brief pretrain: conversion assumes a TRAINED source model (its
    # activations carry the cluster structure k-means exploits)
    from repro.optim import AdamW
    from repro.train.train_step import make_train_step

    opt = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(dense, opt, compute_dtype=jnp.float32))
    ostate = opt.init(dparams)
    for i in range(30):
        dparams, ostate, _ = step(dparams, ostate, data.batch_at(i))
    samples = [data.batch_at(i) for i in range(2)]
    blut, lparams = convert.convert_dense_to_lut_train(
        dense, dparams, samples, jax.random.PRNGKey(1)
    )
    return arch, data, dense, dparams, blut, lparams


def test_graft_copies_weights(setup):
    arch, data, dense, dparams, blut, lparams = setup
    # embedding copied verbatim
    np.testing.assert_array_equal(
        np.asarray(dparams["embed"]["table"]), np.asarray(lparams["embed"]["table"])
    )
    # layer-0 (dense segment) weights = dense model layer 0
    d0 = jax.tree.leaves(jax.tree.map(lambda a: a[0], dparams["segments"][0]))
    l0 = jax.tree.leaves(jax.tree.map(lambda a: a[0], lparams["segments"][0]))
    # lut segment 0 has no centroids (dense mode) -> same leaf count
    assert len(d0) == len(l0)
    # replaced-layer weights preserved as the frozen table source
    wq_dense = dparams["segments"][0]["attn"]["q"]["w"][1:]    # layers 1..L-1
    wq_lut = lparams["segments"][1]["attn"]["q"]["w"]
    np.testing.assert_array_equal(np.asarray(wq_dense), np.asarray(wq_lut))


def test_kmeans_init_beats_random(setup):
    arch, data, dense, dparams, blut, lparams = setup
    batch = data.batch_at(99)
    loss_km = float(blut.loss(lparams, batch, compute_dtype=jnp.float32))

    rnd = blut.init(jax.random.PRNGKey(2))
    rnd = convert.graft_dense_to_lut(dparams, rnd)           # weights same, centroids random
    loss_rnd = float(blut.loss(rnd, batch, compute_dtype=jnp.float32))
    assert loss_km < loss_rnd


def test_deploy_matches_train_forward(setup):
    """Deployed int8 path must equal the QAT forward (which already fake-
    quantizes) up to int8 rounding noise."""
    arch, data, dense, dparams, blut, lparams = setup
    batch = data.batch_at(7)
    l_train = float(blut.loss(lparams, batch, compute_dtype=jnp.float32))
    binf, iparams = convert.deploy_lut_train_params(blut, lparams)
    l_inf = float(binf.loss(iparams, batch, compute_dtype=jnp.float32))
    assert abs(l_train - l_inf) < 0.02 * max(1.0, abs(l_train))


def test_tape_capture_covers_lut_sites(setup):
    arch, data, dense, dparams, blut, lparams = setup
    import dataclasses
    from repro.models import transformer as tf
    from repro.models.common import tape_capture

    cfg = dataclasses.replace(dense.cfg, unroll=True, remat=False)
    batch = data.batch_at(0)
    pos = jnp.arange(16, dtype=jnp.int32)[None, :].repeat(8, 0)
    with tape_capture() as tape:
        tf.lm_apply(cfg, dparams, tokens=batch["tokens"], pos=pos, compute_dtype=jnp.float32)
    # 3 layers x 7 sites (q,k,v,o,gate,up,down) + lm_head — every taped
    # registry site records under its tape_key
    assert len(tape.records) == 3 * 7 + 1
    keys = {s.tape_key for s in dense.sites() if s.tape_key is not None}
    assert set(tape.records) == keys


# ---------------------------------------------------------------------------
# cross-plan deploy (DESIGN.md §14.1): one LUT_TRAIN checkpoint, many plans

def test_cross_plan_deploy_shares_tables(setup):
    """Deploying the trained state under keeping_dense('attn/*') drops the
    attn tables back to dense weights while every other site's int8 table
    is byte-identical to the full-plan deploy — the invariant the artifact
    dedup (and the spec-decode shared-table draft) relies on."""
    from repro.configs import effective_plan

    arch, data, dense, dparams, blut, lparams = setup
    trained = effective_plan(arch)
    _, full = convert.deploy_lut_train_params(blut, lparams, plan=trained)
    tb, sub = convert.deploy_lut_train_params(
        blut, lparams, plan=trained.keeping_dense("attn/*"))

    fflat = convert._flat_paths(full)
    sflat = convert._flat_paths(sub)
    # the sub-plan carries dense attn weights the full plan replaced ...
    dense_attn = [p for p in sflat
                  if "/attn/" in p and p.endswith("/w") and p not in fflat]
    assert dense_attn
    # ... and no attn tables of its own
    assert not any("/attn/" in p and p.endswith("/table") for p in sflat)

    shared = [p for p, v in sflat.items()
              if p in fflat and fflat[p].shape == v.shape]
    tables = [p for p in shared if p.endswith("/table")]
    assert tables                      # ffn sites overlap across the plans
    for p in shared:
        np.testing.assert_array_equal(np.asarray(sflat[p]),
                                      np.asarray(fflat[p]))

    # the sub-plan deploy still serves: loss is finite and close to the
    # full deploy (both share the non-attn tables; attn is exact dense)
    batch = data.batch_at(3)
    l_sub = float(tb.loss(sub, batch, compute_dtype=jnp.float32))
    assert np.isfinite(l_sub)


def test_cross_plan_superset_plan_raises(setup):
    """A deploy plan may only replace sites the TRAINED plan replaced —
    a checkpoint trained under keeping_dense('attn/*') has no attn
    centroids, so deploying it under the full plan must fail with the
    actionable message, not a raw KeyError."""
    import dataclasses

    from repro.configs import build_model as _bm, effective_plan

    arch, *_ = setup
    trained = effective_plan(arch)
    arch_sub = dataclasses.replace(arch, lut_plan=trained.keeping_dense("attn/*"))
    blut_sub = _bm(arch_sub, Mode.LUT_TRAIN)
    lp_sub = blut_sub.init(jax.random.PRNGKey(5))
    with pytest.raises(ValueError, match="keeping_dense"):
        convert.deploy_lut_train_params(blut_sub, lp_sub, plan=trained)

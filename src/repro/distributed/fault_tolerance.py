"""Fault tolerance & straggler mitigation for the training loop.

On real pods, failures surface as raised exceptions from collectives /
device halts, and stragglers as step-time skew across hosts. Both are
host-side control-plane concerns, so they are implementable (and testable)
without TPUs:

  * StepGuard      — wraps the jitted step; classifies exceptions as
                     retryable (preemption / transient runtime error) or
                     fatal (shape/compile bugs), with bounded retries.
                     After `max_retries`, the trainer restores from the
                     last committed checkpoint instead of crashing the job.
  * StragglerMonitor — per-step wall-time EMA; flags steps slower than
                     `threshold` x EMA. On a real deployment the flag feeds
                     the scheduler (hot-spare swap); here it feeds logs +
                     metrics so the policy is exercised by tests.
  * HeartbeatFile  — liveness breadcrumb for an external supervisor
                     (restart-on-hang), one json line per step.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Any, Callable


def is_retryable(e: Exception) -> bool:
    """Preemptions / transient device errors are retryable; programming
    errors (TypeError, ValueError from shapes) are not."""
    if isinstance(e, (TypeError, ValueError, KeyError, AssertionError)):
        return False
    msg = str(e).lower()
    fatal_markers = ("invalid argument", "rank", "incompatible shapes")
    return not any(m in msg for m in fatal_markers)


@dataclasses.dataclass(frozen=True)
class Backoff:
    """Capped exponential backoff schedule: base * factor^attempt, <= cap.

    Shared restart-delay shape for StepGuard-style retries and the serving
    supervisor's worker restarts (repro.serving.supervisor)."""

    base_s: float = 0.1
    factor: float = 2.0
    cap_s: float = 5.0

    def __post_init__(self) -> None:
        if self.base_s < 0 or self.factor < 1.0 or self.cap_s < 0:
            raise ValueError(f"invalid backoff: {self}")

    def delay(self, attempt: int) -> float:
        """Delay before retry `attempt` (0-based)."""
        return min(self.base_s * self.factor ** attempt, self.cap_s)


@dataclasses.dataclass
class StepGuard:
    max_retries: int = 2
    backoff_s: float = 0.0
    on_failure: Callable[[Exception, int], None] | None = None

    def run(self, fn: Callable[[], Any]) -> Any:
        last: Exception | None = None
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except Exception as e:  # noqa: BLE001 — classification below
                if not is_retryable(e):
                    raise
                last = e
                if self.on_failure:
                    self.on_failure(e, attempt)
                if self.backoff_s:
                    time.sleep(self.backoff_s * (attempt + 1))
        raise RuntimeError(
            f"step failed after {self.max_retries + 1} attempts"
        ) from last


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0           # x EMA counts as straggler
    decay: float = 0.9
    warmup_steps: int = 5

    _ema: float = 0.0
    _n: int = 0
    events: list = dataclasses.field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self._n += 1
        if self._n <= self.warmup_steps:
            self._ema = seconds if self._ema == 0 else (
                self.decay * self._ema + (1 - self.decay) * seconds
            )
            return False
        slow = seconds > self.threshold * self._ema
        if slow:
            self.events.append({"step": step, "seconds": seconds, "ema": self._ema})
        else:
            self._ema = self.decay * self._ema + (1 - self.decay) * seconds
        return slow

    @property
    def ema(self) -> float:
        return self._ema


class HeartbeatFile:
    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int, **extra: Any) -> None:
        rec = {"step": step, "t": time.time(), **extra}
        self.path.write_text(json.dumps(rec))

"""Quickstart: replace one matmul with a LUT-NN table lookup.

  PYTHONPATH=src python examples/quickstart.py

Walks the paper end to end on a single operator: k-means centroids (Eq. 1),
table precompute (Eq. 3), argmin encode + table read (Eq. 4), and the cost
accounting of Table 1.
"""

import jax
import jax.numpy as jnp

from repro.core import LUTConfig, Mode, dense_bytes, dense_flops, lut_flops, lut_linear, lut_table_bytes
from repro.core.lut_layer import deploy_params, init_dense, lut_train_params_from_dense

key = jax.random.PRNGKey(0)
N, D, M = 1024, 256, 512
cfg = LUTConfig(k=16, v=8, bits=8)

# clustered inputs — the structure LUT-NN exploits (paper section 1)
centers = jax.random.normal(key, (16, D))   # 16 clusters: one per centroid slot
x = centers[jax.random.randint(key, (N,), 0, 16)]
x = x + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (N, D))

dense = init_dense(jax.random.PRNGKey(2), D, M)
y_ref = lut_linear(cfg, Mode.DENSE, dense, x)

# offline: learn centroids from activations, precompute + quantize the table
trainable, frozen = lut_train_params_from_dense(jax.random.PRNGKey(3), dense, x, cfg)
deployed = deploy_params(trainable, frozen, cfg)

# online: encode -> lookup -> accumulate (no D-contraction matmul)
y_lut = lut_linear(cfg, Mode.LUT_INFER, deployed, x)

rel = float(jnp.linalg.norm(y_lut - y_ref) / jnp.linalg.norm(y_ref))
print(f"approximation rel. error     : {rel:.4f}")
print(f"FLOPs   dense -> LUT         : {dense_flops(N, D, M):.2e} -> {lut_flops(N, D, M, cfg):.2e} "
      f"({dense_flops(N, D, M)/lut_flops(N, D, M, cfg):.1f}x, paper Table 1)")
print(f"weights dense -> int8 tables : {dense_bytes(D, M):.2e} -> {lut_table_bytes(D, M, cfg):.2e} bytes "
      f"({dense_bytes(D, M)/lut_table_bytes(D, M, cfg):.1f}x)")

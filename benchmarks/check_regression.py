"""Perf-counter regression gate: re-run the serving benchmarks and diff
their deterministic counters against the committed BENCH_*.json files.

  PYTHONPATH=src python -m benchmarks.check_regression              # run + diff
  PYTHONPATH=src python -m benchmarks.check_regression --fresh-dir D # diff only

The serving scheduler is single-threaded and its counters (steps, prefill
forwards/tokens, pages resident, prefix hits, COW copies, ...) are pure
functions of the request trace — any drift is a behavior change, not noise,
so those keys are compared EXACTLY. Wall-clock-derived keys (tok/s, *_s)
are machine noise and skipped. The fault-injection rows sit in between:
sleeps and deadlines make shed/timeout splits timing-sensitive, so their
status counts get absolute tolerances instead of exact equality.

Exit code 0 = no regression; 1 = drift (each offending key printed).
A committed row missing from the fresh run fails, except rows listed as
best-effort (the tp2 subprocess row); NEW fresh rows/keys are reported but
do not fail — committing the fresh file is the upgrade path.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile

_ROOT = pathlib.Path(__file__).resolve().parents[1]

# keys never compared: wall-clock and rates derived from it
_TIMING = ("wall_s", "prefill_tok_s", "decode_tok_s", "tok_s", "p50_s", "p99_s")
# kernel/plan artifacts carry per-row wall-clock under uniform suffixes
_TIMING_SUFFIX = ("_ms", "_us")


def _is_timing(key: str) -> bool:
    return key in _TIMING or key.endswith(_TIMING_SUFFIX)

# per-file rules: how rows are keyed, which module regenerates them, which
# keys are timing-tolerant (abs tolerance), which rows may be absent fresh
RULES = {
    "BENCH_serving.json": {
        "module": "serving_bench",
        "row_key": "load",
        "tol_abs": {},                       # everything non-timing is exact
        "optional_rows": {"tp2_12req"},      # subprocess row is best-effort
    },
    "BENCH_spec.json": {
        "module": "serving_spec",
        "row_key": "scenario",
        # load-determined counters (requests, decode/prefill tokens,
        # spec_tokens_emitted == decode_tokens, plain_decode_forwards) are
        # exact; acceptance-dependent counters get bounds: the draft runs a
        # separate width-1 jit vs the width-γ+1 verify, and on near-flat
        # logits a rounding-level argmax tie can break differently between
        # the two compiled paths — one flip truncates that acceptance run
        # and cascades through every downstream round count. The bench
        # itself gates the invariants (greedy parity, tfpt < 1.0).
        "tol_abs": {
            "spec_acceptance_rate": 0.6,
            "target_forwards_per_token": 0.5,
            "steps": 7, "spec_rounds": 7, "spec_slot_rounds": 20,
            "spec_draft_forwards": 14, "spec_verify_forwards": 7,
            "spec_catchup_forwards": 4,
            "spec_tokens_proposed": 25, "spec_tokens_accepted": 25,
            "spec_bonus_tokens": 10,
            "shape_cache_hits": 10,
        },
        "optional_rows": set(),
    },
    "BENCH_faults.json": {
        "module": "serving_faults",
        "row_key": "scenario",
        "tol_abs": {
            "availability": 0.25,            # shed/timeout splits move with
            "ok": 2, "shed": 2, "timeout": 2, "error": 2,  # machine speed
            "restarts": 1, "requeued": 8,
        },
        "optional_rows": set(),
    },
    "BENCH_router.json": {
        "module": "serving_router",
        "row_key": "scenario",
        # routing decisions are deterministic (burst submits, index
        # tie-break, rendezvous hashing), so placement counters compare
        # exactly; prefix_hits ride slot-concurrency inside a replica
        # (whether two burst members prefill before the first one's pages
        # are published) and the failover scenario's requeue count rides
        # where in the stream the kill lands — bound, don't pin
        "tol_abs": {
            "prefix_hits": 6,
            "requeues": 8,
            "routed": 8,             # counts requeue re-placements too
            "affinity_hits": 2, "spills": 2,
        },
        "optional_rows": set(),
    },
    "BENCH_kernels.json": {
        "module": "op_microbench",
        "row_key": "op",
        # structural/counter keys (shape, kernel_n_cap, tuned_version,
        # tuned_measured, tuned blocks, decode_byte_ratio) are deterministic
        # functions of the shape list + autotune model — compared exactly;
        # all *_ms / *_us keys are wall-clock and skipped by _is_timing
        "tol_abs": {},
        # the big ops take minutes under interpret mode; the CI kernel-parity
        # job regenerates only the smoke rows (op_microbench --smoke)
        "optional_rows": {"bert_ffn_up", "llama3_qproj", "llama3_ffn_gate"},
    },
    "BENCH_plans.json": {
        "module": "fig13_replaced_layers",
        "row_key": "plan",
        # seeded training losses are deterministic on one machine but float
        # reductions drift across BLAS builds — bound, don't pin
        "tol_abs": {"eval_loss": 0.05, "deployed_loss": 0.05},
        "optional_rows": set(),
    },
}


def _index(payload: dict, row_key: str) -> dict[str, dict]:
    return {r[row_key]: r for r in payload["rows"]}


def _diff_rows(name: str, old: dict, new: dict, tol_abs: dict) -> list[str]:
    bad = []
    for k, want in old.items():
        if _is_timing(k) or not isinstance(want, (int, float)) or isinstance(want, bool):
            continue
        got = new.get(k)
        if got is None:
            bad.append(f"{name}.{k}: committed {want}, missing from fresh run")
            continue
        tol = tol_abs.get(k, 0)
        # floats that are deterministic ratios (occupancy, hit rate) still
        # compare exactly up to float noise
        limit = tol if tol else (1e-9 if isinstance(want, float) else 0)
        if abs(got - want) > limit:
            bad.append(f"{name}.{k}: committed {want}, fresh {got}"
                       + (f" (tol ±{tol})" if tol else ""))
    return bad


def check_file(committed: pathlib.Path, fresh: pathlib.Path, rules: dict) -> list[str]:
    old = json.loads(committed.read_text())
    new = json.loads(fresh.read_text())
    if old.get("schema") != new.get("schema"):
        return [f"{committed.name}: schema {old.get('schema')!r} != "
                f"fresh {new.get('schema')!r} — re-commit the artifact"]
    bad = []
    old_rows, new_rows = _index(old, rules["row_key"]), _index(new, rules["row_key"])
    for rid, row in old_rows.items():
        if rid not in new_rows:
            msg = f"{committed.name}[{rid}]: row missing from fresh run"
            if rid in rules["optional_rows"]:
                print(f"# warn (best-effort row): {msg}")
            else:
                bad.append(msg)
            continue
        bad += _diff_rows(f"{committed.name}[{rid}]", row, new_rows[rid],
                          rules["tol_abs"])
    for rid in new_rows.keys() - old_rows.keys():
        print(f"# new row not in committed file: {committed.name}[{rid}]")
    return bad


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", default=None,
                    help="directory holding freshly-generated BENCH_*.json; "
                         "default: re-run the bench modules into a tempdir")
    ap.add_argument("--only", choices=sorted(RULES), action="append",
                    help="check just this artifact (repeatable)")
    args = ap.parse_args(argv)
    names = args.only or sorted(RULES)

    with tempfile.TemporaryDirectory() as td:
        fresh_dir = pathlib.Path(args.fresh_dir or td)
        failures = []
        for name in names:
            committed = _ROOT / name
            if not committed.exists():
                failures.append(f"{name}: no committed baseline at {committed}")
                continue
            fresh = fresh_dir / name
            if args.fresh_dir is None:
                mod = __import__(f"benchmarks.{RULES[name]['module']}",
                                 fromlist=["main"])
                print(f"# regenerating {name} via benchmarks."
                      f"{RULES[name]['module']} ...")
                mod.main(json_path=fresh)
            if not fresh.exists():
                failures.append(f"{name}: fresh artifact missing at {fresh}")
                continue
            failures += check_file(committed, fresh, RULES[name])

    if failures:
        print(f"\nREGRESSION: {len(failures)} drifted counter(s)")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"\nno counter drift across {', '.join(names)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

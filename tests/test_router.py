"""Multi-replica router (DESIGN.md §15): least-loaded placement, failover
token parity (kill a replica mid-stream, replay byte-identical on the
survivor), prefix-affinity stickiness with load-based spill, all-dead
fail-closed. Spawns 2 real worker processes per router, so these sit with
test_supervisor.py among the slowest serving tests."""

import threading

import jax
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.artifact import save_artifact
from repro.serving.faults import FaultSpec
from repro.serving.router import EngineRouter, affinity_key, _hrw_weight
from repro.serving.supervisor import EngineSupervisor

ENGINE_KW = dict(n_slots=2, max_seq=64, prefill_chunk=4)


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=1)
    bundle = build_model(arch, Mode.DENSE)
    params = bundle.init(jax.random.PRNGKey(0))
    path = tmp_path_factory.mktemp("router") / "artifact"
    save_artifact(path, bundle, params)
    return path


def _specs(n=3):
    return [{"prompt": [i * 3 + 1, i * 3 + 2, i * 3 + 3], "max_tokens": 4}
            for i in range(n)]


@pytest.fixture(scope="module")
def baseline(artifact):
    """Fault-free single-supervisor reference tokens, per spec index."""
    ref = EngineSupervisor(artifact, engine_kwargs=ENGINE_KW)
    try:
        grids = [ref.submit(s) for s in _specs()]
        states = {g: ref.wait(g, timeout=300) for g in grids}
        assert all(st.status == "ok" for st in states.values())
        return [list(states[g].tokens) for g in grids]
    finally:
        ref.close()


# ---------------------------------------------------------------------------
# construction + pure routing math
# ---------------------------------------------------------------------------

def test_router_validates_construction(tmp_path):
    with pytest.raises(ValueError, match="replicas"):
        EngineRouter(tmp_path, replicas=0)
    with pytest.raises(ValueError, match="routing"):
        EngineRouter(tmp_path, routing="round_robin")
    with pytest.raises(ValueError, match="faults"):
        EngineRouter(tmp_path, replicas=2, faults=[None, None, None])


def test_affinity_key_and_rendezvous_stability():
    # the key is the first full KV page (kv_pool's share unit)
    assert affinity_key(list(range(40)), 16) == tuple(range(16))
    assert affinity_key([1, 2, 3], 16) == (1, 2, 3)   # short prompt: whole
    key = affinity_key(list(range(16)), 16)
    ranked = sorted(range(4), key=lambda i: -_hrw_weight(key, i))
    # rendezvous property: removing the winner promotes the runner-up
    # without re-ranking anyone else
    survivors = [i for i in ranked if i != ranked[0]]
    reranked = sorted(survivors, key=lambda i: -_hrw_weight(key, i))
    assert reranked == survivors


# ---------------------------------------------------------------------------
# least-loaded placement + token parity through the router
# ---------------------------------------------------------------------------

def test_least_loaded_spreads_and_token_parity(artifact, baseline):
    r = EngineRouter(artifact, replicas=2, engine_kwargs=ENGINE_KW)
    try:
        assert r.wait_ready(timeout=300)
        assert r.healthy
        grids = [r.submit(s) for s in _specs()]
        states = {g: r.wait(g, timeout=300) for g in grids}
        for i, g in enumerate(grids):
            assert states[g].status == "ok"
            # the router adds nothing to the token stream: byte-identical
            # to a single supervised engine
            assert states[g].tokens == baseline[i], g
        s = r.stats()
        assert s["backend"] == "router"
        assert s["routed"] == 3 and s["lost"] == 0 and s["failovers"] == 0
        assert s["replicas"] == 2 and s["replicas_live"] == 2
        # req 0 lands on replica 0 (tie -> lowest index); while it is in
        # flight replica 1 is strictly less loaded, so req 1 must go there
        per = s["per_replica"]
        assert per["0"]["routed"] >= 1 and per["1"]["routed"] >= 1
        assert per["0"]["routed"] + per["1"]["routed"] == 3
        assert s["pending"] == 0
    finally:
        r.close()
    assert "live" in r.exit_summary


# ---------------------------------------------------------------------------
# failover: kill one replica mid-stream, replay byte-identical on survivor
# ---------------------------------------------------------------------------

def test_failover_token_parity_after_replica_death(artifact, baseline):
    # replica 0 crash-loops (fault respawns every incarnation) past
    # max_restarts and fails closed; the router must requeue its rids onto
    # replica 1 and the replayed generations must match the fault-free run
    events: list[tuple[int, tuple]] = []
    ev_lock = threading.Lock()

    def sub(i):
        def on_event(ev):
            with ev_lock:
                events.append((i, ev))
        return on_event

    r = EngineRouter(
        artifact, replicas=2, engine_kwargs=ENGINE_KW, retry_budget=2,
        faults=[FaultSpec(kill_at_step=1), None],
        supervisor_kwargs=dict(faults_once=False, max_restarts=1,
                               healthy_after_s=3600.0),
    )
    try:
        assert r.wait_ready(timeout=300)
        grids = [r.submit(s, on_event=sub(i))
                 for i, s in enumerate(_specs())]
        states = {g: r.wait(g, timeout=300) for g in grids}
        for i, g in enumerate(grids):
            st = states[g]
            assert st.status == "ok", (g, st.status)   # nothing lost
            assert st.tokens == baseline[i], g         # byte-identical replay
        s = r.stats()
        assert s["failovers"] == 1                     # replica 0 died once
        assert s["requeues"] >= 1 and s["lost"] == 0
        assert s["replicas_live"] == 1 and s["replicas_dead"] == 1
        assert r.healthy                               # degraded, not down
        # a request that had streamed tokens before the failover told its
        # subscriber to discard them via the ("restart", None) event
        with ev_lock:
            per_req: dict[int, list] = {}
            for i, ev in events:
                per_req.setdefault(i, []).append(ev)
        failed_over = [g for g in grids if states[g].retries > 0]
        assert failed_over                             # the fault did fire
        for g in failed_over:
            streamed: list[int] = []
            for kind, payload in per_req.get(g, []):
                if kind == "tokens":
                    streamed.extend(payload)
                elif kind == "restart":
                    streamed = []                      # discard, per contract
            # a subscriber that honors the discard events reconstructs
            # exactly the final token list — pre-crash partials never leak
            assert streamed == states[g].tokens, g

        # the dead replica refuses direct submits, the router still serves
        lone = r.submit({"prompt": [42, 43], "max_tokens": 2})
        assert r.wait(lone, timeout=300).status == "ok"
    finally:
        r.close()
    assert "dead" in r.exit_summary


def test_all_replicas_dead_fails_closed(artifact):
    r = EngineRouter(
        artifact, replicas=2, engine_kwargs=ENGINE_KW, retry_budget=1,
        faults=[FaultSpec(kill_at_step=0), FaultSpec(kill_at_step=0)],
        supervisor_kwargs=dict(faults_once=False, max_restarts=1,
                               healthy_after_s=3600.0),
    )
    try:
        assert r.wait_ready(timeout=300)
        g = r.submit({"prompt": [1, 2, 3], "max_tokens": 4})
        st = r.wait(g, timeout=300)
        assert st.status == "error"                    # resolved, not hung
        s = r.stats()
        assert s["replicas_live"] == 0 and s["lost"] >= 1
        assert not r.healthy
        assert r.pending() == 0
        with pytest.raises(RuntimeError, match="every replica is dead"):
            r.submit({"prompt": [1], "max_tokens": 1})
    finally:
        r.close()


# ---------------------------------------------------------------------------
# prefix affinity: stickiness, prefix-cache hits, load-based spill
# ---------------------------------------------------------------------------

def test_prefix_affinity_sticks_and_spills(artifact):
    # paged engines so the replica that attracts the same-prefix session
    # actually converts stickiness into prefix-cache hits
    kw = dict(ENGINE_KW, paged=True, page_size=8)
    r = EngineRouter(artifact, replicas=2, routing="prefix_affinity",
                     engine_kwargs=kw)
    try:
        assert r.wait_ready(timeout=300)
        assert r.affinity_page_size == 8               # follows the engines
        same = {"prompt": list(range(1, 17)), "max_tokens": 2}

        # sequential same-prefix session: every request sticks to the
        # rendezvous favorite (no load, no reason to spill)
        reps = set()
        for _ in range(3):
            g = r.submit(dict(same))
            st = r.wait(g, timeout=300)
            assert st.status == "ok"
            reps.add(st.replica)
        assert len(reps) == 1                          # sticky
        fav = reps.pop()
        s = r.stats()
        assert s["affinity_hits"] == 3 and s["spills"] == 0
        # stickiness pays: the favorite's prefix cache served the repeats
        assert s["per_replica"][str(fav)]["prefix_hits"] > 0
        other = 1 - fav
        assert s["per_replica"][str(other)]["routed"] == 0

        # saturate the favorite: a same-prefix burst beyond n_slots must
        # spill to the strictly-less-loaded survivor instead of queueing
        grids = [r.submit(dict(same)) for _ in range(2 * kw["n_slots"])]
        states = [r.wait(g, timeout=300) for g in grids]
        assert all(st.status == "ok" for st in states)
        s = r.stats()
        assert s["spills"] >= 1
        assert s["affinity_hits"] + s["spills"] == 3 + len(grids)
    finally:
        r.close()


# ---------------------------------------------------------------------------
# lifecycle odds and ends
# ---------------------------------------------------------------------------

def test_router_cancel_and_abort_pending(artifact):
    r = EngineRouter(artifact, replicas=2, engine_kwargs=ENGINE_KW)
    try:
        assert r.wait_ready(timeout=300)
        g = r.submit({"prompt": [1, 2, 3], "max_tokens": 50})
        assert r.cancel(g) is True
        assert r.wait(g, timeout=300).status == "cancelled"
        assert r.cancel(g) is False                    # already terminal
        assert r.cancel(999) is False                  # unknown grid
        # validation happens at the router boundary, not in a worker
        with pytest.raises(ValueError, match="priority must be an int"):
            r.submit({"prompt": [1], "priority": "high"})
        g2 = r.submit({"prompt": [4, 5, 6], "max_tokens": 50})
        assert r.abort_pending() >= 1
        assert r.wait(g2, timeout=60).status == "error"
        assert r.pending() == 0
    finally:
        r.close()

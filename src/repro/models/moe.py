"""Mixture-of-Experts FFN with GShard-style capacity dispatch.

Covers llama4-maverick (128e top-1 + shared expert) and arctic (128e top-2 +
dense residual branch — the residual lives at the block level, see
transformer.py).

LUT-NN integration (DESIGN.md section 4): the router stays exact (dense) —
approximating routing logits destabilizes top-k selection; expert
projections are LUT sites with **per-expert tables sharing per-layer
codebooks** (the layer input distribution is expert-independent, so one
codebook serves all experts; table memory scales with E, encode cost does
not have to — the encode-once-dispatch-codes variant is a §Perf lever).

Tokens are grouped by the batch axis (G = B groups of S tokens), which is
also the data-sharded axis, so dispatch/combine einsums stay local until the
expert contraction itself — GSPMD then emits the all-to-all across the
expert-sharded axis.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import pq, quant
from repro.core.amm import LUTConfig, Mode
from repro.core.temperature import init_log_temperature, temperature
from repro.models.common import Params, SiteCfg, activation, linear, linear_init


@dataclasses.dataclass(frozen=True)
class ExpertSiteCfg:
    """Expert-stacked linear site: (E, Cap, d_in) -> (E, Cap, d_out)."""

    n_experts: int
    d_in: int
    d_out: int
    mode: Mode
    lut: LUTConfig


def expert_linear_init(key: jax.Array, s: ExpertSiteCfg, *, dtype=jnp.float32) -> Params:
    kw, kc = jax.random.split(key)
    scale = 1.0 / (s.d_in ** 0.5)
    w = (jax.random.normal(kw, (s.n_experts, s.d_in, s.d_out), jnp.float32) * scale).astype(dtype)
    if s.mode == Mode.DENSE:
        return {"w": w}
    c = s.lut.codebooks(s.d_in)
    centroids = jax.random.normal(kc, (c, s.lut.k, s.lut.v), jnp.float32) * 0.02
    if s.mode == Mode.LUT_TRAIN:
        return {"w": w, "centroids": centroids, "log_t": init_log_temperature()}
    # LUT_INFER: int8 tables per expert, shared codebooks; scale layout
    # mirrors quant.table_scale per the site's policy (deploy writes the
    # same shapes — core.convert._build_quantize_tables)
    if s.lut.int8_dot or s.lut.use_kernel:
        s_shape = (s.n_experts, 1, 1, s.d_out)
    elif s.lut.per_column:
        s_shape = (s.n_experts, c, 1, s.d_out)
    else:
        s_shape = (s.n_experts, c, 1, 1)
    return {
        "centroids": centroids,
        "table_q": jax.random.randint(kc, (s.n_experts, c, s.lut.k, s.d_out), -127, 127, jnp.int8),
        "table_scale": jnp.full(s_shape, 0.02, jnp.float32),
    }


def _expert_tables_train(p: Params, s: ExpertSiteCfg) -> jax.Array:
    """(E, C, K, F) fake-quantized tables rebuilt from frozen expert weights."""
    c = s.lut.codebooks(s.d_in)
    w = jax.lax.stop_gradient(p["w"]).reshape(s.n_experts, c, s.lut.v, s.d_out)
    t = jnp.einsum("ckv,ecvf->eckf", p["centroids"].astype(w.dtype), w)
    # per-(expert, codebook) symmetric scale — same policy as quant.fake_quant
    scale = jnp.maximum(
        jnp.max(jnp.abs(t), axis=(2, 3), keepdims=True).astype(jnp.float32), 1e-8
    ) / (2 ** (s.lut.bits - 1) - 1)
    t32 = t.astype(jnp.float32)
    qdq = jnp.clip(jnp.round(t32 / scale), -(2 ** (s.lut.bits - 1) - 1), 2 ** (s.lut.bits - 1) - 1) * scale
    return (t32 + jax.lax.stop_gradient(qdq - t32)).astype(t.dtype)


def expert_linear(s: ExpertSiteCfg, p: Params, x: jax.Array) -> jax.Array:
    """x: (E, Cap*, d_in) -> (E, Cap*, d_out). Cap* may have extra leading dims
    folded in by the caller (we use (E, G*Cap, d_in))."""
    if s.mode == Mode.DENSE:
        return jnp.einsum("ecd,edf->ecf", x, p["w"].astype(x.dtype))

    P = p["centroids"]
    e, cap, _ = x.shape
    xf = x.reshape(e * cap, s.d_in)
    dists = pq.pairwise_sq_dists(pq.split_subvectors(xf, s.lut.v), P)
    if s.mode == Mode.LUT_TRAIN:
        enc = pq.ste_encode(dists, temperature(p["log_t"]))
        tables = _expert_tables_train(p, s)
    elif s.lut.int8_dot:
        # integer batched contraction: tables stream once as int8
        enc8 = pq.hard_encode(dists).reshape(e, cap, -1).astype(jnp.int8)
        tq = p["table_q"].reshape(e, -1, s.d_out)
        acc = jax.lax.dot_general(
            enc8, tq, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )
        return (acc.astype(jnp.float32) * p["table_scale"].reshape(e, 1, s.d_out)).astype(x.dtype)
    else:
        enc = pq.hard_encode(dists)
        tables = (p["table_q"].astype(jnp.float32) * p["table_scale"]).astype(x.dtype)
    enc = enc.reshape(e, cap, -1).astype(x.dtype)             # (E, Cap, C*K)
    tbl = tables.reshape(e, tables.shape[1] * tables.shape[2], s.d_out)
    return jnp.einsum("ecx,exf->ecf", enc, tbl.astype(x.dtype))


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    router: SiteCfg                      # always DENSE
    gate: ExpertSiteCfg
    up: ExpertSiteCfg
    down: ExpertSiteCfg
    shared: object | None = None         # optional MLPCfg for a shared expert
    act: str = "silu"
    capacity_factor: float = 1.25
    # tokens per routing group: dispatch/combine tensors scale LINEARLY with
    # the group size (total = tokens * cf * k * G elems), so long-sequence
    # prefill/train must not use the whole sequence as one group
    # (section Perf, MoE iteration 1)
    group_tokens: int = 1024


def moe_init(key: jax.Array, cfg: MoECfg, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": linear_init(ks[0], cfg.router, dtype=jnp.float32),
        "gate": expert_linear_init(ks[1], cfg.gate, dtype=dtype),
        "up": expert_linear_init(ks[2], cfg.up, dtype=dtype),
        "down": expert_linear_init(ks[3], cfg.down, dtype=dtype),
    }
    if cfg.shared is not None:
        from repro.models.mlp import mlp_init

        p["shared"] = mlp_init(ks[4], cfg.shared, dtype=dtype)
    return p


def moe(cfg: MoECfg, p: Params, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss). Routing groups = `group_tokens` chunks
    of the (batch-major) token stream, so per-group capacity stays bounded
    at long sequence lengths."""
    b0, s0, d = x.shape
    g_tok = max(1, min(cfg.group_tokens, s0))
    while s0 % g_tok:
        g_tok //= 2
    x = x.reshape(b0 * (s0 // g_tok), g_tok, d)
    b, s, _ = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(k, int(cfg.capacity_factor * k * s / e) + 1)

    logits = linear(cfg.router, p["router"], x.astype(jnp.float32))   # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k routing with per-group capacity (GShard)
    combine = jnp.zeros((b, s, e, cap), x.dtype)
    dispatch = jnp.zeros((b, s, e, cap), bool)
    remaining = probs
    fill = jnp.zeros((b, e), jnp.int32)                                # slots used
    for _ in range(k):
        gate, idx = jnp.max(remaining, -1), jnp.argmax(remaining, -1)  # (B, S)
        onehot_e = jax.nn.one_hot(idx, e, dtype=jnp.int32)             # (B, S, E)
        pos = fill[:, None, :] + jnp.cumsum(onehot_e, axis=1) - onehot_e  # (B, S, E)
        slot = jnp.sum(onehot_e * pos, -1)                             # (B, S)
        keep = slot < cap
        oh_slot = jax.nn.one_hot(slot, cap, dtype=x.dtype) * keep[..., None]
        d_k = onehot_e.astype(x.dtype)[..., None] * oh_slot[:, :, None, :]
        dispatch |= d_k.astype(bool)
        combine = combine + gate.astype(x.dtype)[..., None, None] * d_k
        fill = fill + jnp.sum(onehot_e * keep[..., None].astype(jnp.int32), axis=1)
        remaining = remaining * (1.0 - jax.nn.one_hot(idx, e, dtype=probs.dtype))

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    frac_tokens = jnp.mean(jnp.sum(dispatch, axis=-1).astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs) / k

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch.astype(x.dtype), x)    # (E, B, Cap, D)
    xin = xin.reshape(e, b * cap, d)
    g = activation(cfg.act, expert_linear(cfg.gate, p["gate"], xin))
    u = expert_linear(cfg.up, p["up"], xin)
    h = expert_linear(cfg.down, p["down"], g * u)                      # (E, B*Cap, D)
    h = h.reshape(e, b, cap, d)
    y = jnp.einsum("bsec,ebcd->bsd", combine, h)

    if cfg.shared is not None:
        from repro.models.mlp import mlp as mlp_apply

        y = y + mlp_apply(cfg.shared, p["shared"], x)
    return y.reshape(b0, s0, d), aux

"""Shared fixtures. NOTE: no XLA_FLAGS here — unit tests must see the real
single CPU device; multi-device tests spawn subprocesses with their own
--xla_force_host_platform_device_count (tests/_subproc.py)."""

import jax
import pytest


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _isolate_autotune_cache(tmp_path, monkeypatch):
    """Point the kernel block autotuner at a per-test cache file so tests
    never read or pollute the user-level ~/.cache/repro/autotune.json."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))

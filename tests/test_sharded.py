"""Multi-device correctness (subprocess with forced host devices):
sharded train step == single-device reference; dry-run of a reduced arch
on a 2x4 mesh; grad compression; roofline collective parser."""

import textwrap

from tests._subproc import run_with_devices


def test_sharded_train_matches_single_device():
    out = run_with_devices(
        textwrap.dedent(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import build_model, get_arch, reduce_arch
            from repro.core.amm import Mode
            from repro.data import MarkovLM
            from repro.distributed.sharding import ShardingRules
            from repro.launch.mesh import make_mesh
            from repro.optim import AdamW
            from repro.train.train_step import make_train_step

            arch = reduce_arch(get_arch("llama3_8b"), n_layers=2, vocab=64, d_model=64, d_ff=128)
            data = MarkovLM(vocab=arch.vocab, seq_len=16, batch=8)
            bundle = build_model(arch, Mode.DENSE)
            params = bundle.init(jax.random.PRNGKey(0))
            opt = AdamW(lr=1e-2, clip_norm=None)
            ostate = opt.init(params)
            batch = data.batch_at(0)
            step = make_train_step(bundle, opt, compute_dtype=jnp.float32)

            # single-device reference
            p_ref, _, m_ref = jax.jit(step)(params, ostate, batch)

            mesh = make_mesh((2, 4), ("data", "model"))
            rules = ShardingRules(mesh)
            ps = rules.params_shardings(jax.eval_shape(lambda: params))
            os_ = rules.opt_shardings(jax.eval_shape(lambda: ostate))
            bs = rules.batch_shardings({k: jax.eval_shape(lambda v=v: v) for k, v in batch.items()})
            with mesh:
                p_sh, _, m_sh = jax.jit(
                    step, in_shardings=(ps, os_, bs), out_shardings=(ps, os_, None)
                )(jax.device_put(params, ps), jax.device_put(ostate, os_),
                  {k: jax.device_put(v, bs[k]) for k, v in batch.items()})

            assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-4, (m_ref, m_sh)
            for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-2, atol=1e-3)
            print("SHARDED_OK")
            """
        ),
        n_devices=8,
    )
    assert "SHARDED_OK" in out


def test_reduced_dryrun_lut_modes():
    """Reduced arch lowers+compiles on a mesh in both serve and train LUT
    modes — the same path launch/dryrun.py runs at 512 devices."""
    out = run_with_devices(
        textwrap.dedent(
            """
            import jax, jax.numpy as jnp
            from repro.configs import build_model, get_arch, reduce_arch
            from repro.core.amm import Mode
            from repro.distributed.sharding import ShardingRules
            from repro.optim import AdamW, SOFT_PQ_RULES, lut_frozen_mask
            from repro.train.train_step import make_train_step, make_serve_step
            from repro.roofline.analysis import analyze_compiled

            arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, vocab=64)
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 4), ("data", "model"))
            rules = ShardingRules(mesh)

            bundle = build_model(arch, Mode.LUT_TRAIN)
            pspecs = bundle.param_specs()
            opt = AdamW(lr=1e-3, rules=SOFT_PQ_RULES)
            frozen = lut_frozen_mask(pspecs)
            ospecs = jax.eval_shape(lambda p: opt.init(p, frozen), pspecs)
            batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
            with mesh:
                c = jax.jit(
                    make_train_step(bundle, opt, frozen_mask=frozen, compute_dtype=jnp.float32),
                    in_shardings=(rules.params_shardings(pspecs),
                                  rules.opt_shardings(ospecs),
                                  rules.batch_shardings(batch)),
                ).lower(pspecs, ospecs, batch).compile()
            r = analyze_compiled(c)
            assert r.flops > 0
            print("TRAIN_LOWERED", r.bottleneck)

            binf = build_model(arch, Mode.LUT_INFER)
            ispecs = binf.param_specs()
            cspecs = binf.init_caches(8, 32, abstract=True)
            sbatch = {"tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32),
                      "cache_len": jax.ShapeDtypeStruct((8,), jnp.int32)}
            with mesh:
                c2 = jax.jit(
                    make_serve_step(binf, compute_dtype=jnp.float32),
                    in_shardings=(rules.params_shardings(ispecs),
                                  rules.batch_shardings(sbatch),
                                  rules.cache_shardings(cspecs, 8)),
                ).lower(ispecs, sbatch, cspecs).compile()
            print("SERVE_LOWERED", analyze_compiled(c2).bottleneck)
            """
        ),
        n_devices=8,
    )
    assert "TRAIN_LOWERED" in out and "SERVE_LOWERED" in out


def test_grad_compression_matches_exact():
    out = run_with_devices(
        textwrap.dedent(
            """
            import jax, jax.numpy as jnp
            from repro.train.grad_compression import make_compressed_grad_fn, init_residual
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((8,), ("data",))
            def loss_fn(params, batch):
                return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)
            key = jax.random.PRNGKey(0)
            params = {"w": jax.random.normal(key, (16, 4))}
            batch = {"x": jax.random.normal(key, (32, 16)), "y": jax.random.normal(key, (32, 4))}
            fn = jax.jit(make_compressed_grad_fn(loss_fn, mesh))
            loss, grads, res = fn(params, init_residual(params), batch)
            loss_ref, grads_ref = jax.value_and_grad(loss_fn)(params, batch)
            err = float(jnp.max(jnp.abs(grads["w"] - grads_ref["w"]))
                        / jnp.max(jnp.abs(grads_ref["w"])))
            assert abs(float(loss) - float(loss_ref)) < 1e-5
            assert err < 0.05, err
            # error feedback: residual is exactly what int8 dropped
            assert float(jnp.max(jnp.abs(res["w"]))) > 0
            print("GC_OK", err)
            """
        ),
        n_devices=8,
    )
    assert "GC_OK" in out


def test_elastic_rescale_8_to_4():
    out = run_with_devices(
        textwrap.dedent(
            """
            import numpy as np
            import jax, jax.numpy as jnp
            from repro.configs import build_model, get_arch, reduce_arch
            from repro.core.amm import Mode
            from repro.checkpoint.checkpointer import Checkpointer
            from repro.data import MarkovLM
            from repro.distributed.elastic import ElasticContext
            from repro.optim import AdamW
            from repro.train.train_step import make_train_step
            import tempfile

            arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2, vocab=64, d_model=64, d_ff=128)
            data = MarkovLM(vocab=arch.vocab, seq_len=16, batch=8)
            bundle = build_model(arch, Mode.DENSE)
            opt = AdamW(lr=3e-3)
            step_raw = make_train_step(bundle, opt, compute_dtype=jnp.float32)

            def make_step(mesh, rules):
                return jax.jit(step_raw)

            params = bundle.init(jax.random.PRNGKey(0))
            ostate = opt.init(params)

            ckdir = tempfile.mkdtemp()
            ck = Checkpointer(ckdir)

            # phase 1: all 8 devices
            ctx8 = ElasticContext.build(jax.devices(), make_step, prefer_model=2)
            ps = ctx8.rules.params_shardings(jax.eval_shape(lambda: params))
            params = jax.device_put(params, ps)
            losses = []
            for i in range(6):
                params, ostate, m = ctx8.step_fn(params, ostate, data.batch_at(i))
                losses.append(float(m["loss"]))
            ck.save(6, {"params": params, "opt": ostate}, blocking=True)

            # phase 2: "node failure" -> only 4 devices survive
            ctx4 = ElasticContext.build(jax.devices()[:4], make_step, prefer_model=2)
            ps4 = ctx4.rules.params_shardings(jax.eval_shape(lambda: params))
            os4 = ctx4.rules.opt_shardings(jax.eval_shape(lambda: ostate))
            step, tree = ck.restore({"params": params, "opt": ostate},
                                    shardings={"params": ps4, "opt": os4})
            params2, ostate2 = tree["params"], tree["opt"]
            for i in range(step, step + 6):
                params2, ostate2, m = ctx4.step_fn(params2, ostate2, data.batch_at(i))
                losses.append(float(m["loss"]))
            assert step == 6
            assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses
            assert all(np.isfinite(losses)), losses
            print("ELASTIC_OK", [round(l, 3) for l in losses])
            """
        ),
        n_devices=8,
    )
    assert "ELASTIC_OK" in out

"""Request lifecycle (DESIGN.md §11.1): deadlines, priorities, cancellation,
bounded-queue shedding, stranded-work detection, TokenTap, fault injection."""

import time

import jax
import pytest

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.engine import ServingEngine, TokenTap, submit_from_spec
from repro.serving.faults import (
    FaultInjector,
    FaultSpec,
    InjectedFault,
    InjectedKill,
)


@pytest.fixture(scope="module")
def small():
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=1)
    bundle = build_model(arch, Mode.DENSE)
    return bundle, bundle.init(jax.random.PRNGKey(0))


def _engine(small, **kw):
    bundle, params = small
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("autotune_lut", False)
    return ServingEngine(bundle, params, **kw)


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_expired_queued_request_times_out(small):
    eng = _engine(small)
    rid_dead = eng.submit([1, 2, 3], max_tokens=4, deadline_s=0.0)
    rid_live = eng.submit([4, 5, 6], max_tokens=4)
    done = {r.rid: r for r in eng.run_until_done()}
    assert done[rid_dead].status == "timeout"
    assert done[rid_dead].out_tokens == []        # never burned a forward
    assert done[rid_live].status == "ok"
    assert eng.stats()["timeout"] == 1


def test_inflight_deadline_keeps_partial_output(small):
    eng = _engine(small, n_slots=1)
    rid = eng.submit([1, 2, 3], max_tokens=50, deadline_s=60.0)
    eng.step()                                    # admit + prefill: 1 token out
    req = eng.slots[0]
    assert req is not None and req.rid == rid
    req.deadline = time.monotonic() - 1.0         # force expiry mid-decode
    eng.step()                                    # sweep retires before forward
    assert req.done and req.status == "timeout"
    assert len(req.out_tokens) >= 1               # partial output preserved
    assert not req.ok
    assert req.latency_s > 0


# ---------------------------------------------------------------------------
# priorities + bounded-queue shedding
# ---------------------------------------------------------------------------

def test_priority_admission_order(small):
    eng = _engine(small, n_slots=1)
    lo = eng.submit([1, 2], max_tokens=1, priority=0)
    hi = eng.submit([3, 4], max_tokens=1, priority=5)
    done = eng.run_until_done()
    assert [r.rid for r in done] == [hi, lo]      # high priority served first


def test_fifo_within_priority(small):
    eng = _engine(small, n_slots=1)
    rids = [eng.submit([i + 1, i + 2], max_tokens=1) for i in range(3)]
    done = eng.run_until_done()
    assert [r.rid for r in done] == rids          # equal priority: FIFO


def test_shed_evicts_lowest_priority_newest(small):
    eng = _engine(small, max_queue=2)
    r0 = eng.submit([1], max_tokens=1, priority=0)
    r1 = eng.submit([2], max_tokens=1, priority=0)
    # queue full: a higher-priority arrival evicts the NEWEST equal-lowest
    r2 = eng.submit([3], max_tokens=1, priority=1)
    assert [r.rid for r in eng.queue] == [r0, r2]
    shed = eng.finished[-1]
    assert shed.rid == r1 and shed.status == "shed" and shed.done
    # an arrival that does not beat the floor priority is itself shed
    r3 = eng.submit([4], max_tokens=1, priority=0)
    assert eng.finished[-1].rid == r3
    assert eng.finished[-1].status == "shed"
    assert eng.stats()["shed"] == 2
    done = {r.rid: r for r in eng.run_until_done()}
    assert done[r0].ok and done[r2].ok            # survivors complete


def test_expired_entries_swept_before_shedding(small):
    eng = _engine(small, max_queue=1)
    r0 = eng.submit([1, 2], max_tokens=1, deadline_s=0.0)
    r1 = eng.submit([3, 4], max_tokens=1)         # sweep frees the slot: no shed
    assert [r.rid for r in eng.queue] == [r1]
    done = {r.rid: r for r in eng.run_until_done()}
    assert done[r0].status == "timeout"
    assert done[r1].status == "ok"
    assert eng.stats()["shed"] == 0


def test_max_queue_validation(small):
    with pytest.raises(ValueError):
        _engine(small, max_queue=0)


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------

def test_cancel_queued_and_inflight(small):
    eng = _engine(small, n_slots=1)
    r0 = eng.submit([1, 2, 3], max_tokens=30)
    r1 = eng.submit([4, 5, 6], max_tokens=30)
    assert eng.cancel(r1) is True                 # still queued
    eng.step()                                    # r0 admitted
    assert eng.cancel(r0) is True                 # mid-flight
    assert eng.cancel(r0) is False                # already terminal
    assert eng.cancel(999) is False               # unknown rid
    done = {r.rid: r for r in eng.run_until_done()}
    assert done[r0].status == done[r1].status == "cancelled"
    assert done[r1].out_tokens == []
    assert eng.stats()["cancelled"] == 2


# ---------------------------------------------------------------------------
# stranded work is never silent
# ---------------------------------------------------------------------------

def test_run_until_done_raises_on_exhaustion(small):
    eng = _engine(small, n_slots=1)
    r0 = eng.submit([1, 2, 3], max_tokens=30)
    r1 = eng.submit([4, 5, 6], max_tokens=30)
    with pytest.raises(RuntimeError, match="2 request\\(s\\) still live") as ei:
        eng.run_until_done(max_steps=1)
    assert str(r0) in str(ei.value) and str(r1) in str(ei.value)
    # the engine is still coherent: finishing the work afterwards is fine
    done = {r.rid: r for r in eng.run_until_done()}
    assert done[r0].ok and done[r1].ok


def test_run_until_done_strand_mode(small):
    eng = _engine(small, n_slots=1)
    r0 = eng.submit([1, 2, 3], max_tokens=30)
    r1 = eng.submit([4, 5, 6], max_tokens=30)
    done = {r.rid: r for r in eng.run_until_done(max_steps=1, on_exhausted="strand")}
    assert done[r0].status == "error" and done[r1].status == "error"
    assert not eng.has_work()
    assert eng.stats()["error"] == 2
    with pytest.raises(ValueError):
        eng.run_until_done(on_exhausted="panic")


def test_abort_all(small):
    eng = _engine(small, n_slots=1)
    rids = [eng.submit([i + 1, i + 2], max_tokens=30) for i in range(3)]
    eng.step()
    aborted = eng.abort_all("error")
    assert sorted(r.rid for r in aborted) == rids
    assert all(r.status == "error" for r in aborted)
    assert not eng.has_work()


# ---------------------------------------------------------------------------
# spec wire format (HTTP body / supervisor pipe)
# ---------------------------------------------------------------------------

def test_submit_from_spec_validation(small):
    eng = _engine(small)
    with pytest.raises(ValueError, match="unknown request fields"):
        submit_from_spec(eng, {"prompt": [1], "banana": 1})
    with pytest.raises(ValueError, match="list of ints"):
        submit_from_spec(eng, {"prompt": "not tokens"})
    with pytest.raises(ValueError, match="list of ints"):
        submit_from_spec(eng, {"prompt": [1, True, 3]})   # bools are not tokens
    rid = submit_from_spec(
        eng, {"prompt": [1, 2, 3], "max_tokens": 2, "priority": 1,
              "temperature": 0.7, "seed": 9},
    )
    done = {r.rid: r for r in eng.run_until_done()}
    assert done[rid].ok and len(done[rid].out_tokens) == 2


def test_validate_spec_rejects_bad_priority_and_deadline():
    # engine-free validation: bad types surface here (-> HTTP 400) instead
    # of a confusing failure deep in admission, or a worker crash loop on
    # the far side of the supervisor pipe
    from repro.serving.engine import validate_spec

    validate_spec({"prompt": [1, 2], "priority": 3, "deadline_s": 1.5})
    validate_spec({"prompt": [1, 2], "priority": None, "deadline_s": None})
    with pytest.raises(ValueError, match="priority must be an int"):
        validate_spec({"prompt": [1], "priority": "high"})
    with pytest.raises(ValueError, match="priority must be an int"):
        validate_spec({"prompt": [1], "priority": 1.5})
    with pytest.raises(ValueError, match="priority must be an int"):
        validate_spec({"prompt": [1], "priority": True})  # bools are not ints
    with pytest.raises(ValueError, match="deadline_s must be a number"):
        validate_spec({"prompt": [1], "deadline_s": "soon"})
    with pytest.raises(ValueError, match="deadline_s must be a number"):
        validate_spec({"prompt": [1], "deadline_s": True})
    with pytest.raises(ValueError, match="spec_decode must be a bool"):
        validate_spec({"prompt": [1], "spec_decode": 1})
    with pytest.raises(ValueError, match="JSON object"):
        validate_spec([1, 2, 3])


# ---------------------------------------------------------------------------
# TokenTap
# ---------------------------------------------------------------------------

def test_token_tap_incremental_and_consume(small):
    eng = _engine(small, n_slots=2)
    tap = TokenTap(eng, consume=True)
    r0 = eng.submit([1, 2, 3], max_tokens=4)
    r1 = eng.submit([4, 5], max_tokens=2)
    streamed: dict[int, list[int]] = {r0: [], r1: []}
    finals = {}
    for _ in range(50):
        if not eng.has_work():
            break
        eng.step()
        tokens, done = tap.poll()
        for rid, toks in tokens:
            streamed[rid].extend(toks)
        for req in done:
            finals[req.rid] = req
    # every token surfaced exactly once, in order, and finished is drained
    assert streamed[r0] == finals[r0].out_tokens
    assert streamed[r1] == finals[r1].out_tokens
    assert eng.finished == []                     # consume=True bounds memory
    assert tap.poll() == ([], [])                 # nothing new after quiesce


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

def test_fault_spec_round_trip_and_validation():
    spec = FaultSpec(seed=3, spike_p=0.5, error_steps=(1, 4), kill_at_step=9)
    assert FaultSpec.from_dict(spec.to_dict()) == spec
    assert spec.active
    assert not FaultSpec().active
    with pytest.raises(ValueError):
        FaultSpec(error_p=1.5)
    with pytest.raises(ValueError):
        FaultSpec(spike_s=-1.0)


def test_injector_deterministic_and_counts():
    a = FaultInjector(FaultSpec(seed=5, error_p=0.3), sleep=lambda s: None)
    b = FaultInjector(FaultSpec(seed=5, error_p=0.3), sleep=lambda s: None)
    for inj in (a, b):
        for _ in range(30):
            try:
                inj.on_step()
            except InjectedFault:
                pass
    assert a.events == b.events                   # same seed => same schedule
    assert a.counts()["error"] == len(a.events) > 0


def test_injector_kill_is_base_exception():
    inj = FaultInjector(FaultSpec(kill_at_step=0))
    with pytest.raises(InjectedKill):
        try:
            inj.on_step()
        except Exception:                         # must NOT absorb a kill
            pytest.fail("InjectedKill was caught by `except Exception`")
    assert inj.counts()["kill"] == 1


def test_injector_spike_sleeps():
    slept = []
    inj = FaultInjector(FaultSpec(spike_p=1.0, spike_s=0.5),
                        sleep=slept.append)
    inj.on_step()
    assert slept == [0.5]


def test_retried_call_advances_past_transient_fault():
    """A retry draws the NEXT call index, so an explicit one-step fault
    fails once and then passes — the transient-fault contract StepGuard
    relies on."""
    inj = FaultInjector(FaultSpec(error_steps=(0,)))
    with pytest.raises(InjectedFault):
        inj.on_step()
    inj.on_step()                                 # retry: clean


def test_engine_resumes_after_injected_fault(small):
    """A step fault surfaces to the caller, and the engine completes the
    request with the SAME tokens as a fault-free run once stepping resumes."""
    bundle, params = small
    ref_eng = _engine(small, n_slots=1)
    ref_eng.submit([1, 2, 3], max_tokens=4)
    ref = ref_eng.run_until_done()[0].out_tokens

    eng = _engine(small, n_slots=1)
    eng.faults = FaultInjector(FaultSpec(error_steps=(1,)))
    rid = eng.submit([1, 2, 3], max_tokens=4)
    eng.step()                                    # call 0: clean
    with pytest.raises(InjectedFault):
        eng.step()                                # call 1: injected, no forward
    done = {r.rid: r for r in eng.run_until_done()}
    assert done[rid].ok
    assert done[rid].out_tokens == ref

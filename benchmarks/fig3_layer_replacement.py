"""Paper Fig. 3: accuracy collapse as more layers are replaced by PQ-based
AMM without end-to-end centroid learning — vanilla PQ (k-means encode)
degrades slower than MADDNESS (hash encode), and both end at chance.

Carrier: 5-hidden-layer MLP on the clustered-feature classification task
(conv == matmul per the paper's im2col argument). Replacement proceeds
from the LAST layer toward the first, exactly as in the paper.
"""

from __future__ import annotations

import time

import jax

from benchmarks._mlp import MLPSpec, attach_pq, evaluate, train_dense
from repro.data import ClusteredTask


def run(steps: int = 300):
    key = jax.random.PRNGKey(0)
    spec = MLPSpec(d_in=64, width=128, depth=5, n_out=10)
    task = ClusteredTask(d_in=spec.d_in, n_classes=10)
    dense = train_dense(key, spec, task, steps=steps)
    base_acc = evaluate(dense, spec, task)

    n_layers = spec.depth + 1
    results = {"baseline": base_acc, "pq": [], "maddness": []}
    for kind in ("pq", "maddness"):
        for n_rep in range(1, n_layers + 1):
            layer_ids = list(range(n_layers - n_rep, n_layers))
            params = attach_pq(key, dense, spec, task, layer_ids, kind=kind)
            modes = [(kind if i in layer_ids else None) for i in range(n_layers)]
            acc = evaluate(params, spec, task, modes=modes)
            results[kind].append((n_rep, acc))
    return results


def main() -> None:
    t0 = time.time()
    res = run()
    print("# Fig. 3 analog: accuracy vs #replaced layers (last -> first)")
    print(f"baseline_acc,{res['baseline']:.4f}")
    print("n_replaced,vanilla_pq_acc,maddness_acc")
    for (n, a_pq), (_, a_md) in zip(res["pq"], res["maddness"]):
        print(f"{n},{a_pq:.4f},{a_md:.4f}")
    # paper claims: both degrade with depth of replacement; maddness <= pq
    lastn, pq_last = res["pq"][-1]
    _, md_last = res["maddness"][-1]
    print(f"claim_pq_degrades,{res['baseline'] - pq_last > 0.05}")
    print(f"claim_maddness_worse_or_equal,{md_last <= pq_last + 0.02}")
    print(f"fig3_layer_replacement,{(time.time()-t0)*1e6:.0f},accuracy_curve")


if __name__ == "__main__":
    main()

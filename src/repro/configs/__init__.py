"""Architecture registry: 10 assigned archs + the paper's BERT-base.

Each `configs/<id>.py` defines `ARCH: ArchSpec` with the exact published
dims. `build_model(arch, mode)` assembles the model (LM / hybrid / enc-dec)
with every linear site resolved to dense or LUT per the paper's replacement
policy; `input_specs(arch, shape)` produces ShapeDtypeStruct stand-ins for
the four assigned input shapes (train_4k / prefill_32k / decode_32k /
long_500k).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.amm import LUTConfig, Mode
from repro.core.plan import (  # noqa: F401  (re-exported: the plan API surface)
    PAPER_DEFAULT,
    LUTPlan,
    PlanRule,
    SitePolicy,
    SiteSelector,
    SiteSpec,
    rule,
)
from repro.models import attention as attn_mod
from repro.models import encdec as encdec_mod
from repro.models import hybrid as hybrid_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import transformer as tf_mod
from repro.models.common import SiteCfg


# ---------------------------------------------------------------------------
# arch spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    act: str = "silu"
    mlp_gated: bool = True
    qk_norm: bool = False
    use_bias: bool = False
    causal: bool = True
    rope_theta: float = 500_000.0
    mrope_sections: tuple[int, ...] = ()
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_shared_expert: bool = False
    moe_dense_residual: bool = False
    moe_group_tokens: int = 1024        # routing-group size (section Perf M1)
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 256
    # hybrid
    attn_every: int = 0
    # enc-dec (audio)
    n_enc_layers: int = 0
    enc_frames: int = 0
    takes_embeds: bool = False       # stub frontend provides embeddings
    # LUT-NN settings (paper defaults: K=16, V aligned to site width, INT8)
    lut_k: int = 16
    lut_v: int = 32
    lut_bits: int = 8
    lut_int8_dot: bool = False          # integer one-hot contraction (section Perf)
    lut_use_kernel: bool = False        # fused Pallas v2 kernel at LUT sites (DESIGN.md §2.3)
    lut_policy: str = "all_but_first"   # or "last_n:<n>" (BERT, Fig. 13), "all"
    # First-class per-site plan (DESIGN.md §9). When set it SUBSUMES
    # lut_policy and the flat lut_* flags above; when None those legacy
    # fields are parsed into an equivalent single-rule plan (the shim), so
    # old configs/checkpoints/artifacts keep building identical models.
    lut_plan: LUTPlan | None = None
    # scale/precision policy for the production dry-run
    param_dtype: str = "float32"        # giants use bfloat16 (DESIGN.md section 5)
    kv_cache_dtype: str = "bfloat16"    # "float8_e4m3fn" halves decode cache reads
    sub_quadratic: bool = False         # eligible for long_500k
    grad_accum: int = 1                 # microbatching for the train dry-run
    notes: str = ""

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model


# ---------------------------------------------------------------------------
# shapes (assigned to all LM-family archs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

ARCH_IDS = (
    "mamba2_370m",
    "llama3_8b",
    "minitron_8b",
    "qwen3_1p7b",
    "command_r_35b",
    "llama4_maverick_400b",
    "arctic_480b",
    "qwen2_vl_7b",
    "whisper_tiny",
    "zamba2_1p2b",
)
EXTRA_IDS = ("bert_base",)           # paper's own model, benchmarks only


def get_arch(name: str) -> ArchSpec:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.ARCH


# ---------------------------------------------------------------------------
# arch-spec serialization (deployment artifacts, DESIGN.md §8.1)
# ---------------------------------------------------------------------------

def arch_to_dict(arch: ArchSpec) -> dict[str, Any]:
    """JSON-safe dict of every ArchSpec field (tuples become lists)."""
    out = dataclasses.asdict(dataclasses.replace(arch, lut_plan=None))
    for k, v in out.items():
        if isinstance(v, tuple):
            out[k] = list(v)
    # the plan serializes through its own schema, not dataclasses.asdict
    out["lut_plan"] = arch.lut_plan.to_dict() if arch.lut_plan is not None else None
    return out


def arch_from_dict(d: dict[str, Any]) -> ArchSpec:
    """Rebuild an ArchSpec from `arch_to_dict` output.

    Unknown keys (written by a newer repo) are ignored so old readers stay
    forward-compatible; list-valued fields are restored to tuples.
    """
    fields = {f.name: f for f in dataclasses.fields(ArchSpec)}
    kw: dict[str, Any] = {}
    for k, v in d.items():
        if k not in fields:
            continue
        if k == "lut_plan":
            kw[k] = LUTPlan.from_dict(v) if v else None
            continue
        if isinstance(v, list):
            v = tuple(v)
        kw[k] = v
    missing = [
        n for n, f in fields.items()
        if n not in kw
        and f.default is dataclasses.MISSING
        and f.default_factory is dataclasses.MISSING
    ]
    if missing:
        raise ValueError(f"arch dict missing required fields: {missing}")
    return ArchSpec(**kw)


def all_archs() -> list[ArchSpec]:
    return [get_arch(n) for n in ARCH_IDS]


def shape_applicable(arch: ArchSpec, shape: str) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason if skipped (DESIGN.md §4)."""
    if shape == "long_500k" and not arch.sub_quadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic mixing"
    return True, ""


def reduce_arch(arch: ArchSpec, **overrides: Any) -> ArchSpec:
    """Shrink an arch to a CPU-smoke-testable config of the same family.

    Keeps every structural feature (GQA ratio, qk-norm, MoE top-k, SSD,
    shared block, enc-dec, M-RoPE) while cutting width/depth/vocab.
    """
    small: dict[str, Any] = dict(
        n_layers=min(arch.n_layers, 4),
        d_model=128,
        d_ff=0 if arch.d_ff == 0 else 256,
        vocab=512,
        param_dtype="float32",
        grad_accum=1,
    )
    if arch.n_heads:
        small.update(n_heads=4, n_kv_heads=min(arch.n_kv_heads, 2) if arch.n_kv_heads < arch.n_heads else 4, d_head=32)
    if arch.n_experts:
        small.update(n_experts=4, top_k=arch.top_k)
    if arch.ssm_state:
        small.update(ssm_state=16, ssm_head_dim=16, ssd_chunk=8)
    if arch.attn_every:
        small.update(attn_every=2)
    if arch.n_enc_layers:
        small.update(n_enc_layers=2, enc_frames=8)
    if arch.mrope_sections:
        small.update(mrope_sections=(4, 6, 6))
    small.update(lut_v=16)
    small.update(overrides)
    out = dataclasses.replace(arch, **small)
    # depth cuts can strand a last_n policy past the new layer count (the
    # plan resolver validates and would rightly reject it) — clamp
    if out.lut_plan is not None:
        clamped = tuple(
            dataclasses.replace(
                r, select=dataclasses.replace(
                    r.select, n=min(r.select.n, out.n_layers),
                    # out-of-range indices pin to the new last layer (not
                    # dropped): a "first and last dense" set keeps its intent
                    layer_set=tuple(sorted({
                        min(i, out.n_layers - 1) for i in r.select.layer_set
                    })),
                )
            ) if r.select.layers in ("last_n", "set") else r
            for r in out.lut_plan.rules
        )
        out = dataclasses.replace(
            out, lut_plan=dataclasses.replace(out.lut_plan, rules=clamped)
        )
    elif out.lut_policy.startswith("last_n:"):
        n = int(out.lut_policy.split(":", 1)[1])
        if n > out.n_layers:
            out = dataclasses.replace(out, lut_policy=f"last_n:{out.n_layers}")
    return out


# ---------------------------------------------------------------------------
# replacement plan resolution
# ---------------------------------------------------------------------------

def effective_plan(arch: ArchSpec) -> LUTPlan:
    """The arch's LUTPlan: `lut_plan` when set, else the back-compat shim
    parsing `lut_policy` + the flat `lut_*` flags into a single-rule plan."""
    if arch.lut_plan is not None:
        return arch.lut_plan
    return LUTPlan.from_policy_string(
        arch.lut_policy,
        default=SitePolicy(
            k=arch.lut_k, v=arch.lut_v, bits=arch.lut_bits, per_column=False,
            int8_dot=arch.lut_int8_dot, use_kernel=arch.lut_use_kernel,
        ),
    )


# ---------------------------------------------------------------------------
# model assembly
# ---------------------------------------------------------------------------

class _PlanResolver:
    """Resolves every linear site of one build to (mode, LUTConfig).

    A site resolves to `mode` (the bundle's LUT_TRAIN/LUT_INFER) iff the
    plan replaces its (layer, kind); otherwise it stays DENSE. Dense sites
    still carry the plan-default LUTConfig as metadata (roofline/bench
    tooling reads it; params never depend on it). `layer=None` marks
    weight-shared / uniformly-stacked sites (hybrid, enc-dec), which layer
    selectors treat as matching.
    """

    def __init__(self, arch: ArchSpec, mode: Mode):
        self.arch = arch
        self.mode = mode
        self.plan = effective_plan(arch).validate(arch.n_layers)

    def _resolve(self, layer: int | None, kind: str, d_in: int,
                 lut_site: bool) -> tuple[Mode, LUTConfig]:
        cfg = None
        if lut_site and self.mode != Mode.DENSE:
            cfg = self.plan.lut_config(layer, kind, d_in, self.arch.n_layers)
        if cfg is None:
            return Mode.DENSE, self.plan.default.lut_config(d_in)
        return self.mode, cfg

    def site(self, d_in: int, d_out: int, kind: str, *,
             layer: int | None = None, lut_site: bool = True) -> SiteCfg:
        mode, cfg = self._resolve(layer, kind, d_in, lut_site)
        return SiteCfg(d_in=d_in, d_out=d_out, mode=mode, lut=cfg,
                       bias=self.arch.use_bias, name=kind)

    def expert_site(self, d_in: int, d_out: int, kind: str,
                    *, layer: int | None = None) -> moe_mod.ExpertSiteCfg:
        mode, cfg = self._resolve(layer, kind, d_in, lut_site=True)
        return moe_mod.ExpertSiteCfg(
            n_experts=self.arch.n_experts, d_in=d_in, d_out=d_out,
            mode=mode, lut=cfg,
        )


def _attn_cfg(res: _PlanResolver, *, layer: int | None = None, causal=None,
              cross=False, prefix: str = "attn") -> attn_mod.AttnCfg:
    arch = res.arch
    d, h, kv, dh = arch.d_model, arch.n_heads, arch.n_kv_heads, arch.d_head
    return attn_mod.AttnCfg(
        d_model=d, n_heads=h, n_kv_heads=kv, d_head=dh,
        q=res.site(d, h * dh, f"{prefix}/q", layer=layer),
        k=res.site(d, kv * dh, f"{prefix}/k", layer=layer),
        v=res.site(d, kv * dh, f"{prefix}/v", layer=layer),
        o=res.site(h * dh, d, f"{prefix}/o", layer=layer),
        qk_norm=arch.qk_norm,
        rope_theta=arch.rope_theta,
        mrope_sections=arch.mrope_sections,
        causal=arch.causal if causal is None else causal,
        use_rope=not cross,
    )


def _mlp_cfg(res: _PlanResolver, *, layer: int | None = None,
             prefix: str = "mlp") -> mlp_mod.MLPCfg:
    arch = res.arch
    d, f = arch.d_model, arch.d_ff
    return mlp_mod.MLPCfg(
        d_model=d, d_ff=f,
        gate=res.site(d, f, f"{prefix}/gate", layer=layer),
        up=res.site(d, f, f"{prefix}/up", layer=layer),
        down=res.site(f, d, f"{prefix}/down", layer=layer),
        act=arch.act,
        gated=arch.mlp_gated,
    )


def _moe_cfg(res: _PlanResolver, *, layer: int | None = None) -> moe_mod.MoECfg:
    arch = res.arch
    d, f, e = arch.d_model, arch.d_ff, arch.n_experts
    return moe_mod.MoECfg(
        d_model=d, d_ff=f, n_experts=e, top_k=arch.top_k,
        # the router stays exact: approximated routing logits destabilize
        # top-k selection (DESIGN.md §4)
        router=res.site(d, e, "moe/router", layer=layer, lut_site=False),
        gate=res.expert_site(d, f, "moe/gate", layer=layer),
        up=res.expert_site(d, f, "moe/up", layer=layer),
        down=res.expert_site(f, d, "moe/down", layer=layer),
        shared=(_mlp_cfg(res, layer=layer, prefix="moe/shared")
                if arch.moe_shared_expert else None),
        act=arch.act,
        group_tokens=arch.moe_group_tokens,
    )


def _mamba_block(res: _PlanResolver, *, layer: int | None = None) -> tf_mod.BlockCfg:
    arch = res.arch
    di = arch.d_inner
    h = di // arch.ssm_head_dim
    mcfg = mamba_mod.Mamba2Cfg(
        d_model=arch.d_model, d_inner=di, n_heads=h, head_dim=arch.ssm_head_dim,
        ssm_state=arch.ssm_state, n_groups=arch.ssm_groups,
        conv_width=arch.conv_width, chunk=arch.ssd_chunk,
        in_proj=res.site(arch.d_model,
                         2 * di + 2 * arch.ssm_groups * arch.ssm_state + h,
                         "mamba/in_proj", layer=layer),
        out_proj=res.site(di, arch.d_model, "mamba/out_proj", layer=layer),
    )
    return tf_mod.BlockCfg(kind="mamba", d_model=arch.d_model, mamba=mcfg)


def _block(res: _PlanResolver, *, layer: int | None = None) -> tf_mod.BlockCfg:
    arch = res.arch
    if arch.family == "ssm":
        return _mamba_block(res, layer=layer)
    if arch.family == "moe":
        return tf_mod.BlockCfg(
            kind="moe", d_model=arch.d_model,
            attn=_attn_cfg(res, layer=layer),
            moe=_moe_cfg(res, layer=layer),
            residual_mlp=(_mlp_cfg(res, layer=layer, prefix="residual_mlp")
                          if arch.moe_dense_residual else None),
        )
    return tf_mod.BlockCfg(
        kind="dense", d_model=arch.d_model,
        attn=_attn_cfg(res, layer=layer), mlp=_mlp_cfg(res, layer=layer),
    )


def _segments(res: _PlanResolver) -> tuple[tuple[int, tf_mod.BlockCfg], ...]:
    """Resolve the plan to per-layer blocks, grouped into runs of identical
    config (jax.lax.scan segments). Non-contiguous and mixed-precision
    replacement fall out: each change of resolved block config starts a new
    segment, so e.g. dense/K16/K8/dense builds four scanned runs."""
    L = res.arch.n_layers
    if res.mode == Mode.DENSE:
        return ((L, _block(res)),)
    segs: list[list[Any]] = []
    for j in range(L):
        b = _block(res, layer=j)
        if segs and segs[-1][1] == b:
            segs[-1][0] += 1
        else:
            segs.append([1, b])
    return tuple((n, b) for n, b in segs)


# ---------------------------------------------------------------------------
# site registry (DESIGN.md §9.2)
# ---------------------------------------------------------------------------

def _mlp_site_list(m: mlp_mod.MLPCfg) -> list[tuple[str, Any, bool]]:
    sites = ([m.gate] if m.gated else []) + [m.up, m.down]
    return [(s.name, s, True) for s in sites]


def _attn_site_list(a: attn_mod.AttnCfg) -> list[tuple[str, Any, bool]]:
    return [(s.name, s, True) for s in (a.q, a.k, a.v, a.o)]


def _block_site_list(bcfg: tf_mod.BlockCfg) -> list[tuple[str, Any, bool]]:
    """(rel_path, site_cfg, goes_through_common.linear) per site of a block.

    rel_path doubles as the site kind and equals the site's param sub-tree
    path inside the block (SiteCfg.name is constructed to match); MoE expert
    sites are expert-stacked (no tape capture) so they're enumerated with
    explicit rel paths.
    """
    if bcfg.kind == "mamba":
        m = bcfg.mamba
        out = [(m.in_proj.name, m.in_proj, True), (m.out_proj.name, m.out_proj, True)]
    elif bcfg.kind == "dense":
        out = _attn_site_list(bcfg.attn) + _mlp_site_list(bcfg.mlp)
    elif bcfg.kind == "moe":
        mo = bcfg.moe
        out = _attn_site_list(bcfg.attn)
        out.append((mo.router.name, mo.router, True))
        out += [("moe/gate", mo.gate, False), ("moe/up", mo.up, False),
                ("moe/down", mo.down, False)]
        if mo.shared is not None:
            out += _mlp_site_list(mo.shared)
    else:
        raise ValueError(bcfg.kind)
    if bcfg.residual_mlp is not None:
        out += _mlp_site_list(bcfg.residual_mlp)
    return out


def _make_site_spec(path, layer, stack_index, kind, sc, tape_key) -> SiteSpec:
    return SiteSpec(
        path=path, layer=layer, stack_index=stack_index, kind=kind,
        d_in=sc.d_in, d_out=sc.d_out, bias=getattr(sc, "bias", False),
        mode=sc.mode, lut=sc.lut, tape_key=tape_key,
    )


# ---------------------------------------------------------------------------
# unified model bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelBundle:
    arch: ArchSpec
    mode: Mode
    kind: str                    # "lm" | "hybrid" | "encdec"
    cfg: Any

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.arch.param_dtype == "bfloat16" else jnp.float32

    def init(self, key: jax.Array):
        if self.kind == "lm":
            return tf_mod.lm_init(key, self.cfg, dtype=self.param_dtype)
        if self.kind == "hybrid":
            return hybrid_mod.hybrid_init(key, self.cfg, dtype=self.param_dtype)
        return encdec_mod.encdec_init(key, self.cfg, dtype=self.param_dtype)

    def param_specs(self, key: jax.Array | None = None):
        k = jax.random.PRNGKey(0) if key is None else key
        return jax.eval_shape(self.init, k)

    # ---------------- site registry ----------------
    def sites(self) -> list[SiteSpec]:
        """Enumerate every linear site of this model, across all families.

        One SiteSpec per (site, layer): sites whose leaves are stacked over
        a layer run appear once per layer with the SAME `path` and
        increasing `stack_index` (consumers that act per-leaf dedupe on
        `path`). This registry replaces per-family path-string surgery in
        conversion, sharding, autotune warmup, and artifact snapshots.
        """
        out: list[SiteSpec] = []
        if self.kind == "lm":
            g = 0
            for i, (count, bcfg) in enumerate(self.cfg.segments):
                rels = _block_site_list(bcfg)
                for j in range(count):
                    for rel, sc, taped in rels:
                        out.append(_make_site_spec(
                            f"segments/{i}/{rel}", g + j, j, rel, sc,
                            f"segments/{i}/{j}/{rel}" if taped else None,
                        ))
                g += count
            if self.cfg.lm_head is not None:
                out.append(_make_site_spec(
                    "lm_head", None, None, "lm_head", self.cfg.lm_head, "lm_head"
                ))
            return out

        if self.kind == "hybrid":
            cfg = self.cfg
            rels = _block_site_list(cfg.mamba_block)
            for j in range(cfg.n_layers):
                for rel, sc, taped in rels:
                    out.append(_make_site_spec(
                        f"mamba_stack/{rel}", j, j, rel, sc,
                        f"mamba_stack/{j}/{rel}" if taped else None,
                    ))
            shared = ([(cfg.fuse.name, cfg.fuse, True)]
                      + _attn_site_list(cfg.shared_attn)
                      + _mlp_site_list(cfg.shared_mlp)
                      + [(cfg.out.name, cfg.out, True)])
            for rel, sc, taped in shared:
                out.append(_make_site_spec(
                    f"shared/{rel}", None, None, rel, sc,
                    f"shared/{rel}" if taped else None,
                ))
            return out

        # encdec: encoder layers number 0..E-1, decoder E..E+D-1 so
        # (layer, kind) stays unique model-wide
        cfg = self.cfg
        rels = _block_site_list(cfg.enc_block)
        for j in range(cfg.n_enc_layers):
            for rel, sc, taped in rels:
                out.append(_make_site_spec(
                    f"encoder/{rel}", j, j, rel, sc,
                    f"encoder/{j}/{rel}" if taped else None,
                ))
        dec = (_attn_site_list(cfg.dec_self) + _attn_site_list(cfg.dec_cross)
               + _mlp_site_list(cfg.dec_mlp))
        for j in range(cfg.n_dec_layers):
            for rel, sc, taped in dec:
                out.append(_make_site_spec(
                    f"decoder/{rel}", cfg.n_enc_layers + j, j, rel, sc,
                    f"decoder/{j}/{rel}" if taped else None,
                ))
        return out

    def lut_sites(self) -> list[SiteSpec]:
        """Registry entries that resolved to a LUT mode in this bundle."""
        return [s for s in self.sites() if s.mode != Mode.DENSE]

    # ---------------- training ----------------
    def train_logits(self, params, batch, *, compute_dtype=jnp.bfloat16):
        """Training-time forward to logits: `(logits (B,S,vocab), aux)`.

        This is the shared forward under `loss` and the teacher/student
        halves of the distillation loss (repro.train.train_step). `aux` is
        the MoE load-balance penalty for the lm family, 0 elsewhere.
        """
        if self.kind == "lm":
            pos = batch.get("pos")
            if pos is None:
                b, s = batch["labels"].shape[:2]
                pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
            logits, _, aux = tf_mod.lm_apply(
                self.cfg, params, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), pos=pos, compute_dtype=compute_dtype,
            )
            return logits, aux
        if self.kind == "hybrid":
            b, s = batch["labels"].shape
            pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
            logits, _, _ = hybrid_mod.hybrid_apply(
                self.cfg, params, tokens=batch["tokens"], pos=pos,
                compute_dtype=compute_dtype,
            )
            return logits, jnp.zeros((), jnp.float32)
        # encdec
        enc_out = encdec_mod.encode(self.cfg, params, batch["frames"],
                                    compute_dtype=compute_dtype)
        b, s = batch["labels"].shape
        pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
        logits, _ = encdec_mod.decode(
            self.cfg, params, tokens=batch["tokens"], pos=pos, enc_out=enc_out,
            compute_dtype=compute_dtype,
        )
        return logits, jnp.zeros((), jnp.float32)

    def loss_from_logits(self, logits, aux, labels):
        """CE (+ the lm family's MoE aux penalty) from `train_logits`
        output — the one place the aux weight is applied."""
        from repro.models.common import cross_entropy

        ce = cross_entropy(logits, labels)
        return ce + tf_mod.LM_AUX_WEIGHT * aux if self.kind == "lm" else ce

    def loss(self, params, batch, *, compute_dtype=jnp.bfloat16):
        logits, aux = self.train_logits(params, batch, compute_dtype=compute_dtype)
        return self.loss_from_logits(logits, aux, batch["labels"])

    # ---------------- serving ----------------
    def init_caches(self, b: int, s_max: int, *, abstract=False, dtype=jnp.bfloat16,
                    paged=None):
        """paged: an attention.PagedSpec — attention KV leaves become pooled
        {"k_pool","v_pool"} of (n_pages, page_size, KV, Dh) shared across the
        batch (DESIGN.md §12); mamba/cross leaves stay per-slot."""
        if self.kind == "lm":
            return tf_mod.init_caches(self.cfg, b, s_max, dtype, abstract=abstract,
                                      paged=paged)
        if self.kind == "hybrid":
            return hybrid_mod.hybrid_caches(self.cfg, b, s_max, dtype, abstract=abstract,
                                            paged=paged)
        return encdec_mod.encdec_caches(self.cfg, b, s_max, dtype, abstract=abstract,
                                        paged=paged)

    def forward_step(self, params, batch, caches, *, compute_dtype=jnp.bfloat16):
        """One serving step (prefill if S>1, decode if S==1).

        batch: tokens/embeds (+ optional frames for encdec prefill),
        cache_len (B,); paged caches additionally take block_tables (B, P)
        and write_len (B,). Returns (logits for the new positions, new
        caches).
        """
        cache_len = batch["cache_len"]
        block_tables = batch.get("block_tables")
        write_len = batch.get("write_len")
        if self.kind == "encdec":
            caches = dict(caches)
            if "frames" in batch:                      # prefill: run encoder
                enc_out = encdec_mod.encode(self.cfg, params, batch["frames"],
                                            compute_dtype=compute_dtype)
                caches["cross"] = jax.tree.map(
                    lambda a: a.astype(compute_dtype),
                    encdec_mod.cross_kv(self.cfg, params, enc_out),
                )
            b, s = batch["tokens"].shape
            pos = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            logits, new_caches = encdec_mod.decode(
                self.cfg, params, tokens=batch["tokens"], pos=pos,
                caches=caches, cache_len=cache_len, compute_dtype=compute_dtype,
                block_tables=block_tables, write_len=write_len,
            )
            return logits, new_caches

        if self.kind == "hybrid":
            b, s = batch["tokens"].shape
            pos = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            logits, new_caches, _ = hybrid_mod.hybrid_apply(
                self.cfg, params, tokens=batch["tokens"], pos=pos,
                caches=caches, cache_len=cache_len, compute_dtype=compute_dtype,
                block_tables=block_tables, write_len=write_len,
            )
            return logits, new_caches

        tok = batch.get("tokens")
        emb = batch.get("embeds")
        ref = tok if tok is not None else emb
        b, s = ref.shape[0], ref.shape[1]
        pos = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
        if self.arch.mrope_sections:
            pos = jnp.broadcast_to(pos[None], (3, b, s))
        logits, new_caches, _ = tf_mod.lm_apply(
            self.cfg, params, tokens=tok, embeds=emb, pos=pos,
            caches=caches, cache_len=cache_len, compute_dtype=compute_dtype,
            block_tables=block_tables, write_len=write_len,
        )
        return logits, new_caches


def build_model(arch: ArchSpec | str, mode: Mode | str = Mode.DENSE) -> ModelBundle:
    if isinstance(arch, str):
        arch = get_arch(arch)
    if isinstance(mode, str):
        mode = Mode(mode)
    res = _PlanResolver(arch, mode)

    if arch.family == "hybrid":
        # mamba layers share one stacked config and the attention block is
        # one weight-shared module, so sites resolve at kind granularity
        # (layer=None); layer selectors don't subdivide this family.
        d = arch.d_model
        cfg = hybrid_mod.HybridCfg(
            vocab=arch.vocab, d_model=d, n_layers=arch.n_layers,
            attn_every=arch.attn_every,
            mamba_block=_mamba_block(res),
            shared_attn=_attn_cfg(res),
            shared_mlp=_mlp_cfg(res),
            fuse=res.site(2 * d, d, "fuse", lut_site=False),
            out=res.site(d, d, "out"),
        )
        return ModelBundle(arch=arch, mode=mode, kind="hybrid", cfg=cfg)

    if arch.family == "audio":
        enc_block = tf_mod.BlockCfg(
            kind="dense", d_model=arch.d_model,
            attn=_attn_cfg(res, causal=False),
            mlp=_mlp_cfg(res),
        )
        cfg = encdec_mod.EncDecCfg(
            vocab=arch.vocab, d_model=arch.d_model,
            n_enc_layers=arch.n_enc_layers, n_dec_layers=arch.n_layers,
            enc_frames=arch.enc_frames,
            enc_block=enc_block,
            dec_self=_attn_cfg(res, causal=True, prefix="self"),
            dec_cross=_attn_cfg(res, causal=False, cross=True, prefix="cross"),
            dec_mlp=_mlp_cfg(res),
        )
        return ModelBundle(arch=arch, mode=mode, kind="encdec", cfg=cfg)

    d = arch.d_model
    cfg = tf_mod.LMCfg(
        vocab=arch.vocab, d_model=d,
        segments=_segments(res),
        lm_head=(None if arch.tie_embeddings
                 else res.site(d, arch.vocab, "lm_head", lut_site=False)),
        takes_embeds=arch.takes_embeds,
    )
    return ModelBundle(arch=arch, mode=mode, kind="lm", cfg=cfg)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(arch: ArchSpec | str, shape: str) -> dict[str, Any]:
    """Abstract model inputs for one (arch x shape) dry-run cell."""
    if isinstance(arch, str):
        arch = get_arch(arch)
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16

    def tok(bb, ss):
        return jax.ShapeDtypeStruct((bb, ss), i32)

    if sp.kind == "train":
        batch: dict[str, Any] = {"labels": tok(b, s)}
        if arch.family == "vlm":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, arch.d_model), bf16)
            batch["pos"] = jax.ShapeDtypeStruct((3, b, s), i32)
        elif arch.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((b, arch.enc_frames, arch.d_model), bf16)
            batch["tokens"] = tok(b, s)
        else:
            batch["tokens"] = tok(b, s)
        return batch

    if sp.kind == "prefill":
        batch = {"cache_len": jax.ShapeDtypeStruct((b,), i32)}
        if arch.family == "vlm":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, arch.d_model), bf16)
        elif arch.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((b, arch.enc_frames, arch.d_model), bf16)
            batch["tokens"] = tok(b, s)
        else:
            batch["tokens"] = tok(b, s)
        return batch

    # decode: one new token against a seq_len-deep cache
    batch = {"cache_len": jax.ShapeDtypeStruct((b,), i32)}
    if arch.family == "vlm":
        batch["embeds"] = jax.ShapeDtypeStruct((b, 1, arch.d_model), bf16)
    else:
        batch["tokens"] = tok(b, 1)
    return batch
